"""phi3-medium-14b — RoPE, SwiGLU, GQA kv=10. [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
    d_ff=17920, vocab=100352,
    rope_theta=10000.0, mlp="swiglu", norm="rms",
    source="arXiv:2404.14219",
)

SMOKE = ArchConfig(
    name="phi3-medium-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=256, vocab=768, mlp="swiglu", norm="rms",
)
