"""Autotuning sweep: candidate lattice sanity, tuned-vs-analytic numerical
equivalence, and the acceptance-criterion flow (tune >= 3 shapes -> persisted
cache -> consumed plans)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.blocking import plan_gemm, plan_with_blocks
from repro.core.constants import DEFAULT_HW
from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref
from repro.tuning import (
    PlanCache, candidate_plans, lookup_plan, set_plan_cache, sweep_axis,
    tune_gemm,
)


def test_candidate_lattice_is_bounded_and_seeded():
    cands = candidate_plans(4096, 4096, 7168, "bfloat16", max_candidates=24)
    seed = plan_gemm(4096, 4096, 7168, "bfloat16")
    assert (cands[0].bm, cands[0].bn, cands[0].bk) == (seed.bm, seed.bn,
                                                       seed.bk)
    assert 1 < len(cands) <= 24
    budget = DEFAULT_HW.vmem_bytes * 0.75
    blocks = set()
    for p in cands:
        assert p.vmem_bytes <= budget          # paper eq (1) holds for all
        assert p.bn % DEFAULT_HW.lane == 0     # alignment floors hold
        assert p.bk % DEFAULT_HW.lane == 0
        blocks.add((p.bm, p.bn, p.bk))
    assert len(blocks) == len(cands)           # deduplicated


@pytest.mark.parametrize("m,n,k", [(96, 144, 160), (64, 256, 300)])
def test_tuned_plans_are_numerically_equivalent(rng, m, n, k):
    """Any lattice point must compute the same GEMM (plans move BlockSpecs,
    never math)."""
    a = jnp.asarray(rng.standard_normal((m, k)), "float32")
    b = jnp.asarray(rng.standard_normal((k, n)), "float32")
    ref = np.asarray(mpgemm_ref(a, b))
    for p in candidate_plans(m, n, k, "float32", max_candidates=4):
        out = mpgemm_pallas(a, b, plan=p, interpret=True)
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4,
                                   rtol=1e-4)


def test_sweep_axis_varies_one_axis_only():
    ms = sweep_axis(512, 512, 2048, "bk", "bfloat16", mode="modeled")
    assert len(ms) >= 2
    seed = plan_gemm(512, 512, 2048, "bfloat16")
    assert len({m.plan.bk for m in ms}) == len(ms)
    for m in ms:
        assert (m.plan.bm, m.plan.bn) == (seed.bm, seed.bn)


def test_tune_gemm_interpret_measures_and_caches(tmp_path):
    cache = PlanCache(tmp_path / "plans.json")
    r = tune_gemm(64, 128, 256, "float32", mode="interpret",
                  max_candidates=3, iters=1, cache=cache)
    assert r.speedup >= 1.0
    assert all(m.mode == "interpret" and m.wall_us > 0
               for m in r.measurements)
    assert len(cache) == 1
    assert (tmp_path / "plans.json").exists()   # save=True flushed to disk


def test_acceptance_flow_three_shapes(tmp_path, rng):
    """ISSUE acceptance: tune_gemm over >= 3 workload shapes produces a
    persisted cache, and mp_dot demonstrably consumes the plans."""
    from repro.core import config as cfg
    from repro.core.gemm import mp_dot

    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    shapes = [(64, 256, 512), (128, 128, 256), (256, 512, 128)]
    results = [tune_gemm(m, n, k, "float32", mode="modeled", cache=cache)
               for (m, n, k) in shapes]
    assert len(cache) == 3 and path.exists()

    prev = set_plan_cache(PlanCache(path))      # fresh reload, like a new proc
    try:
        for (m, n, k), r in zip(shapes, results):
            assert lookup_plan(m, n, k, "float32") == r.best.plan
        m, n, k = shapes[0]
        x = jnp.asarray(rng.standard_normal((m, k)), "float32")
        w = jnp.asarray(rng.standard_normal((k, n)), "float32")
        with cfg.gemm_backend("interpret"):
            got = mp_dot(x, w, policy="fp32")
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(mpgemm_ref(x, w)),
                                   atol=1e-5, rtol=1e-5)
    finally:
        set_plan_cache(prev)


def test_report_covers_all_workloads(tmp_path):
    from repro.tuning.report import characterization_report
    cache = PlanCache(None)
    rs = [tune_gemm(m, n, k, "bfloat16", mode="modeled", cache=cache)
          for (m, n, k) in [(64, 2112, 7168), (4096, 256, 4096)]]
    md = characterization_report(rs)
    assert "| 64×2112×7168, bfloat16 |" in md
    assert "| 4096×256×4096, bfloat16 |" in md
    assert "speedup" in md and "geomean" in md
