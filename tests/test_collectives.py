"""distributed/collectives.py numerics: int8 grad compression against jnp
oracles on the host, and the shard_map collectives against plain sums on a
forced-host-device mesh (skipped below the needed device count — the CI
multidevice job runs with REPRO_FORCE_HOST_DEVICES=8)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.collectives import (
    compressed_psum, dequantize_grad, hierarchical_all_reduce,
    quantize_grad_int8,
)


def test_quantize_roundtrip_and_error_feedback(rng):
    g = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    err0 = jnp.zeros_like(g)
    q, scale, err = quantize_grad_int8(g, err0)
    assert q.dtype == jnp.int8
    deq = dequantize_grad(q, scale)
    # Quantization error bounded by half an int8 step, and the residual
    # carried forward is exactly that error (g + 0 - deq).
    step = float(scale)
    assert float(jnp.max(jnp.abs(deq - g))) <= 0.5 * step + 1e-7
    np.testing.assert_allclose(np.asarray(err), np.asarray(g - deq),
                               rtol=0, atol=1e-7)
    # Feeding the error back makes the SECOND step compensate: quantizing
    # the same gradient with the carried residual recovers g + err within
    # one step, so the two-step average error shrinks below step one's.
    q2, scale2, err2 = quantize_grad_int8(g, err)
    deq2 = dequantize_grad(q2, scale2)
    two_step_bias = float(jnp.max(jnp.abs((deq + deq2) / 2 - g)))
    assert two_step_bias <= 0.75 * step + 1e-7


def test_quantize_zero_grad_safe():
    g = jnp.zeros((4, 4), jnp.float32)
    q, scale, err = quantize_grad_int8(g, jnp.zeros_like(g))
    assert float(scale) > 0.0                     # clamped, no div-by-zero
    assert not np.asarray(q).any() and not np.asarray(err).any()


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices "
                           "(REPRO_FORCE_HOST_DEVICES=8)")
def test_compressed_psum_matches_sum_oracle(rng):
    p = 2
    mesh = Mesh(np.array(jax.devices()[:p]), ("data",))
    g = jnp.asarray(rng.standard_normal((p * 4, 8)), jnp.float32)
    err = jnp.zeros_like(g)

    @jax.jit
    def run(gg, ee):
        return shard_map(
            lambda gl, el: compressed_psum(gl, el, "data"),
            mesh=mesh, in_specs=(P("data"), P("data")),
            out_specs=(P("data"), P("data")), check_rep=False)(gg, ee)

    total, new_err = run(g, err)
    # Every shard's reduced value is the sum of ALL shards' dequantized
    # locals; tolerance is one int8 step per participating shard.
    want = np.asarray(g).reshape(p, 4, 8).sum(axis=0)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    got = np.asarray(total).reshape(p, 4, 8)
    for shard in got:
        np.testing.assert_allclose(shard, want, rtol=0,
                                   atol=p * scale + 1e-6)
    # Error feedback stays local: each shard's residual is bounded by its
    # own quantization step.
    assert float(jnp.max(jnp.abs(new_err))) <= 0.5 * scale + 1e-6


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs >= 4 devices "
                           "(REPRO_FORCE_HOST_DEVICES=8)")
def test_hierarchical_all_reduce_matches_total_sum(rng):
    mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("pod", "data"))
    x = jnp.asarray(rng.standard_normal((4, 6)), jnp.float32)

    @jax.jit
    def run(xx):
        return shard_map(
            lambda xl: hierarchical_all_reduce(xl),
            mesh=mesh, in_specs=(P("pod", "data"),),
            out_specs=P("pod", "data"), check_rep=False)(xx)

    got = np.asarray(run(x))
    # reduce-scatter in-pod + all-reduce cross-pod + all-gather in-pod ==
    # a plain all-reduce: every device block holds the total sum.
    # block (i, j) of the (pod, data)-sharded global is x[2i:2i+2, 3j:3j+3]
    # == reshape axes (pod, row, data, col); the total sums pod AND data.
    want = np.asarray(x).reshape(2, 2, 2, 3).sum(axis=(0, 2))
    for i in range(2):
        for j in range(2):
            np.testing.assert_allclose(got[2 * i:2 * i + 2, 3 * j:3 * j + 3],
                                       want, rtol=1e-6, atol=1e-6)
