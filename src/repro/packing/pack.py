"""Pack / unpack kernels: ahead-of-time tile packing with on-the-fly
transposition (the paper's §IV-C packing pass, run ONCE instead of per call).

``pack_operand`` reorders a weight into the plan's (bk, bn)-tiled block
layout described by :class:`repro.packing.layout.PackedLayout`:

* edge tiles are ZERO-padded (so the GEMM's K-tail needs no B-side
  predication and M/N-edge garbage cannot leak through the masked store),
* a ``trans_w`` source (stored (n, k)) is transposed DURING the pack —
  the paper's on-the-fly transposition, paid once,
* ``dtype="int8"`` quantizes each (bk, bn) tile symmetrically with its own
  f32 scale (per-tile, finer than ``core/quantization.py``'s per-tensor
  scheme) so the dequant rides the GEMM per tile.

Two implementations with identical semantics:

* a Pallas kernel (grid = tile grid, one tile per step) — the production
  path, used on the ``pallas``/``interpret`` backends;
* a pure-jnp reference (pad + reshape + transpose) — used on the ``xla``
  backend and under ``vmap`` (stacked-layer packing in ``params.py``).

``unpack_operand`` is the exact inverse (modulo int8 rounding) and is what
non-kernel backends and the backward pass use to recover a dense operand.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import config as cfg
from repro.core.blocking import GemmPlan
from repro.packing.layout import PackedLayout, PackedOperand


def _blocks_of(plan_or_blocks) -> Tuple[int, int]:
    if isinstance(plan_or_blocks, GemmPlan):
        return plan_or_blocks.bk, plan_or_blocks.bn
    bk, bn = plan_or_blocks
    return int(bk), int(bn)


def _layout_for(w, bk: int, bn: int, *, trans_w: bool, dtype,
                grouped: bool) -> PackedLayout:
    shape = w.shape[1:] if grouped else w.shape
    if len(shape) != 2:
        raise ValueError(f"pack_operand expects a 2-D (or grouped 3-D) "
                         f"operand, got {w.shape}")
    k, n = (shape[1], shape[0]) if trans_w else shape
    # Clamp blocks to the problem extent (mirrors plan_with_blocks): a tiny
    # operand packs as a single exact-fit tile instead of a mostly-pad one.
    return PackedLayout(
        k=k, n=n, bk=min(bk, k), bn=min(bn, n),
        dtype=str(jnp.dtype(dtype or w.dtype)),
        orig_dtype=str(jnp.dtype(w.dtype)), trans_w=trans_w,
        g=w.shape[0] if grouped else 1,
    )


def _strip_group(layout: PackedLayout) -> PackedLayout:
    return dataclasses.replace(layout, g=1)


# --- pure-jnp reference (xla backend, vmap-able) ------------------------------

def _pack_dense_ref(w2d, layout: PackedLayout):
    """(k, n) / (n, k) source -> zero-padded (nkb, nnb, bk, bn) tiles."""
    if layout.trans_w:
        w2d = w2d.T
    k, n, bk, bn = layout.k, layout.n, layout.bk, layout.bn
    wp = jnp.pad(w2d, ((0, layout.nkb * bk - k), (0, layout.nnb * bn - n)))
    return wp.reshape(layout.nkb, bk, layout.nnb, bn).transpose(0, 2, 1, 3)


def _quantize_tiles_ref(tiles):
    """Per-tile symmetric int8: (..., bk, bn) -> (int8 tiles, f32 scales)."""
    t32 = tiles.astype(jnp.float32)
    amax = jnp.max(jnp.abs(t32), axis=(-2, -1))
    scales = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(t32 / scales[..., None, None]), -127, 127)
    return q.astype(jnp.int8), scales.astype(jnp.float32)


def pack_reference(w, layout: PackedLayout):
    """The jnp pack: (payload, scales|None).  Also the payload-cotangent
    map used by the packed ops' VJP (linear for float payloads)."""
    if layout.g != 1:
        tiles = jax.vmap(
            lambda x: _pack_dense_ref(x, _strip_group(layout)))(w)
    else:
        tiles = _pack_dense_ref(w, layout)
    if layout.per_tile_scales:
        return _quantize_tiles_ref(tiles)
    return tiles.astype(jnp.dtype(layout.dtype)), None


def _unpack_tiles_ref(tiles, layout: PackedLayout):
    full = tiles.transpose(0, 2, 1, 3).reshape(
        layout.nkb * layout.bk, layout.nnb * layout.bn)
    return full[: layout.k, : layout.n]


def unpack_reference(payload, scales, layout: PackedLayout, dtype):
    tiles = payload
    if scales is not None:
        tiles = tiles.astype(jnp.float32) * scales[..., None, None]
    if layout.g != 1:
        inner = _strip_group(layout)
        return jax.vmap(
            lambda t: _unpack_tiles_ref(t, inner))(tiles).astype(dtype)
    return _unpack_tiles_ref(tiles, layout).astype(dtype)


# --- Pallas kernels -----------------------------------------------------------

def _masked_tile(src_ref, i, j, layout: PackedLayout):
    """Read one source tile at tile-grid (i, j), transpose-resolved, with
    out-of-bounds lanes zeroed: edge tiles of a non-multiple operand read
    pipeline pad garbage (possibly NaN) which must never reach the payload
    — zero pads are what let the GEMM skip B-side K-edge predication."""
    tile = src_ref[...].reshape(src_ref.shape[-2:])
    if layout.trans_w:
        tile = tile.T                      # (bn, bk) storage -> (bk, bn)
    rows = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    valid_r = layout.k - i * layout.bk
    valid_c = layout.n - j * layout.bn
    return jnp.where((rows < valid_r) & (cols < valid_c), tile,
                     jnp.zeros_like(tile))


def _tile_ids(grouped: bool):
    return ((pl.program_id(1), pl.program_id(2)) if grouped
            else (pl.program_id(0), pl.program_id(1)))


def _pack_kernel(src_ref, out_ref, *, layout: PackedLayout, grouped: bool):
    tile = _masked_tile(src_ref, *_tile_ids(grouped), layout)
    out_ref[...] = tile.astype(out_ref.dtype).reshape(out_ref.shape)


def _pack_quant_kernel(src_ref, out_ref, scale_ref, *, layout: PackedLayout,
                       grouped: bool):
    tile = _masked_tile(src_ref, *_tile_ids(grouped), layout)
    tile = tile.astype(jnp.float32)
    amax = jnp.max(jnp.abs(tile))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(tile / scale), -127, 127)
    out_ref[...] = q.astype(jnp.int8).reshape(out_ref.shape)
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)


def _unpack_kernel(payload_ref, out_ref, *, dtype):
    out_ref[...] = payload_ref[...].reshape(out_ref.shape).astype(dtype)


def _unpack_quant_kernel(payload_ref, scale_ref, out_ref, *, dtype):
    tile = payload_ref[...].astype(jnp.float32).reshape(out_ref.shape)
    out_ref[...] = (tile * scale_ref[0].reshape(-1)[0]).astype(dtype)


def _src_spec(layout: PackedLayout, grouped: bool):
    bk, bn = layout.bk, layout.bn
    if layout.trans_w:
        block, imap = (bn, bk), lambda i, j: (j, i)
    else:
        block, imap = (bk, bn), lambda i, j: (i, j)
    if grouped:
        return pl.BlockSpec((1,) + block,
                            lambda g, i, j: (g,) + imap(i, j))
    return pl.BlockSpec(block, imap)


def _payload_spec(layout: PackedLayout, grouped: bool):
    if grouped:
        return pl.BlockSpec((1, 1, 1, layout.bk, layout.bn),
                            lambda g, i, j: (g, i, j, 0, 0))
    return pl.BlockSpec((1, 1, layout.bk, layout.bn),
                        lambda i, j: (i, j, 0, 0))


def _scales_spec(grouped: bool):
    if grouped:
        return pl.BlockSpec((1, 1, 1), lambda g, i, j: (g, i, j))
    return pl.BlockSpec((1, 1), lambda i, j: (i, j))


def _pack_pallas(w, layout: PackedLayout, *, interpret: bool):
    grouped = layout.g != 1
    grid = ((layout.g,) if grouped else ()) + (layout.nkb, layout.nnb)
    src_spec = _src_spec(layout, grouped)
    payload_spec = _payload_spec(layout, grouped)
    if not layout.per_tile_scales:
        kernel = functools.partial(_pack_kernel, layout=layout,
                                   grouped=grouped)
        payload = pl.pallas_call(
            kernel, grid=grid, in_specs=[src_spec], out_specs=payload_spec,
            out_shape=jax.ShapeDtypeStruct(layout.payload_shape,
                                           jnp.dtype(layout.dtype)),
            interpret=interpret,
        )(w)
        return payload, None
    kernel = functools.partial(_pack_quant_kernel, layout=layout,
                               grouped=grouped)
    payload, scales = pl.pallas_call(
        kernel, grid=grid, in_specs=[src_spec],
        out_specs=[payload_spec, _scales_spec(grouped)],
        out_shape=[
            jax.ShapeDtypeStruct(layout.payload_shape, jnp.int8),
            jax.ShapeDtypeStruct(layout.scales_shape, jnp.float32),
        ],
        interpret=interpret,
    )(w)
    return payload, scales


def _unpack_pallas(p: PackedOperand, dtype, *, interpret: bool):
    layout = p.layout
    grouped = layout.g != 1
    grid = ((layout.g,) if grouped else ()) + (layout.nkb, layout.nnb)
    out_spec = pl.BlockSpec(
        ((1,) if grouped else ()) + (layout.bk, layout.bn),
        (lambda g, i, j: (g, i, j)) if grouped else (lambda i, j: (i, j)))
    out_shape = jax.ShapeDtypeStruct(
        ((layout.g,) if grouped else ()) + (layout.k, layout.n),
        jnp.dtype(dtype))
    if p.scales is None:
        kernel = functools.partial(_unpack_kernel, dtype=jnp.dtype(dtype))
        return pl.pallas_call(
            kernel, grid=grid, in_specs=[_payload_spec(layout, grouped)],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(p.payload)
    kernel = functools.partial(_unpack_quant_kernel, dtype=jnp.dtype(dtype))
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[_payload_spec(layout, grouped), _scales_spec(grouped)],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(p.payload, p.scales)


# --- public API ---------------------------------------------------------------

def _resolve_method(backend: Optional[str]) -> str:
    backend = backend or cfg.get_gemm_backend()
    return backend if backend in ("pallas", "interpret", "xla") else "xla"


def pack_operand(
    w,
    plan_or_blocks: Union[GemmPlan, Tuple[int, int]],
    *,
    trans_w: bool = False,
    dtype=None,
    backend: Optional[str] = None,
) -> PackedOperand:
    """Pack a (k, n) / (n, k) weight — or a grouped (g, ., .) stack — into
    the (bk, bn)-tiled block layout of ``plan_or_blocks``.

    ``dtype`` selects the payload: a float dtype stores cast tiles;
    ``"int8"`` stores per-tile symmetrically-quantized tiles plus f32
    scales.  Defaults to the source dtype.  The result is a
    :class:`PackedOperand` consumable by ``mp_dot(x, packed)`` /
    ``mpgemm_pallas(a, packed)``.
    """
    bk, bn = _blocks_of(plan_or_blocks)
    grouped = w.ndim == 3
    layout = _layout_for(w, bk, bn, trans_w=trans_w, dtype=dtype,
                         grouped=grouped)
    method = _resolve_method(backend)
    if method == "xla":
        payload, scales = pack_reference(w, layout)
    else:
        payload, scales = _pack_pallas(w, layout,
                                       interpret=(method == "interpret"))
    return PackedOperand(payload, scales, layout)


def unpack_operand(p: PackedOperand, *, dtype=None,
                   backend: Optional[str] = None):
    """Inverse of :func:`pack_operand`: dense (k, n) (grouped: (g, k, n)),
    transpose already resolved.  int8 payloads dequantize per tile; float
    payloads round-trip exactly.  ``dtype`` defaults to the payload dtype
    (int8: the source dtype recorded at pack time)."""
    layout = p.layout
    if dtype is None:
        dtype = layout.orig_dtype if layout.per_tile_scales else layout.dtype
    method = _resolve_method(backend)
    if method == "xla":
        return unpack_reference(p.payload, p.scales, layout, dtype)
    return _unpack_pallas(p, dtype, interpret=(method == "interpret"))
