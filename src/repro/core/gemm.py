"""``mp_dot`` / ``mp_dot_grouped`` — the paper's technique as first-class,
differentiable ops.

Every matmul in every model in this framework flows through here — 2-D
projections through :func:`mp_dot`, grouped/batched contractions (MoE expert
GEMMs, per-stream LoRA blocks, generic batched matmuls) through
:func:`mp_dot_grouped`.  Each op:

* applies a :class:`PrecisionPolicy` (fp32 / bf16->f32 / dynamic int8->i32 —
  the paper's Section V multi-precision surface),
* consults the tuned-plan cache (repro.tuning) so empirically characterized
  block shapes transparently replace the analytic planner's on a hit,
* dispatches to the Pallas MPGEMM kernel (TPU / interpret) or to an XLA
  ``dot_general`` with identical precision semantics (CPU dry-run; XLA
  picks its own tiling, so plans only affect the kernel backends),
* implements its own VJP whose backward GEMMs use the **fused-transpose**
  kernel variants (dx = dy · Wᵀ, dW = Xᵀ · dy) — the training-time payoff of
  the paper's on-the-fly transposition: no transposed weight copies are ever
  materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import config as cfg
from repro.core.policy import PrecisionPolicy, get_policy, quantize_per_tensor
from repro.kernels.mpgemm import mpgemm_grouped_pallas, mpgemm_pallas
from repro.packing.layout import PackedOperand, is_packed


def _dims(trans_a: bool, trans_b: bool):
    ca = 0 if trans_a else 1
    cb = 1 if trans_b else 0
    return (((ca,), (cb,)), ((), ()))


def _cached_plan(x, w, trans_a: bool, trans_b: bool, out_dtype):
    """Tuned plan for this GEMM instance from the global plan cache, or None.

    Resolved at trace time (shapes are static under jit), so a cache hit
    changes only the BlockSpecs baked into the lowered kernel — numerics are
    plan-independent.  Miss -> None -> mpgemm_pallas falls back to the
    analytic planner.  Lazy import: core must not hard-depend on tuning.
    """
    from repro.tuning.plan_cache import lookup_plan
    m = x.shape[1] if trans_a else x.shape[0]
    k = x.shape[0] if trans_a else x.shape[1]
    n = w.shape[0] if trans_b else w.shape[1]
    return lookup_plan(
        m, n, k, x.dtype, w.dtype, out_dtype,
        trans_a=trans_a, trans_b=trans_b,
    )


def _matmul_impl(
    x, w, bias, policy: PrecisionPolicy, trans_a: bool, trans_b: bool,
    backend: str, out_dtype, acc_dtype, *, grouped: bool,
):
    """One GEMM (2-D or grouped) under a policy, on the selected backend.

    The single home of the policy logic for both op shapes:

    * ``w`` may be a static-int8 {"q","scale"} dict (core/quantization.py):
      the dequant rides the GEMM — int8 HBM reads, upcast at the compute
      unit.  Under a *dynamic*-quantized policy the dequant target is f32
      (the policy's own compute dtype is int8 — dequantizing into it would
      truncate the float weights to ~0); quantize_per_tensor re-quantizes.
    * The compute-dtype down-cast is pinned shard-local BEFORE any
      FSDP/EP all-gather: without the barrier GSPMD gathers the f32 master
      weights and converts after, doubling gather wire bytes (measured on
      mixtral train_4k — EXPERIMENTS.md §Perf).
    * ``acc_dtype`` overrides the accumulator/partial-sum dtype on the XLA
      backend: backward GEMMs pass bf16 so that TP/EP partial-sum
      all-reduces move bf16 instead of f32 (halves gradient wire bytes).
      Kernel backends accumulate per the plan's acc dtype instead (plans
      own kernel numerics; f32/i32 VMEM scratch).
    """
    kernel = mpgemm_grouped_pallas if grouped else mpgemm_pallas
    cached_plan = _cached_grouped_plan if grouped else _cached_plan
    dims = _grouped_dims(trans_a, trans_b) if grouped else _dims(trans_a, trans_b)

    def _bias_add(acc):
        if bias is None:
            return acc
        b = (bias.reshape(bias.shape[0], 1, -1) if grouped
             else bias.reshape(1, -1))
        return acc + b.astype(acc.dtype)

    from repro.core.quantization import dequantize_tensor, is_quantized
    if is_quantized(w):
        w = dequantize_tensor(
            w, jnp.float32 if policy.quantized else jnp.dtype(policy.compute_dtype))
    out_dtype = out_dtype or policy.out_dtype
    if policy.quantized:
        xq, sx = quantize_per_tensor(x)
        wq, sw = quantize_per_tensor(w)
        scale = sx * sw
        if backend in ("pallas", "interpret"):
            return kernel(
                xq, wq, trans_a=trans_a, trans_b=trans_b, scale=scale,
                bias=bias, out_dtype=out_dtype,
                plan=cached_plan(xq, wq, trans_a, trans_b, out_dtype),
                interpret=(backend == "interpret"),
            )
        acc = jax.lax.dot_general(xq, wq, dims,
                                  preferred_element_type=jnp.int32)
        return _bias_add(acc.astype(jnp.float32) * scale).astype(out_dtype)

    cd = jnp.dtype(policy.compute_dtype)
    xc = x.astype(cd)
    wc = w.astype(cd)
    if wc.dtype != w.dtype:
        wc = jax.lax.optimization_barrier(wc)  # see docstring
    if backend in ("pallas", "interpret"):
        return kernel(
            xc, wc, trans_a=trans_a, trans_b=trans_b, bias=bias,
            out_dtype=out_dtype,
            plan=cached_plan(xc, wc, trans_a, trans_b, out_dtype),
            interpret=(backend == "interpret"),
        )
    acc = jax.lax.dot_general(
        xc, wc, dims,
        preferred_element_type=jnp.dtype(acc_dtype or policy.acc_dtype),
    )
    return _bias_add(acc).astype(out_dtype)


def _matmul_2d(x, w, bias, policy, trans_a, trans_b, backend,
               out_dtype=None, acc_dtype=None):
    """One 2-D GEMM under a policy (see :func:`_matmul_impl`)."""
    return _matmul_impl(x, w, bias, policy, trans_a, trans_b, backend,
                        out_dtype, acc_dtype, grouped=False)


# --- packed-weight path ------------------------------------------------------

def _matmul_packed_impl(x, wp: PackedOperand, bias, policy: PrecisionPolicy,
                        backend: str, out_dtype, *, grouped: bool):
    """One GEMM (2-D or grouped) against a pre-packed weight, under a policy.

    Kernel backends read the payload directly — identity tile index maps,
    transpose resolved at pack time, per-tile int8 dequant riding the
    accumulation — so NO per-call operand prep (cast / dequant / strided
    re-layout) is materialized; that is the whole point of packing.  The
    XLA backend, which picks its own tiling and cannot consume the block
    layout, unpacks once and reuses the dense-path policy logic, keeping
    numerics aligned across backends.
    """
    from repro.packing.pack import unpack_operand
    layout = wp.layout
    kernel_backend = backend in ("pallas", "interpret")
    if not kernel_backend or (policy.quantized and layout.dtype != "int8"):
        # XLA fallback — or a float payload under the dynamic-int8 policy,
        # whose per-tensor weight quantization needs a dense array.
        w = unpack_operand(wp, backend=backend if kernel_backend else None)
        return _matmul_impl(x, w, bias, policy, False, False, backend,
                            out_dtype, None, grouped=grouped)
    kernel = mpgemm_grouped_pallas if grouped else mpgemm_pallas
    interp = backend == "interpret"
    out_dtype = out_dtype or policy.out_dtype
    if policy.quantized:
        # Dynamic x-side quantization only: the weight side is already
        # int8 with per-tile scales inside the payload.
        xq, sx = quantize_per_tensor(x)
        return kernel(xq, b_packed=wp, scale=sx, bias=bias,
                      out_dtype=out_dtype, interpret=interp)
    xc = x.astype(jnp.dtype(policy.compute_dtype))
    if layout.dtype != "int8":
        wp = wp.astype(policy.compute_dtype)  # no-op when packed right
    return kernel(xc, b_packed=wp, bias=bias, out_dtype=out_dtype,
                  interpret=interp)


def _bwd_flavor(policy: PrecisionPolicy):
    """(backward policy, backward partial-sum dtype) — see _mp_dot_bwd."""
    bwd_policy = get_policy("fp32" if policy.name == "fp32" else "bf16")
    bwd_acc = "float32" if policy.name == "fp32" else "bfloat16"
    return bwd_policy, bwd_acc


def _packed_weight_cotangent(wp: PackedOperand, dw_dense) -> PackedOperand:
    """Cotangent pytree for a packed-weight primal.

    Float payloads: pack/unpack is a LINEAR bijection onto the tile grid
    (zero pads aside), so the payload cotangent is simply the packed dense
    gradient — packed weights stay trainable.  int8 payloads (per-tile
    quantized) have no usable tangent space: integer leaves get float0
    zeros (JAX's unit cotangent for int primals), scales zeros — the
    weight is frozen, the standard serving configuration.
    """
    import dataclasses

    from repro.packing.pack import pack_reference
    layout = wp.layout
    if layout.per_tile_scales:
        return PackedOperand(
            np.zeros(wp.payload.shape, jax.dtypes.float0),
            jnp.zeros_like(wp.scales), layout)
    # dw_dense is in the LOGICAL (k, n) orientation (the bwd GEMMs resolve
    # the transpose), so the cotangent pack must not re-apply the layout's
    # recorded source transpose.
    payload_ct, _ = pack_reference(
        dw_dense, dataclasses.replace(layout, trans_w=False))
    return PackedOperand(payload_ct, None, layout)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _mp_dot_packed_core(x2d, wp, bias, policy_name: str, backend: str):
    policy = get_policy(policy_name)
    return _matmul_packed_impl(x2d, wp, bias, policy, backend, None,
                               grouped=False)


def _mp_dot_packed_fwd(x2d, wp, bias, policy_name, backend):
    y = _mp_dot_packed_core(x2d, wp, bias, policy_name, backend)
    return y, (x2d, wp, bias is not None)


def _mp_dot_packed_bwd(policy_name, backend, res, dy):
    """Same two fused-transpose backward GEMMs as :func:`_mp_dot_bwd` — the
    only packing-specific step is recovering a dense weight once (the
    payload's layout serves the FORWARD read pattern; backward contracts
    over N, for which the dense on-the-fly-transpose kernel path already
    exists) and re-packing the weight gradient."""
    from repro.packing.pack import unpack_operand
    x2d, wp, has_bias = res
    policy = get_policy(policy_name)
    bwd_policy, bwd_acc = _bwd_flavor(policy)
    kb = backend if backend in ("pallas", "interpret") else None
    w = unpack_operand(wp, backend=kb)      # dense (k, n), transpose resolved
    dx = _matmul_2d(dy, w, None, bwd_policy, False, True, backend,
                    out_dtype=x2d.dtype, acc_dtype=bwd_acc)
    if wp.layout.per_tile_scales:
        dw_dense = None
    else:
        dw_dense = _matmul_2d(x2d, dy, None, bwd_policy, True, False, backend,
                              out_dtype=w.dtype, acc_dtype=bwd_acc)
    dwp = _packed_weight_cotangent(wp, dw_dense)
    dbias = jnp.sum(dy, axis=0, dtype=jnp.float32) if has_bias else None
    return dx, dwp, dbias


_mp_dot_packed_core.defvjp(_mp_dot_packed_fwd, _mp_dot_packed_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mp_dot_grouped_packed_core(x3, wp, bias, policy_name: str, backend: str,
                                out_dtype: Optional[str]):
    policy = get_policy(policy_name)
    return _matmul_packed_impl(x3, wp, bias, policy, backend, out_dtype,
                               grouped=True)


def _mp_dot_grouped_packed_fwd(x3, wp, bias, policy_name, backend, out_dtype):
    y = _mp_dot_grouped_packed_core(x3, wp, bias, policy_name, backend,
                                    out_dtype)
    return y, (x3, wp, bias)


def _mp_dot_grouped_packed_bwd(policy_name, backend, out_dtype, res, dy):
    from repro.packing.pack import unpack_operand
    x3, wp, bias = res
    policy = get_policy(policy_name)
    bwd_policy, bwd_acc = _bwd_flavor(policy)
    kb = backend if backend in ("pallas", "interpret") else None
    w = unpack_operand(wp, backend=kb)      # dense (g, k, n)
    dx = _matmul_grouped(dy, w, None, bwd_policy, False, True, backend,
                         out_dtype=x3.dtype, acc_dtype=bwd_acc)
    if wp.layout.per_tile_scales:
        dw_dense = None
    else:
        dw_dense = _matmul_grouped(x3, dy, None, bwd_policy, True, False,
                                   backend, out_dtype=w.dtype,
                                   acc_dtype=bwd_acc)
    dwp = _packed_weight_cotangent(wp, dw_dense)
    dbias = (jnp.sum(dy, axis=1, dtype=jnp.float32).astype(bias.dtype)
             if bias is not None else None)
    return dx, dwp, dbias


_mp_dot_grouped_packed_core.defvjp(_mp_dot_grouped_packed_fwd,
                                   _mp_dot_grouped_packed_bwd)


# --- differentiable core -----------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mp_dot_core(x2d, w, bias, policy_name: str, trans_w: bool, backend: str):
    policy = get_policy(policy_name)
    return _matmul_2d(x2d, w, bias, policy, False, trans_w, backend)


def _mp_dot_fwd(x2d, w, bias, policy_name, trans_w, backend):
    y = _mp_dot_core(x2d, w, bias, policy_name, trans_w, backend)
    return y, (x2d, w, bias is not None)


def _mp_dot_bwd(policy_name, trans_w, backend, res, dy):
    x2d, w, has_bias = res
    policy = get_policy(policy_name)
    # Non-quantized sibling precision (STE for int8), bf16 partial sums so
    # TP/FSDP gradient reductions move bf16 on the wire (see _bwd_flavor).
    bwd_policy, bwd_acc = _bwd_flavor(policy)
    # dx = dy @ op(w)^T : if w stored (k,n) -> dy(m,n) x w(k,n)^T == trans_b=True
    #                     if w stored (n,k) (trans_w) -> plain dy @ w.
    dx = _matmul_2d(
        dy, w, None, bwd_policy, False, not trans_w, backend,
        out_dtype=x2d.dtype, acc_dtype=bwd_acc,
    )
    # dw: (k,n) = x^T @ dy ; transposed storage: (n,k) = dy^T @ x.
    if trans_w:
        dw = _matmul_2d(
            dy, x2d, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    else:
        dw = _matmul_2d(
            x2d, dy, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    dbias = jnp.sum(dy, axis=0, dtype=jnp.float32) if has_bias else None
    return dx, dw, dbias


_mp_dot_core.defvjp(_mp_dot_fwd, _mp_dot_bwd)


def mp_dot(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    policy="bf16",
    trans_w: bool = False,
    backend: Optional[str] = None,
) -> jax.Array:
    """y[..., n] = x[..., k] @ (w[n, k]ᵀ if trans_w else w[k, n]) + bias.

    ``trans_w=True`` is the on-the-fly-transposition path — used e.g. for
    tied-embedding logits (w stored (vocab, d_model)).

    ``w`` may be a :class:`repro.packing.PackedOperand` (pre-packed at
    parameter-load time): the forward then reads the tiled payload directly
    — no per-call cast/dequant/transposition — and ``trans_w`` must match
    the orientation recorded at pack time (the transpose is already
    resolved inside the payload).
    """
    policy = get_policy(policy)
    backend = backend or cfg.get_gemm_backend()
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if bias is not None:
        bias = bias.reshape(-1)
    if is_packed(w):
        if w.layout.g != 1:
            raise ValueError("grouped PackedOperand: use mp_dot_grouped")
        if trans_w != w.layout.trans_w:
            raise ValueError(
                f"trans_w={trans_w} but the operand was packed with "
                f"trans_w={w.layout.trans_w} (transposition is resolved at "
                f"pack time)")
        y2d = _mp_dot_packed_core(x2d, w, bias, policy.name, backend)
        return y2d.reshape(*lead, w.layout.n)
    y2d = _mp_dot_core(x2d, w, bias, policy.name, trans_w, backend)
    wshape = w["q"].shape if isinstance(w, dict) else w.shape
    n = wshape[0] if trans_w else wshape[-1]
    return y2d.reshape(*lead, n)


# --- grouped / batched op ----------------------------------------------------

def _grouped_dims(trans_a: bool, trans_b: bool):
    """dot_general dims for (G, ., .) x (G, ., .): group is the batch axis."""
    ca = 1 if trans_a else 2
    cb = 2 if trans_b else 1
    return (((ca,), (cb,)), ((0,), (0,)))


def _cached_grouped_plan(x, w, trans_a: bool, trans_b: bool, out_dtype):
    """Tuned grouped plan from the global cache, or None (same contract as
    :func:`_cached_plan`, keyed with the extra group dimension)."""
    from repro.tuning.plan_cache import lookup_plan
    g = x.shape[0]
    m = x.shape[2] if trans_a else x.shape[1]
    k = x.shape[1] if trans_a else x.shape[2]
    n = w.shape[1] if trans_b else w.shape[2]
    return lookup_plan(
        m, n, k, x.dtype, w.dtype, out_dtype,
        trans_a=trans_a, trans_b=trans_b, g=g,
    )


def _matmul_grouped(x, w, bias, policy, trans_a, trans_b, backend,
                    out_dtype=None, acc_dtype=None):
    """One grouped GEMM (G independent problems) under a policy.

    Same policy logic as the 2-D op (see :func:`_matmul_impl`).  Dynamic
    int8 uses one per-tensor scale pair across all groups (the fused
    dequant stays a scalar epilogue multiply).  The barrier'd down-cast is
    safe under differentiation: it only ever runs inside the custom-VJP
    core, where JAX never needs a JVP rule for the barrier.  ``bias`` must
    be (G, N) here — :func:`mp_dot_grouped` normalizes.
    """
    return _matmul_impl(x, w, bias, policy, trans_a, trans_b, backend,
                        out_dtype, acc_dtype, grouped=True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _mp_dot_grouped_core(x3, w, bias, policy_name: str, trans_w: bool,
                         backend: str, out_dtype: Optional[str]):
    policy = get_policy(policy_name)
    return _matmul_grouped(x3, w, bias, policy, False, trans_w, backend,
                           out_dtype=out_dtype)


def _mp_dot_grouped_fwd(x3, w, bias, policy_name, trans_w, backend, out_dtype):
    y = _mp_dot_grouped_core(x3, w, bias, policy_name, trans_w, backend,
                             out_dtype)
    return y, (x3, w, bias)


def _mp_dot_grouped_bwd(policy_name, trans_w, backend, out_dtype, res, dy):
    x3, w, bias = res
    policy = get_policy(policy_name)
    # Non-quantized sibling precision (STE for int8); bf16 partial sums on
    # the XLA backend so EP/TP gradient reductions move bf16 on the wire
    # (kernel backends accumulate per the plan's acc dtype — see
    # _matmul_impl and _bwd_flavor).
    bwd_policy, bwd_acc = _bwd_flavor(policy)
    # Fused-transpose grouped GEMMs — the paper's on-the-fly transposition
    # applied per expert: no transposed expert-weight copies materialize.
    # dx[g] = dy[g] @ op(w[g])^T
    dx = _matmul_grouped(
        dy, w, None, bwd_policy, False, not trans_w, backend,
        out_dtype=x3.dtype, acc_dtype=bwd_acc,
    )
    # dw[g]: (k,n) = x[g]^T @ dy[g] ; transposed storage: (n,k) = dy[g]^T @ x[g].
    if trans_w:
        dw = _matmul_grouped(
            dy, x3, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    else:
        dw = _matmul_grouped(
            x3, dy, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    # f32 accumulation for the reduction, cast back to the primal's dtype
    # (custom-VJP cotangents must match primal dtypes).
    dbias = (jnp.sum(dy, axis=1, dtype=jnp.float32).astype(bias.dtype)
             if bias is not None else None)
    return dx, dw, dbias


_mp_dot_grouped_core.defvjp(_mp_dot_grouped_fwd, _mp_dot_grouped_bwd)


def mp_dot_grouped(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    policy="bf16",
    trans_w: bool = False,
    backend: Optional[str] = None,
    group_sizes: Optional[jax.Array] = None,
    out_dtype=None,
) -> jax.Array:
    """y[g, m, n] = x[g, m, k] @ (w[g, n, k]ᵀ if trans_w else w[g, k, n]) + bias[g, n].

    The grouped sibling of :func:`mp_dot`: G independent GEMMs — MoE expert
    blocks, batched projections — in ONE kernel launch with the group as the
    leading grid axis, under the same precision policies, plan cache (keyed
    with the extra ``g`` dimension), and fused-transpose custom VJP.

    ``group_sizes`` (shape (G,), int) marks ragged groups: rows ``>=
    group_sizes[g]`` of each output group are forced to zero, so capacity-
    padded expert buffers contribute neither output nor (via the masked
    cotangent) gradient.  The mask sits outside the custom VJP, so autodiff
    handles it natively.

    ``out_dtype`` overrides the policy's output dtype — MoE keeps f32
    activations between the expert GEMMs and the combine, matching the
    accumulator precision.
    """
    if x.ndim != 3:
        raise ValueError(f"mp_dot_grouped expects x of rank 3, got {x.shape}")
    policy = get_policy(policy)
    backend = backend or cfg.get_gemm_backend()
    if is_packed(w):
        if w.layout.g != x.shape[0]:
            raise ValueError(
                f"group mismatch: x has {x.shape[0]}, payload {w.layout.g}")
        if trans_w != w.layout.trans_w:
            raise ValueError(
                f"trans_w={trans_w} but the operand was packed with "
                f"trans_w={w.layout.trans_w}")
    else:
        from repro.core.quantization import dequantize_tensor, is_quantized
        if is_quantized(w):
            # Dequantize static-int8 dicts BEFORE the custom-VJP core: the
            # bwd rule contracts against w and must see an array primal (a
            # dict residual has no dtype and no array cotangent).  XLA
            # still fuses the dequant into the GEMM read; differentiation
            # flows through the dequant natively, as the pre-grouped MoE
            # path did.
            w = dequantize_tensor(
                w, jnp.float32 if policy.quantized
                else jnp.dtype(policy.compute_dtype))
    if bias is not None and bias.ndim == 1:
        # Normalize a shared (N,) bias to (G, N) BEFORE the custom-VJP core:
        # outside it autodiff sum-reduces the (G, N) bias cotangent back to
        # (N,); inside, backends would disagree on broadcasting.
        bias = jnp.broadcast_to(bias[None, :], (x.shape[0], bias.shape[0]))
    out_dtype_s = str(jnp.dtype(out_dtype)) if out_dtype is not None else None
    if is_packed(w):
        y = _mp_dot_grouped_packed_core(x, w, bias, policy.name, backend,
                                        out_dtype_s)
    else:
        y = _mp_dot_grouped_core(x, w, bias, policy.name, trans_w, backend,
                                 out_dtype_s)
    if group_sizes is not None:
        sizes = jnp.asarray(group_sizes, jnp.int32).reshape(-1, 1, 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(rows < sizes, y, jnp.zeros_like(y))
    return y


def _as_grouped_matmul(spec: str, n_ops: int) -> Optional[bool]:
    """Is ``spec`` a grouped matmul ``Xab,Xbc->Xac`` (any letters)?

    Returns ``trans_w`` (False for ``Xab,Xbc->Xac``, True for
    ``Xab,Xcb->Xac``) or None when the spec is not a grouped matmul.
    """
    if n_ops != 2:
        return None
    try:
        ins, out = spec.replace(" ", "").split("->")
        a, b = ins.split(",")
    except ValueError:
        return None
    if not (len(a) == len(b) == len(out) == 3 and len(set(a)) == 3):
        return None
    if not (a[0] == b[0] == out[0] and out[1] == a[1]):
        return None
    if b[1] == a[2] and out[2] == b[2] and len({a[0], a[1], a[2], b[2]}) == 4:
        return False           # Xab,Xbc->Xac
    if b[2] == a[2] and out[2] == b[1] and len({a[0], a[1], a[2], b[1]}) == 4:
        return True            # Xab,Xcb->Xac (stored-transposed rhs)
    return None


def mp_einsum(spec: str, *operands, policy="bf16") -> jax.Array:
    """Policy-aware einsum for non-2D contractions (attention score/value).

    Grouped-matmul specs (``gmk,gkn->gmn`` and the stored-transposed
    ``gmk,gnk->gmn``, any letters) are routed through :func:`mp_dot_grouped`
    — i.e. through the grouped MPGEMM kernel and plan cache — rather than a
    raw einsum.  Anything else runs on XLA with the policy's
    compute/accumulate dtypes; quantized policies fall back to their bf16
    sibling there (per-slice dynamic quantization needs the grouped path).
    """
    trans_w = _as_grouped_matmul(spec, len(operands))
    if trans_w is not None and all(
        jnp.dtype(o.dtype).kind == "f" for o in operands
    ):
        return mp_dot_grouped(operands[0], operands[1], policy=policy,
                              trans_w=trans_w)
    policy = get_policy(policy)
    if policy.quantized:
        policy = get_policy("bf16")
    cd = jnp.dtype(policy.compute_dtype)
    ops = [o.astype(cd) if jnp.dtype(o.dtype).kind == "f" else o for o in operands]
    out = jnp.einsum(
        spec, *ops, preferred_element_type=jnp.dtype(policy.acc_dtype)
    )
    return out.astype(policy.out_dtype)
