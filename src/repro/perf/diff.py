"""Baseline comparison: per-metric relative tolerances + markdown report.

The CI contract (``benchmarks/run.py --diff``): compare the freshly
emitted BENCH files against the committed baselines and fail ONLY on
regressions — a metric moving beyond its tolerance in the *bad*
direction, or a baseline record/metric disappearing.  Improvements and
newly added metrics/records are reported, never failed, so adding a
benchmark or making the code faster doesn't require touching tolerances.

Direction is resolved per metric name (:func:`metric_direction`): times,
bytes, FLOPs, visit counts, and overheads are lower-is-better; speedups,
CMR, peak fractions, and efficiency terms are higher-is-better.  A metric
the table can't classify is conservatively two-sided: ANY out-of-tolerance
move fails, which is the right default for deterministic modeled numbers
(they should not move at all unless the model changed on purpose).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.perf.trajectory import BenchFile, read_bench

# Default relative tolerance for deterministic metrics.  Modeled/traced
# numbers are exact re-computations, so the default only absorbs float
# round-off in the JSON round-trip.
DEFAULT_REL_TOL = 1e-9

# Suffix/substring → direction.  First match wins; checked longest-first
# so e.g. "speedup_vs_naive" resolves via "speedup" not "naive".
_LOWER_IS_BETTER = (
    "_us", "_s", "_ms", "bytes", "flops", "tile_visits", "visits",
    "overhead", "waste", "breakeven", "vmem", "grid_steps", "launches",
    "gating_ops", "prep_", "maxerr", "schedule_len",
)
_HIGHER_IS_BETTER = (
    "speedup", "cmr", "peak_frac", "frac", "geomean", "eff_bw",
    "useful", "tokens_per_s", "density_saving", "gain",
)


def metric_direction(name: str) -> str:
    """'lower' | 'higher' | 'both' — which way is worse for ``name``.

    'both' (unknown metric family) means any out-of-tolerance change is a
    regression: deterministic numbers must not drift silently.
    """
    low = name.lower()
    for pat in _HIGHER_IS_BETTER:
        if pat in low:
            return "higher"
    for pat in _LOWER_IS_BETTER:
        if pat in low:
            return "lower"
    return "both"


def _rel_change(baseline: float, current: float) -> float:
    if baseline == current:
        return 0.0
    denom = max(abs(baseline), abs(current), 1e-30)
    return (current - baseline) / denom


def resolve_tolerance(name: str,
                      tolerances: Optional[Dict[str, float]],
                      default_rel_tol: float) -> float:
    """Tolerance for metric ``name``: exact key > substring key > default.

    Substring keys let one entry cover a family (``{"modeled": 0.02}``
    matches ``modeled_us`` and ``modeled_speedup``); the longest matching
    key wins so specific entries override broad ones.
    """
    if not tolerances:
        return default_rel_tol
    if name in tolerances:
        return tolerances[name]
    best = None
    for key in tolerances:
        if key in name and (best is None or len(key) > len(best)):
            best = key
    return tolerances[best] if best is not None else default_rel_tol


@dataclasses.dataclass(frozen=True)
class MetricDelta:
    """One (record, metric) comparison."""

    record: str
    metric: str
    baseline: float
    current: float
    rel_change: float
    tolerance: float
    direction: str              # lower | higher | both
    status: str                 # unchanged | within_tol | regression | improvement

    def describe(self) -> str:
        return (f"{self.record}:{self.metric} {self.baseline:g} -> "
                f"{self.current:g} ({self.rel_change:+.2%}, "
                f"tol {self.tolerance:g}, {self.direction}-is-worse)")


@dataclasses.dataclass
class DiffResult:
    """Outcome of one baseline-vs-current comparison for one area."""

    area: str
    regressions: List[MetricDelta]
    improvements: List[MetricDelta]
    within_tol: List[MetricDelta]
    unchanged_count: int
    new_records: List[str]
    missing_records: List[str]
    new_metrics: List[Tuple[str, str]]        # (record, metric)
    missing_metrics: List[Tuple[str, str]]

    @property
    def ok(self) -> bool:
        """CI gate: no regressions, nothing from the baseline vanished."""
        return not (self.regressions or self.missing_records
                    or self.missing_metrics)

    @property
    def compared(self) -> int:
        return (self.unchanged_count + len(self.within_tol)
                + len(self.regressions) + len(self.improvements))


def _classify(delta: MetricDelta) -> str:
    if delta.rel_change == 0.0:
        return "unchanged"
    if abs(delta.rel_change) <= delta.tolerance:
        return "within_tol"
    if delta.direction == "both":
        return "regression"
    worse = (delta.rel_change > 0) if delta.direction == "lower" \
        else (delta.rel_change < 0)
    return "regression" if worse else "improvement"


def diff_bench(
    baseline: BenchFile,
    current: BenchFile,
    *,
    tolerances: Optional[Dict[str, float]] = None,
    default_rel_tol: float = DEFAULT_REL_TOL,
) -> DiffResult:
    """Compare ``current`` against ``baseline`` record-by-record.

    Only ``metrics`` participate; ``noisy`` values (wall clocks) are
    carried in the files for trajectory plots but never gated.  Records
    present only in ``current`` are "new" (reported, not failed); records
    or metrics present only in ``baseline`` are failures — a benchmark
    silently dropping a number is exactly the regression-blindness this
    subsystem exists to prevent.
    """
    if baseline.area != current.area:
        raise ValueError(f"area mismatch: baseline {baseline.area!r} vs "
                         f"current {current.area!r}")
    base_by = baseline.by_name()
    cur_by = current.by_name()
    result = DiffResult(
        area=current.area, regressions=[], improvements=[], within_tol=[],
        unchanged_count=0, new_records=sorted(set(cur_by) - set(base_by)),
        missing_records=sorted(set(base_by) - set(cur_by)),
        new_metrics=[], missing_metrics=[],
    )
    for name in sorted(set(base_by) & set(cur_by)):
        bm, cm = base_by[name].metrics, cur_by[name].metrics
        for metric in sorted(set(bm) - set(cm)):
            result.missing_metrics.append((name, metric))
        for metric in sorted(set(cm) - set(bm)):
            result.new_metrics.append((name, metric))
        for metric in sorted(set(bm) & set(cm)):
            tol = resolve_tolerance(metric, tolerances, default_rel_tol)
            delta = MetricDelta(
                record=name, metric=metric,
                baseline=float(bm[metric]), current=float(cm[metric]),
                rel_change=_rel_change(float(bm[metric]),
                                       float(cm[metric])),
                tolerance=tol, direction=metric_direction(metric),
                status="",
            )
            status = _classify(delta)
            delta = dataclasses.replace(delta, status=status)
            if status == "unchanged":
                result.unchanged_count += 1
            elif status == "within_tol":
                result.within_tol.append(delta)
            elif status == "improvement":
                result.improvements.append(delta)
            else:
                result.regressions.append(delta)
    return result


def diff_paths(baseline_path, current_path, **kw) -> DiffResult:
    """:func:`diff_bench` over two on-disk BENCH files."""
    return diff_bench(read_bench(baseline_path), read_bench(current_path),
                      **kw)


def markdown_report(results: List[DiffResult]) -> str:
    """Human-readable regression report across areas (CI job summary)."""
    lines = ["# Perf-trajectory diff", ""]
    total_reg = sum(len(r.regressions) for r in results)
    total_missing = sum(len(r.missing_records) + len(r.missing_metrics)
                        for r in results)
    verdict = "PASS" if total_reg == 0 and total_missing == 0 else "FAIL"
    lines.append(f"**{verdict}** — "
                 f"{sum(r.compared for r in results)} metrics compared, "
                 f"{total_reg} regressions, "
                 f"{sum(len(r.improvements) for r in results)} "
                 f"improvements, {total_missing} missing.")
    for r in results:
        lines += ["", f"## area `{r.area}`", ""]
        lines.append(f"- records: {len(r.new_records)} new, "
                     f"{len(r.missing_records)} missing; metrics "
                     f"compared: {r.compared} "
                     f"({r.unchanged_count} byte-identical)")
        if r.regressions:
            lines += ["", "### Regressions", "",
                      "| record | metric | baseline | current | Δ | tol |",
                      "|---|---|---|---|---|---|"]
            for d in r.regressions:
                lines.append(
                    f"| {d.record} | {d.metric} | {d.baseline:g} "
                    f"| {d.current:g} | {d.rel_change:+.2%} "
                    f"| {d.tolerance:g} |")
        if r.improvements:
            lines += ["", "### Improvements (consider refreshing the "
                          "baseline)", ""]
            for d in r.improvements:
                lines.append(f"- {d.describe()}")
        if r.missing_records:
            lines += ["", "### Missing records (present in baseline, "
                          "absent now)", ""]
            lines += [f"- {n}" for n in r.missing_records]
        if r.missing_metrics:
            lines += ["", "### Missing metrics", ""]
            lines += [f"- {rec}:{m}" for rec, m in r.missing_metrics]
        if r.new_records:
            lines += ["", "### New records (not in baseline — refresh to "
                          "start tracking)", ""]
            lines += [f"- {n}" for n in r.new_records]
    lines.append("")
    return "\n".join(lines)
