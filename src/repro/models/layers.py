"""Common neural-net layers.  Every matmul routes through ``core.gemm.mp_dot``
so the paper's multi-precision GEMM technique is the substrate of every
architecture in the framework.  MLPs use the registry epilogues
(core/gemm_spec.py): the SwiGLU gating step and the block residual add ride
the GEMM's accumulator store instead of running as separate elementwise
passes (``core.config.fused_epilogues`` toggles, for A/B benchmarks).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import config as cfg
from repro.core.gemm import mp_dot


# --- initializers ------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    scale = (2.0 / (d_in + d_out)) ** 0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# --- norms -------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dt)


def layernorm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# --- rotary position embeddings ---------------------------------------------

def rope_frequencies(head_dim: int, max_t: int, theta: float = 10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_t, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)                        # (T, hd/2)
    return jnp.cos(freqs), jnp.sin(freqs)


def apply_rope(x, cos, sin, positions=None):
    """x: (B, H, T, hd); cos/sin: (maxT, hd/2); positions: (T,) or (B,T)."""
    if positions is not None:
        cos = cos[positions]
        sin = sin[positions]
    else:
        cos = cos[: x.shape[-2]]
        sin = sin[: x.shape[-2]]
    while cos.ndim < x.ndim - 1:
        cos = cos[None]
        sin = sin[None]
    # cos/sin now broadcastable to (B?, 1?, T, hd/2) against (B,H,T,hd/2)
    if cos.ndim == x.ndim - 1:
        cos = jnp.expand_dims(cos, -3)
        sin = jnp.expand_dims(sin, -3)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLPs ---------------------------------------------------------------------

def swiglu_mlp(params, x, policy, residual=None):
    """silu(x@w_gate) * (x@w_up) @ w_down [+ residual].

    Fused path: the gating step — gate GEMM, silu, elementwise product —
    is ONE kernel launch (the ``gated`` registry epilogue riding the gate
    GEMM's accumulator store), and the block residual rides the down
    projection's store (``residual`` epilogue).  The unfused path keeps the
    pre-registry three-GEMMs-plus-elementwise form for A/B benchmarks.
    """
    if cfg.fused_epilogues():
        up = mp_dot(x, params["w_up"], policy=policy)
        h = mp_dot(x, params["w_gate"], policy=policy,
                   activation="silu", gate=up)
        return mp_dot(h, params["w_down"], policy=policy, residual=residual)
    gate = mp_dot(x, params["w_gate"], policy=policy)
    up = mp_dot(x, params["w_up"], policy=policy)
    out = mp_dot(jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up,
                 params["w_down"], policy=policy)
    return out if residual is None else residual + out


def gelu_mlp(params, x, policy, residual=None):
    if cfg.fused_epilogues():
        h = mp_dot(x, params["w_up"], params.get("b_up"), policy=policy,
                   activation="gelu")
        return mp_dot(h, params["w_down"], params.get("b_down"),
                      policy=policy, residual=residual)
    h = mp_dot(x, params["w_up"], params.get("b_up"), policy=policy)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    out = mp_dot(h, params["w_down"], params.get("b_down"), policy=policy)
    return out if residual is None else residual + out


def init_swiglu(key, d: int, f: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, f, dtype),
        "w_up": dense_init(k2, d, f, dtype),
        "w_down": dense_init(k3, f, d, dtype),
    }


def init_gelu_mlp(key, d: int, f: int, dtype=jnp.float32, bias: bool = False):
    k1, k2 = jax.random.split(key)
    p = {"w_up": dense_init(k1, d, f, dtype), "w_down": dense_init(k2, f, d, dtype)}
    if bias:
        p["b_up"] = jnp.zeros((f,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


# --- embedding / logits -------------------------------------------------------

def embed_tokens(emb, tokens, policy_out_dtype=jnp.bfloat16):
    return emb[tokens].astype(policy_out_dtype)


def logits_from_hidden(x, head, *, tied: bool, policy):
    """tied=True: head is the (V, d) embedding table -> on-the-fly transpose.

    A packed or tile-sparse head (repro.packing / repro.sparse; only the
    UNtied head is ever transformed — the tied table doubles as the
    embedding gather source, which needs a dense array) carries its
    orientation in the payload layout: the transpose was resolved at
    pack/sparsify time, so the layout's flag wins over ``tied``.  The
    sparse head is the logits-layer win: vocab columns whose weight tiles
    were pruned cost neither HBM reads nor MXU passes."""
    from repro.packing import is_packed
    from repro.sparse import is_sparse
    if is_packed(head) or is_sparse(head):
        return mp_dot(x, head, policy=policy, trans_w=head.layout.trans_w)
    return mp_dot(x, head, policy=policy, trans_w=tied)
