"""MPGEMM Pallas kernel vs pure-jnp oracle: shape/dtype sweeps (interpret
mode), fused transposes, epilogues, paper's irregular sizes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref


def _mk(rng, shape, dtype):
    if dtype == "int8":
        return jnp.asarray(rng.integers(-127, 127, shape), "int8")
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _tol(dtype, k):
    if dtype == "int8":
        return 0
    base = 1e-5 if dtype == "float32" else 3e-2
    return base * max(1.0, k / 128) * 8


SHAPES = [
    (128, 128, 128),
    (256, 384, 512),
    (200, 130, 330),        # irregular everything (paper Fig. 13 regime)
    (80, 110, 25600),       # skinny, huge K (paper irregular suite)
    (64, 2112, 896),        # DeepSeek workload ID1 flavor
    (1, 128, 256),          # GEMV edge
    (8, 8, 8),              # tiny
]


@pytest.mark.parametrize("m,n,k", SHAPES)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_mpgemm_matches_oracle(rng, m, n, k, dtype):
    a = _mk(rng, (m, k), dtype)
    b = _mk(rng, (k, n), dtype)
    out = mpgemm_pallas(a, b, interpret=True)
    ref = mpgemm_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(out, np.float64), np.asarray(ref, np.float64),
        atol=_tol(dtype, k), rtol=1e-2)


@pytest.mark.parametrize("trans_a,trans_b", [(True, False), (False, True),
                                             (True, True)])
@pytest.mark.parametrize("m,n,k", [(128, 128, 256), (100, 70, 50)])
def test_mpgemm_fused_transpose(rng, trans_a, trans_b, m, n, k):
    a = _mk(rng, (k, m) if trans_a else (m, k), "float32")
    b = _mk(rng, (n, k) if trans_b else (k, n), "float32")
    out = mpgemm_pallas(a, b, trans_a=trans_a, trans_b=trans_b, interpret=True)
    ref = mpgemm_ref(a, b, trans_a=trans_a, trans_b=trans_b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("kw", [
    dict(alpha=0.5),
    dict(alpha=1.5, beta=2.0),
    dict(bias=True),
    dict(bias=True, activation="silu"),
    dict(activation="gelu", alpha=0.7, beta=0.3),
    dict(activation="relu"),
])
def test_mpgemm_epilogue(rng, kw):
    m, n, k = 96, 144, 160
    a = _mk(rng, (m, k), "float32")
    b = _mk(rng, (k, n), "float32")
    c = _mk(rng, (m, n), "float32") if kw.get("beta") else None
    bias = _mk(rng, (n,), "float32") if kw.pop("bias", False) else None
    out = mpgemm_pallas(a, b, c, bias=bias, interpret=True, **kw)
    ref = mpgemm_ref(a, b, c, bias=bias, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_mpgemm_int8_dequant_epilogue(rng):
    a = _mk(rng, (64, 256), "int8")
    b = _mk(rng, (256, 128), "int8")
    out = mpgemm_pallas(a, b, scale=jnp.float32(0.013), out_dtype="float32",
                        interpret=True)
    ref = mpgemm_ref(a, b, scale=0.013, out_dtype="float32")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_mpgemm_mixed_precision_accumulate(rng):
    """bf16 inputs MUST accumulate in f32 (paper Section V)."""
    k = 4096
    a = jnp.ones((8, k), jnp.bfloat16) * 0.01
    b = jnp.ones((k, 8), jnp.bfloat16) * 0.01
    out = mpgemm_pallas(a, b, out_dtype="float32", interpret=True)
    # bf16 accumulation would stall near 0.25 (eps); f32 accumulates to
    # ~k * 1e-4 with only input-rounding error.
    expect = k * float(jnp.bfloat16(0.01)) ** 2
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-2)
