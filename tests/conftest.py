import os
import sys

# Tests must see exactly ONE device (the dry-run's 512-device trick is
# strictly scoped to launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
