"""phi3-mini-3.8b — RoPE, SwiGLU, GQA kv=32 (== MHA). [arXiv:2404.14219]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    rope_theta=10000.0, mlp="swiglu", norm="rms",
    source="arXiv:2404.14219",
)

SMOKE = ArchConfig(
    name="phi3-mini-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab=512, mlp="swiglu", norm="rms",
)
