"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §6 for the mapping
to the paper's tables and EXPERIMENTS.md for methodology (CPU wall-time is
a sanity signal; modeled roofline terms are the graded numbers)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (
        bench_autotune, bench_breakdown, bench_epilogue,
        bench_gemm_workloads, bench_irregular, bench_loads,
        bench_mixed_precision, bench_packing, bench_sparse, bench_tiles,
        roofline_report,
    )
    bench_tiles.run()                      # paper Fig. 2
    bench_loads.run()                      # paper Fig. 3
    bench_gemm_workloads.run("float32")    # paper Table III + Fig. 10/11
    bench_gemm_workloads.run("bfloat16", wall=False)   # Fig. 12 ladder
    bench_gemm_workloads.run_grouped(wall=False)       # MoE expert shapes
    bench_irregular.run()                  # paper Fig. 13
    bench_mixed_precision.run()            # paper Fig. 14
    bench_breakdown.run()                  # paper Fig. 15
    roofline_report.run()                  # beyond-paper: dry-run roofline
    bench_autotune.run()                   # beyond-paper: Sec. III closed loop
    for policy in ("bf16", "int8"):        # beyond-paper: §IV-C AOT packing
        bench_packing.run(policy)
        bench_packing.run_grouped(policy)
    bench_packing.run("bf16", trans_w=True)
    bench_epilogue.run()                   # beyond-paper: fused epilogues
    bench_epilogue.run_trace_gate()
    bench_epilogue.run_wall_sanity()
    bench_sparse.run()                     # beyond-paper: tile-sparse MPGEMM
    bench_sparse.run_trace_gate()
    bench_sparse.run_wall()


if __name__ == "__main__":
    main()
