"""starcoder2-3b — GQA (kv=2), RoPE, LayerNorm + GeLU MLP w/ bias.
[arXiv:2402.19173; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
    d_ff=12288, vocab=49152,
    rope_theta=100000.0, mlp="gelu", mlp_bias=True, norm="layer",
    source="arXiv:2402.19173",
)

SMOKE = ArchConfig(
    name="starcoder2-3b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab=512, mlp="gelu", mlp_bias=True, norm="layer",
)
