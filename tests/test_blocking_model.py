"""Property tests (hypothesis) for the analytic block planner — the paper's
eq (1)-(3) analogue must respect capacity, alignment, and beat the naive
fixed-tile baseline on modeled traffic."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

from repro.core.blocking import naive_plan, plan_gemm, vmem_working_set
from repro.core.constants import DEFAULT_HW

dims = st.integers(min_value=1, max_value=8192)
dtypes = st.sampled_from(["float32", "bfloat16", "int8"])


@hp.given(m=dims, n=dims, k=dims, dtype=dtypes)
@hp.settings(max_examples=150, deadline=None)
def test_plan_respects_vmem_budget(m, n, k, dtype):
    plan = plan_gemm(m, n, k, dtype)
    assert plan.vmem_bytes <= DEFAULT_HW.vmem_bytes * 0.75 + 1


@hp.given(m=dims, n=dims, k=dims, dtype=dtypes)
@hp.settings(max_examples=150, deadline=None)
def test_plan_alignment_and_coverage(m, n, k, dtype):
    plan = plan_gemm(m, n, k, dtype)
    # grid covers the problem
    assert plan.grid[0] * plan.bm >= m
    assert plan.grid[1] * plan.bn >= n
    assert plan.grid[2] * plan.bk >= k
    # lane alignment (paper P2: wide loads) unless the dim itself is tiny
    assert plan.bn % DEFAULT_HW.lane == 0
    assert plan.bk % DEFAULT_HW.lane == 0
    assert plan.bm % DEFAULT_HW.sublane(4) == 0 or plan.bm >= m


@hp.given(m=st.integers(256, 8192), n=st.integers(256, 8192),
          k=st.integers(256, 8192))
@hp.settings(max_examples=60, deadline=None)
def test_plan_beats_naive_traffic(m, n, k):
    """The analytic model's modeled HBM traffic never exceeds the fixed
    256^3 baseline's (paper Fig. 15: partitioning is the biggest win)."""
    plan = plan_gemm(m, n, k, "float32")
    naive = naive_plan(m, n, k, "float32")
    assert plan.hbm_bytes <= naive.hbm_bytes * 1.001


@hp.given(m=dims, n=dims, k=dims)
@hp.settings(max_examples=100, deadline=None)
def test_kernel_grid_edges_flagged(m, n, k):
    plan = plan_gemm(m, n, k, "float32")
    if k % plan.bk:
        assert plan.k_rem == k % plan.bk  # predication armed


def test_min_dma_row_constraint():
    """Minor-dim blocks span >= 512B (the four-Z-register analogue)."""
    for dtype, min_lanes in [("float32", 128), ("bfloat16", 256), ("int8", 512)]:
        plan = plan_gemm(4096, 4096, 4096, dtype)
        assert plan.bk >= min_lanes
        assert plan.bn >= min_lanes


def test_dtype_awareness():
    """Lower precision -> same VMEM fits bigger tiles -> higher CMR
    (paper Section V: mixed precision raises compute intensity)."""
    p32 = plan_gemm(8192, 8192, 8192, "float32")
    p16 = plan_gemm(8192, 8192, 8192, "bfloat16")
    p8 = plan_gemm(8192, 8192, 8192, "int8")
    assert p16.cmr >= p32.cmr
    assert p8.cmr >= p16.cmr
