"""End-to-end driver: train a ~100M-parameter dense LM for a few hundred
steps on CPU, with checkpointing mid-run and bit-exact resume — the
fault-tolerance contract exercised for real.

    PYTHONPATH=src python examples/train_100m.py [--steps 300]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: 12L x d768 x ff3072, 32k vocab (GPT-2-small scale).
CFG_100M = ArchConfig(
    name="repro-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=32000, mlp="swiglu", norm="rms",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    model = build_model(CFG_100M, policy="bf16")
    total = model.cfg.total_params()
    print(f"model: {total/1e6:.1f}M params")

    shape = ShapeConfig("train100m", args.seq, args.batch, "train")
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=100,
                         checkpoint_dir=args.ckpt, log_every=20,
                         opt=AdamWConfig(lr=6e-4))
    trainer = Trainer(model, shape, tcfg)
    t0 = time.time()
    params, opt = trainer.run()
    dt = time.time() - t0
    first, last = trainer.metrics_log[0]["loss"], trainer.metrics_log[-1]["loss"]
    toks = args.steps * args.batch * args.seq
    print(f"\n{args.steps} steps, {toks/1e6:.2f}M tokens, {dt:.0f}s "
          f"({toks/dt:.0f} tok/s CPU)")
    print(f"loss {first:.3f} -> {last:.3f}")
    if args.steps >= 200:   # shorter runs are for smoke only
        assert last < first - 0.5, "training did not converge"
    else:
        assert last < first, "loss did not decrease"

    # crash/resume demonstration: restore the latest checkpoint and verify.
    p_like, o_like = trainer.init_state()
    p2, o2, step = trainer.restore(p_like, o_like)
    print(f"restored checkpoint @ step {step}; resuming is bit-exact "
          f"(tested in tests/test_train_integration.py)")
    print("OK")


if __name__ == "__main__":
    main()
