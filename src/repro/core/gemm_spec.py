"""GemmSpec / EpilogueSpec — the declarative layer of the GEMM operator stack.

The paper's core lesson (Sections IV-V) is that ONE parameterized
micro-kernel family — cache-aware blocking, on-the-fly transposition, and a
fused epilogue that never leaves the accumulator — should serve every
precision, shape, and layout.  This module is the declarative half of that
design:

* :class:`GemmSpec` names one GEMM *shape family*: 2-D vs grouped, dense vs
  pre-packed B, transposition flags, ragged grouping, output dtype.  It is
  static/hashable, so it can ride ``jax.custom_vjp`` nondiff args and key
  dispatch tables.
* :class:`EpilogueSpec` names what happens to the accumulator after the
  K loop, *before* it ever leaves VMEM: scalar dequant, alpha, bias, an
  activation, a registry-selected fusion tail (gated activation, residual
  add, ...), and beta·C.
* The **epilogue registry** (:func:`register_epilogue`) is where new fusions
  are added.  An entry contributes the forward tail, the extra (M, N)-shaped
  operands it streams, and its backward rule — so a new fusion is ONE
  registry entry consumed by every path (2-D, grouped, packed, every
  precision policy, forward and backward), never a new kernel clone.

:func:`apply_epilogue` is the single implementation of the epilogue
semantics.  The Pallas kernel factory (``kernels/mpgemm.py``) calls it on
VMEM blocks inside the kernel body; the XLA backend and the reference
oracle (``kernels/ref.py``) call it on full arrays — one definition, three
consumers, zero drift.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _has_act(ep: "EpilogueSpec") -> bool:
    return ep.activation not in (None, "none")


# --- the fused-epilogue registry ---------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpilogueDef:
    """One registered epilogue family.

    ``tail(ep, acc, extras)`` maps the post-bias accumulator to the output
    block; ``extras`` is a dict of the entry's named streamed operands.
    ``bwd(ep, z, extras, dy)`` returns ``(dz, dextras)``: the cotangent
    flowing back into the GEMM (pre-tail) and the cotangents of the
    *external* extra operands.  ``z`` is the recomputed pre-tail value
    (f32) when ``needs_pre(ep)`` is true, else ``None`` — entries that
    only need the incoming cotangent (e.g. a pure residual add) skip the
    recompute GEMM.

    ``pre(ep, x)`` — when set — is a PRE-stage run on the X operand before
    the GEMM launch (still inside the one custom-VJP core, as plain jnp
    ops, so the launch count does not change): it returns ``(x',
    internal_extras)`` and the internal extras are PREPENDED to the
    caller's.  ``internal`` names the extras the pre-stage supplies (the
    leading entries of ``extra_operands``); callers only ever provide the
    remaining :attr:`external_operands`.  ``row_operands`` names extras
    shaped (M, 1) per-row instead of (M, N) — the kernel streams them as
    (bm, 1) blocks.
    """

    kind: str
    extra_operands: Tuple[str, ...]
    tail: Callable
    bwd: Callable
    needs_pre: Callable
    pre: Optional[Callable] = None
    internal: Tuple[str, ...] = ()
    row_operands: Tuple[str, ...] = ()

    @property
    def external_operands(self) -> Tuple[str, ...]:
        """The extras a CALLER passes (``extra_operands`` minus the
        pre-stage-supplied ``internal`` ones)."""
        return tuple(nm for nm in self.extra_operands
                     if nm not in self.internal)


_EPILOGUES: Dict[str, EpilogueDef] = {}


def register_epilogue(kind: str, *, extra_operands: Tuple[str, ...] = (),
                      bwd: Callable, needs_pre: Callable,
                      pre: Optional[Callable] = None,
                      internal: Tuple[str, ...] = (),
                      row_operands: Tuple[str, ...] = ()):
    """Register a fused-epilogue family under ``kind`` (decorator).

    This is the extension point the four hand-cloned GEMM paths used to be:
    a new fusion is registered once and immediately works on the 2-D,
    grouped, and packed paths, every precision policy, and in the op-level
    custom VJP.  See docs/gemm_stack.md for a worked example.
    """
    def deco(tail: Callable) -> Callable:
        if kind in _EPILOGUES:
            raise ValueError(f"epilogue {kind!r} already registered")
        if not set(internal) <= set(extra_operands):
            raise ValueError(f"internal operands {internal} must be a "
                             f"subset of extra_operands {extra_operands}")
        _EPILOGUES[kind] = EpilogueDef(
            kind=kind, extra_operands=tuple(extra_operands), tail=tail,
            bwd=bwd, needs_pre=needs_pre, pre=pre,
            internal=tuple(internal), row_operands=tuple(row_operands),
        )
        return tail
    return deco


def get_epilogue(kind: str) -> EpilogueDef:
    try:
        return _EPILOGUES[kind]
    except KeyError:
        raise ValueError(
            f"unknown epilogue kind {kind!r}; registered: "
            f"{sorted(_EPILOGUES)}") from None


def epilogue_kinds() -> Tuple[str, ...]:
    return tuple(sorted(_EPILOGUES))


# --- specs -------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpilogueSpec:
    """Everything applied to the accumulator before it leaves VMEM.

    Order of operations (``apply_epilogue``)::

        acc = acc(f32) * scale        # scalar dequant, when a scale is fed
        acc = alpha * acc
        acc = acc + bias
        acc = tail(acc)               # registry: act(acc) | act(acc)·g | ...
        acc = acc + beta * c

    ``has_bias`` / ``has_scale`` record operand *presence* for the kernel
    factory (the launch normalizes them from the actual arguments); the
    activation and fusion ``kind`` are the user-facing surface.
    """

    kind: str = "linear"
    activation: Optional[str] = None
    alpha: float = 1.0
    beta: float = 0.0
    has_bias: bool = False
    has_scale: bool = False

    def __post_init__(self):
        get_epilogue(self.kind)  # raises on unknown kinds
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"unknown activation {self.activation!r}; valid: "
                f"{sorted(k for k in ACTIVATIONS if k)}")

    @property
    def extra_operands(self) -> Tuple[str, ...]:
        return get_epilogue(self.kind).extra_operands

    @property
    def tag(self) -> str:
        """Plan-cache namespace tag (``make_key(..., epilogue=...)``).

        Empty for the ``linear`` family so pre-registry cache keys stay
        byte-identical; fusion kinds tag with kind(+activation) so fused
        and unfused tunings never collide — the extra streamed operands
        change the measured optimum.
        """
        if self.kind == "linear":
            return ""
        return self.kind if not _has_act(self) else \
            f"{self.kind}-{self.activation}"


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """Static description of one GEMM instance family.

    The single dispatch key replacing the four hand-written paths
    (2-D / grouped × dense / packed): the kernel factory emits the Pallas
    body from it, and the op layer's one custom-VJP core dispatches on it.
    ``out_dtype`` is a dtype string (None → policy/planner default);
    ``ragged`` records that the grouped op masks rows by ``group_sizes``
    (the mask itself lives outside the custom VJP, where autodiff handles
    it natively).
    """

    grouped: bool = False
    packed: bool = False
    sparse: bool = False
    tile_scaled: bool = False
    trans_a: bool = False
    trans_b: bool = False
    ragged: bool = False
    out_dtype: Optional[str] = None

    def __post_init__(self):
        if self.tile_scaled and not (self.packed or self.sparse):
            raise ValueError(
                "tile_scaled implies a packed or tile-sparse operand")
        if self.packed and self.sparse:
            raise ValueError("packed and sparse B are mutually exclusive")
        if self.ragged and not self.grouped:
            raise ValueError("ragged grouping requires grouped=True")
        if (self.packed or self.sparse) and self.trans_b:
            raise ValueError(
                "packed/sparse B has its transpose resolved at pack time")
        if self.out_dtype is not None:
            object.__setattr__(self, "out_dtype",
                               str(jnp.dtype(self.out_dtype)))


# --- the one epilogue implementation -----------------------------------------

def apply_epilogue(ep: EpilogueSpec, acc, *, bias=None, scale=None, c=None,
                   extras=()):
    """Apply ``ep`` to an accumulator value.

    The SINGLE home of the epilogue semantics: the Pallas kernel body calls
    it on (bm, bn) VMEM blocks, the XLA backend and the reference oracle on
    full arrays.  ``extras`` is a tuple in the registry entry's
    ``extra_operands`` order; ``bias``/``c`` must already broadcast against
    ``acc``; ``scale`` is a scalar.
    """
    ed = get_epilogue(ep.kind)
    if scale is not None:
        acc = acc.astype(jnp.float32) * scale
    if ep.alpha != 1.0:
        acc = acc * jnp.asarray(ep.alpha, acc.dtype)
    if bias is not None:
        acc = acc + bias.astype(acc.dtype)
    acc = ed.tail(ep, acc, dict(zip(ed.extra_operands, extras)))
    if ep.beta != 0.0:
        if c is None:
            raise ValueError("beta != 0 requires c")
        acc = acc + jnp.asarray(ep.beta, acc.dtype) * c.astype(acc.dtype)
    return acc


def epilogue_bwd(ep: EpilogueSpec, z, extras, dy):
    """Cotangents through the registry tail: ``(dz, dextras)``.

    ``dy`` must be f32; ``z`` is the recomputed pre-tail value (f32) when
    the entry's ``needs_pre`` demanded it, else None.  The beta·C term is
    linear and handled by the caller (C is never differentiated at the op
    layer); bias/alpha/scale cotangents likewise (dbias = Σ dz rows).
    """
    return get_epilogue(ep.kind).bwd(ep, z, extras, dy)


def epilogue_needs_pre(ep: EpilogueSpec) -> bool:
    """Does the backward rule need the recomputed pre-tail value?"""
    return bool(get_epilogue(ep.kind).needs_pre(ep))


# --- operand -> spec resolution (shared by ops, kernel wrappers, oracle) -----

def infer_epilogue_kind(named: dict) -> str:
    """The registry kind whose ``extra_operands`` exactly match the non-None
    ``named`` operands (``{}`` / all-None -> ``linear``).  Registry-driven,
    so a newly registered fusion is constructible from named operands
    without touching any call site."""
    present = frozenset(k for k, v in named.items() if v is not None)
    if not present:
        return "linear"
    for kind, ed in _EPILOGUES.items():
        if ed.pre is not None:
            continue  # pre-stage kinds (quant_in) are explicit-only
        if present == frozenset(ed.extra_operands):
            return kind
    raise ValueError(
        f"operands {sorted(present)} are not consumed together by any "
        f"registered epilogue; registered: "
        f"{ {k: v.extra_operands for k, v in _EPILOGUES.items()} }")


def collect_extras(ep: EpilogueSpec, named: dict) -> tuple:
    """``named`` operands ordered per the registry entry, with presence and
    leftover validation.  Only the entry's EXTERNAL operands are collected
    — pre-stage-supplied (internal) extras are produced inside the GEMM
    core, never by callers."""
    ed = get_epilogue(ep.kind)
    external = ed.external_operands
    extras = []
    for nm in external:
        if named.get(nm) is None:
            raise ValueError(f"epilogue {ep.kind!r} requires operand {nm!r}")
        extras.append(named[nm])
    for nm, v in named.items():
        if v is not None and nm not in external:
            raise ValueError(
                f"operand {nm!r} is not consumed by epilogue {ep.kind!r}")
    return tuple(extras)


def resolve_epilogue(named: dict, *, epilogue: "EpilogueSpec" = None,
                     activation=None, alpha: float = 1.0, beta: float = 0.0,
                     has_bias: bool = False, has_scale: bool = False):
    """(EpilogueSpec, ordered extras) from named fusion operands.

    The ONE implementation behind the op layer (``mp_dot``), the kernel
    wrappers (``mpgemm_pallas``), and the reference oracle — an explicit
    ``epilogue`` wins (its kind names the operands it consumes); otherwise
    the kind is inferred from which operands are present.
    """
    if epilogue is None:
        epilogue = EpilogueSpec(
            kind=infer_epilogue_kind(named), activation=activation,
            alpha=float(alpha), beta=float(beta), has_bias=has_bias,
            has_scale=has_scale)
    elif activation is not None:
        raise ValueError(
            "pass the activation inside the EpilogueSpec OR as the "
            "activation kwarg, not both")
    return epilogue, collect_extras(epilogue, named)


# --- built-in epilogue families ----------------------------------------------

def _act_vjp(ep, z, dy):
    _, vjp = jax.vjp(ACTIVATIONS[ep.activation], z)
    return vjp(dy)[0]


def _linear_bwd(ep, z, extras, dy):
    return (_act_vjp(ep, z, dy) if _has_act(ep) else dy), ()


@register_epilogue("linear", bwd=_linear_bwd, needs_pre=_has_act)
def _linear_tail(ep, acc, extras):
    """act(acc) — the classic BLAS-plus-activation epilogue."""
    return ACTIVATIONS[ep.activation](acc)


def _gated_bwd(ep, z, extras, dy):
    g = extras[0]
    a_z, vjp = jax.vjp(ACTIVATIONS[ep.activation], z)
    dz = vjp(dy * g.astype(dy.dtype))[0]
    dg = (dy * a_z.astype(dy.dtype)).astype(g.dtype)
    return dz, (dg,)


@register_epilogue("gated", extra_operands=("gate",), bwd=_gated_bwd,
                   needs_pre=lambda ep: True)
def _gated_tail(ep, acc, extras):
    """act(acc) · g — SwiGLU/GeGLU gating fused into the gate GEMM's store:
    the gate projection, its activation, and the elementwise product lower
    to ONE kernel launch instead of a GEMM plus an XLA elementwise pass."""
    return ACTIVATIONS[ep.activation](acc) * extras["gate"].astype(acc.dtype)


def _residual_bwd(ep, z, extras, dy):
    dz = _act_vjp(ep, z, dy) if _has_act(ep) else dy
    return dz, (dy.astype(extras[0].dtype),)


@register_epilogue("residual", extra_operands=("residual",),
                   bwd=_residual_bwd, needs_pre=_has_act)
def _residual_tail(ep, acc, extras):
    """act(acc) + r — the transformer residual add riding the GEMM's final
    store (unscaled, unlike beta·C, and available on the grouped path)."""
    return ACTIVATIONS[ep.activation](acc) + \
        extras["residual"].astype(acc.dtype)


def _quant_pre(ep, x):
    """Per-token (per-row) dynamic int8 quantization of X — the pre-stage
    of the ``quant_in`` family.  Returns the quantized operand and its
    (M, 1) row scales; runs as plain jnp ops inside the custom-VJP core,
    so quantize -> GEMM -> dequant stays ONE kernel launch."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    row_scale = jnp.maximum(amax, 1e-8) / 127.0
    xq = jnp.clip(jnp.round(xf / row_scale), -127, 127).astype(jnp.int8)
    return xq, (row_scale,)


def _quant_in_bwd(ep, z, extras, dy):
    # Straight-through estimator: the backward ignores the quantization
    # round/clip entirely (z is the recomputed FLOAT pre-tail GEMM).
    return (_act_vjp(ep, z, dy) if _has_act(ep) else dy), ()


@register_epilogue("quant_in", extra_operands=("row_scale",),
                   internal=("row_scale",), row_operands=("row_scale",),
                   pre=_quant_pre, bwd=_quant_in_bwd, needs_pre=_has_act)
def _quant_in_tail(ep, acc, extras):
    """act(acc · row_scale) — the dequant tail of per-token activation
    quantization.  The per-row scale computed by the pre-stage rides the
    extras stream as (bm, 1) blocks; combined with the weight side's
    per-tile/per-tensor scale the full int GEMM dequantizes without ever
    leaving the accumulator."""
    rs = extras["row_scale"].astype(jnp.float32)
    return ACTIVATIONS[ep.activation](acc.astype(jnp.float32) * rs)


def _quant_in_residual_bwd(ep, z, extras, dy):
    dz = _act_vjp(ep, z, dy) if _has_act(ep) else dy
    return dz, (dy.astype(extras[0].dtype),)


@register_epilogue("quant_in_residual",
                   extra_operands=("row_scale", "residual"),
                   internal=("row_scale",), row_operands=("row_scale",),
                   pre=_quant_pre, bwd=_quant_in_residual_bwd,
                   needs_pre=_has_act)
def _quant_in_residual_tail(ep, acc, extras):
    """act(acc · row_scale) + r — activation quantization composed with
    the residual-add fusion: quantize, GEMM, dequant, activation, and the
    transformer skip connection in one launch."""
    rs = extras["row_scale"].astype(jnp.float32)
    out = ACTIVATIONS[ep.activation](acc.astype(jnp.float32) * rs)
    return out + extras["residual"].astype(out.dtype)


LINEAR = EpilogueSpec()
