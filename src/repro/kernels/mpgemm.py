"""MPGEMM-TPU: ONE spec-driven Pallas kernel factory.

TPU-native re-derivation of the paper's SME micro-kernel (Sections IV-C,
V-C), generated from a :class:`~repro.core.gemm_spec.GemmSpec` +
:class:`~repro.core.gemm_spec.EpilogueSpec` instead of hand-cloned per
path:

* "All four ZA tiles resident across the K loop"  ->  an fp32/int32 VMEM
  scratch accumulator revisited by a K-innermost grid; the output block is
  written exactly once, after the full reduction (Algorithm 1 lines 1/8).
* "Four-Z-register grouped loads"  ->  BlockSpec minor dims chosen by the
  analytic planner so every DMA row is >= 512 contiguous bytes.
* "On-the-fly transposition"  ->  ``dot_general`` dimension numbers contract
  whichever axis the stored layout dictates; no materialized transpose pass.
* "Predicated edge micro-kernels"  ->  K-remainder masking with iota
  predicates in-kernel; M/N edges use Pallas partial-block masked stores.
* "Mixed precision FMOPA"  ->  bf16 x bf16 -> f32 and int8 x int8 -> int32
  via ``preferred_element_type``, with the registry-driven fused epilogue
  (``core/gemm_spec.py``): dequant/alpha/bias/activation plus the gated-
  activation and residual-add fusions, all riding the accumulator's single
  store — the paper's first-round-online-packing lesson: never run a
  separate memory pass for work that can ride the GEMM.

:func:`make_gemm_kernel` is the single factory — 2-D vs grouped, dense vs
packed B, and every registered epilogue are spec parameters of ONE body,
not separate kernels.  :func:`mpgemm_pallas` / :func:`mpgemm_grouped_pallas`
are thin argument-to-spec adapters kept as the public entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu imports fine on CPU installs; guard anyway.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro import obs
from repro.core.blocking import (
    GemmPlan, grouped_plan_from_2d, plan_gemm, plan_with_blocks,
)
from repro.core.gemm_spec import (
    EpilogueSpec, GemmSpec, apply_epilogue, get_epilogue, resolve_epilogue,
)
from repro.packing.layout import PackedOperand, is_packed
from repro.packing.pack import unpack_nibbles
from repro.sparse.layout import TileSparseOperand, build_schedule, is_sparse


def resolve_b_operand(
    name: str,
    b,
    b_packed: Optional[PackedOperand] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    *,
    stacklevel: int = 3,
):
    """Collapse the legacy ``b_packed=``/``b_sparse=`` keywords into the
    polymorphic ``b`` operand.

    Returns a normalized ``(b, b_packed, b_sparse)`` triple with exactly one
    entry set, dispatched on the OPERAND'S TYPE (dense array / PackedOperand
    / TileSparseOperand) rather than on which keyword carried it.  Passing
    the operand through ``b_packed=``/``b_sparse=`` still works but emits a
    DeprecationWarning — the keywords survive only as migration shims.
    """
    if sum(x is not None for x in (b, b_packed, b_sparse)) != 1:
        raise ValueError("exactly one of b / b_packed / b_sparse is required")
    if b_packed is not None or b_sparse is not None:
        kw = "b_packed" if b_packed is not None else "b_sparse"
        obs.warn_deprecated(
            f"{name}.{kw}",
            f"{name}({kw}=...) is deprecated; pass the operand as the "
            "polymorphic `b` argument (dispatch is by operand type)",
            stacklevel=stacklevel)
    op = b if b is not None else b_packed if b_packed is not None else b_sparse
    if is_packed(op):
        return None, op, None
    if is_sparse(op):
        return None, None, op
    return op, None, None


def _mask_contract(x, axis: int, valid):
    """Zero out lanes >= ``valid`` along ``axis`` (edge predication)."""
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    return jnp.where(idx < valid, x, jnp.zeros_like(x))


def _dot_dims(trans_a: bool, trans_b: bool):
    """dot_general dimension numbers for on-the-fly transposition.

    a block is stored (bm,bk) or, transposed, (bk,bm); likewise b is (bk,bn)
    or (bn,bk).  The contracting axis in the *stored* layout:
    """
    ca = 0 if trans_a else 1
    cb = 1 if trans_b else 0
    return (((ca,), (cb,)), ((), ()))


def _accumulate(acc_ref, a, b, ts, trans_a: bool, trans_b: bool, acc_dtype):
    """One K-step FMA into the resident accumulator.

    ``ts`` is the packed payload's per-tile dequant scale (None on the
    unpacked path).  With a per-tile scale the accumulator is f32 and the
    scale is applied per K step:

    * int x int (int8/int4-decoded payload vs an int8-quantized A): dot in
      int32, scale on the way into the f32 accumulator;
    * float A x quantized payload: dequantize the tile in VMEM before the
      dot (int8/fp8 HBM reads, upcast at the compute unit);
    * int A x FLOAT payload (activation-quantized X over an fp8 tile): no
      mixed int x float dot exists — both sides upcast to f32.
    """
    if ts is None:
        acc_ref[...] += jax.lax.dot_general(
            a, b, _dot_dims(trans_a, trans_b),
            preferred_element_type=acc_dtype)
        return
    a_int = jnp.issubdtype(a.dtype, jnp.integer)
    b_int = jnp.issubdtype(b.dtype, jnp.integer)
    if a_int and b_int:
        part = jax.lax.dot_general(
            a, b, _dot_dims(trans_a, trans_b),
            preferred_element_type=jnp.int32)
        acc_ref[...] += part.astype(jnp.float32) * ts
    else:
        bf = b.astype(jnp.float32) * ts
        af = a.astype(jnp.float32) if a_int else a
        if not a_int:
            bf = bf.astype(a.dtype)
        acc_ref[...] += jax.lax.dot_general(
            af, bf, _dot_dims(trans_a, trans_b),
            preferred_element_type=acc_dtype)


def make_gemm_kernel(*, spec: GemmSpec, epilogue: EpilogueSpec, nk: int,
                     k_rem: int, acc_dtype,
                     b_codec: Optional[str] = None,
                     b_rows: Optional[int] = None):
    """THE kernel factory: emit one Pallas body from the spec.

    ``b_codec``/``b_rows`` select an in-register payload decode for
    sub-byte packed B tiles (``int4``): the DMA'd (ceil(bk/2), bn) nibble
    tile is unpacked to ``b_rows`` int8 K rows right after the read, so
    the dequant rides the accumulation — no separate unpack launch ever
    exists (the paper's never-run-a-separate-memory-pass rule applied to
    the precision ladder).

    Grid = (M/bm, N/bn, K/bk) — grouped specs prepend the group axis G —
    with K innermost ('arbitrary').  Ref order (presence driven by the
    spec/epilogue): a, b, [tile_scales], [c], [bias], [scale],
    *epilogue-extras, out, acc-scratch.  Grouped block refs carry a size-1
    leading group dim; the accumulator scratch does not (it is recycled
    across groups because K is the only revisiting axis).

    **Tile-sparse specs** (``spec.sparse``) swap the dense K axis for a
    walk over the operand's stored-tile schedule: grid = (M/bm,
    schedule_len), the scalar-prefetched schedule arrays (kk, jj, slot,
    first, last[, gg]) lead the ref list, and the accumulator
    initializes/stores on the schedule's per-column first/last flags
    instead of ``kk == 0`` / ``kk == nk - 1`` — zero tiles are never
    visited.  ``nk`` is then the dense k-tile count (for K-tail
    predication via the prefetched ``kk``), and the grid never prepends a
    group axis (grouping is folded into the schedule).
    """
    ep_def = get_epilogue(epilogue.kind)
    grouped = spec.grouped
    k_axis = 3 if grouped else 2
    n_lead = 1 if grouped else 0  # size-1 group dim on every block ref

    def _read(ref, extra_lead: int = 0):
        lead = n_lead + extra_lead
        return ref[(0,) * lead] if lead else ref[...]

    def sparse_kernel(*refs):
        refs = list(refs)
        kk_ref = refs.pop(0)
        refs.pop(0)  # jj: consumed by the index maps only
        refs.pop(0)  # slot: consumed by the index maps only
        first_ref = refs.pop(0)
        last_ref = refs.pop(0)
        if grouped:
            refs.pop(0)  # gg: consumed by the index maps only
        a_ref = refs.pop(0)
        b_ref = refs.pop(0)
        ts_ref = refs.pop(0) if spec.tile_scaled else None
        c_ref = refs.pop(0) if epilogue.beta != 0.0 else None
        bias_ref = refs.pop(0) if epilogue.has_bias else None
        scale_ref = refs.pop(0) if epilogue.has_scale else None
        extra_refs = [refs.pop(0) for _ in ep_def.extra_operands]
        out_ref = refs.pop(0)
        acc_ref = refs.pop(0)

        t = pl.program_id(1)

        @pl.when(first_ref[t] == 1)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = _read(a_ref)
        b = b_ref[0]  # payload tile (1, bk, bn) -> (bk, bn)
        if k_rem:
            # The K-tail tile can appear ANYWHERE in the schedule; the
            # prefetched kk identifies it.  Payload tiles were zero-padded
            # at sparsify time, so only A needs the predicate.
            valid = jnp.where(kk_ref[t] == nk - 1, k_rem,
                              a.shape[0 if spec.trans_a else 1])
            a = _mask_contract(a, 0 if spec.trans_a else 1, valid)
        ts = ts_ref[0, 0] if spec.tile_scaled else None
        _accumulate(acc_ref, a, b, ts, spec.trans_a, False, acc_dtype)

        @pl.when(last_ref[t] == 1)
        def _epilogue():
            out = apply_epilogue(
                epilogue, acc_ref[...],
                bias=_read(bias_ref) if bias_ref is not None else None,
                scale=scale_ref[0] if scale_ref is not None else None,
                c=_read(c_ref) if c_ref is not None else None,
                extras=tuple(_read(r) for r in extra_refs),
            ).astype(out_ref.dtype)
            out_ref[...] = out[None] if grouped else out

    if spec.sparse:
        return sparse_kernel

    def kernel(*refs):
        refs = list(refs)
        a_ref = refs.pop(0)
        b_ref = refs.pop(0)
        ts_ref = refs.pop(0) if spec.tile_scaled else None
        c_ref = refs.pop(0) if epilogue.beta != 0.0 else None
        bias_ref = refs.pop(0) if epilogue.has_bias else None
        scale_ref = refs.pop(0) if epilogue.has_scale else None
        extra_refs = [refs.pop(0) for _ in ep_def.extra_operands]
        out_ref = refs.pop(0)
        acc_ref = refs.pop(0)

        kk = pl.program_id(k_axis)

        @pl.when(kk == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        a = _read(a_ref)
        # Packed B: the payload block is a pre-transposed, zero-padded
        # physical tile behind leading (1, 1) tile indices — an identity
        # index map, no strided DMA, no on-the-fly transposition.
        b = _read(b_ref, 2 if spec.packed else 0)
        if b_codec is not None:
            # Sub-byte payload: two K-adjacent nibbles per byte — unpack
            # the register tile to b_rows int8 K rows (zero-padded rows
            # decode to zero, so K-tail predication stays A-side only).
            b = unpack_nibbles(b, b_rows)
        if k_rem:
            # Paper's predicate registers: mask the K tail so pipeline pad
            # garbage (possibly NaN) never pollutes the accumulator.
            # Packed payload tiles were zero-padded at pack time, so only
            # A needs the predicate on that path.
            valid = jnp.where(kk == nk - 1, k_rem,
                              a.shape[0 if spec.trans_a else 1])
            a = _mask_contract(a, 0 if spec.trans_a else 1, valid)
            if not spec.packed:
                b = _mask_contract(b, 1 if spec.trans_b else 0, valid)

        ts = _read(ts_ref, 2) if spec.tile_scaled else None
        _accumulate(acc_ref, a, b, ts, spec.trans_a, spec.trans_b, acc_dtype)

        @pl.when(kk == nk - 1)
        def _epilogue():
            out = apply_epilogue(
                epilogue, acc_ref[...],
                bias=_read(bias_ref) if bias_ref is not None else None,
                scale=scale_ref[0] if scale_ref is not None else None,
                c=_read(c_ref) if c_ref is not None else None,
                extras=tuple(_read(r) for r in extra_refs),
            ).astype(out_ref.dtype)
            out_ref[...] = out[None] if grouped else out

    return kernel


def _compiler_params(interpret: bool, grid_rank: int = 3):
    """Grid semantics: every axis parallel except the K-innermost one."""
    if interpret or pltpu is None:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:
        return None
    semantics = ("parallel",) * (grid_rank - 1) + ("arbitrary",)
    try:
        return cls(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None


def _layout_plan(m: int, k: int, n: int, layout, a_dtype, out_dtype,
                 trans_a: bool, beta: float, *, sparse: bool, g: int = 1,
                 epilogue_tag: str = "", extra_mn: int = 0) -> GemmPlan:
    """Resolve a plan for a layout-pinned B operand (packed OR tile-sparse).

    ONE resolver for both pre-laid-out forms: tuned plan from the layout's
    namespace (``make_key(layout=tag)`` for packed, ``make_key(sparsity=
    tag)`` for sparse) if its blocks agree with the payload layout, else
    the analytic solve with (bn, bk) pinned to the layout — the payload's
    tiling IS the block decision, only bm stays free.  Sparse layouts
    additionally DENSITY-PRICE the analytic traffic/FLOP model (skipped
    tiles cost neither B bytes nor MACs — core/blocking.py ``density=``).
    Per-tile-scaled payloads force an f32 accumulator (scales vary per K
    step, so int32 accumulation across blocks is no longer exact)."""
    from repro.tuning.plan_cache import (
        lookup_plan, make_key, note_analytic_fallback,
    )
    acc = "float32" if layout.per_tile_scales else None
    density = layout.density if sparse else 1.0
    namespace = {"sparsity": layout.tag} if sparse else {"layout": layout.tag}
    plan = lookup_plan(
        m, n, k, a_dtype, layout.dtype, out_dtype,
        trans_a=trans_a, trans_b=False, beta=beta, g=g,
        epilogue=epilogue_tag, analytic_memo=True, **namespace,
    )
    if plan is not None and (plan.bn, plan.bk) != (layout.bn, layout.bk):
        plan = None  # tuned entry from a different payload tiling
    if plan is None:
        base = plan_gemm(m, n, k, a_dtype, layout.dtype,
                         out_dtype=out_dtype, acc_dtype=acc, beta=beta,
                         extra_mn_inputs=extra_mn, density=density)
        plan = plan_with_blocks(
            m, n, k, base.bm, layout.bn, layout.bk, a_dtype, layout.dtype,
            out_dtype, acc, beta=beta, extra_mn_inputs=extra_mn,
            density=density, notes="tile-sparse" if sparse else "packed-b",
        )
        if g != 1:
            plan = grouped_plan_from_2d(plan, g)
        note_analytic_fallback(make_key(
            m, n, k, a_dtype, layout.dtype, out_dtype,
            trans_a=trans_a, trans_b=False, beta=beta, g=g,
            epilogue=epilogue_tag, **namespace), plan)
    if layout.per_tile_scales and plan.acc_dtype != "float32":
        plan = dataclasses.replace(plan, acc_dtype="float32")
    return plan


def _bias_input(bias, grouped: bool, g: int, n: int):
    """Normalize a bias operand for the kernel's (1, bn) block reads:
    (N,)/(G, N) -> (1, N) or broadcast (G, 1, N) — shared by the dense and
    sparse launch paths."""
    if grouped:
        return jnp.broadcast_to(
            bias.reshape((1, -1) if bias.ndim == 1 else (g, -1))[:, None, :],
            (g, 1, n))
    return bias.reshape(1, -1)


def _scale_spec_and_input(scale, interpret: bool):
    """The dynamic-quant per-tensor scale rides SMEM (1-elem f32)."""
    spec = pl.BlockSpec(
        memory_space=pltpu.SMEM if (pltpu and not interpret) else None)
    return spec, jnp.asarray(scale, jnp.float32).reshape(1)


def _resolve_epilogue(activation, alpha, beta, bias, scale, gate, residual):
    """Build the EpilogueSpec + ordered extras tuple from wrapper kwargs
    (the shared registry-driven resolution — core/gemm_spec.py)."""
    return resolve_epilogue(
        {"gate": gate, "residual": residual},
        activation=activation, alpha=alpha, beta=beta,
        has_bias=bias is not None, has_scale=scale is not None,
    )


def _launch_sparse(a, b_sparse: TileSparseOperand, *, c, bias, scale, extras,
                   spec: GemmSpec, epilogue: EpilogueSpec, plan: GemmPlan,
                   out_dtype, acc_dtype, m: int, n: int, g: int,
                   interpret: bool):
    """Launch the tile-sparse walk: grid (M/bm, schedule_len).

    The dense K axis is replaced by the operand's stored-tile schedule;
    every BlockSpec index map reads the scalar-prefetched schedule arrays
    (kk = A-side k-tile, jj = output column, slot = payload tile, gg =
    group), so each grid step DMAs exactly one stored tile — zero tiles
    appear in neither the grid nor the DMA stream.  Grouped operands fold
    the group axis into the schedule (the grid stays rank 2); empty output
    columns get one anchor visit of the shared zero payload tile so their
    epilogue (bias/activation/residual/beta·C) still runs.
    """
    if pltpu is None:  # pragma: no cover - CPU jaxlibs ship pltpu
        raise NotImplementedError(
            "tile-sparse launches need pallas.tpu (PrefetchScalarGridSpec)")
    layout = b_sparse.layout
    grouped = spec.grouped
    sched = build_schedule(layout)
    t_len = layout.schedule_len
    bm, bn, bk = plan.bm, layout.bn, layout.bk
    grid = (pl.cdiv(m, bm), t_len)
    lead = (1,) if grouped else ()
    n_sp = 6 if grouped else 5  # kk, jj, slot, first, last [, gg]

    def _sim(f):
        """Index map over (i, t) + the scalar-prefetch refs; ``f`` gets
        (i, t, kk, jj, slot, gg)."""
        if grouped:
            return lambda i, t, kk, jj, slot, fr, la, gg: \
                f(i, t, kk, jj, slot, gg)
        return lambda i, t, kk, jj, slot, fr, la: \
            f(i, t, kk, jj, slot, None)

    def _lead(gg, t):
        return (gg[t],) if grouped else ()

    a_spec = (
        pl.BlockSpec(lead + (bk, bm),
                     _sim(lambda i, t, kk, jj, slot, gg:
                          _lead(gg, t) + (kk[t], i)))
        if spec.trans_a
        else pl.BlockSpec(lead + (bm, bk),
                          _sim(lambda i, t, kk, jj, slot, gg:
                               _lead(gg, t) + (i, kk[t])))
    )
    b_spec = pl.BlockSpec((1, bk, bn),
                          _sim(lambda i, t, kk, jj, slot, gg:
                               (slot[t], 0, 0)))
    in_specs = [a_spec, b_spec]
    inputs = [a, b_sparse.payload]
    if spec.tile_scaled:
        in_specs.append(pl.BlockSpec(
            (1, 1), _sim(lambda i, t, kk, jj, slot, gg: (slot[t], 0))))
        inputs.append(b_sparse.scales)
    mn_spec = pl.BlockSpec(
        lead + (bm, bn),
        _sim(lambda i, t, kk, jj, slot, gg: _lead(gg, t) + (i, jj[t])))
    if epilogue.beta != 0.0:
        in_specs.append(mn_spec)
        inputs.append(c)
    if bias is not None:
        in_specs.append(pl.BlockSpec(
            lead + (1, bn),
            _sim(lambda i, t, kk, jj, slot, gg: _lead(gg, t) + (0, jj[t]))))
        inputs.append(_bias_input(bias, grouped, g, n))
    if scale is not None:
        sspec, scale1d = _scale_spec_and_input(scale, interpret)
        in_specs.append(sspec)
        inputs.append(scale1d)
    ep_def = get_epilogue(epilogue.kind)
    row_spec = pl.BlockSpec(
        lead + (bm, 1),
        _sim(lambda i, t, kk, jj, slot, gg: _lead(gg, t) + (i, 0)))
    for nm, x in zip(ep_def.extra_operands, extras):
        in_specs.append(row_spec if nm in ep_def.row_operands else mn_spec)
        inputs.append(x)

    kernel = make_gemm_kernel(
        spec=spec, epilogue=epilogue, nk=layout.nkb, k_rem=plan.k_rem,
        acc_dtype=acc_dtype,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=n_sp,
        grid=grid,
        in_specs=in_specs,
        out_specs=mn_spec,
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
    )
    kwargs = {}
    params = _compiler_params(interpret, grid_rank=len(grid))
    if params is not None:
        kwargs["compiler_params"] = params
    sp_args = [jnp.asarray(x) for x in
               (sched.kk, sched.jj, sched.slot, sched.first, sched.last)]
    if grouped:
        sp_args.append(jnp.asarray(sched.gg))
    out_shape = ((g, m, n) if grouped else (m, n))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, out_dtype),
        interpret=interpret,
        **kwargs,
    )(*sp_args, *inputs)


def mpgemm_pallas_spec(
    a: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    b_packed: Optional[PackedOperand] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    c: Optional[jax.Array] = None,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    extras: Sequence[jax.Array] = (),
    spec: GemmSpec,
    epilogue: EpilogueSpec = EpilogueSpec(),
    out_dtype=None,
    plan: Optional[GemmPlan] = None,
    interpret: bool = False,
) -> jax.Array:
    """Launch ONE spec-described GEMM through the kernel factory.

    The single launch path behind :func:`mpgemm_pallas` and
    :func:`mpgemm_grouped_pallas` (and the op layer's custom-VJP core):
    resolves shapes, plan (tuned cache -> analytic fallback, keyed with the
    epilogue tag so fused and unfused tunings never collide), BlockSpecs,
    and the kernel body — one accumulator / edge-predication / epilogue
    implementation for all spec combinations.  ``b_sparse`` selects the
    tile-sparse walk: the grid's K axis is replaced by the operand's
    stored-tile schedule (scalar-prefetched index maps), so pruned tiles
    are never DMA'd or multiplied.
    """
    grouped = spec.grouped
    b, b_packed, b_sparse = resolve_b_operand(
        "mpgemm_pallas_spec", b, b_packed, b_sparse)
    layout = b_packed.layout if b_packed is not None else None
    slayout = b_sparse.layout if b_sparse is not None else None
    # Normalize packed/sparse/tile_scaled from the ACTUAL operand, not the
    # caller's spec: a default-constructed spec over a per-tile-scaled
    # payload must still stream the scales (silently skipping the dequant
    # would return wrong numerics with no error).
    spec = dataclasses.replace(
        spec, packed=layout is not None, sparse=slayout is not None,
        tile_scaled=(layout is not None and layout.per_tile_scales)
        or (slayout is not None and slayout.per_tile_scales))
    b_layout = layout if layout is not None else slayout
    if layout is not None and not layout.kernel_native:
        raise NotImplementedError(
            f"payload codec {layout.dtype!r} is bit-emulated on this "
            "install; use the XLA unpack path (packing.pack.unpack_operand)")
    if b_layout is not None:
        if grouped and b_layout.g == 1:
            raise ValueError("2-D payload: use a non-grouped spec")
        if not grouped and b_layout.g != 1:
            raise ValueError("grouped payload: use a grouped spec")
    if grouped:
        if a.ndim != 3 or (b is not None and b.ndim != 3):
            raise ValueError(
                f"grouped operands must be rank-3: got a={a.shape}")
        g = a.shape[0]
        if b_layout is not None and b_layout.g != g:
            raise ValueError(
                f"group mismatch: a has {g}, payload {b_layout.g}")
        if b is not None and b.shape[0] != g:
            raise ValueError(f"group mismatch: {a.shape} x {b.shape}")
        m = a.shape[2] if spec.trans_a else a.shape[1]
        ka = a.shape[1] if spec.trans_a else a.shape[2]
    else:
        g = 1
        m = a.shape[1] if spec.trans_a else a.shape[0]
        ka = a.shape[0] if spec.trans_a else a.shape[1]
    if b_layout is not None:
        n, kb = b_layout.n, b_layout.k
    elif grouped:
        n = b.shape[1] if spec.trans_b else b.shape[2]
        kb = b.shape[2] if spec.trans_b else b.shape[1]
    else:
        n = b.shape[0] if spec.trans_b else b.shape[1]
        kb = b.shape[1] if spec.trans_b else b.shape[0]
    if ka != kb:
        bshape = (b_layout.payload_shape if b_layout is not None
                  else b.shape)
        raise ValueError(f"contraction mismatch: {a.shape} x {bshape}")
    k = ka

    # Normalize the epilogue to operand presence (the factory keys ref
    # unpacking off these flags).
    epilogue = dataclasses.replace(
        epilogue, has_bias=bias is not None, has_scale=scale is not None)
    ep_def = get_epilogue(epilogue.kind)
    extras = tuple(extras)
    if len(extras) != len(ep_def.extra_operands):
        raise ValueError(
            f"epilogue {epilogue.kind!r} needs operands "
            f"{ep_def.extra_operands}, got {len(extras)}")
    if epilogue.beta != 0.0 and c is None:
        raise ValueError("beta != 0 requires c")
    # (M, 1) row-scale extras stream (bm, 1) blocks — only the (M, N)-shaped
    # ones price as full output-sized inputs in the traffic model.
    n_extra_mn = sum(1 for nm in ep_def.extra_operands
                     if nm not in ep_def.row_operands)

    # --- plan resolution: explicit > tuned (epilogue-tagged) > analytic ---
    if plan is not None and b_layout is not None and (
            (plan.bn, plan.bk) != (b_layout.bn, b_layout.bk)):
        raise ValueError(
            f"plan blocks ({plan.bn}, {plan.bk}) incompatible with "
            f"packed/sparse layout ({b_layout.bn}, {b_layout.bk})")
    with obs.span("gemm.plan", m=m, n=n, k=k, g=g):
        if plan is None and b_layout is not None:
            plan = _layout_plan(m, k, n, b_layout, a.dtype, out_dtype,
                                spec.trans_a, epilogue.beta,
                                sparse=slayout is not None, g=g,
                                epilogue_tag=epilogue.tag,
                                extra_mn=n_extra_mn)
        if plan is None:
            # Closed-loop planning: a tuned plan from the persistent cache
            # wins over the analytic model (repro.tuning populates it; lazy
            # import keeps the kernel layer free of a hard tuning
            # dependency).
            from repro.tuning.plan_cache import lookup_plan
            plan = lookup_plan(
                m, n, k, a.dtype, b.dtype, out_dtype,
                trans_a=spec.trans_a, trans_b=spec.trans_b,
                beta=epilogue.beta, g=g, epilogue=epilogue.tag,
                analytic_memo=True,
            )
        if plan is None:
            from repro.tuning.plan_cache import (
                make_key, note_analytic_fallback,
            )
            plan = plan_gemm(
                m, n, k, a.dtype, b.dtype, out_dtype=out_dtype,
                beta=epilogue.beta, extra_mn_inputs=n_extra_mn,
            )
            if grouped:
                plan = grouped_plan_from_2d(plan, g)
            note_analytic_fallback(make_key(
                m, n, k, a.dtype, b.dtype, out_dtype,
                trans_a=spec.trans_a, trans_b=spec.trans_b,
                beta=epilogue.beta, g=g, epilogue=epilogue.tag), plan)
        obs.annotate(bytes=plan.hbm_bytes, flops=plan.flops, cmr=plan.cmr)
    out_dtype = jnp.dtype(out_dtype or plan.out_dtype)
    acc_dtype = jnp.dtype(plan.acc_dtype)
    if b_layout is not None and b_layout.per_tile_scales:
        # Per-tile scales accumulate scaled f32 partials — coerce even for
        # an explicitly supplied plan (mirrors _layout_plan; an int32
        # accumulator would reject the scaled stores deep inside Pallas).
        acc_dtype = jnp.dtype(jnp.float32)
    # Per-spec launch accounting: one series per (layout, codec, epilogue,
    # sparse, grouped) combination — the runtime census of which kernel
    # variants a workload actually exercises (counted at trace time, like
    # every other jaxpr-level fact in this stack).
    launch_labels = dict(
        layout=("packed" if layout is not None
                else "sparse" if slayout is not None else "dense"),
        codec=(b_layout.dtype if b_layout is not None else "none"),
        epilogue=epilogue.kind,
        sparse=str(slayout is not None).lower(),
        grouped=str(grouped).lower(),
    )
    obs.counter_inc("gemm_launches_total",
                    help="GEMM launches by spec combination",
                    **launch_labels)
    if spec.sparse:
        with obs.span("gemm.launch", bytes=plan.hbm_bytes,
                      flops=plan.flops, m=m, n=n, k=k, g=g,
                      **launch_labels):
            return _launch_sparse(
                a, b_sparse, c=c, bias=bias, scale=scale, extras=extras,
                spec=spec, epilogue=epilogue, plan=plan, out_dtype=out_dtype,
                acc_dtype=acc_dtype, m=m, n=n, g=g, interpret=interpret)
    bm, bn, bk = plan.bm, plan.bn, plan.bk
    grid = ((g,) if grouped else ()) + (
        pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    # --- BlockSpecs: grouped specs prepend a size-1 group block dim and a
    # leading group index to every map -----------------------------------
    lead = (1,) if grouped else ()

    def _im(f):
        if grouped:
            return lambda gg, i, j, kk: (gg,) + f(i, j, kk)
        return lambda i, j, kk: f(i, j, kk)

    a_spec = (
        pl.BlockSpec(lead + (bk, bm), _im(lambda i, j, kk: (kk, i)))
        if spec.trans_a
        else pl.BlockSpec(lead + (bm, bk), _im(lambda i, j, kk: (i, kk)))
    )
    if layout is not None:
        # Identity tile read: grid step (i, j, kk) fetches payload tile
        # (kk, j) — one contiguous DMA, the payoff of ahead-of-time packing.
        # The block minor dims are the PHYSICAL payload tile (sub-byte
        # codecs store ceil(bk/2) nibble-pair rows per logical bk).
        b_spec = pl.BlockSpec(lead + (1, 1) + layout.payload_tile,
                              _im(lambda i, j, kk: (kk, j, 0, 0)))
        inputs = [a, b_packed.payload]
    else:
        b_spec = (
            pl.BlockSpec(lead + (bn, bk), _im(lambda i, j, kk: (j, kk)))
            if spec.trans_b
            else pl.BlockSpec(lead + (bk, bn), _im(lambda i, j, kk: (kk, j)))
        )
        inputs = [a, b]
    in_specs = [a_spec, b_spec]
    if spec.tile_scaled:
        in_specs.append(pl.BlockSpec(lead + (1, 1),
                                     _im(lambda i, j, kk: (kk, j))))
        inputs.append(b_packed.scales)
    mn_spec = pl.BlockSpec(lead + (bm, bn), _im(lambda i, j, kk: (i, j)))
    if epilogue.beta != 0.0:
        in_specs.append(mn_spec)
        inputs.append(c)
    if bias is not None:
        in_specs.append(pl.BlockSpec(lead + (1, bn),
                                     _im(lambda i, j, kk: (0, j))))
        inputs.append(_bias_input(bias, grouped, g, n))
    if scale is not None:
        sspec, scale1d = _scale_spec_and_input(scale, interpret)
        in_specs.append(sspec)
        inputs.append(scale1d)
    row_spec = pl.BlockSpec(lead + (bm, 1), _im(lambda i, j, kk: (i, 0)))
    for nm, x in zip(ep_def.extra_operands, extras):
        in_specs.append(row_spec if nm in ep_def.row_operands else mn_spec)
        inputs.append(x)

    scratch = [pltpu.VMEM((bm, bn), acc_dtype)] if pltpu else [
        pl.BlockSpec(memory_space=pl.ANY)
    ]

    codec = layout.codec if layout is not None else None
    sub_byte = codec is not None and codec.elems_per_byte > 1
    kernel = make_gemm_kernel(
        spec=spec,
        epilogue=epilogue,
        nk=grid[-1],
        k_rem=plan.k_rem,
        acc_dtype=acc_dtype,
        b_codec=codec.name if sub_byte else None,
        b_rows=layout.bk if sub_byte else None,
    )

    kwargs = {}
    params = _compiler_params(interpret, grid_rank=len(grid))
    if params is not None:
        kwargs["compiler_params"] = params

    out_shape = ((g, m, n) if grouped else (m, n))
    with obs.span("gemm.launch", bytes=plan.hbm_bytes, flops=plan.flops,
                  m=m, n=n, k=k, g=g, grid=str(grid), **launch_labels):
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=in_specs,
            out_specs=mn_spec,
            out_shape=jax.ShapeDtypeStruct(out_shape, out_dtype),
            scratch_shapes=scratch,
            interpret=interpret,
            **kwargs,
        )(*inputs)


# --- public wrappers (argument -> spec adapters) -----------------------------

def mpgemm_pallas(
    a: jax.Array,
    b: Optional[jax.Array] = None,
    c: Optional[jax.Array] = None,
    *,
    b_packed: Optional[PackedOperand] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    out_dtype=None,
    plan: Optional[GemmPlan] = None,
    interpret: bool = False,
) -> jax.Array:
    """out = tail(alpha * op(a) @ op(b) * scale + bias) + beta * c.

    ``tail`` is the registry epilogue: ``activation`` alone selects the
    linear family; ``gate`` selects the gated fusion (``act(acc) · gate``,
    the SwiGLU/GeGLU step in one launch); ``residual`` the residual-add
    fusion (``act(acc) + residual``).  ``gate``/``residual`` are (M, N)
    operands streamed per output block.

    ``b_packed`` replaces ``b`` with a pre-packed operand (repro.packing):
    the kernel reads the (bk, bn)-tiled payload through identity index
    maps — no strided DMA, no on-the-fly transposition (it was resolved at
    pack time), and for int8 payloads the per-tile dequant rides the
    accumulation.  ``b_sparse`` replaces ``b`` with a tile-sparse operand
    (repro.sparse): only the stored tiles are visited — the grid's K axis
    becomes the stored-tile schedule, steered by scalar-prefetched index
    maps.  ``b``/``b_packed``/``b_sparse`` are mutually exclusive, and the
    pre-packed forms exclude ``trans_b`` (resolved at pack/sparsify time).
    """
    b, b_packed, b_sparse = resolve_b_operand(
        "mpgemm_pallas", b, b_packed, b_sparse)
    layout = (b_packed.layout if b_packed is not None
              else b_sparse.layout if b_sparse is not None else None)
    if layout is not None and layout.g != 1:
        raise ValueError("grouped payload: use mpgemm_grouped_pallas")
    epilogue, extras = _resolve_epilogue(
        activation, alpha, beta, bias, scale, gate, residual)
    spec = GemmSpec(
        grouped=False,
        packed=b_packed is not None,
        sparse=b_sparse is not None,
        tile_scaled=layout is not None and layout.per_tile_scales,
        trans_a=trans_a,
        trans_b=False if layout is not None else trans_b,
    )
    op = (b if b is not None
          else b_packed if b_packed is not None else b_sparse)
    return mpgemm_pallas_spec(
        a, op, c=c, bias=bias,
        scale=scale, extras=extras, spec=spec, epilogue=epilogue,
        out_dtype=out_dtype, plan=plan, interpret=interpret,
    )


def mpgemm_grouped_pallas(
    a: jax.Array,
    b: Optional[jax.Array] = None,
    c: Optional[jax.Array] = None,
    *,
    b_packed: Optional[PackedOperand] = None,
    b_sparse: Optional[TileSparseOperand] = None,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    gate: Optional[jax.Array] = None,
    residual: Optional[jax.Array] = None,
    out_dtype=None,
    plan: Optional[GemmPlan] = None,
    interpret: bool = False,
) -> jax.Array:
    """out[g] = tail(alpha * op(a[g]) @ op(b[g]) * scale + bias[g]) + beta*c[g].

    ``a``: (G, M, K) — or (G, K, M) under ``trans_a``; ``b``: (G, K, N) —
    or (G, N, K) under ``trans_b``; ``bias``: (G, N) or (N,) broadcast to
    every group; ``gate``/``residual``/``c``: (G, M, N); output (G, M, N).
    The G expert/batch problems share one kernel launch with the group as
    the leading (parallel) grid axis, so small per-expert GEMMs amortize
    launch and pipeline ramp-up instead of paying them G times — the
    grouped-GEMM-on-SME pattern (LOHO, Hello SME!) in TPU form.  The same
    registry epilogues as :func:`mpgemm_pallas` apply per group (the
    spec-driven factory made the grouped beta·C term free).

    ``b_packed`` replaces ``b`` with a grouped packed operand (payload
    ``(G, nkb, nnb, bk, bn)``): identity tile reads per group, transpose
    resolved at pack time, per-tile int8 dequant riding the accumulation —
    the pre-packed-expert-weights serving configuration.  ``b_sparse``
    replaces ``b`` with a grouped tile-sparse operand: the per-expert
    sparsity patterns fold into one flat stored-tile schedule, so the
    launch walks exactly the union of every expert's nonzero tiles
    (pruned experts cost nothing — the tile-sparse MoE configuration).
    """
    b, b_packed, b_sparse = resolve_b_operand(
        "mpgemm_grouped_pallas", b, b_packed, b_sparse)
    layout = (b_packed.layout if b_packed is not None
              else b_sparse.layout if b_sparse is not None else None)
    if layout is not None and layout.g == 1:
        raise ValueError("2-D payload: use mpgemm_pallas")
    epilogue, extras = _resolve_epilogue(
        activation, alpha, beta, bias, scale, gate, residual)
    spec = GemmSpec(
        grouped=True,
        packed=b_packed is not None,
        sparse=b_sparse is not None,
        tile_scaled=layout is not None and layout.per_tile_scales,
        trans_a=trans_a,
        trans_b=False if layout is not None else trans_b,
    )
    op = (b if b is not None
          else b_packed if b_packed is not None else b_sparse)
    return mpgemm_pallas_spec(
        a, op, c=c, bias=bias,
        scale=scale, extras=extras, spec=spec, epilogue=epilogue,
        out_dtype=out_dtype, plan=plan, interpret=interpret,
    )
