"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's
xla_force_host_platform_device_count trick to work.
"""
from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=`` for ``jax.make_mesh``, version-guarded.

    ``jax.sharding.AxisType`` (and the ``axis_types`` parameter) landed
    after the pinned jax 0.4.37; on older jax every mesh axis already
    behaves as ``Auto``, so omitting the argument is semantically
    identical — the guard only skips spelling out the default.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def _mesh(shape, axes):
    import numpy as np
    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} "
            "(dry-runs must set xla_force_host_platform_device_count first)")
    return jax.make_mesh(shape, axes, devices=devices,
                         **_axis_types_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires >= n devices)."""
    if multi_pod:
        return _mesh((2, n_data, n_model), ("pod", "data", "model"))
    return _mesh((n_data, n_model), ("data", "model"))


def make_tp_mesh(n: int, axis: str = "model"):
    """1-D tensor-parallel mesh over ``n`` devices.

    The mesh the sharded GEMMs in ``distributed/shard_gemm.py`` run over:
    one named axis that weight N/K shards (and MoE expert groups) are laid
    out along.  ``n = 1`` is valid and runs the same shard_map code paths
    degenerately — useful for oracle-parity tests.
    """
    return _mesh((n,), (axis,))
