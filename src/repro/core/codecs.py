"""Payload codecs — the precision ladder's storage formats.

The source paper's point is *multi-precision* GEMM: MpGEMM specializes
packing and micro-kernels per precision.  Here a precision is one
:class:`PayloadCodec` — the dtype string stored in a
``PackedLayout.dtype``, its bits-per-element (sub-byte formats pack
several elements per storage byte), the jnp storage dtype of the payload
array, and the symmetric quantization range.  Everything downstream keys
off this table:

* ``packing/layout.py`` — payload shapes / ``bits_per_element`` / tags
* ``packing/pack.py`` — encode/decode (nibble interleave, saturating cast)
* ``core/blocking.py`` / ``perf/metrics.py`` — byte pricing by bits, not
  ``dtype.itemsize`` (an int4 weight element moves half a byte of HBM)
* ``kernels/mpgemm.py`` — in-kernel decode riding the accumulation

Codecs:

``int8``
    One byte per element, per-tile symmetric scale ``amax/127``.
``int4``
    Two elements (nibbles) per byte, per-tile symmetric scale ``amax/7``.
    Packed along the K axis of the transpose-resolved (bk, bn) tile.
``fp8e4m3``
    E4M3 floating storage (``jnp.float8_e4m3fn`` via ml_dtypes when the
    installed jax exposes it; emulated uint8 bit-packing otherwise) with
    a per-tile ``amax/448`` scale and a saturating cast — e4m3fn has no
    inf, so out-of-range values clamp to +-448 instead of producing NaN.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

# e4m3fn: max finite = 1.75 * 2**8 = 448 (no inf encoding).
FP8_E4M3_MAX = 448.0

HAS_JNP_FP8 = hasattr(jnp, "float8_e4m3fn")


@dataclasses.dataclass(frozen=True)
class PayloadCodec:
    """One storage format for packed weight payloads."""

    name: str                  # the PackedLayout.dtype string
    bits: int                  # logical bits per weight element
    storage: str               # jnp dtype string of the payload array
    qmax: float                # symmetric quant range: scale = amax / qmax
    integer: bool              # int-valued payload (int dot + scale)
    # False when the format is bit-emulated on this install — the Pallas
    # kernel path can't decode it natively and callers fall back to the
    # reference unpack (XLA) path.
    kernel_native: bool = True

    @property
    def elems_per_byte(self) -> int:
        return max(1, 8 // self.bits)

    def payload_rows(self, bk: int) -> int:
        """Physical payload rows storing ``bk`` logical K rows."""
        e = self.elems_per_byte
        return (bk + e - 1) // e


CODECS: Dict[str, PayloadCodec] = {
    "int8": PayloadCodec("int8", 8, "int8", 127.0, integer=True),
    "int4": PayloadCodec("int4", 4, "int8", 7.0, integer=True),
    "fp8e4m3": PayloadCodec(
        "fp8e4m3", 8,
        "float8_e4m3fn" if HAS_JNP_FP8 else "uint8",
        FP8_E4M3_MAX, integer=False, kernel_native=HAS_JNP_FP8),
}

# CLI spellings (launch/serve.py --pack-format) -> codec names.
_ALIASES = {"fp8": "fp8e4m3", "float8_e4m3fn": "fp8e4m3",
            "float8": "fp8e4m3", "e4m3": "fp8e4m3"}


def get_codec(dtype) -> Optional[PayloadCodec]:
    """The codec for a dtype string (aliases resolve), or None for plain
    (float) dtypes."""
    if not isinstance(dtype, str):
        return None
    return CODECS.get(_ALIASES.get(dtype, dtype))


def is_codec(dtype) -> bool:
    return get_codec(dtype) is not None


def canonical_payload_dtype(dtype) -> str:
    """Normalize a payload-dtype spelling: codec names and their aliases
    pass through canonically; everything else resolves via jnp.dtype."""
    if isinstance(dtype, str):
        name = _ALIASES.get(dtype, dtype)
        if name in CODECS:
            return name
    return str(jnp.dtype(dtype))


def dtype_bits(dtype) -> int:
    """Logical bits per element — the byte-pricing primitive.  Codec
    strings use the table; plain dtypes use itemsize."""
    codec = get_codec(dtype)
    if codec is not None:
        return codec.bits
    return jnp.dtype(dtype).itemsize * 8


def dtype_bytes(dtype) -> float:
    """Bytes per element; fractional for sub-byte codecs (int4 -> 0.5).
    Whole-byte dtypes return an exact int so existing integer-arithmetic
    call sites (block lattices, DMA-row floors) are unchanged."""
    bits = dtype_bits(dtype)
    return bits // 8 if bits % 8 == 0 else bits / 8


def storage_dtype(dtype) -> jnp.dtype:
    """jnp dtype of the payload array holding elements of ``dtype``."""
    codec = get_codec(dtype)
    return jnp.dtype(codec.storage if codec is not None else dtype)


def plan_dtype(dtype) -> str:
    """The dtype string handed to the analytic planner / cache keys.
    Codec names are preserved verbatim (they ARE the namespace); plain
    dtypes canonicalize through jnp."""
    return canonical_payload_dtype(dtype)


# -- emulated e4m3 (no jnp.float8_e4m3fn on this install) ---------------------

def _e4m3_grid() -> Tuple[float, ...]:
    """The 127 non-negative finite e4m3fn magnitudes, ascending (0,
    subnormals m*2^-9, then (1+m/8)*2^(e-7) up to 448)."""
    vals = [0.0]
    for m in range(1, 8):                 # e == 0: subnormals
        vals.append(m * 2.0 ** -9)
    for e in range(1, 16):
        for m in range(8):
            if e == 15 and m == 7:        # the NaN encoding
                continue
            vals.append((1.0 + m / 8.0) * 2.0 ** (e - 7))
    return tuple(vals)


E4M3_GRID = _e4m3_grid()


def emulated_fp8_encode(x):
    """f32 (already clipped to +-448) -> uint8 e4m3fn bit codes, nearest
    magnitude on the finite grid (never the NaN code)."""
    grid = jnp.asarray(E4M3_GRID, jnp.float32)
    mag = jnp.abs(x).astype(jnp.float32)
    hi = jnp.clip(jnp.searchsorted(grid, mag), 0, len(E4M3_GRID) - 1)
    lo = jnp.clip(hi - 1, 0, len(E4M3_GRID) - 1)
    nearer_lo = (mag - grid[lo]) <= (grid[hi] - mag)
    code = jnp.where(nearer_lo, lo, hi).astype(jnp.uint8)
    sign = (x < 0).astype(jnp.uint8) << 7
    return code | sign


def emulated_fp8_decode(codes):
    """uint8 e4m3fn bit codes -> f32 values."""
    grid = jnp.asarray(E4M3_GRID, jnp.float32)
    mag = grid[jnp.clip(codes & 0x7F, 0, len(E4M3_GRID) - 1)]
    return jnp.where((codes >> 7) != 0, -mag, mag)
