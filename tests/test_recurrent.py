"""Recurrent substrates vs naive step-by-step oracles: the chunked,
checkpointed scans must match an explicit python-loop recurrence exactly
(same math, different scheduling)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models.recurrent import (
    _causal_conv, _chunk_scan, _wkv_step, init_rglru, init_rwkv, rglru_scan,
    rwkv_fwd, rwkv_decode, rwkv_init_cache,
)


def test_chunk_scan_equals_plain_scan(rng):
    xs = jnp.asarray(rng.standard_normal((37, 4)), "float32")  # T % chunk != 0

    def step(c, x):
        c = 0.9 * c + x
        return c, c * 2.0

    c0 = jnp.zeros((4,))
    c_ref, ys_ref = jax.lax.scan(step, c0, xs)
    c_out, ys_out = _chunk_scan(step, c0, xs, chunk=8)
    np.testing.assert_allclose(np.asarray(c_out), np.asarray(c_ref), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ys_out), np.asarray(ys_ref), rtol=1e-6)


def test_wkv_matches_naive_loop(rng):
    b, t, h, dh = 2, 12, 2, 4
    r, k, v = [jnp.asarray(rng.standard_normal((t, b, h, dh)), "float32")
               for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.5, 0.99, (t, b, h, dh)), "float32")
    u = jnp.asarray(rng.standard_normal((h, dh)), "float32")
    state = jnp.zeros((b, h, dh, dh))

    # naive python-loop recurrence
    s_np = np.zeros((b, h, dh, dh), np.float32)
    outs = []
    for tt in range(t):
        kv = np.asarray(k[tt])[..., :, None] * np.asarray(v[tt])[..., None, :]
        att = s_np + np.asarray(u)[..., :, None] * kv
        outs.append(np.einsum("bhk,bhkv->bhv", np.asarray(r[tt]), att))
        s_np = np.asarray(w[tt])[..., :, None] * s_np + kv

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        return _wkv_step(s, (r_t, k_t, v_t, w_t, u))

    s_out, outs_jax = _chunk_scan(step, state, (r, k, v, w), chunk=5)
    np.testing.assert_allclose(np.asarray(outs_jax), np.stack(outs),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_out), s_np, rtol=1e-5, atol=1e-5)


def test_rwkv_prefill_decode_state_equivalence(rng):
    """Processing [x0..x7] as prefill must equal 8 single-token decodes."""
    cfg = cb.get("rwkv6-1.6b", smoke=True)
    params = init_rwkv(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((1, 8, cfg.d_model)), "float32") \
        .astype(jnp.bfloat16)
    ctx = {"cfg": cfg, "policy": "fp32", "collect_cache": True,
           "cache_dtype": jnp.float32}
    y_full, _, cache_full = rwkv_fwd(params, x, ctx)

    cache = rwkv_init_cache(cfg, 1, 8, dtype=jnp.float32)
    ys = []
    for tt in range(8):
        y_t, cache = rwkv_decode(params, x[:, tt:tt + 1],
                                 cache, {"cfg": cfg, "policy": "fp32"})
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step, np.float32),
                               np.asarray(y_full, np.float32),
                               atol=5e-2, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(cache_full["state"]),
                               atol=2e-2, rtol=2e-2)


def test_causal_conv_matches_numpy(rng):
    b, t, w, kw = 2, 10, 6, 4
    x = jnp.asarray(rng.standard_normal((b, t, w)), "float32")
    cw = jnp.asarray(rng.standard_normal((kw, w)), "float32")
    cb_ = jnp.zeros((w,))
    out, state = _causal_conv(x, cw, cb_)
    xp = np.concatenate([np.zeros((b, kw - 1, w), np.float32),
                         np.asarray(x)], axis=1)
    ref = sum(xp[:, i:i + t] * np.asarray(cw[i]) for i in range(kw))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(state), xp[:, -(kw - 1):])


def test_rglru_scan_matches_naive(rng):
    cfg = cb.get("recurrentgemma-2b", smoke=True)
    params = init_rglru(jax.random.PRNGKey(1), cfg)
    w = cfg.lru_width
    u = jnp.asarray(rng.standard_normal((2, 9, w)), "float32")
    h0 = jnp.zeros((2, w))
    hs, h_last = rglru_scan(params, u, h0)

    # naive recurrence with the same gate math
    r = jax.nn.sigmoid(u @ params["w_gate_r"])
    i = jax.nn.sigmoid(u @ params["w_gate_i"])
    log_a = -8.0 * jax.nn.softplus(params["lambda_p"])[None, None] * r
    a = np.asarray(jnp.exp(log_a))
    scale = np.asarray(jnp.sqrt(jnp.maximum(1 - jnp.exp(2 * log_a), 1e-12)))
    gx = scale * np.asarray(i) * np.asarray(u)
    h = np.zeros((2, w), np.float32)
    ref = []
    for tt in range(9):
        h = a[:, tt] * h + gx[:, tt]
        ref.append(h.copy())
    np.testing.assert_allclose(np.asarray(hs, np.float32),
                               np.stack(ref, 1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), ref[-1], rtol=1e-4,
                               atol=1e-5)
