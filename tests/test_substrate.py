"""Data pipeline, checkpointing, fault tolerance, gradient compression,
serving engine."""
import os

import numpy as np
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointing import Checkpointer
from repro.configs import base as cb
from repro.data.pipeline import SyntheticLM
from repro.distributed.collectives import dequantize_grad, quantize_grad_int8
from repro.distributed.fault_tolerance import (
    FailureEvent, StragglerDetector, plan_elastic_mesh, simulate_failures,
)
from repro.models.transformer import build_model
from repro.serve.engine import Request, ServeEngine


# --- data pipeline -------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    p1 = SyntheticLM(1000, 4, 16, seed=7)
    batches = [p1.next_batch() for _ in range(5)]
    snap = p1.snapshot()
    later = [p1.next_batch() for _ in range(3)]

    p2 = SyntheticLM(1000, 4, 16, seed=7)
    p2.restore(snap)
    resumed = [p2.next_batch() for _ in range(3)]
    for a, b in zip(later, resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # and from scratch, identical stream
    p3 = SyntheticLM(1000, 4, 16, seed=7)
    np.testing.assert_array_equal(p3.next_batch()["tokens"],
                                  batches[0]["tokens"])


def test_pipeline_tokens_in_range():
    p = SyntheticLM(512, 8, 64, seed=3)
    t = p.next_batch()["tokens"]
    assert t.min() >= 0 and t.max() < 512
    assert t.shape == (8, 65)


def test_pipeline_host_slice():
    p = SyntheticLM(512, 8, 16, seed=3)
    b = p.next_batch()
    s0 = p.host_slice(b, 0, 4)
    s3 = p.host_slice(b, 3, 4)
    assert s0["tokens"].shape == (2, 17)
    np.testing.assert_array_equal(s3["tokens"], b["tokens"][6:8])


# --- checkpointing -------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                       "c": [jnp.zeros((2, 2)), jnp.full((3,), 7)]}}
    ck.save(10, tree, extra={"pipeline": {"seed": 1, "step": 10}})
    restored, manifest = ck.restore(tree)
    assert manifest["step"] == 10
    assert manifest["extra"]["pipeline"]["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        ck.save(step, {"x": jnp.ones((2,)) * step})
    assert ck.list_steps() == [3, 4]
    restored, m = ck.restore({"x": jnp.zeros((2,))})
    assert m["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["x"]), [4.0, 4.0])


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"x": jnp.ones((128, 128))}, blocking=False)
    ck.wait()
    assert ck.latest_step() == 5


# --- fault tolerance ------------------------------------------------------

def test_straggler_detector():
    det = StragglerDetector(factor=2.0, patience=2)
    verdicts = [det.observe(t) for t in
                [1.0, 1.0, 1.0, 5.0, 5.0, 1.0, 1.0]]
    assert verdicts[3] == "suspect"
    assert verdicts[4] == "remesh"
    assert verdicts[5] == "ok"


def test_elastic_mesh_plan():
    assert plan_elastic_mesh(256) == (16, 16)
    assert plan_elastic_mesh(255) == (15, 16)   # one node lost
    assert plan_elastic_mesh(15) is None


def test_simulate_failures_recovers():
    saved = {"step": 0}
    work = []

    def run_step(step):
        work.append(step)
        return 1.0

    log = simulate_failures(
        run_step, total_steps=20,
        events=[FailureEvent(step=7, kind="crash"),
                FailureEvent(step=12, kind="straggle", magnitude=10)],
        checkpoint_every=5,
        save=lambda s: saved.update(step=s),
        restore=lambda: saved["step"])
    assert ("crash->restore" in {k for _, k in log})
    assert max(work) == 19                      # completed despite crash
    assert work.count(5) >= 2                   # steps 5-6 replayed


# --- gradient compression -------------------------------------------------

@hp.given(st.lists(st.floats(-10, 10, allow_nan=False), min_size=4,
                   max_size=64))
@hp.settings(max_examples=50, deadline=None)
def test_grad_compression_error_feedback(vals):
    g = jnp.asarray(np.array(vals, np.float32))
    err = jnp.zeros_like(g)
    q, scale, err2 = quantize_grad_int8(g, err)
    deq = dequantize_grad(q, scale)
    # quantization error bounded by scale/2 per element
    assert float(jnp.max(jnp.abs(deq - g))) <= float(scale) * 0.5 + 1e-6
    # error feedback carries exactly the residual
    np.testing.assert_allclose(np.asarray(deq + err2), np.asarray(g),
                               atol=1e-5)


def test_grad_compression_unbiased_over_steps():
    """With error feedback, the SUM of dequantized grads tracks the true
    sum (compression bias does not accumulate)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(256).astype(np.float32) * 0.01)
    err = jnp.zeros_like(g_true)
    total = jnp.zeros_like(g_true)
    for _ in range(50):
        q, s, err = quantize_grad_int8(g_true, err)
        total = total + dequantize_grad(q, s)
    np.testing.assert_allclose(np.asarray(total), np.asarray(g_true * 50),
                               atol=float(s) + 1e-4)


# --- serving -------------------------------------------------------------

def test_serve_engine_generates(rng):
    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_len=64)
    reqs = [Request(uid=i, prompt=rng.integers(2, cfg.vocab, (8,))
                    .astype(np.int32), max_new_tokens=5) for i in range(3)]
    out = eng.generate(reqs)          # 3 requests > batch 2 -> two waves
    assert set(out) == {0, 1, 2}
    for uid, toks in out.items():
        assert 1 <= len(toks) <= 5
        assert all(0 <= t < cfg.vocab + 200 for t in toks)
