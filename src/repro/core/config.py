"""Global runtime configuration for the MPGEMM op layer.

Backend dispatch:
  * ``pallas``     — real Mosaic lowering (TPU runtime).
  * ``interpret``  — Pallas interpret mode (CPU correctness tests).
  * ``xla``        — plain XLA dot_general with the same precision semantics
                     (CPU dry-runs / AOT compiles; also the fallback any time
                     a GEMM shape is degenerate).

The dry-run lowers the ``xla`` path: cost_analysis FLOPs/bytes are identical
to the kernel path, and the Mosaic kernel cannot lower to the CPU backend.
"""
from __future__ import annotations

import contextlib
import os
import threading

import jax

_state = threading.local()

_VALID = ("auto", "pallas", "interpret", "xla")


def _default_backend() -> str:
    env = os.environ.get("REPRO_GEMM_BACKEND", "auto")
    return env if env in _VALID else "auto"


def get_gemm_backend() -> str:
    backend = getattr(_state, "backend", None) or _default_backend()
    if backend == "auto":
        platform = jax.default_backend()
        backend = "pallas" if platform == "tpu" else "xla"
    return backend


@contextlib.contextmanager
def gemm_backend(name: str):
    """Context manager: force the GEMM backend (tests use ``interpret``)."""
    if name not in _VALID:
        raise ValueError(f"unknown backend {name!r}; valid: {_VALID}")
    prev = getattr(_state, "backend", None)
    _state.backend = name
    try:
        yield
    finally:
        _state.backend = prev


# --- fused-epilogue toggle ---------------------------------------------------

def fused_epilogues() -> bool:
    """Should model layers fuse gated-activation / residual epilogues?

    Default ON (the registry epilogues ride the GEMM's accumulator store —
    core/gemm_spec.py).  ``REPRO_FUSED_EPILOGUE=0`` or the
    :func:`fused_epilogue` context disable it, which the fused-vs-unfused
    benchmark (benchmarks/bench_epilogue.py) uses for its A/B.  Read at
    trace time, so functions jitted under one setting keep it.
    """
    val = getattr(_state, "fused_epilogue", None)
    if val is not None:
        return val
    return os.environ.get("REPRO_FUSED_EPILOGUE", "1").lower() not in (
        "0", "false", "off")


@contextlib.contextmanager
def fused_epilogue(enabled: bool):
    """Context manager: force epilogue fusion on/off for traces inside."""
    prev = getattr(_state, "fused_epilogue", None)
    _state.fused_epilogue = bool(enabled)
    try:
        yield
    finally:
        _state.fused_epilogue = prev
