"""Tile-sparse MPGEMM: modeled savings, the tile-visit gate, and the
wall-time-vs-density ladder.

Three measurements per paper workload (DeepSeek/LLaMA serving shapes,
benchmarks/common.PAPER_WORKLOADS):

  * ``sparse_model_*``  — density-priced roofline terms from the planner
                          (core/blocking.py ``plan_gemm(density=)``): HBM
                          bytes and FLOPs fall linearly with tile density,
                          the modeled time with them;
  * ``sparse_trace_*``  — the **tile-visit gate**: the traced jaxpr of the
                          sparse launch has grid (M/bm, schedule_len), so
                          the number of tile visits is a trace-time fact —
                          ``--smoke`` asserts it equals nnz (+ anchor
                          visits) and SHRINKS with density, proving zero
                          tiles are skipped rather than multiplied;
  * ``sparse_wall_*``   — interpret-mode wall clock on one LLaMA shape
                          across a density ladder: wall time must fall
                          monotonically as tiles are pruned (the
                          interpreter pays per grid step, so this is the
                          skipped-work signal a CPU container can see).

``--smoke`` runs the gates on reduced-M variants (the weight shapes — the
sparsified operands — stay the paper's) and exits nonzero on any gate
failure.  Set ``REPRO_SPARSE_OUT`` to also write ``sparse_report.md``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, modeled_time_s, record, wall_time_us
from repro.core.blocking import plan_gemm
from repro.core.gemm import mp_dot
from repro.obs import audit
from repro.kernels.mpgemm import mpgemm_pallas
from repro.sparse import TileSparseOperand, sparsify_magnitude

# (name, M, N, K) — LLaMA/DeepSeek serving GEMMs from the paper's Table III
# (workloads 19/21 and 5: the attention-out and MLP shapes pruning targets).
SPARSE_WORKLOADS = [
    ("llama-w19", 4096, 256, 4096),
    ("llama-w21", 4096, 256, 11008),
    ("deepseek-w5", 64, 4096, 7168),
]

DENSITIES = (1.0, 0.75, 0.5, 0.25)

# The wall ladder's tile lattice: fine enough that every density step
# changes the stored-tile count (the planner would pick one huge tile for
# these skinny-N shapes, collapsing the ladder).
WALL_BLOCKS = (512, 256)


def run(policy: str = "bfloat16", rows=None):
    """Modeled density ladder: the planner's density-priced roofline."""
    rows = rows if rows is not None else []
    for name, m, n, k in SPARSE_WORKLOADS:
        dense = plan_gemm(m, n, k, policy)
        for d in DENSITIES:
            plan = plan_gemm(m, n, k, policy, density=d)
            us = modeled_time_s(plan.flops, plan.hbm_bytes, policy) * 1e6
            rows.append(dict(name=name, m=m, n=n, k=k, density=d,
                             hbm_bytes=plan.hbm_bytes, flops=plan.flops,
                             modeled_us=us))
            emit(f"sparse_model_{name}_d{d}", us,
                 f"bytes={plan.hbm_bytes};flops={plan.flops};"
                 f"bytes_vs_dense={plan.hbm_bytes / dense.hbm_bytes:.2f}")
            record(f"sparse_model_{name}_d{d}", "sparse",
                   workload={"m": m, "n": n, "k": k, "density": d,
                             "dtype": policy},
                   metrics={"hbm_bytes": float(plan.hbm_bytes),
                            "flops": float(plan.flops),
                            "modeled_us": us,
                            "density_saving_frac":
                            1 - plan.hbm_bytes / dense.hbm_bytes})
    return rows


def _traced_tile_visits(x_shape, sp: TileSparseOperand) -> tuple:
    """(m_blocks, tile_visits) from the traced jaxpr's pallas grid."""
    x = jax.ShapeDtypeStruct(x_shape, jnp.bfloat16)

    def f(x, payload):
        op = TileSparseOperand(
            payload, None if sp.scales is None else sp.scales, sp.layout)
        return mp_dot(x, op, policy="bf16", backend="interpret")

    return audit.first_pallas_grid(audit.trace(
        f, x, jax.ShapeDtypeStruct(sp.payload.shape, sp.payload.dtype)))


def run_trace_gate(assert_gate: bool = False, m_tokens: int = 128):
    """The jaxpr proof that zero tiles are SKIPPED, not multiplied."""
    rng = np.random.default_rng(0)
    results = []
    for name, _, n, k in SPARSE_WORKLOADS:
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        visits = {}
        for d in (1.0, 0.5, 0.25):
            sp = sparsify_magnitude(w, WALL_BLOCKS, density=d,
                                    dtype="bfloat16")
            grid = _traced_tile_visits((m_tokens, k), sp)
            visits[d] = grid[-1]
            dense_tiles = sp.layout.ntiles
            emit(f"sparse_trace_{name}_d{d}", 0.0,
                 f"grid={grid};tile_visits={grid[-1]};"
                 f"dense_tiles={dense_tiles};nnz={sp.layout.nnz};"
                 f"schedule={sp.layout.schedule_len}")
            record(f"sparse_trace_{name}_d{d}", "sparse", kind="trace",
                   workload={"m": m_tokens, "n": n, "k": k, "density": d},
                   metrics={"tile_visits": float(grid[-1]),
                            "dense_tiles": float(dense_tiles),
                            "schedule_len": float(sp.layout.schedule_len)})
            if assert_gate:
                assert grid[-1] == sp.layout.schedule_len, (
                    f"{name} d={d}: traced grid visits {grid[-1]} tiles, "
                    f"schedule has {sp.layout.schedule_len} — the launch "
                    f"is not walking the stored-tile schedule")
                if d < 1.0:
                    assert grid[-1] < dense_tiles, (
                        f"{name} d={d}: {grid[-1]} visits >= dense "
                        f"{dense_tiles} — zero tiles are NOT being skipped")
        if assert_gate:
            assert visits[1.0] > visits[0.5] > visits[0.25], (
                f"{name}: tile visits {visits} not decreasing with density")
        results.append((name, visits))
    return results


def run_wall(assert_gate: bool = False, m_tokens: int = 1024,
             iters: int = 3):
    """Interpret-mode wall clock vs density on the LLaMA w19 shape.

    The JITTED interpret launch lowers the sparse grid to a scan whose
    trip count IS the stored-tile schedule, so compiled execution time
    falls with density — the CPU-visible form of "skipped tiles cost
    nothing".  M is the token batch (the pruned operand keeps the paper's
    (K, N) weight shape); the gate asserts a monotone decrease with a 5%
    slack for timer noise.
    """
    name, _, n, k = SPARSE_WORKLOADS[0]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m_tokens, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((k, n)), np.float32)
    walls = {}
    for d in (1.0, 0.5, 0.25):
        sp = sparsify_magnitude(w, WALL_BLOCKS, density=d, dtype="bfloat16")
        f = jax.jit(
            lambda x, sp=sp: mpgemm_pallas(x, sp, interpret=True))
        us = wall_time_us(f, x, iters=iters, warmup=1)
        walls[d] = us
        emit(f"sparse_wall_{name}_d{d}", us,
             f"m={m_tokens};schedule={sp.layout.schedule_len};"
             f"wall_us={us:.0f}")
        record(f"sparse_wall_{name}_d{d}", "sparse", kind="wall",
               workload={"m": m_tokens, "n": n, "k": k, "density": d},
               metrics={"schedule_len": float(sp.layout.schedule_len)},
               noisy={"wall_us": us})
    if assert_gate:
        assert walls[1.0] * 1.05 > walls[0.5] and \
            walls[0.5] * 1.05 > walls[0.25], (
                f"wall time not decreasing with density: {walls}")
        assert walls[0.25] < walls[1.0], (
            f"quarter-density not faster than dense: {walls}")
    return walls


def write_report(rows, trace, walls, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "sparse_report.md")
    lines = [
        "# Tile-sparse MPGEMM: skipped tiles, end to end",
        "",
        "Modeled terms are the planner's density-priced roofline "
        "(core/blocking.py); tile visits are trace-time facts from the "
        "sparse launch's pallas grid; wall times are CPU interpret mode "
        "(structural signal, not MXU throughput).",
        "",
        "| workload | density | HBM bytes | FLOPs | modeled us |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']} | {r['density']} | {r['hbm_bytes']:,} "
            f"| {r['flops']:,} | {r['modeled_us']:.1f} |")
    lines += ["", "## Tile-visit gate (traced grid)", ""]
    for name, visits in trace:
        lines.append(f"- **{name}**: visits "
                     + " → ".join(f"{d}: {v}" for d, v in visits.items())
                     + " (dense grid would visit every tile)")
    lines += [
        "",
        "## Wall-clock ladder (LLaMA w19 shape, interpret mode)",
        "",
        "| density | wall us |",
        "|---|---|",
    ]
    for d, us in walls.items():
        lines.append(f"| {d} | {us:.0f} |")
    lines.append("")
    with open(path, "w") as f:
        f.write("\n".join(lines))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes + hard gates: tile visits == "
                         "schedule length, shrink with density, wall "
                         "monotone (CI gate)")
    args = ap.parse_args()

    rows = run()
    trace = run_trace_gate(assert_gate=True,
                           m_tokens=128 if args.smoke else 512)
    walls = run_wall(assert_gate=True,
                     m_tokens=512 if args.smoke else 1024,
                     iters=2 if args.smoke else 3)

    out_dir = os.environ.get("REPRO_SPARSE_OUT")
    if out_dir:
        print(f"report: {write_report(rows, trace, walls, out_dir)}")


if __name__ == "__main__":
    main()
