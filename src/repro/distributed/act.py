"""Activation sharding constraints.

GSPMD's automatic propagation loses the batch sharding across scan carries
and transposes deep inside chunked attention / MoE dispatch, silently
replicating the heaviest tensors in the model (observed: 16x flop and 50x
byte blowups on the granite train cell).  Production JAX frameworks pin
activation shardings explicitly; we do the same with a thread-local ambient
mesh so model code stays mesh-agnostic (no-op when no mesh is installed —
smoke tests and single-device runs are unaffected).

Spec tokens: "batch" -> all data-parallel axes present in the mesh
(('pod','data')); "model" -> the tensor-parallel axis; None -> unsharded.
Every token is divisibility-guarded, falling back to None.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def use_mesh(mesh):
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def current_mesh():
    return getattr(_state, "mesh", None)


def _resolve(token, dim: int, mesh) -> Optional[object]:
    if token is None:
        return None
    if token == "batch":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if axes and dim % size == 0:
            return axes if len(axes) > 1 else axes[0]
        return None
    if token in mesh.axis_names and dim % mesh.shape[token] == 0:
        return token
    return None


def constrain(x, *spec):
    """with_sharding_constraint against the ambient mesh (no-op without)."""
    mesh = current_mesh()
    if mesh is None or not hasattr(x, "shape") or len(spec) != x.ndim:
        return x
    resolved = tuple(_resolve(t, d, mesh) for t, d in zip(spec, x.shape))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))
