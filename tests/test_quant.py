"""Precision-ladder subsystem: int4 nibble-packed and fp8 e4m3 scaled
payload codecs, per-token activation quantization riding the epilogue
registry (quant_in), codec-aware byte pricing, and the serving surface
(pack_params --pack-format, sweep codec layouts)."""
import math
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.blocking import plan_gemm
from repro.core.codecs import (
    FP8_E4M3_MAX, dtype_bytes, emulated_fp8_decode, emulated_fp8_encode,
    get_codec,
)
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.packing import pack_operand, pack_params, unpack_operand
from repro.perf.metrics import gemm_bytes

G, M, K, N = 4, 24, 40, 24
BLOCKS = (16, 8)

LADDER = ("int8", "int4", "fp8e4m3")
# Forward tolerance per rung, relative to |x @ w|max: 8-bit payloads round
# to 1/255 of the tile range, int4 to 1/15, e4m3 to a 3-bit mantissa.
FWD_TOL = {"int8": 0.03, "int4": 0.2, "fp8e4m3": 0.06}


@pytest.fixture
def ops(rng):
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    return x, w


def _tile_amax(w, bk, bn):
    """Per-element map of each element's (bk, bn)-tile abs-max."""
    w = np.asarray(w, np.float64)
    out = np.zeros_like(w)
    for i0 in range(0, w.shape[0], bk):
        for j0 in range(0, w.shape[1], bn):
            t = w[i0:i0 + bk, j0:j0 + bn]
            out[i0:i0 + bk, j0:j0 + bn] = np.abs(t).max()
    return out


# --- codec round trips -------------------------------------------------------

@pytest.mark.parametrize("kn", [(K, N), (33, 17), (129, 7)])
def test_int4_roundtrip_error_bound(rng, kn):
    """int4 dequant error <= half a quantization step, per tile."""
    k, n = kn
    w = jnp.asarray(rng.standard_normal((k, n)), "float32")
    p = pack_operand(w, BLOCKS, dtype="int4", backend="xla")
    assert p.layout.bits_per_element == 4
    assert p.layout.codec.qmax == 7.0
    u = np.asarray(unpack_operand(p, backend="xla"), np.float64)
    step = _tile_amax(w, *BLOCKS) / 7.0
    assert np.all(np.abs(u - np.asarray(w, np.float64)) <= step / 2 + 1e-6)


def test_int4_payload_is_nibble_packed(rng):
    """The stored payload holds TWO elements per byte along K."""
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    p = pack_operand(w, BLOCKS, dtype="int4", backend="xla")
    bk, bn = BLOCKS
    kt, nt = math.ceil(K / bk), math.ceil(N / bn)
    assert p.payload.shape == (kt, nt, bk // 2, bn)
    assert p.payload.dtype == jnp.int8
    assert p.nbytes < math.ceil(K / bk) * bk * math.ceil(N / bn) * bn


def test_fp8_roundtrip_and_saturation(rng):
    """fp8 payloads stay finite under outliers; per-tile scaling maps the
    tile amax onto the e4m3 range so nothing overflows to NaN/inf."""
    w = np.asarray(rng.standard_normal((K, N)), np.float32)
    w[3, 5] = 1e4                      # outlier: must saturate, not NaN
    w[7, 2] = -1e4
    p = pack_operand(jnp.asarray(w), BLOCKS, dtype="fp8e4m3", backend="xla")
    u = np.asarray(unpack_operand(p, backend="xla"), np.float32)
    assert np.all(np.isfinite(u))
    assert np.abs(u[3, 5] - 1e4) <= 0.1 * 1e4
    # non-outlier elements keep a few-percent relative accuracy
    mask = np.abs(w) < 100
    err = np.abs(u - w)[mask].max()
    assert err <= 0.08 * np.abs(w[mask]).max() + 1e-3


def test_emulated_fp8_codec_grid():
    """The emulated e4m3 encode/decode round-trips the finite grid and
    never emits the NaN code, even at the +-448 extremes."""
    vals = jnp.asarray([0.0, 2.0 ** -9, 0.017, 1.0, -1.5, 447.9,
                        FP8_E4M3_MAX, -FP8_E4M3_MAX], jnp.float32)
    dec = emulated_fp8_decode(emulated_fp8_encode(vals))
    assert bool(jnp.all(jnp.isfinite(dec)))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(vals),
                               rtol=0.07, atol=2.0 ** -10)
    assert float(dec[-2]) == FP8_E4M3_MAX
    assert float(dec[-1]) == -FP8_E4M3_MAX


@pytest.mark.parametrize("codec", LADDER)
def test_all_zero_tile_guard(codec):
    """An all-zero weight packs to zero payload/scales and dequantizes to
    exact zeros — the amax guard must not divide by zero (NaN parity with
    the int8 rung)."""
    w = jnp.zeros((K, N), jnp.float32)
    p = pack_operand(w, BLOCKS, dtype=codec, backend="xla")
    u = np.asarray(unpack_operand(p, backend="xla"), np.float32)
    assert np.all(np.isfinite(np.asarray(p.scales, np.float32)))
    assert np.all(u == 0.0)
    x = jnp.ones((M, K), jnp.bfloat16)
    y = np.asarray(mp_dot(x, p, policy="bf16", backend="interpret"),
                   np.float32)
    assert np.all(y == 0.0)


# --- packed forward parity across the ladder ---------------------------------

@pytest.mark.parametrize("backend", ["interpret", "xla"])
@pytest.mark.parametrize("policy", ["bf16", "int8"])
@pytest.mark.parametrize("codec", LADDER)
def test_packed_codec_forward_parity(ops, codec, policy, backend):
    x, w = ops
    p = pack_operand(w, BLOCKS, dtype=codec, backend="xla")
    y = np.asarray(mp_dot(x.astype(jnp.bfloat16), p, policy=policy,
                          backend=backend), np.float32)
    ref = np.asarray(x) @ np.asarray(w)
    tol = FWD_TOL[codec] + (0.03 if policy == "int8" else 0.0)
    assert np.abs(y - ref).max() <= tol * np.abs(ref).max()


@pytest.mark.parametrize("codec", ["int4", "fp8e4m3"])
def test_grouped_packed_codec_parity(rng, codec):
    x = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    p = pack_operand(w, BLOCKS, dtype=codec, backend="xla")
    y = np.asarray(mp_dot_grouped(x.astype(jnp.bfloat16), p, policy="bf16",
                                  backend="interpret"), np.float32)
    ref = np.einsum("gmk,gkn->gmn", np.asarray(x), np.asarray(w))
    assert np.abs(y - ref).max() <= FWD_TOL[codec] * np.abs(ref).max()


# --- gradients: float0 freeze + straight-through -----------------------------

@pytest.mark.parametrize("codec", ["int4", "fp8e4m3"])
def test_packed_codec_vjp_frozen_payload(ops, codec):
    """dx flows; the payload cotangent is symbolically zero (float0) just
    like the int8 rung — serving weights are frozen."""
    x, w = ops
    p = pack_operand(w, BLOCKS, dtype=codec, backend="xla")
    dx, dp = jax.grad(
        lambda x, p: jnp.sum(
            mp_dot(x, p, policy="bf16", backend="interpret") ** 2),
        (0, 1), allow_int=True)(x.astype(jnp.bfloat16), p)
    assert bool(jnp.all(jnp.isfinite(dx))) and float(jnp.abs(dx).sum()) > 0
    assert dp.payload.dtype == jax.dtypes.float0
    assert float(jnp.abs(dp.scales).sum()) == 0.0


def test_int4_ste_grad_contracts_dequantized_weight(ops):
    """The STE backward contracts dy against the DEQUANTIZED payload —
    exact parity with the dense twin built by unpack_operand."""
    x, w = ops
    p = pack_operand(w, BLOCKS, dtype="int4", backend="xla")
    wd = unpack_operand(p, backend="xla")       # the dense twin
    dx1 = jax.grad(lambda x: jnp.sum(
        mp_dot(x, p, policy="fp32", backend="interpret")))(x)
    dx0 = jax.grad(lambda x: jnp.sum(
        mp_dot(x, wd, policy="fp32", backend="interpret")))(x)
    np.testing.assert_allclose(np.asarray(dx1), np.asarray(dx0),
                               atol=1e-4 * max(1.0,
                                               float(jnp.abs(dx0).max())))


# --- activation quantization (quant_in epilogues) ----------------------------

def _row_quant_ref(x, w):
    """Per-row activation quantization; the dense path ALSO per-tensor
    quantizes the float weight so the fused dot runs int8 x int8."""
    xf = np.asarray(x, np.float32)
    rs = np.maximum(np.abs(xf).max(axis=-1, keepdims=True), 1e-8) / 127.0
    xq = np.clip(np.round(xf / rs), -127, 127)
    wf = np.asarray(w, np.float32)
    sw = max(np.abs(wf).max(), 1e-8) / 127.0
    wq = np.clip(np.round(wf / sw), -127, 127)
    return (xq @ wq) * rs * sw


def test_quant_in_forward_matches_row_quant_reference(ops):
    x, w = ops
    y = np.asarray(mp_dot(x, w, policy="fp32", backend="interpret",
                          quant_in=True), np.float32)
    np.testing.assert_allclose(y, _row_quant_ref(x, w), rtol=1e-5,
                               atol=1e-4)


def test_quant_in_with_activation_and_residual(ops, rng):
    x, w = ops
    res = jnp.asarray(rng.standard_normal((M, N)), "float32")
    y = np.asarray(mp_dot(x, w, policy="fp32", backend="interpret",
                          quant_in=True, activation="relu", residual=res),
                   np.float32)
    ref = np.maximum(_row_quant_ref(x, w), 0.0) + np.asarray(res)
    np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("codec", LADDER)
def test_quant_in_over_packed_codecs(ops, codec):
    """The fused pre-stage composes with every payload rung."""
    x, w = ops
    p = pack_operand(w, BLOCKS, dtype=codec, backend="xla")
    y = np.asarray(mp_dot(x, p, policy="bf16", backend="interpret",
                          quant_in=True), np.float32)
    ref = np.asarray(x) @ np.asarray(w)
    tol = FWD_TOL[codec] + 0.02         # + the per-row activation rounding
    assert np.abs(y - ref).max() <= tol * np.abs(ref).max()


def test_quant_in_grad_is_straight_through(ops):
    """No activation: the quantizer backward is the identity, so dx equals
    the unquantized GEMM's gradient exactly (contraction against w)."""
    x, w = ops
    dx = jax.grad(lambda x: jnp.sum(
        mp_dot(x, w, policy="fp32", backend="interpret", quant_in=True)))(x)
    ref = np.ones((M, N), np.float32) @ np.asarray(w).T
    np.testing.assert_allclose(np.asarray(dx), ref, rtol=1e-5, atol=1e-4)


def test_quant_in_rejects_bias(ops):
    x, w = ops
    with pytest.raises(ValueError):
        mp_dot(x, w, jnp.zeros((N,), jnp.float32), policy="fp32",
               backend="interpret", quant_in=True)


@pytest.mark.parametrize("codec", [None, "int4"])
def test_quant_in_is_single_launch(ops, codec):
    """quantize -> GEMM -> dequant(+act) is ONE Pallas launch, dense and
    nibble-packed alike (the int4 decode rides the same kernel)."""
    from repro.obs import audit
    x, w = ops
    b = w if codec is None else pack_operand(w, BLOCKS, dtype=codec,
                                             backend="xla")
    jaxpr = audit.trace(
        lambda x, b: mp_dot(x, b, policy="bf16", backend="interpret",
                            quant_in=True, activation="silu"),
        x.astype(jnp.bfloat16), b)
    assert audit.count_pallas(jaxpr) == 1


# --- byte pricing ------------------------------------------------------------

# Paper Table III rows the bench gates on: DeepSeek decode / DeepSeek
# prefill / LLaMA decode.
PRICING_WORKLOADS = [(1, 64, 2112, 7168), (13, 4096, 2112, 7168),
                     (19, 4096, 256, 4096)]


@pytest.mark.parametrize("wid,m,n,k", PRICING_WORKLOADS)
def test_gemm_bytes_prices_sub_byte_payloads(wid, m, n, k):
    """Hand-computed K-innermost revisiting traffic: the int4 B term costs
    0.5 bytes/element, everything else is unchanged."""
    bm, bn = 128, 256
    a_b, out_b = 2.0, 2.0               # bf16 activations and output
    col, row = math.ceil(n / bn), math.ceil(m / bm)

    def expected(b_bytes):
        return int(m * k * a_b * col + k * n * b_bytes * row
                   + m * n * out_b)

    for codec, b_bytes in (("int8", 1.0), ("int4", 0.5), ("fp8e4m3", 1.0)):
        got = gemm_bytes(m, n, k, bm, bn, a_dtype="bfloat16",
                         b_dtype=codec, out_dtype="bfloat16")
        assert got == expected(b_bytes), (wid, codec)
    assert dtype_bytes("int4") == 0.5


@pytest.mark.parametrize("wid,m,n,k", PRICING_WORKLOADS)
def test_int4_weight_term_halves(wid, m, n, k):
    """The acceptance ratio: int4's per-call weight stream is exactly half
    int8's payload term (<= 0.55x with scale overhead) on the gated
    workloads."""
    from benchmarks.bench_quant import weight_stream_bytes
    plan8 = plan_gemm(m, n, k, "bfloat16", "int8")
    plan4 = plan_gemm(m, n, k, "bfloat16", "int4")
    wb8 = weight_stream_bytes(n, k, "int8", plan8.bk, plan8.bn)
    wb4 = weight_stream_bytes(n, k, "int4", plan4.bk, plan4.bn)
    assert wb4 <= 0.55 * wb8


# --- serving surface ---------------------------------------------------------

@pytest.mark.parametrize("fmt,payload_dtype,bits", [
    ("int4", "int8", 4), ("fp8", None, 8), ("int8", "int8", 8)])
def test_pack_params_pack_format(rng, fmt, payload_dtype, bits):
    params = {"head": jnp.asarray(rng.standard_normal((K, N)), "float32")}
    packed = pack_params(params, policy="bf16", m_hint=M, cache=None,
                         pack_format=fmt)
    leaf = packed["head"]
    assert leaf.layout.bits_per_element == bits
    if payload_dtype is not None:
        assert str(leaf.payload.dtype) == payload_dtype
    assert leaf.layout.per_tile_scales
    u = np.asarray(unpack_operand(leaf, backend="xla"), np.float32)
    ref = np.asarray(params["head"])
    assert np.abs(u - ref).max() <= {4: 0.15, 8: 0.08}[bits] \
        * np.abs(ref).max()


def test_pack_params_rejects_unknown_format(rng):
    params = {"head": jnp.asarray(rng.standard_normal((K, N)), "float32")}
    with pytest.raises(ValueError, match="pack_format"):
        pack_params(params, policy="bf16", cache=None,
                    pack_format="bfloat16")


def test_sweep_enumerates_and_warms_codec_layouts():
    from repro.perf.sweep import (
        LAYOUTS, enumerate_shipped_combos, verify_warm, warm_plan_cache,
    )
    from repro.tuning.plan_cache import PlanCache
    assert "packed_int4" in LAYOUTS and "packed_fp8" in LAYOUTS
    combos = enumerate_shipped_combos(["granite-moe-1b-a400m"],
                                      m_tokens=(32,), smoke=True)
    by_layout = {lay: [c for c in combos if c.layout == lay]
                 for lay in LAYOUTS}
    assert by_layout["packed_int4"] and by_layout["packed_fp8"]
    assert all("b=int4" in c.key and "int4" in c.key.split("lay=")[1]
               for c in by_layout["packed_int4"])
    assert all("b=fp8e4m3" in c.key for c in by_layout["packed_fp8"])
    with tempfile.TemporaryDirectory() as d:
        cache = PlanCache(os.path.join(d, "plans.json"))
        warm_plan_cache(combos, cache, mode="modeled")
        assert verify_warm(combos, cache) == []


def test_codec_registry_shape():
    for name in LADDER:
        c = get_codec(name)
        assert c is not None and c.name == name
        assert c.bits in (4, 8)
    assert get_codec("bfloat16") is None
    assert get_codec("fp8") is not None          # alias resolves
