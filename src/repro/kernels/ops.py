"""Jit'd public wrappers for the Pallas kernels in this package."""
from __future__ import annotations

import functools
import logging
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import mpgemm as _mpgemm
from repro.kernels import ref as _ref

_log = logging.getLogger(__name__)
_FALLBACKS_LOGGED = set()


def _note_fallback(op: str, reason: str) -> None:
    """Log the FIRST implicit reference fallback per op; later ones are
    silent (the wrapper is jit'd — this fires at trace time, so a hot loop
    never spams the log).  Every fallback lands in the registry under its
    reason string, so the rate stays observable after the log goes quiet."""
    obs.counter_inc("kernel_fallback_total",
                    help="implicit XLA-reference fallbacks by reason",
                    op=op, reason=reason)
    if op not in _FALLBACKS_LOGGED:
        _FALLBACKS_LOGGED.add(op)
        _log.warning(
            "%s: tracing the XLA reference path instead of the Pallas "
            "kernel (%s)", op, reason)


def flash_attention_fallback_reason(
    q_dtype, k_dtype, v_dtype, *, interpret: bool, backend: str,
) -> Optional[str]:
    """Why :func:`flash_attention` will trace the XLA reference instead of
    the Pallas kernel — None means the kernel path is taken.

    The predicate is deliberately public: callers (and tests) can ask it
    BEFORE tracing, and the wrapper's dispatch uses exactly this function,
    so the answer can never drift from the behavior.
    """
    if backend == "xla":
        return "backend='xla' requested"
    for name, dt in (("q", q_dtype), ("k", k_dtype), ("v", v_dtype)):
        if not jnp.issubdtype(jnp.dtype(dt), jnp.floating):
            return (f"non-float {name} dtype {jnp.dtype(dt).name} "
                    "(the kernel's online softmax needs float operands)")
    if not interpret:
        from repro.kernels import flash_attention as _fa_mod
        if _fa_mod.pltpu is None:
            return "Pallas TPU backend unavailable and interpret=False"
    return None


@functools.partial(
    jax.jit,
    static_argnames=(
        "trans_a", "trans_b", "alpha", "beta", "activation", "out_dtype",
        "interpret", "backend",
    ),
)
def mpgemm(
    a,
    b,
    c=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias=None,
    scale=None,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
    backend: str = "pallas",
):
    """out = activation(alpha * op(a)·op(b) * scale + bias) + beta*c."""
    if backend == "xla":
        return _ref.mpgemm_ref(
            a, b, c, trans_a=trans_a, trans_b=trans_b, alpha=alpha, beta=beta,
            bias=bias, scale=scale, activation=activation, out_dtype=out_dtype,
        )
    return _mpgemm.mpgemm_pallas(
        a, b, c, trans_a=trans_a, trans_b=trans_b, alpha=alpha, beta=beta,
        bias=bias, scale=scale, activation=activation, out_dtype=out_dtype,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret", "backend"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window=None,
    scale=None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    backend: str = "pallas",
):
    """Blocked online-softmax attention; q (B,H,Tq,D), k/v (B,Hkv,Tk,D).

    Dispatch is explicit: :func:`flash_attention_fallback_reason` decides
    whether this call traces the Pallas kernel or the XLA reference, and an
    IMPLICIT fallback (anything other than ``backend="xla"``) is logged
    once per process.
    """
    if q.shape[1] % k.shape[1]:
        raise ValueError(
            f"GQA requires H % Hkv == 0, got {q.shape[1]} % {k.shape[1]}")
    reason = flash_attention_fallback_reason(
        q.dtype, k.dtype, v.dtype, interpret=interpret, backend=backend)
    if reason is not None:
        if backend != "xla":
            _note_fallback("flash_attention", reason)
        kr = jnp.repeat(k, q.shape[1] // k.shape[1], axis=1)
        vr = jnp.repeat(v, q.shape[1] // v.shape[1], axis=1)
        return _ref.flash_attention_ref(q, kr, vr, causal=causal,
                                        window=window, scale=scale)
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, window=window, scale=scale,
               block_q=block_q, block_k=block_k, interpret=interpret)
