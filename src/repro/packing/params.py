"""Pack model parameter trees at load time — the serving-side entry point.

``pack_params`` walks a parameter pytree (the same walk discipline as
``core/quantization.py::quantize_params``) and replaces every eligible GEMM
weight with its :class:`PackedOperand` form, chosen to match the serving
policy:

    policy fp32 / bf16 / bf16_serve  ->  float payload in the policy's
                                         compute dtype (the per-call
                                         down-cast disappears)
    policy int8                      ->  int8 payload + per-tile scales
                                         (finer than quantize_params'
                                         per-tensor scheme; the dequant
                                         rides the GEMM per tile)

Eligibility reuses ``quantization.QUANT_LEAVES`` (2-D+ GEMM operands;
embeddings and router/norm/gate leaves stay dense).  Three structural
cases, disambiguated by where the leaf sits:

* plain 2-D weight (tail layers, the untied head)      -> 2-D pack
* scanned-stack leaf (leading layer axis under "stack"/"encoder")
      -> per-layer vmapped pack; the payload keeps the leading layer axis
         and ``lax.scan`` slices it away, so every in-scan ``mp_dot`` sees
         an ordinary 2-D PackedOperand
* MoE expert weight (trailing 3-D (E, d, f))           -> grouped pack
  (stacked MoE combines both: leading layer axis + grouped payload)

Every pack goes through the process-global :class:`PackedWeightCache`
(``REPRO_PACK_CACHE``), so repeated serve starts reuse packed payloads.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.blocking import plan_gemm
from repro.core.policy import get_policy
from repro.core.codecs import CODECS, canonical_payload_dtype, get_codec
from repro.core.quantization import QUANT_LEAVES
from repro.packing.cache import PackedWeightCache, get_pack_cache
from repro.packing.layout import PackedOperand
from repro.packing.pack import pack_operand

# Leaves that are grouped (expert-batched) when their trailing rank is 3.
MOE_GROUPED_LEAVES = frozenset({"w_gate", "w_up", "w_down"})

# Parameter-tree roots whose leaves carry a leading scanned-layer axis.
STACKED_PREFIXES = ("stack", "encoder")


def _payload_dtype(policy) -> str:
    return "int8" if policy.quantized else str(jnp.dtype(policy.compute_dtype))


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", "")))


def _is_stacked(path) -> bool:
    first = path[0] if path else None
    return str(getattr(first, "key", "")) in STACKED_PREFIXES


def _path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", getattr(p, "idx", "?"))) for p in path)


def pack_params(
    params,
    *,
    policy="bf16",
    m_hint: int = 256,
    backend: Optional[str] = None,
    cache: Optional[PackedWeightCache] = None,
    leaves: Optional[Sequence[str]] = None,
    pack_format: Optional[str] = None,
):
    """Replace eligible GEMM weights in ``params`` with packed operands.

    ``m_hint`` seeds the block planner's M dimension (the activation-side
    extent packing cannot know ahead of time — bn/bk, the axes the payload
    layout pins, are driven by (N, K, dtype), so the hint only nudges bm
    which stays free at call time anyway).  Run this on the UNQUANTIZED
    checkpoint: under the int8 policy the pack itself performs (per-tile)
    quantization, strictly finer than ``quantize_params``.

    ``pack_format`` overrides the payload codec on the precision ladder —
    ``"int8"`` / ``"int4"`` / ``"fp8"`` (any ``core.codecs`` alias works).
    The default keeps the policy-derived payload dtype (int8 under the
    quantized policy, the compute dtype otherwise); int4 halves the
    weight-side HBM traffic against int8 at one extra in-kernel nibble
    unpack.
    """
    policy = get_policy(policy)
    if pack_format is not None:
        dtype = canonical_payload_dtype(pack_format)
        if get_codec(dtype) is None:
            raise ValueError(
                f"pack_format {pack_format!r} is not a quantized payload "
                f"codec; valid: {sorted(CODECS)} (or their aliases)")
    else:
        dtype = _payload_dtype(policy)
    a_dtype = "int8" if policy.quantized else policy.compute_dtype
    eligible = frozenset(leaves) if leaves is not None else QUANT_LEAVES
    cache = cache if cache is not None else get_pack_cache()

    def _blocks(k: int, n: int):
        plan = plan_gemm(m_hint, n, k, a_dtype, dtype)
        return plan.bk, plan.bn

    def _pack_leaf(path, leaf):
        name = _leaf_name(path)
        if (name not in eligible or not hasattr(leaf, "ndim")
                or isinstance(leaf, PackedOperand)):
            return leaf
        if jnp.dtype(leaf.dtype).kind != "f":
            return leaf
        stacked = _is_stacked(path)
        eff_ndim = leaf.ndim - (1 if stacked else 0)
        if eff_ndim == 2:
            grouped = False
        elif eff_ndim == 3 and name in MOE_GROUPED_LEAVES:
            grouped = True
        else:
            return leaf
        k, n = leaf.shape[-2], leaf.shape[-1]
        blocks = _blocks(k, n)
        if stacked:
            # vmap over the scanned layer axis; the reference (jnp) packer
            # is the vmap-safe implementation.
            pack_fn = jax.vmap(
                lambda w: pack_operand(w, blocks, dtype=dtype, backend="xla"))
            packer = lambda w, b, **kw: pack_fn(w)  # noqa: E731
            lead = 1
        else:
            packer, lead = None, 0
        if cache is None:
            if stacked:
                return pack_fn(leaf)
            return pack_operand(leaf, blocks, dtype=dtype, backend=backend)
        return cache.get_or_pack(
            _path_str(path), leaf, blocks, dtype=dtype, backend=backend,
            pack_fn=packer, lead_axes=lead)

    return jax.tree_util.tree_map_with_path(_pack_leaf, params)


def packed_param_bytes(params) -> int:
    """Total bytes of packed payloads in a tree (serving-footprint report)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, PackedOperand)):
        if isinstance(leaf, PackedOperand):
            total += leaf.nbytes
    return total
