"""Jit'd public wrappers for the Pallas kernels in this package."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import mpgemm as _mpgemm
from repro.kernels import ref as _ref


@functools.partial(
    jax.jit,
    static_argnames=(
        "trans_a", "trans_b", "alpha", "beta", "activation", "out_dtype",
        "interpret", "backend",
    ),
)
def mpgemm(
    a,
    b,
    c=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias=None,
    scale=None,
    activation: Optional[str] = None,
    out_dtype=None,
    interpret: bool = False,
    backend: str = "pallas",
):
    """out = activation(alpha * op(a)·op(b) * scale + bias) + beta*c."""
    if backend == "xla":
        return _ref.mpgemm_ref(
            a, b, c, trans_a=trans_a, trans_b=trans_b, alpha=alpha, beta=beta,
            bias=bias, scale=scale, activation=activation, out_dtype=out_dtype,
        )
    return _mpgemm.mpgemm_pallas(
        a, b, c, trans_a=trans_a, trans_b=trans_b, alpha=alpha, beta=beta,
        bias=bias, scale=scale, activation=activation, out_dtype=out_dtype,
        interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret", "backend"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window=None,
    scale=None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
    backend: str = "pallas",
):
    """Blocked online-softmax attention; q (B,H,Tq,D), k/v (B,Hkv,Tk,D)."""
    if backend == "xla":
        kr = jnp.repeat(k, q.shape[1] // k.shape[1], axis=1)
        vr = jnp.repeat(v, q.shape[1] // v.shape[1], axis=1)
        return _ref.flash_attention_ref(q, kr, vr, causal=causal,
                                        window=window, scale=scale)
    from repro.kernels.flash_attention import flash_attention as _fa
    return _fa(q, k, v, causal=causal, window=window, scale=scale,
               block_q=block_q, block_k=block_k, interpret=interpret)
