"""Multi-device scale-out: sharded MPGEMM, collectives, fault tolerance.

The mesh is the next level of the paper's cache-aware partitioning
hierarchy.  ``shard_gemm`` runs ``mp_dot`` / ``mp_dot_grouped`` under
``shard_map`` with compute/communication overlap (ring reduce-scatter and
ring all-gather matmuls, expert-parallel all-to-all dispatch) and
per-shard planning (CMR on the local (M, N, K), tuned plans in a
``|mesh=…`` key namespace).  ``collectives`` holds compressed/hierarchical
all-reduce building blocks; ``fault_tolerance`` the straggler/elastic-mesh
contract; ``sharding`` the parameter/activation partitioning rules.

Public API: :func:`mp_dot_sharded`, :func:`mp_dot_grouped_sharded`,
:func:`shard_operand`, :func:`mesh_plan_tag`, :func:`mesh_axis_size`.
See docs/distributed.md for mesh setup and the overlap design.
"""
from repro.distributed.shard_gemm import (
    OVERLAPS, PARTITIONS, mesh_axis_size, mesh_plan_tag,
    mp_dot_grouped_sharded, mp_dot_sharded, shard_operand,
)

__all__ = [
    "OVERLAPS", "PARTITIONS", "mesh_axis_size", "mesh_plan_tag",
    "mp_dot_grouped_sharded", "mp_dot_sharded", "shard_operand",
]
