"""Packed vs on-the-fly operands on the paper's DeepSeek/LLaMA workloads.

What packing eliminates is PER-CALL operand preparation — work the
unpacked path re-does on every launch even though the weight never
changes:

  * bf16 policy: the f32 master -> bf16 compute-dtype cast (a materialized
    weight-sized copy, barrier-pinned shard-local);
  * int8 policy: per-tensor dynamic re-quantization of the static weight
    (abs/max/div/round/clip chain, all weight-sized);
  * transposed storage: strided tile DMA (the on-the-fly-transposition
    index maps read short rows instead of whole contiguous tiles).

This benchmark quantifies each on the 24 paper workloads + the MoE grouped
shapes:

  * ``prep_bytes``     — weight-sized intermediates materialized per call,
                         counted from the traced jaxpr of the jitted
                         forward (exact, shape-independent of timing noise;
                         the packed path must trace to ZERO);
  * ``dma_row_bytes``  — modeled contiguous bytes per B-side DMA row:
                         unpacked reads (bn x itemsize)-wide rows (or
                         bk-wide under trans), packed reads whole
                         (bk x bn) tiles;
  * ``breakeven``      — one-time pack traffic / per-call prep savings =
                         calls until ahead-of-time packing wins;
  * wall-clock sanity on one small shape (interpret kernel, CPU).

``--smoke`` runs 3 workloads and asserts the packed path's prep_bytes is
exactly 0 while unpacked's is > 0 (the CI regression gate).  Set
``REPRO_PACK_OUT`` to also write ``packing_report.md``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (
    MOE_GROUPED_WORKLOADS, PAPER_WORKLOADS, emit, record, wall_time_us,
)
from repro.core.blocking import plan_gemm
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.obs.audit import prep_bytes
from repro.packing import pack_operand


def _trace_m(m: int, n: int, k: int) -> int:
    """M used for TRACING only.  Weight-prep work is m-independent, but the
    size-based isolation above needs m distinct from n and k — otherwise
    x-sized ops (m*k or m*n elements) collide with the k*n weight extent
    (workload 17 has m == n == 4096)."""
    while m in (n, k):
        m += 8
    return m


def _dma_rows(plan, layout, dtype_bytes: int, trans_w: bool):
    """Modeled contiguous bytes per B-side DMA row (paper P2, four-Z loads)."""
    unpacked = (plan.bk if trans_w else plan.bn) * dtype_bytes
    packed = layout.bk * layout.bn * dtype_bytes  # whole tile contiguous
    return unpacked, packed


def _shapes(m, n, k, g=None):
    if g is None:
        return (m, k), (k, n)
    return (g, m, k), (g, k, n)


def run(policy: str = "bf16", *, smoke: bool = False, trans_w: bool = False,
        rows=None, work=None):
    """-> list of per-workload result dicts (also emitted as CSV).

    ``work`` overrides the workload list (same tuples as PAPER_WORKLOADS);
    the emit harness uses it to keep the packed-zeros footprint small.
    """
    rows = rows if rows is not None else []
    if work is None:
        work = PAPER_WORKLOADS[:3] if smoke else PAPER_WORKLOADS
    pdt = "int8" if policy == "int8" else "bfloat16"
    for wid, m, n, k in work:
        xs, ws = _shapes(_trace_m(m, n, k), n, k)
        x = jax.ShapeDtypeStruct(xs, jnp.bfloat16)
        w_shape = ws[::-1] if trans_w else ws
        w = jax.ShapeDtypeStruct(w_shape, jnp.float32)
        plan = plan_gemm(m, n, k, "bfloat16", pdt)
        # Abstract pack: layout only (tracing needs shapes, not values).
        packed = pack_operand(jnp.zeros(w_shape, jnp.float32), plan,
                              trans_w=trans_w, dtype=pdt, backend="xla")

        def unpacked_fn(x, w):
            return mp_dot(x, w, policy=policy, trans_w=trans_w,
                          backend="interpret")

        def packed_fn(x, p):
            return mp_dot(x, p, policy=policy, trans_w=trans_w,
                          backend="interpret")

        pb_un = prep_bytes(unpacked_fn, x, w, weight_elems=k * n)
        pb_pk = prep_bytes(packed_fn, x, packed, weight_elems=k * n)
        row_un, row_pk = _dma_rows(plan, packed.layout,
                                   np.dtype(pdt).itemsize, trans_w)
        pack_traffic = k * n * 4 + packed.nbytes      # read master + write payload
        breakeven = pack_traffic / max(1, pb_un)
        rows.append(dict(
            name=f"workload_{wid:02d}", policy=policy, g=1, m=m, n=n, k=k,
            trans_w=trans_w, prep_unpacked=pb_un, prep_packed=pb_pk,
            dma_row_unpacked=row_un, dma_row_packed=row_pk,
            breakeven_calls=breakeven,
        ))
        emit(f"packing_{wid:02d}_{policy}{'_t' if trans_w else ''}", 0.0,
             f"prep_bytes_per_call={pb_un}->{pb_pk};"
             f"dma_row_bytes={row_un}->{row_pk};"
             f"pack_breakeven_calls={breakeven:.2f}")
        record(f"packing_{wid:02d}_{policy}{'_t' if trans_w else ''}",
               "packing", kind="trace",
               workload={"paper_workload": wid, "m": m, "n": n, "k": k,
                         "policy": policy, "trans_w": trans_w},
               metrics={"prep_bytes_unpacked": float(pb_un),
                        "prep_bytes_packed": float(pb_pk),
                        "dma_row_bytes_unpacked": float(row_un),
                        "dma_row_bytes_packed": float(row_pk),
                        "breakeven_calls": breakeven})
    return rows


def run_grouped(policy: str = "bf16", *, smoke: bool = False, rows=None,
                work=None):
    rows = rows if rows is not None else []
    if work is None:
        work = MOE_GROUPED_WORKLOADS[:2] if smoke else MOE_GROUPED_WORKLOADS
    pdt = "int8" if policy == "int8" else "bfloat16"
    for name, g, m, n, k in work:
        xs, ws = _shapes(_trace_m(m, n, k), n, k, g)
        x = jax.ShapeDtypeStruct(xs, jnp.bfloat16)
        w = jax.ShapeDtypeStruct(ws, jnp.float32)
        plan = plan_gemm(m, n, k, "bfloat16", pdt)
        packed = pack_operand(jnp.zeros(ws, jnp.float32), plan, dtype=pdt,
                              backend="xla")

        def unpacked_fn(x, w):
            return mp_dot_grouped(x, w, policy=policy, backend="interpret")

        def packed_fn(x, p):
            return mp_dot_grouped(x, p, policy=policy, backend="interpret")

        pb_un = prep_bytes(unpacked_fn, x, w, weight_elems=g * k * n)
        pb_pk = prep_bytes(packed_fn, x, packed, weight_elems=g * k * n)
        row_un, row_pk = _dma_rows(plan, packed.layout,
                                   np.dtype(pdt).itemsize, False)
        pack_traffic = g * k * n * 4 + packed.nbytes
        breakeven = pack_traffic / max(1, pb_un)
        rows.append(dict(
            name=f"moe_{name}", policy=policy, g=g, m=m, n=n, k=k,
            trans_w=False, prep_unpacked=pb_un, prep_packed=pb_pk,
            dma_row_unpacked=row_un, dma_row_packed=row_pk,
            breakeven_calls=breakeven,
        ))
        emit(f"packing_moe_{name}_{policy}", 0.0,
             f"g={g};prep_bytes_per_call={pb_un}->{pb_pk};"
             f"dma_row_bytes={row_un}->{row_pk};"
             f"pack_breakeven_calls={breakeven:.2f}")
        record(f"packing_moe_{name}_{policy}", "packing", kind="trace",
               workload={"g": g, "m": m, "n": n, "k": k, "policy": policy},
               metrics={"prep_bytes_unpacked": float(pb_un),
                        "prep_bytes_packed": float(pb_pk),
                        "dma_row_bytes_unpacked": float(row_un),
                        "dma_row_bytes_packed": float(row_pk),
                        "breakeven_calls": breakeven})
    return rows


def run_wall_sanity():
    """CPU wall clock on one small shape through the interpret kernel:
    per-call prep is real time, not just traced bytes."""
    rng = np.random.default_rng(0)
    m, n, k = 64, 256, 512
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    plan = plan_gemm(m, n, k, "bfloat16")
    packed = pack_operand(w, plan, dtype="bfloat16", backend="interpret")
    f_un = jax.jit(lambda x, w: mp_dot(x, w, policy="bf16",
                                       backend="interpret"))
    f_pk = jax.jit(lambda x, p: mp_dot(x, p, policy="bf16",
                                       backend="interpret"))
    us_un = wall_time_us(f_un, x, w, iters=3)
    us_pk = wall_time_us(f_pk, x, packed, iters=3)
    emit("packing_wall_sanity_64x256x512_bf16", us_pk,
         f"unpacked_us={us_un:.1f};packed_us={us_pk:.1f}")
    record("packing_wall_sanity_64x256x512_bf16", "packing", kind="wall",
           workload={"m": 64, "n": 256, "k": 512},
           noisy={"unpacked_wall_us": us_un, "packed_wall_us": us_pk})
    return us_un, us_pk


def write_report(rows, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "packing_report.md")
    lines = [
        "# Packed vs on-the-fly operands",
        "",
        "Per-call weight-prep bytes are counted from the traced jaxpr of "
        "the jitted forward (weight-sized cast/quantize/transpose "
        "intermediates); the packed path must show 0.  DMA row bytes are "
        "the modeled contiguous extent per B-side read (paper P2).",
        "",
        "| workload | policy | G | M,N,K | prep B/call unpacked | packed |"
        " DMA row B | packed | break-even calls |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']}{' (trans)' if r['trans_w'] else ''} "
            f"| {r['policy']} | {r['g']} | {r['m']},{r['n']},{r['k']} "
            f"| {r['prep_unpacked']:,} | {r['prep_packed']:,} "
            f"| {r['dma_row_unpacked']:,} | {r['dma_row_packed']:,} "
            f"| {r['breakeven_calls']:.2f} |")
    zero = all(r["prep_packed"] == 0 for r in rows)
    saved = sum(r["prep_unpacked"] for r in rows)
    lines += [
        "",
        f"**Packed path materializes {'ZERO' if zero else 'NONZERO (BUG)'} "
        f"per-call weight-prep bytes**; the on-the-fly path re-materializes "
        f"{saved/2**20:.1f} MiB per call across these workloads.",
        "",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="3 workloads + hard assertions (CI gate)")
    args = ap.parse_args()

    rows = []
    for policy in ("bf16", "int8"):
        run(policy, smoke=args.smoke, rows=rows)
    run("bf16", smoke=args.smoke, trans_w=True, rows=rows)
    for policy in ("bf16", "int8"):
        run_grouped(policy, smoke=args.smoke, rows=rows)
    run_wall_sanity()

    out_dir = os.environ.get("REPRO_PACK_OUT")
    if out_dir:
        print(f"report: {write_report(rows, out_dir)}")

    # The acceptance gate: ahead-of-time packing ELIMINATES per-call
    # transposition/prep work on every workload shape.
    bad_packed = [r for r in rows if r["prep_packed"] != 0]
    no_savings = [r for r in rows if r["prep_unpacked"] <= 0]
    better_rows = [r for r in rows if r["dma_row_packed"] < r["dma_row_unpacked"]]
    if bad_packed:
        raise SystemExit(f"packed path materializes per-call prep: {bad_packed}")
    if no_savings:
        raise SystemExit(f"unpacked path shows no prep to eliminate: {no_savings}")
    if better_rows:
        raise SystemExit(f"packed DMA rows shorter than unpacked: {better_rows}")
    print(f"packing gate OK: {len(rows)} workloads, packed prep "
          f"bytes all zero")


if __name__ == "__main__":
    main()
