"""Tile sparsifiers + payload build/densify for the tile-sparse subsystem.

Sparsification is an OFFLINE weight transformation (like packing and static
quantization): the sparsity pattern must be static so it can live in the
hashable :class:`~repro.sparse.layout.TileSparseLayout` and steer a
trace-time-constant Pallas grid.  The scoring/pattern step therefore runs
on host numpy over concrete weights; the payload build is pure jnp (and
vmap-safe, for scanned layer stacks).

Two pattern families, both scored by per-tile Frobenius norm on the plan's
(bk, bn) lattice:

* :func:`sparsify_magnitude` — keep the top ``density`` fraction of tiles
  (per group, so grouped/MoE operands stay balanced across experts).
* :func:`sparsify_nm` — structured N:M over the K-tile axis: in every run
  of ``m_block`` consecutive k-tiles of one output column, keep the
  ``n_keep`` strongest.  Bounds work per column (uniform schedule depth),
  the tile-level analogue of 2:4 weight sparsity.

Both drop exactly-zero tiles unconditionally (``prune_zero``): a weight
already pruned upstream compresses at ``density=1.0`` with no accuracy
change at all.

The tiling/quantization primitives are REUSED from ``repro.packing.pack``
(``_pack_dense_ref`` / ``_quantize_tiles_ref``) — a tile-sparse payload is
a packed payload minus the zero tiles, which is what makes the two layouts
composable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import GemmPlan
from repro.packing.pack import _pack_dense_ref, _quantize_tiles_ref
from repro.sparse.layout import TileSparseLayout, TileSparseOperand


def _blocks_of(plan_or_blocks) -> Tuple[int, int]:
    if isinstance(plan_or_blocks, GemmPlan):
        return plan_or_blocks.bk, plan_or_blocks.bn
    bk, bn = plan_or_blocks
    return int(bk), int(bn)


def _core_dims(w, *, trans_w: bool, grouped: bool) -> Tuple[int, int, int]:
    shape = w.shape[1:] if grouped else w.shape
    if len(shape) != 2:
        raise ValueError(f"sparsify expects a 2-D (or grouped 3-D) operand, "
                         f"got {w.shape}")
    k, n = (shape[1], shape[0]) if trans_w else shape
    return k, n, (w.shape[0] if grouped else 1)


def tile_scores(w, blocks: Tuple[int, int], *, trans_w: bool = False
                ) -> np.ndarray:
    """Per-tile Frobenius norms on the (bk, bn) lattice: (g, nkb, nnb) f64.

    Host-side (concrete weights only) — the scores decide the STATIC
    pattern, so they can never be traced.
    """
    bk, bn = blocks
    grouped = w.ndim == 3
    k, n, g = _core_dims(w, trans_w=trans_w, grouped=grouped)
    bk, bn = min(bk, k), min(bn, n)
    arr = np.asarray(w, np.float64)
    if not grouped:
        arr = arr[None]
    if trans_w:
        arr = arr.swapaxes(-1, -2)
    nkb, nnb = -(-k // bk), -(-n // bn)
    pad = ((0, 0), (0, nkb * bk - k), (0, nnb * bn - n))
    arr = np.pad(arr, pad)
    t = arr.reshape(g, nkb, bk, nnb, bn)
    return np.sqrt((t * t).sum(axis=(2, 4)))


def _keep_to_structure(keep: np.ndarray) -> Tuple[Tuple[int, ...],
                                                  Tuple[int, ...]]:
    """(g, nkb, nnb) bool mask -> column-major BSR (indptr, indices)."""
    g, nkb, nnb = keep.shape
    indptr = [0]
    indices = []
    for gi in range(g):
        for j in range(nnb):
            col = np.nonzero(keep[gi, :, j])[0]
            indices.extend(int(kk) for kk in col)
            indptr.append(len(indices))
    return tuple(indptr), tuple(indices)


def _stored_linear_idx(layout: TileSparseLayout) -> np.ndarray:
    """(nnz,) linear indices of stored tiles into the flat (g*nkb*nnb)
    dense tile lattice, in payload (column-major) order."""
    nkb, nnb = layout.nkb, layout.nnb
    out = np.empty(layout.nnz, np.int64)
    for c in range(layout.g * nnb):
        gi, j = divmod(c, nnb)
        lo, hi = layout.indptr[c], layout.indptr[c + 1]
        for t, kk in enumerate(layout.indices[lo:hi]):
            out[lo + t] = (gi * nkb + kk) * nnb + j
    return out


def build_payload(w, layout: TileSparseLayout):
    """Stored tiles (+ trailing zero tile) for ``w`` under ``layout``.

    Pure jnp (vmap-safe — scanned stacks vmap this over their layer axis):
    tile the transpose-resolved, zero-padded weight exactly as the packer
    would, then GATHER only the stored tiles.  Returns
    ``(payload, scales | None)``; int8 payloads quantize each stored tile
    symmetrically with its own f32 scale (the trailing zero tile gets
    scale 1.0 — its value is irrelevant against all-zero data).
    """
    if layout.g != 1:
        tiles = jax.vmap(lambda x: _pack_dense_ref(x, layout))(w)
    else:
        tiles = _pack_dense_ref(w, layout)
    flat = tiles.reshape(layout.g * layout.nkb * layout.nnb,
                         layout.bk, layout.bn)
    stored = flat[jnp.asarray(_stored_linear_idx(layout))]
    zero_tile = jnp.zeros((1, layout.bk, layout.bn), jnp.float32)
    if layout.per_tile_scales:
        q, s = _quantize_tiles_ref(stored)
        payload = jnp.concatenate([q, zero_tile.astype(jnp.int8)])
        scales = jnp.concatenate([s, jnp.ones((1,), jnp.float32)])
        return payload, scales.reshape(-1, 1)
    dt = jnp.dtype(layout.dtype)
    return jnp.concatenate([stored.astype(dt), zero_tile.astype(dt)]), None


def payload_cotangent(dense_ct, layout: TileSparseLayout):
    """Mask a DENSE weight cotangent to the stored tiles (the sparse op's
    custom-VJP weight rule): gather the stored tiles of ``dense_ct``; the
    trailing zero tile is a structural constant and gets a zero cotangent.
    ``dense_ct`` is in the logical (k, n) / (g, k, n) orientation (the
    backward GEMMs resolve the transpose), so the recorded source
    transpose must not be re-applied."""
    lay = dataclasses.replace(layout, trans_w=False,
                              dtype=str(jnp.dtype(dense_ct.dtype)))
    payload, _ = build_payload(dense_ct, lay)
    return payload


def densify_operand(p: TileSparseOperand, *, dtype=None):
    """Dense (k, n) (grouped: (g, k, n)) array with zeros at pruned tiles —
    the XLA-backend fallback and the backward pass's contraction operand.
    int8 payloads dequantize per stored tile; ``dtype`` defaults to the
    payload dtype (int8: the source dtype recorded at sparsify time)."""
    layout = p.layout
    if dtype is None:
        dtype = layout.orig_dtype if layout.per_tile_scales else layout.dtype
    tiles = p.payload[: layout.nnz].astype(jnp.float32)
    if p.scales is not None:
        tiles = tiles * p.scales[: layout.nnz].reshape(-1, 1, 1)
    lattice = jnp.zeros(
        (layout.g * layout.nkb * layout.nnb, layout.bk, layout.bn),
        jnp.float32,
    ).at[jnp.asarray(_stored_linear_idx(layout))].set(tiles)
    full = lattice.reshape(
        layout.g, layout.nkb, layout.nnb, layout.bk, layout.bn
    ).transpose(0, 1, 3, 2, 4).reshape(
        layout.g, layout.nkb * layout.bk, layout.nnb * layout.bn
    )[:, : layout.k, : layout.n]
    full = full.astype(dtype)
    return full if layout.g != 1 else full[0]


# --- pattern -> operand -------------------------------------------------------

def sparsify_with_mask(
    w,
    plan_or_blocks: Union[GemmPlan, Tuple[int, int]],
    keep: np.ndarray,
    *,
    trans_w: bool = False,
    dtype=None,
) -> TileSparseOperand:
    """Build a :class:`TileSparseOperand` from an explicit tile keep-mask.

    ``keep`` is (nkb, nnb) bool — or (g, nkb, nnb) for a grouped operand —
    over the (bk, bn) tile lattice of the transpose-resolved weight.  The
    general entry point the scored sparsifiers funnel into (an externally
    computed pattern — e.g. from an upstream pruning run — plugs in here).
    """
    bk, bn = _blocks_of(plan_or_blocks)
    grouped = w.ndim == 3
    k, n, g = _core_dims(w, trans_w=trans_w, grouped=grouped)
    bk, bn = min(bk, k), min(bn, n)
    keep = np.asarray(keep, bool)
    if keep.ndim == 2:
        keep = keep[None]
    nkb, nnb = -(-k // bk), -(-n // bn)
    if keep.shape != (g, nkb, nnb):
        raise ValueError(
            f"keep mask shape {keep.shape} != tile lattice {(g, nkb, nnb)}")
    indptr, indices = _keep_to_structure(keep)
    layout = TileSparseLayout(
        k=k, n=n, bk=bk, bn=bn,
        dtype=str(jnp.dtype(dtype or w.dtype)),
        orig_dtype=str(jnp.dtype(w.dtype)),
        indptr=indptr, indices=indices, trans_w=trans_w, g=g,
    )
    payload, scales = build_payload(w, layout)
    return TileSparseOperand(payload, scales, layout)


def magnitude_mask(scores: np.ndarray, density: float,
                   *, prune_zero: bool = True) -> np.ndarray:
    """Top-``density`` tile mask per group from (g, nkb, nnb) scores."""
    if not 0.0 <= density <= 1.0:
        raise ValueError(f"density must be in [0, 1], got {density}")
    g, nkb, nnb = scores.shape
    budget = math.ceil(density * nkb * nnb)
    keep = np.zeros_like(scores, dtype=bool)
    for gi in range(g):
        flat = scores[gi].ravel()
        order = np.argsort(-flat, kind="stable")[:budget]
        m = np.zeros(flat.shape, bool)
        m[order] = True
        if prune_zero:
            m &= flat > 0.0
        keep[gi] = m.reshape(nkb, nnb)
    return keep


def nm_mask(scores: np.ndarray, n_keep: int, m_block: int,
            *, prune_zero: bool = True) -> np.ndarray:
    """N:M structured mask over the K-tile axis from (g, nkb, nnb) scores."""
    if not 0 < n_keep <= m_block:
        raise ValueError(f"need 0 < n_keep <= m_block, got "
                         f"{n_keep}:{m_block}")
    g, nkb, nnb = scores.shape
    keep = np.zeros_like(scores, dtype=bool)
    for gi in range(g):
        for j in range(nnb):
            col = scores[gi, :, j]
            for lo in range(0, nkb, m_block):
                chunk = col[lo: lo + m_block]
                order = np.argsort(-chunk, kind="stable")[:n_keep]
                m = np.zeros(chunk.shape, bool)
                m[order] = True
                if prune_zero:
                    m &= chunk > 0.0
                keep[gi, lo: lo + m_block, j] = m
    return keep


def sparsify_magnitude(
    w,
    plan_or_blocks: Union[GemmPlan, Tuple[int, int]],
    *,
    density: float,
    trans_w: bool = False,
    dtype=None,
    prune_zero: bool = True,
) -> TileSparseOperand:
    """Magnitude tile pruning: keep the top ``density`` fraction of (bk, bn)
    tiles by Frobenius norm (per group for grouped operands), drop the rest
    from storage AND from the kernel's tile walk."""
    bk, bn = _blocks_of(plan_or_blocks)
    scores = tile_scores(w, (bk, bn), trans_w=trans_w)
    keep = magnitude_mask(scores, density, prune_zero=prune_zero)
    return sparsify_with_mask(w, (bk, bn), keep, trans_w=trans_w, dtype=dtype)


def sparsify_nm(
    w,
    plan_or_blocks: Union[GemmPlan, Tuple[int, int]],
    *,
    n_keep: int = 2,
    m_block: int = 4,
    trans_w: bool = False,
    dtype=None,
    prune_zero: bool = True,
) -> TileSparseOperand:
    """Structured N:M tile pruning along K: every ``m_block`` consecutive
    k-tiles of an output column keep their ``n_keep`` strongest — bounded,
    uniform-depth schedules (the tile-level analogue of 2:4 sparsity)."""
    bk, bn = _blocks_of(plan_or_blocks)
    scores = tile_scores(w, (bk, bn), trans_w=trans_w)
    keep = nm_mask(scores, n_keep, m_block, prune_zero=prune_zero)
    return sparsify_with_mask(w, (bk, bn), keep, trans_w=trans_w, dtype=dtype)
