"""Empirical characterization & plan autotuning (the paper's Section III).

Closes the planning loop around the analytic model in ``core/blocking.py``:

    plan_gemm (analytic seed)
        └─ tune_gemm: sweep the block lattice around the seed, measure,
           persist the winner in a PlanCache
               └─ mp_dot / mpgemm_pallas: lookup_plan() consumes tuned
                  plans transparently, analytic fallback on miss.

Public API: :func:`tune_gemm`, :func:`tune_grouped_gemm`,
:func:`tune_sparse_gemm`, :func:`sweep`,
:func:`sweep_axis`,
:class:`PlanCache`, :func:`get_plan_cache`, :func:`set_plan_cache`,
:func:`lookup_plan`, :func:`make_key`,
:func:`~repro.tuning.report.characterization_report`.
See docs/autotuning.md for the workflow.
"""
from repro.tuning.microbench import (
    Measurement, TuneResult, candidate_plans, measure_grouped_plan,
    measure_plan, sweep, sweep_axis, tune_gemm, tune_grouped_gemm,
    tune_sparse_gemm,
)
from repro.tuning.plan_cache import (
    PlanCache, cached_analytic, clear_analytic_memo, current_mesh_namespace,
    get_plan_cache, key_namespace, lookup_plan, make_key, mesh_namespace,
    note_analytic_fallback, set_plan_cache,
)
from repro.tuning.report import characterization_report, write_report

__all__ = [
    "Measurement", "TuneResult", "candidate_plans", "measure_grouped_plan",
    "measure_plan", "sweep", "sweep_axis", "tune_gemm", "tune_grouped_gemm",
    "tune_sparse_gemm",
    "PlanCache", "cached_analytic", "clear_analytic_memo",
    "current_mesh_namespace", "get_plan_cache", "key_namespace",
    "lookup_plan", "make_key", "mesh_namespace", "note_analytic_fallback",
    "set_plan_cache",
    "characterization_report", "write_report",
]
