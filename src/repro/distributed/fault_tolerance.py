"""Fault tolerance for 1000+-node operation: design + simulation harness.

The physical layer (process death, NIC loss) is owned by the cluster
scheduler; this module owns the framework's contract with it:

  1. **Checkpoint/restart** — `checkpoint.Checkpointer` writes per-host
     shards + manifest (atomic rename, async).  `TrainController.restore`
     resumes (params, optimizer, data-pipeline state) bit-exactly: the
     synthetic pipeline is a pure function of (seed, step), so the token
     stream continues where it stopped.

  2. **Elastic re-mesh** — on degraded capacity, relaunch with a smaller
     mesh; `sharding.params_shardings` is a pure function of (tree, cfg,
     mesh), so the same checkpoint restores with new NamedShardings
     (`restore(..., target_shardings=...)`).  `plan_elastic_mesh` picks the
     largest valid (data, model) grid for the surviving chip count.

  3. **Straggler mitigation** — synchronous SPMD cannot drop a slow worker
     mid-step, so mitigation = detection + re-dispatch: the controller
     tracks per-step wall time EWMA; a step exceeding `straggler_factor` x
     EWMA marks the step suspect, and after `patience` suspect steps the
     controller requests a re-mesh excluding the slow host (simulated
     here).  Microbatched steps also bound the blast radius of transient
     slowness (smaller per-dispatch quantum).

`simulate_failures` exercises 1-3 against an in-process trainer.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class StragglerDetector:
    factor: float = 2.5
    patience: int = 3
    ewma: float = 0.0
    alpha: float = 0.1
    suspect_streak: int = 0

    def observe(self, step_time: float) -> str:
        """-> 'ok' | 'suspect' | 'remesh'."""
        if self.ewma == 0.0:
            self.ewma = step_time
            return "ok"
        verdict = "ok"
        if step_time > self.factor * self.ewma:
            self.suspect_streak += 1
            verdict = "suspect"
            if self.suspect_streak >= self.patience:
                verdict = "remesh"
                self.suspect_streak = 0
        else:
            self.suspect_streak = 0
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return verdict


def plan_elastic_mesh(n_chips: int, *, model_parallel: int = 16,
                      min_data: int = 1) -> Optional[Tuple[int, int]]:
    """Largest (data, model) grid for the surviving chip count.

    Keeps the model axis fixed (weight shardings stay valid) and shrinks
    data parallelism; returns None if not even (min_data x model) survives.
    """
    data = n_chips // model_parallel
    if data < min_data:
        return None
    return (data, model_parallel)


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str          # 'crash' | 'straggle'
    magnitude: float = 10.0  # slowdown factor for straggle


def simulate_failures(
    run_step: Callable[[int], float],
    total_steps: int,
    events: List[FailureEvent],
    *,
    checkpoint_every: int = 5,
    save: Callable[[int], None] = lambda step: None,
    restore: Callable[[], int] = lambda: 0,
):
    """Drive a trainer through crash + straggler events.

    ``run_step(step)`` returns the step wall-time; a 'crash' event makes
    the controller restore from the latest checkpoint; a 'straggle' event
    inflates observed step time to exercise the detector.
    Returns the event log."""
    log = []
    det = StragglerDetector()
    by_step = {e.step: e for e in events}
    step = restore()
    while step < total_steps:
        ev = by_step.get(step)
        if ev and ev.kind == "crash":
            del by_step[step]
            log.append((step, "crash->restore"))
            step = restore()
            continue
        t = run_step(step)
        if ev and ev.kind == "straggle":
            t *= ev.magnitude
        verdict = det.observe(t)
        if verdict != "ok":
            log.append((step, verdict))
        if (step + 1) % checkpoint_every == 0:
            save(step + 1)
            log.append((step + 1, "checkpoint"))
        step += 1
    return log
