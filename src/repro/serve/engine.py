"""Serving engine: continuous batching over a paged KV cache.

The engine admits and retires requests every decode step:

  * ``add_request()`` queues work; ``step()`` runs ONE forward — a pure
    decode step (chunk width 1) or, when a prompt is still being prefilled,
    a mixed chunked-prefill step where decode rows ride along with one
    valid column — and returns the requests that finished;
  * ``generate()`` is the compatibility wrapper: add everything, step until
    drained, return ``{uid: tokens}`` exactly like the old wave engine.

KV lives in fixed-size pages (``serve/kv_cache.py``): admission allocates
pages for the prompt (reusing prefix-shared pages), decode grows one page
at a time, and when the pool runs dry the most recently admitted request
is preempted (pages freed, request requeued for recompute) so older work
keeps flowing — no head-of-line blocking, O(actual-length) KV memory.

Every step emits a :class:`StepTelemetry` record (``engine.step_telemetry``,
streamed through ``on_step``).  The old per-wave records survive as an
aggregation: :class:`WaveTelemetry` is built FROM the step records by the
deprecated wave path (``batch_size=`` — a shim that keeps the original
left-padded static-batch loop for archs without a paged path and for
existing callers/tests).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.serve.kv_cache import PagedKVCache, cdiv


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass(frozen=True)
class StepTelemetry:
    """Observability record for ONE engine step (or one wave phase).

    ``kv_bytes`` is the modeled KV footprint actually held (allocated pages
    x page bytes across layers); ``kv_bytes_dense`` is what the wave
    engine's per-slot max-length allocation would hold for the same batch
    width — the paged-vs-dense memory story per step.
    """

    step: int                # 0-based step index within this generate()/run
    phase: str               # "prefill" | "mixed" | "decode"
    live: int                # occupied slots doing useful work this step
    queue_depth: int         # requests waiting for a slot after this step
    tokens: int              # tokens emitted (sampled) this step
    preemptions: int         # requests preempted (requeued) this step
    pages_in_use: int        # KV pages held after this step
    page_occupancy: float    # pages_in_use / allocatable pages
    kv_bytes: int            # modeled bytes held in KV pages (all layers)
    kv_bytes_dense: int      # modeled bytes a dense max-len batch would hold
    prefix_hit_tokens: int   # cumulative prompt tokens skipped via sharing
    wall_s: float            # step wall time (incl. compile on first shapes)
    tokens_per_s: float      # tokens / wall_s


@dataclasses.dataclass(frozen=True)
class WaveTelemetry:
    """Aggregated observability for ONE wave of the deprecated wave engine.

    Since the continuous-batching redesign this is a thin aggregation over
    the per-step :class:`StepTelemetry` records (see :meth:`from_steps`);
    the fields and semantics are unchanged from the original per-wave
    implementation.  ``wall_s`` covers prefill + decode — and, for the
    FIRST wave after process start or a shape change, jax.jit compilation.
    ``prefill_s`` isolates the prefill(+compile) portion.
    """

    wave: int                # 0-based wave index within this generate() call
    requests: int            # requests admitted into the wave
    tokens: int              # tokens emitted by the wave
    decode_steps: int        # decode iterations the wave ran
    wall_s: float            # wave wall time (prefill + decode)
    prefill_s: float         # prefill wall time (incl. compile on wave 0)
    tokens_per_s: float      # tokens / wall_s
    slot_occupancy: float    # mean live-slot fraction over decode steps
    queue_depth: int         # requests still queued when the wave finished

    @classmethod
    def from_steps(cls, wave: int, requests: int, queue_depth: int,
                   steps: List[StepTelemetry], wall_s: float,
                   batch: int) -> "WaveTelemetry":
        """Fold one wave's StepTelemetry stream into the legacy record."""
        emits = [s for s in steps if s.phase != "prefill"]
        n_tok = sum(s.tokens for s in steps)
        prefill_s = sum(s.wall_s for s in steps if s.phase == "prefill")
        occ = (sum(s.live / batch for s in emits) / len(emits)
               if emits else 0.0)
        return cls(
            wave=wave, requests=requests, tokens=n_tok,
            decode_steps=sum(1 for s in emits if s.phase == "decode"),
            wall_s=wall_s, prefill_s=prefill_s,
            tokens_per_s=n_tok / wall_s if wall_s > 0 else 0.0,
            slot_occupancy=occ, queue_depth=queue_depth,
        )


@dataclasses.dataclass
class _Slot:
    req: Request
    length: int                  # tokens written into the KV pages
    pending: np.ndarray          # prompt tokens not yet prefilled
    next_token: Optional[int]    # sampled, not yet written (decode input)
    out: List[int]
    admitted: int                # admission order (preemption picks max)


def _kv_token_bytes(model) -> int:
    """Modeled KV bytes ONE token holds across all attention layers."""
    from repro.models.transformer import PAGED_KINDS
    cfg = model.cfg
    layers = sum(1 for k in cfg.pattern if k in PAGED_KINDS)
    itemsize = jnp.dtype(model.act_dtype).itemsize
    return 2 * cfg.n_kv_heads * cfg.head_dim * itemsize * max(layers, 1)


class ServeEngine:
    """Continuous-batching engine (paged KV).  The deprecated ``batch_size=``
    keyword selects the legacy wave engine (static batch, ring caches)."""

    def __init__(self, model, params, *, max_len: int,
                 max_batch: Optional[int] = None, page_size: int = 16,
                 max_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 eos_id: int = 1, greedy: bool = True,
                 on_step: Optional[Callable[[StepTelemetry], None]] = None,
                 on_wave: Optional[Callable[[WaveTelemetry], None]] = None,
                 batch_size: Optional[int] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.on_step = on_step
        self.on_wave = on_wave
        self.telemetry: List[WaveTelemetry] = []
        self.step_telemetry: List[StepTelemetry] = []
        self._token_bytes = _kv_token_bytes(model)
        self._wave_mode = batch_size is not None
        if self._wave_mode:
            obs.warn_deprecated(
                "serve_engine.batch_size",
                "ServeEngine(batch_size=) selects the deprecated wave "
                "engine; use max_batch= for continuous batching",
                stacklevel=2)
            self.batch_size = batch_size
            self._prefill = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=max_len))
            self._decode = jax.jit(model.decode_step)
            return
        # ----------------------- continuous engine -----------------------
        reason = model.paged_unsupported_reason()
        if reason:
            raise ValueError(
                f"continuous batching unavailable: {reason} "
                f"(construct with batch_size= for the wave engine)")
        self.max_batch = max_batch if max_batch is not None else 8
        self.batch_size = self.max_batch   # observability-compat alias
        self.page_size = page_size
        self.bt_width = cdiv(max_len, page_size)
        # Default pool: dense-equivalent capacity (+ the scratch page), so
        # preemption only kicks in when the caller shrinks max_pages.
        self.max_pages = (max_pages if max_pages is not None
                          else self.max_batch * self.bt_width + 1)
        self.kv = PagedKVCache(self.max_pages, page_size)
        self.prefill_chunk = (prefill_chunk if prefill_chunk is not None
                              else max(page_size, 8))
        self.caches = model.init_paged_caches(self.max_pages, page_size)
        self._step_fn = jax.jit(model.paged_step)
        self._slots: List[Optional[_Slot]] = [None] * self.max_batch
        self._queue: deque = deque()
        self._admit_counter = 0
        self._step_counter = 0
        self._uid_counter = 0

    # ------------------------- continuous API ----------------------------

    def _require_continuous(self, what: str):
        if self._wave_mode:
            raise RuntimeError(
                f"{what} requires the continuous engine; this instance was "
                f"built with the deprecated batch_size= (wave) shim")

    def add_request(self, prompt, max_new_tokens: int = 16,
                    uid: Optional[int] = None) -> int:
        """Queue a request; returns its uid.  Also accepts a Request."""
        self._require_continuous("add_request()")
        if isinstance(prompt, Request):
            req = prompt
        else:
            if uid is None:
                uid = self._uid_counter
                self._uid_counter += 1
            req = Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens)
        self._uid_counter = max(self._uid_counter, req.uid + 1)
        if len(req.prompt) >= self.max_len:
            raise ValueError(f"prompt length {len(req.prompt)} >= max_len "
                             f"{self.max_len}")
        worst = cdiv(min(len(req.prompt) + req.max_new_tokens, self.max_len),
                     self.page_size)
        if worst > self.max_pages - 1:
            raise ValueError(
                f"request {req.uid} needs up to {worst} pages, pool has "
                f"{self.max_pages - 1} allocatable — raise max_pages")
        self._queue.append(req)
        return req.uid

    def _admit(self) -> None:
        """FIFO admission into free slots while prompt pages fit."""
        for i in range(self.max_batch):
            if not self._queue or self._slots[i] is not None:
                continue
            req = self._queue[0]
            shared_pages, shared_tokens = self.kv.match_prefix(req.prompt)
            self.kv.allocate(req.uid, shared_pages, shared_tokens)
            if not self.kv.ensure(req.uid, len(req.prompt)):
                self.kv.free_seq(req.uid)     # head doesn't fit; wait
                self.kv.rollback_prefix_hits(len(shared_pages), shared_tokens)
                break
            self._queue.popleft()
            self._slots[i] = _Slot(
                req=req, length=shared_tokens,
                pending=np.asarray(req.prompt[shared_tokens:], np.int32),
                next_token=None, out=[], admitted=self._admit_counter)
            self._admit_counter += 1

    def _evict_slot(self, i: int) -> None:
        """Preempt slot i: free its pages, requeue its request at the head
        (recompute semantics — generated tokens are discarded)."""
        s = self._slots[i]
        self.kv.free_seq(s.req.uid)
        self._queue.appendleft(s.req)
        self._slots[i] = None
        self._preempted_now += 1
        obs.instant("serve.preempt", uid=s.req.uid, slot=i)

    def _reserve(self, slot: _Slot, n_new: int) -> bool:
        """Grow slot's table for n_new tokens, preempting newer requests
        under page pressure.  False if the slot itself got preempted (or
        already was: an earlier _reserve() this step may have evicted it,
        in which case its pages are gone and ensure() must not run)."""
        if not any(s is slot for s in self._slots):
            return False
        while not self.kv.ensure(slot.req.uid, slot.length + n_new):
            others = [i for i, s in enumerate(self._slots)
                      if s is not None and s is not slot]
            if others:
                j = max(others, key=lambda i: self._slots[i].admitted)
                if self._slots[j].admitted > slot.admitted:
                    self._evict_slot(j)
                    continue
            # slot is itself the newest — preempt it instead
            self._evict_slot(
                next(i for i, s in enumerate(self._slots) if s is slot))
            return False
        return True

    def step(self) -> List[Request]:
        """Run one engine step; returns the requests that finished."""
        self._require_continuous("step()")
        t0 = time.perf_counter()
        self._preempted_now = 0
        with obs.span("serve.admit", step=self._step_counter,
                      queued=len(self._queue)):
            self._admit()
        live = [s for s in self._slots if s is not None]
        if not live:
            if self._queue:
                raise RuntimeError(
                    "queued requests but nothing admitted — pool cannot "
                    "hold any queued prompt")
            return []
        # Chunk width: mixed prefill step if any prompt is still pending.
        chunk = max((min(self.prefill_chunk, len(s.pending))
                     for s in live if len(s.pending)), default=0)
        c = max(chunk, 1)
        phase = ("prefill" if chunk and all(s.next_token is None
                                            for s in live)
                 else "mixed" if chunk else "decode")
        # Reserve pages for this step's writes (may preempt).  _reserve()
        # can evict any NEWER slot, so re-read liveness from self._slots
        # each iteration — a snapshot would hand slots whose pages were
        # just freed back to _reserve().
        for i in range(self.max_batch):
            s = self._slots[i]
            if s is None:
                continue
            n_new = min(c, len(s.pending)) if len(s.pending) else 1
            self._reserve(s, n_new)
        live = [s for s in self._slots if s is not None]
        if not live:
            raise RuntimeError("every live request was preempted — pool "
                               "cannot make progress")
        b = self.max_batch
        tokens = np.zeros((b, c), np.int32)
        q_start = np.zeros((b,), np.int32)
        n_valid = np.zeros((b,), np.int32)
        bt = np.zeros((b, self.bt_width), np.int32)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            if len(s.pending):
                n = min(c, len(s.pending))
                tokens[i, :n] = s.pending[:n]
            else:
                n = 1
                tokens[i, 0] = s.next_token
            q_start[i] = s.length
            n_valid[i] = n
            bt[i] = self.kv.block_table_row(s.req.uid, self.bt_width)
        with obs.span("serve." + phase, step=self._step_counter,
                      live=len(live), chunk=c,
                      pages_in_use=self.kv.pages_in_use):
            logits, self.caches = self._step_fn(
                self.params, jnp.asarray(tokens), self.caches,
                jnp.asarray(bt), jnp.asarray(q_start), jnp.asarray(n_valid))
            logits = np.asarray(logits)   # blocks until device done
            sampled = np.argmax(logits, axis=-1)
        finished: List[Request] = []
        emitted = 0
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            n = int(n_valid[i])
            s.length += n
            if len(s.pending):
                s.pending = s.pending[n:]
                if len(s.pending):
                    continue              # mid-prefill: logits unused
                self.kv.register_prefix(s.req.uid, s.req.prompt)
            else:
                pass                      # decode: next_token now in cache
            tok = int(sampled[i])
            s.out.append(tok)
            emitted += 1
            done = (tok == self.eos_id
                    or len(s.out) >= s.req.max_new_tokens
                    or s.length >= self.max_len - 1)
            if done:
                s.req.out_tokens = list(s.out)
                finished.append(s.req)
                self.kv.free_seq(s.req.uid)
                self._slots[i] = None
            else:
                s.next_token = tok
        wall = time.perf_counter() - t0
        pages = self.kv.pages_in_use
        rec = StepTelemetry(
            step=self._step_counter, phase=phase, live=len(live),
            queue_depth=len(self._queue), tokens=emitted,
            preemptions=self._preempted_now, pages_in_use=pages,
            page_occupancy=self.kv.occupancy,
            kv_bytes=pages * self.page_size * self._token_bytes,
            kv_bytes_dense=self.max_batch * self.max_len * self._token_bytes,
            prefix_hit_tokens=self.kv.stats.prefix_hit_tokens,
            wall_s=wall, tokens_per_s=emitted / wall if wall > 0 else 0.0)
        self._emit_step(rec)
        self._step_counter += 1
        return finished

    def _emit_step(self, rec: StepTelemetry) -> None:
        """One StepTelemetry record lands in all three sinks: the in-memory
        stream, the caller's on_step hook, and the process registry —
        serving, benches, and an HTTP scrape read the same numbers."""
        self.step_telemetry.append(rec)
        obs.counter_inc("serve_steps_total", phase=rec.phase,
                        help="engine steps by phase")
        if rec.tokens:
            obs.counter_inc("serve_tokens_total", amount=rec.tokens,
                            help="tokens sampled")
        if rec.preemptions:
            obs.counter_inc("serve_preemptions_total",
                            amount=rec.preemptions,
                            help="requests preempted under page pressure")
        obs.gauge_set("serve_queue_depth", rec.queue_depth)
        obs.gauge_set("serve_live_slots", rec.live)
        obs.gauge_set("serve_pages_in_use", rec.pages_in_use)
        obs.gauge_set("serve_page_occupancy", rec.page_occupancy)
        obs.gauge_set("serve_kv_bytes", rec.kv_bytes)
        obs.gauge_set("serve_prefix_hit_tokens", rec.prefix_hit_tokens)
        obs.observe("serve_step_wall_seconds", rec.wall_s, phase=rec.phase)
        if self.on_step is not None:
            self.on_step(rec)

    @property
    def pending(self) -> int:
        """Requests not yet finished (queued + occupying a slot)."""
        return len(self._queue) + sum(s is not None for s in self._slots)

    def warm_prefixes(self, prompts,
                      *, max_tokens_each: Optional[int] = None) -> int:
        """Pre-populate the prefix-sharing index with system prompts.

        Runs each prompt through a throwaway 1-token request so its full
        prompt pages land in the refcounted prefix index BEFORE real
        traffic arrives — the first real request sharing that system
        prompt then prefills only its unshared tail instead of the whole
        prefix.  Prompts shorter than one page can never be indexed
        (sharing covers full pages only) and are skipped; longer ones are
        truncated to ``max_tokens_each`` and to what ``max_len`` admits.
        Resets the telemetry streams afterwards so warm-up steps never
        pollute serving observability.  Returns the number of newly
        indexed prefix pages.
        """
        self._require_continuous("warm_prefixes()")
        before = self.kv.prefix_entries
        budget = 0
        for prompt in prompts:
            toks = np.asarray(prompt, np.int32).reshape(-1)
            if max_tokens_each is not None:
                toks = toks[:max_tokens_each]
            toks = toks[: self.max_len - 2]
            if len(toks) < self.page_size:
                continue              # sharing covers full pages only
            self.add_request(toks, max_new_tokens=1)
            budget += 4 * (len(toks) + 1) + 64
        for _ in range(budget):
            if not self.pending:
                break
            self.step()
        if self.pending:
            raise RuntimeError(
                f"prefix warm-up failed to drain: {self.pending} warm "
                f"requests unfinished")
        self.step_telemetry = []
        self._step_counter = 0
        return self.kv.prefix_entries - before

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Compatibility wrapper: run everything to completion.

        Resets the telemetry streams.  On the continuous engine this is
        add_request() + step()-until-drained; with the deprecated
        ``batch_size=`` shim it runs the legacy wave loop (identical
        behaviour and WaveTelemetry records to the pre-paging engine).
        """
        if self._wave_mode:
            return self._generate_waves(requests)
        self.step_telemetry = []
        self._step_counter = 0
        for r in requests:
            self.add_request(r)
        results: Dict[int, List[int]] = {}
        budget = sum(len(r.prompt) + r.max_new_tokens for r in requests)
        budget = 4 * budget + 64          # preemption/chunking slack
        for _ in range(budget):
            for req in self.step():
                results[req.uid] = list(req.out_tokens)
            if not self.pending:
                return results
        raise RuntimeError(f"generate() exceeded its step budget with "
                           f"{self.pending} requests unfinished")

    # ----------------- deprecated wave engine (batch_size=) --------------

    def _generate_waves(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Static-batch generation with slot reuse between waves."""
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        self.telemetry = []
        self.step_telemetry = []
        self._wave_step = 0
        wave_idx = 0
        while queue:
            wave = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            t0 = time.perf_counter()
            n_steps0 = len(self.step_telemetry)
            with obs.span("serve.wave", wave=wave_idx, requests=len(wave)):
                out = self._run_wave(wave, len(queue))
            wall = time.perf_counter() - t0
            record = WaveTelemetry.from_steps(
                wave_idx, len(wave), len(queue),
                self.step_telemetry[n_steps0:], wall, self.batch_size)
            self.telemetry.append(record)
            obs.counter_inc("serve_waves_total",
                            help="waves run by the deprecated wave engine")
            obs.gauge_set("serve_wave_tokens_per_s", record.tokens_per_s)
            if self.on_wave is not None:
                self.on_wave(record)
            results.update(out)
            wave_idx += 1
        return results

    def _wave_record(self, phase: str, live: int, queue_depth: int,
                     tokens: int, wall: float) -> None:
        dense = self.batch_size * self.max_len * self._token_bytes
        rec = StepTelemetry(
            step=self._wave_step, phase=phase, live=live,
            queue_depth=queue_depth, tokens=tokens, preemptions=0,
            pages_in_use=0, page_occupancy=0.0,
            kv_bytes=dense, kv_bytes_dense=dense, prefix_hit_tokens=0,
            wall_s=wall, tokens_per_s=tokens / wall if wall > 0 else 0.0)
        self._emit_step(rec)
        self._wave_step += 1

    def _run_wave(self, wave: List[Request], queue_depth: int):
        b = self.batch_size
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.model.cfg
        # Stubbed modality frontends (per assignment): frame/patch embeds.
        if cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        t_pf = time.perf_counter()
        logits, caches = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        self._wave_record("prefill", len(wave), queue_depth, 0,
                          time.perf_counter() - t_pf)
        out = {r.uid: [] for r in wave}
        live = np.array([True] * len(wave) + [False] * (b - len(wave)))
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        pos = plen
        for step in range(max_new):
            # Emission: live slots doing useful work this step over the
            # static batch width (the occupancy sample).
            t_it = time.perf_counter()
            n_live = int(live.sum())
            emitted = 0
            tok_np = np.asarray(token[:, 0])
            for i, r in enumerate(wave):
                if live[i]:
                    out[r.uid].append(int(tok_np[i]))
                    emitted += 1
                    if (int(tok_np[i]) == self.eos_id
                            or len(out[r.uid]) >= r.max_new_tokens):
                        live[i] = False
            if not live.any() or pos >= self.max_len - 1:
                # Final flush: tokens emitted, no decode ran.
                self._wave_record("emit", n_live, queue_depth, emitted,
                                  time.perf_counter() - t_it)
                break
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(pos))
            jax.block_until_ready(logits)
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
            self._wave_record("decode", n_live, queue_depth, emitted,
                              time.perf_counter() - t_it)
        return out
