"""Observability transparency gate: metrics on == metrics off, bytewise.

The obs subsystem (``repro.obs``) rides the hot paths this suite grades —
plan resolution, packing, kernel launch, serving — so the one property it
must prove continuously is that it is a PURE OBSERVER.  Three measurement
families (area ``obs``, -> ``BENCH_obs.json``):

  * ``obs_gate_transparency`` — the graded payload (planner roofline terms
    for the smoke workloads + trace-time launch facts of dense / packed /
    sparse interpret GEMMs) is computed twice, once with a fresh registry
    and tracer installed and once with both disabled.  The two payloads'
    sorted-key JSON dumps must be BYTE-IDENTICAL, and every audit launch
    count must match — instrumentation may never perturb a modeled metric
    or add/remove a launch.
  * ``obs_census_*``          — deterministic counter facts from the same
    enabled run: the plan-cache miss -> analytic-fallback -> memo-hit
    sequence, per-spec ``gemm_launches_total`` series, and the span names
    the tracer captured.  These pin the *coverage* of the instrumentation
    (a deleted counter_inc shows up here as a baseline diff).
  * ``obs_wall_inc``          — counter_inc hot-path cost (ns/op, enabled
    vs disabled) — recorded as noisy, never gated.

``--smoke`` asserts the transparency gate and the census facts hard and
exits nonzero on any failure (the CI gate).
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import PAPER_WORKLOADS, emit, record
from repro import obs
from repro.obs import audit
from repro.obs.trace import Tracer, set_tracer

# Planner terms from the quant-smoke workload rows (DeepSeek decode /
# DeepSeek prefill / LLaMA decode) — same ids bench_quant pins.
GATE_WORKLOAD_IDS = (1, 13, 19)

# Small traced shapes: big enough for a real (multi-step) grid, small
# enough that interpret-mode tracing stays sub-second.
TRACE_M, TRACE_N, TRACE_K = 32, 256, 256


def _modeled_payload() -> dict:
    """Every graded number in one dict: planner terms + launch facts.

    Pure function of the code under test — MUST NOT depend on whether the
    metrics registry or tracer is installed.  Keys sort deterministically,
    all values are ints, so ``json.dumps(..., sort_keys=True)`` is a
    byte-stable fingerprint.
    """
    from repro.core.blocking import plan_gemm
    from repro.core.gemm import mp_dot
    from repro.packing import pack_operand
    from repro.sparse import sparsify_magnitude

    out = {"plans": {}, "audit": {}}
    for wid, m, n, k in PAPER_WORKLOADS:
        if wid not in GATE_WORKLOAD_IDS:
            continue
        plan = plan_gemm(m, n, k, "bfloat16")
        out["plans"][f"w{wid:02d}"] = dict(
            hbm_bytes=int(plan.hbm_bytes), flops=int(plan.flops),
            bm=int(plan.bm), bn=int(plan.bn), bk=int(plan.bk))

    m, n, k = TRACE_M, TRACE_N, TRACE_K
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    x = jax.ShapeDtypeStruct((m, k), jnp.bfloat16)

    jx = audit.trace(
        lambda x, w: mp_dot(x, w, policy="bf16", backend="interpret"), x, w)
    out["audit"]["dense"] = dict(
        launches=audit.count_pallas(jx),
        grid=[int(g) for g in audit.first_pallas_grid(jx)])

    plan = plan_gemm(m, n, k, "bfloat16", "int4")
    packed = pack_operand(w, plan, dtype="int4", backend="xla")
    jx = audit.trace(
        lambda x, p: mp_dot(x, p, policy="bf16", backend="interpret"),
        x, packed)
    out["audit"]["int4"] = dict(
        launches=audit.count_pallas(jx),
        dequants=audit.weight_sized_intermediates(
            jx, k * n, prims=audit.DEQUANT_PRIMS,
            skip_pallas_bodies=True)[0])

    sp = sparsify_magnitude(w, (128, 128), density=0.5, dtype="bfloat16")
    jx = audit.trace(
        lambda x, payload: mp_dot(
            x, type(sp)(payload, sp.scales, sp.layout),
            policy="bf16", backend="interpret"),
        x, jax.ShapeDtypeStruct(sp.payload.shape, sp.payload.dtype))
    out["audit"]["sparse"] = dict(
        launches=audit.count_pallas(jx),
        schedule=int(audit.first_pallas_grid(jx)[-1]))
    return out


def _plan_cache_census() -> dict:
    """Deterministic miss -> fallback -> memo-hit counter sequence."""
    from repro.core.blocking import plan_gemm
    from repro.tuning.plan_cache import (
        PlanCache, clear_analytic_memo, lookup_plan, make_key,
        note_analytic_fallback, set_plan_cache,
    )

    # Own registry + cache + memo: the census counts exactly this
    # sequence, not whatever the payload run already looked up.
    reg = obs.MetricsRegistry()
    prev_reg = obs.set_registry(reg)
    prev_cache = set_plan_cache(PlanCache(None))
    try:
        m, n, k = TRACE_M, TRACE_N, TRACE_K
        assert lookup_plan(m, n, k, "bfloat16",
                           analytic_memo=True) is None  # miss
        note_analytic_fallback(
            make_key(m, n, k, "bfloat16"), plan_gemm(m, n, k, "bfloat16"))
        hits = sum(
            lookup_plan(m, n, k, "bfloat16", analytic_memo=True) is not None
            for _ in range(2))
    finally:
        set_plan_cache(prev_cache)
        clear_analytic_memo()
        obs.set_registry(prev_reg)

    snap = reg.snapshot()["counters"]
    return dict(
        memo_hits=int(hits),
        lookups_miss=int(snap.get(
            'plan_cache_lookups_total{namespace="default",result="miss"}',
            0)),
        lookups_hit_analytic=int(snap.get(
            'plan_cache_lookups_total'
            '{namespace="default",result="hit_analytic"}', 0)),
        fallbacks=int(snap.get(
            'plan_cache_analytic_fallback_total{namespace="default"}', 0)),
    )


def run_gate(assert_gate: bool = True) -> dict:
    """The transparency gate + the enabled-run census, in one pass."""
    from repro.tuning.plan_cache import clear_analytic_memo

    # Pass 1: obs fully ON (fresh registry so counts are absolute, fresh
    # tracer so the span census is exactly this payload's spans).
    tracer = Tracer()
    prev_reg = obs.set_registry(obs.MetricsRegistry())
    prev_tr = set_tracer(tracer)
    try:
        clear_analytic_memo()
        payload_on = _modeled_payload()
        census = _plan_cache_census()
        launch_series = [
            key for key in obs.get_registry().snapshot()["counters"]
            if key.startswith("gemm_launches_total")]
        span_names = sorted({ev["name"] for ev in tracer.events()
                             if ev.get("ph") == "X"})
    finally:
        set_tracer(prev_tr)
        obs.set_registry(prev_reg)

    # Pass 2: obs fully OFF — identical inputs, no observer.
    prev_reg = obs.set_registry(None)
    prev_tr = set_tracer(None)
    try:
        clear_analytic_memo()
        payload_off = _modeled_payload()
    finally:
        set_tracer(prev_tr)
        obs.set_registry(prev_reg)
        clear_analytic_memo()

    dump_on = json.dumps(payload_on, sort_keys=True).encode()
    dump_off = json.dumps(payload_off, sort_keys=True).encode()
    identical = dump_on == dump_off
    launches_match = all(
        payload_on["audit"][kind]["launches"]
        == payload_off["audit"][kind]["launches"]
        for kind in payload_on["audit"])

    emit("obs_gate_transparency", 0.0,
         f"identical={int(identical)};payload_bytes={len(dump_on)};"
         f"launch_series={len(launch_series)};spans={len(span_names)}")
    record("obs_gate_transparency", "obs", kind="trace",
           workload={"m": TRACE_M, "n": TRACE_N, "k": TRACE_K,
                     "plan_workloads": list(GATE_WORKLOAD_IDS)},
           metrics={
               "payload_identical": float(identical),
               "launches_match": float(launches_match),
               "dense_launches":
                   float(payload_on["audit"]["dense"]["launches"]),
               "int4_launches":
                   float(payload_on["audit"]["int4"]["launches"]),
               "int4_dequants":
                   float(payload_on["audit"]["int4"]["dequants"]),
               "sparse_launches":
                   float(payload_on["audit"]["sparse"]["launches"]),
           })
    record("obs_census_plan_cache", "obs", kind="trace",
           workload={"m": TRACE_M, "n": TRACE_N, "k": TRACE_K},
           metrics={k: float(v) for k, v in census.items()})
    record("obs_census_instrumentation", "obs", kind="trace",
           workload={"m": TRACE_M, "n": TRACE_N, "k": TRACE_K},
           metrics={"gemm_launch_series": float(len(launch_series)),
                    "span_names": float(len(span_names))})
    emit("obs_census_plan_cache", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(census.items())))
    emit("obs_census_spans", 0.0, "names=" + "|".join(span_names))

    if assert_gate:
        if not identical:
            raise SystemExit(
                "obs transparency gate FAILED: modeled payload differs "
                "with the registry/tracer installed — instrumentation is "
                "perturbing graded metrics "
                f"(on={len(dump_on)}B, off={len(dump_off)}B)")
        if not launches_match:
            raise SystemExit(
                "obs transparency gate FAILED: audit launch counts change "
                "when instrumentation is enabled")
        if census != dict(memo_hits=2, lookups_miss=1,
                          lookups_hit_analytic=2, fallbacks=1):
            raise SystemExit(
                f"plan-cache census drifted: {census} — the "
                "miss/fallback/memo-hit counters no longer track lookups")
        for want in ("gemm.plan", "gemm.launch", "pack"):
            if want not in span_names:
                raise SystemExit(
                    f"span census missing {want!r} (saw {span_names}) — "
                    "an obs.span() site was removed from the hot path")
        if not launch_series:
            raise SystemExit("no gemm_launches_total series recorded — "
                             "the launch counter left the kernel path")
    return dict(identical=identical, census=census,
                launch_series=launch_series, span_names=span_names)


def run_wall(iters: int = 20000) -> dict:
    """counter_inc cost per call, enabled vs disabled (noisy)."""
    out = {}
    for state, reg in (("enabled", obs.MetricsRegistry()),
                       ("disabled", None)):
        prev = obs.set_registry(reg)
        try:
            t0 = time.perf_counter()
            for _ in range(iters):
                obs.counter_inc("obs_bench_ticks_total", kind="wall")
            ns = (time.perf_counter() - t0) / iters * 1e9
        finally:
            obs.set_registry(prev)
        out[state] = ns
        emit(f"obs_wall_inc_{state}", ns / 1e3, f"ns_per_inc={ns:.0f}")
    record("obs_wall_inc", "obs", kind="wall",
           workload={"iters": iters},
           metrics={},
           noisy={"ns_per_inc_enabled": out["enabled"],
                  "ns_per_inc_disabled": out["disabled"]})
    return out


def run(smoke: bool = False):
    """Harness entry: the gate (always asserted — it is exact) + wall."""
    res = run_gate(assert_gate=True)
    if not smoke:
        run_wall()
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="hard transparency + census gates, no wall "
                         "timings (CI gate)")
    args = ap.parse_args()
    res = run_gate(assert_gate=True)
    if not args.smoke:
        run_wall()
    print(f"obs gate OK: payload byte-identical with registry+tracer "
          f"on/off; census {res['census']}; spans {res['span_names']}")


if __name__ == "__main__":
    main()
