"""Runtime observability: metrics registry, tracing spans, jaxpr auditor.

One import surface for the whole stack::

    from repro import obs

    obs.counter_inc("gemm_launches_total", layout="packed", ...)
    with obs.span("gemm.launch", bytes=plan.hbm_bytes):
        ...
    obs.audit.count_pallas(obs.audit.trace(fn, x))

Submodules: ``registry`` (counters/gauges/histograms, Prometheus/JSON
exposition), ``trace`` (contextvar-nested spans, Perfetto trace.json),
``audit`` (jaxpr launch auditor), ``deprecation`` (warn-once-per-site
shims), ``server`` (stdlib /metrics + /trace endpoint — import it
directly, it is not pulled in here).

``repro.obs`` itself is dependency-free (stdlib only; ``audit`` imports
jax lazily), so any module in the tree may instrument itself without
creating an import cycle.
"""
from repro.obs import audit
from repro.obs.deprecation import reset_warned_sites, warn_deprecated
from repro.obs.registry import (
    MetricsRegistry, counter_inc, gauge_set, get_registry, metrics_enabled,
    observe, set_registry,
)
from repro.obs.trace import (
    Tracer, annotate, get_tracer, instant, set_tracer, span,
    tracing_enabled,
)

__all__ = [
    "MetricsRegistry",
    "Tracer",
    "annotate",
    "audit",
    "counter_inc",
    "gauge_set",
    "get_registry",
    "get_tracer",
    "instant",
    "metrics_enabled",
    "observe",
    "reset_warned_sites",
    "set_registry",
    "set_tracer",
    "span",
    "tracing_enabled",
    "warn_deprecated",
]
