"""Paper Table III + Figs 10/11: the 24 DeepSeek/LLaMA GEMM workloads,
plus their grouped (MoE expert-batched) forms.

For every workload: the analytic plan's modeled roofline time (MPGEMM) vs
the naive fixed-tile baseline's (the open-source-library stand-in), plus a
CPU XLA wall-time sanity number.  Derived column = modeled speedup (the
paper's headline metric shape: MPGEMM vs baselines).  The grouped section
additionally prices one-launch grouped execution vs G sequential 2-D
launches (per-launch ramp overhead amortization)."""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import (
    MOE_GROUPED_WORKLOADS, PAPER_WORKLOADS, emit, modeled_time_s, record,
    record_plan, wall_time_us,
)
from repro.core.blocking import (
    grouped_plan_from_2d, naive_plan, plan_gemm, plan_grouped_gemm,
)
from repro.core.constants import DEFAULT_HW


def run(dtype="float32", wall: bool = True):
    rng = np.random.default_rng(0)
    speedups = []
    for wid, m, n, k in PAPER_WORKLOADS:
        plan = plan_gemm(m, n, k, dtype)
        naive = naive_plan(m, n, k, dtype)
        t_plan = modeled_time_s(plan.flops, plan.hbm_bytes, dtype)
        t_naive = modeled_time_s(naive.flops, naive.hbm_bytes, dtype)
        speedup = t_naive / t_plan
        speedups.append(speedup)
        us = 0.0
        # CPU wall time is a sanity signal only; restrict to small cells so
        # the harness stays fast on one core.
        if wall and m * n * k <= 1.2e9:
            a = jnp.asarray(rng.standard_normal((m, k)), dtype)
            b = jnp.asarray(rng.standard_normal((k, n)), dtype)
            f = jax.jit(lambda a, b: a @ b)
            us = wall_time_us(f, a, b, iters=1)
        emit(f"gemm_workload_{wid:02d}_{dtype}", us,
             f"modeled_speedup_vs_naive={speedup:.3f};"
             f"blocks=({plan.bm}x{plan.bn}x{plan.bk});cmr={plan.cmr:.1f};"
             f"modeled_us={t_plan*1e6:.1f}")
        record_plan(f"gemm_workload_{wid:02d}_{dtype}", "gemm", plan,
                    workload={"paper_workload": wid},
                    metrics={"modeled_speedup_vs_naive": speedup,
                             "naive_hbm_bytes": float(naive.hbm_bytes)},
                    noisy={"wall_us": us} if us else None)
    record(f"gemm_workloads_geomean_{dtype}", "gemm",
           workload={"dtype": dtype, "workloads": len(PAPER_WORKLOADS)},
           metrics={"modeled_speedup_geomean":
                    float(np.exp(np.mean(np.log(speedups))))})
    emit(f"gemm_workloads_geomean_{dtype}", 0.0,
         f"modeled_speedup_geomean={np.exp(np.mean(np.log(speedups))):.3f}")
    return speedups


def run_grouped(dtype="bfloat16", wall: bool = True):
    """MoE expert-shape grouped GEMMs through plan_grouped_gemm.

    Reported per workload: modeled speedup of the planned grouped launch
    over the naive fixed-tile baseline (same metric as the 2-D table), and
    the CPU XLA batched-matmul wall time as the sanity signal.
    """
    rng = np.random.default_rng(0)
    speedups = []
    for name, g, m, n, k in MOE_GROUPED_WORKLOADS:
        plan = plan_grouped_gemm(g, m, n, k, dtype)
        naive = grouped_plan_from_2d(naive_plan(m, n, k, dtype), g)
        t_plan = modeled_time_s(plan.flops, plan.hbm_bytes, dtype)
        t_naive = modeled_time_s(naive.flops, naive.hbm_bytes, dtype)
        speedup = t_naive / t_plan
        speedups.append(speedup)
        us = 0.0
        # Per-GROUP cell size gates the sanity wall clock (the whole-launch
        # product would exclude every MoE workload); only the small-expert
        # shapes (granite) actually run on one CPU core.
        if wall and m * n * k <= 1.2e9:
            a = jnp.asarray(rng.standard_normal((g, m, k)), dtype)
            b = jnp.asarray(rng.standard_normal((g, k, n)), dtype)
            f = jax.jit(lambda a, b: jnp.einsum("gmk,gkn->gmn", a, b))
            us = wall_time_us(f, a, b, iters=1)
        emit(f"moe_grouped_{name}_{dtype}", us,
             f"g={g};modeled_speedup_vs_naive={speedup:.3f};"
             f"blocks=({plan.bm}x{plan.bn}x{plan.bk});cmr={plan.cmr:.1f};"
             f"modeled_us={t_plan*1e6:.1f}")
        record_plan(f"moe_grouped_{name}_{dtype}", "gemm", plan,
                    metrics={"modeled_speedup_vs_naive": speedup,
                             "naive_hbm_bytes": float(naive.hbm_bytes)},
                    noisy={"wall_us": us} if us else None)
    record(f"moe_grouped_geomean_{dtype}", "gemm",
           workload={"dtype": dtype, "workloads": len(MOE_GROUPED_WORKLOADS)},
           metrics={"modeled_speedup_geomean":
                    float(np.exp(np.mean(np.log(speedups))))})
    emit(f"moe_grouped_geomean_{dtype}", 0.0,
         f"modeled_speedup_geomean={np.exp(np.mean(np.log(speedups))):.3f}")
    return speedups


if __name__ == "__main__":
    run()
    run_grouped()
