"""Warn-once-per-site deprecation shims, counted in the registry.

The legacy keyword shims (``mp_dot(w=...)``, ``mpgemm_pallas(b_packed=
...)``, ``ServeEngine(batch_size=...)``) used to warn on EVERY call —
a serve loop hitting one per step drowned the log.  ``warn_deprecated``
keeps the first warning per (file, line) call site, silences repeats,
and increments ``deprecated_call_total{shim=...}`` on every invocation
so dead shims can be retired with usage evidence instead of guesses.
"""
from __future__ import annotations

import sys
import threading
import warnings
from typing import Set, Tuple

from repro.obs.registry import counter_inc

__all__ = ["reset_warned_sites", "warn_deprecated"]

_lock = threading.Lock()
_warned_sites: Set[Tuple[str, str, int]] = set()


def warn_deprecated(shim: str, message: str, *,
                    stacklevel: int = 2) -> None:
    """Drop-in for ``warnings.warn(message, DeprecationWarning,
    stacklevel=...)`` with per-site dedup + registry counting.

    ``stacklevel`` has the same meaning as in ``warnings.warn`` issued at
    the caller: 2 points the warning at the caller's caller.  The dedup
    site is the frame the warning would be attributed to.
    """
    counter_inc("deprecated_call_total",
                help="legacy-shim invocations by shim name", shim=shim)
    try:
        frame = sys._getframe(stacklevel)
        site = (shim, frame.f_code.co_filename, frame.f_lineno)
    except (AttributeError, ValueError):  # no _getframe / shallow stack
        site = (shim, "<unknown>", 0)
    with _lock:
        if site in _warned_sites:
            return
        _warned_sites.add(site)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel + 1)


def reset_warned_sites() -> None:
    """Forget dedup state (tests re-asserting the first warning)."""
    with _lock:
        _warned_sites.clear()
