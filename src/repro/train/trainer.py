"""Training controller: jit'd step + data pipeline + checkpointing + the
fault-tolerance contract (resume, straggler detection, elastic re-mesh
hooks).  Runs unsharded on one device or sharded under a mesh."""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointing import Checkpointer
from repro.data.pipeline import SyntheticLM
from repro.distributed import act
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import StragglerDetector
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    microbatches: int = 1
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


class Trainer:
    def __init__(self, model, shape, tcfg: TrainerConfig, mesh=None,
                 seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.tcfg = tcfg
        self.mesh = mesh
        extra = {}
        if self.cfg.family == "vlm":
            extra["image_embeds"] = ((self.cfg.n_image_tokens,
                                      self.cfg.d_model), "float32")
        if self.cfg.family == "audio":
            extra["audio_embeds"] = ((self.cfg.encoder_seq,
                                      self.cfg.d_model), "float32")
        self.pipeline = SyntheticLM(self.cfg.vocab, shape.global_batch,
                                    shape.seq_len, seed=seed,
                                    extra_specs=extra)
        self.step_fn = make_train_step(model, tcfg.opt,
                                       microbatches=tcfg.microbatches,
                                       total_steps=tcfg.steps)
        self.ckpt = (Checkpointer(tcfg.checkpoint_dir)
                     if tcfg.checkpoint_dir else None)
        self.detector = StragglerDetector()
        self.metrics_log = []

        if mesh is not None:
            params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(seed))
            p_shard = sh.params_shardings(params_shape, self.cfg, mesh)
            opt_shape = jax.eval_shape(adamw_init, params_shape)
            opt_shard = type(opt_shape)(step=sh.replicated(mesh),
                                        m=p_shard, v=p_shard)
            self._jit = jax.jit(self.step_fn,
                                in_shardings=(p_shard, opt_shard, None),
                                out_shardings=(p_shard, opt_shard, None),
                                donate_argnums=(0, 1))
            self._p_shard = p_shard
            self._opt_shard = opt_shard
        else:
            self._jit = jax.jit(self.step_fn, donate_argnums=(0, 1))
            self._p_shard = self._opt_shard = None

    # -- state ------------------------------------------------------------

    def init_state(self, seed: int = 0):
        ctx = act.use_mesh(self.mesh) if self.mesh is not None else _null()
        with ctx:
            params = self.model.init(jax.random.PRNGKey(seed))
            if self._p_shard is not None:
                params = jax.tree_util.tree_map(jax.device_put, params,
                                                self._p_shard)
            opt = adamw_init(params)
        return params, opt

    def save(self, step: int, params, opt, blocking=True):
        if self.ckpt is None:
            return
        self.ckpt.save(step, {"params": params, "opt": opt},
                       extra={"pipeline": self.pipeline.snapshot()},
                       blocking=blocking)

    def restore(self, params_like, opt_like, step: Optional[int] = None):
        tree, manifest = self.ckpt.restore(
            {"params": params_like, "opt": opt_like}, step=step,
            target_shardings=(None if self._p_shard is None else
                              {"params": self._p_shard,
                               "opt": self._opt_shard}))
        self.pipeline.restore(manifest["extra"]["pipeline"])
        return tree["params"], tree["opt"], manifest["step"]

    # -- loop ----------------------------------------------------------------

    def run(self, params=None, opt=None, start_step: int = 0):
        if params is None:
            params, opt = self.init_state()
        ctx = act.use_mesh(self.mesh) if self.mesh is not None else _null()
        with ctx:
            for step in range(start_step, self.tcfg.steps):
                batch = {k: jnp.asarray(v)
                         for k, v in self.pipeline.next_batch().items()}
                t0 = time.time()
                params, opt, metrics = self._jit(params, opt, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                verdict = self.detector.observe(dt)
                row = {"step": step, "loss": float(metrics["loss"]),
                       "grad_norm": float(metrics["grad_norm"]),
                       "time_s": dt, "straggler": verdict}
                self.metrics_log.append(row)
                if step % self.tcfg.log_every == 0:
                    print(f"[train] step={step} loss={row['loss']:.4f} "
                          f"gnorm={row['grad_norm']:.3f} {dt*1e3:.0f}ms",
                          flush=True)
                if self.ckpt and (step + 1) % self.tcfg.checkpoint_every == 0:
                    self.save(step + 1, params, opt, blocking=False)
        if self.ckpt:
            self.ckpt.wait()
        return params, opt


class _null:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
