"""MPGEMM-TPU Pallas kernel.

TPU-native re-derivation of the paper's SME micro-kernel (Sections IV-C, V-C):

* "All four ZA tiles resident across the K loop"  ->  an fp32/int32 VMEM
  scratch accumulator revisited by a K-innermost grid; the output block is
  written exactly once, after the full reduction (Algorithm 1 lines 1/8).
* "Four-Z-register grouped loads"  ->  BlockSpec minor dims chosen by the
  analytic planner so every DMA row is >= 512 contiguous bytes.
* "On-the-fly transposition"  ->  ``dot_general`` dimension numbers contract
  whichever axis the stored layout dictates; no materialized transpose pass.
* "Predicated edge micro-kernels"  ->  K-remainder masking with iota
  predicates in-kernel; M/N edges use Pallas partial-block masked stores.
* "Mixed precision FMOPA"  ->  bf16 x bf16 -> f32 and int8 x int8 -> int32 via
  ``preferred_element_type``, with a fused dequant/alpha/beta/bias/activation
  epilogue (the paper's first-round-online-packing lesson: never run a
  separate memory pass for work that can ride the GEMM).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu imports fine on CPU installs; guard anyway.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from repro.core.blocking import (
    GemmPlan, grouped_plan_from_2d, plan_gemm, plan_grouped_gemm,
    plan_with_blocks,
)
from repro.packing.layout import PackedOperand

_ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def _mask_contract(x, axis: int, valid):
    """Zero out lanes >= ``valid`` along ``axis`` (edge predication)."""
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, axis)
    return jnp.where(idx < valid, x, jnp.zeros_like(x))


def _dot_dims(trans_a: bool, trans_b: bool):
    """dot_general dimension numbers for on-the-fly transposition.

    a block is stored (bm,bk) or, transposed, (bk,bm); likewise b is (bk,bn)
    or (bn,bk).  The contracting axis in the *stored* layout:
    """
    ca = 0 if trans_a else 1
    cb = 1 if trans_b else 0
    return (((ca,), (cb,)), ((), ()))


def _accumulate(acc_ref, a, b, ts, trans_a: bool, trans_b: bool, acc_dtype):
    """One K-step FMA into the resident accumulator.

    ``ts`` is the packed payload's per-tile dequant scale (None on the
    unpacked path).  With a per-tile scale the accumulator is f32 and the
    scale is applied per K step — int8 x int8 contributions dot in int32
    and scale on the way in; float x int8 tiles dequantize in VMEM before
    the dot (int8 HBM reads, upcast at the compute unit)."""
    if ts is None:
        acc_ref[...] += jax.lax.dot_general(
            a, b, _dot_dims(trans_a, trans_b),
            preferred_element_type=acc_dtype)
    elif jnp.issubdtype(a.dtype, jnp.integer):
        part = jax.lax.dot_general(
            a, b, _dot_dims(trans_a, trans_b),
            preferred_element_type=jnp.int32)
        acc_ref[...] += part.astype(jnp.float32) * ts
    else:
        bf = (b.astype(jnp.float32) * ts).astype(a.dtype)
        acc_ref[...] += jax.lax.dot_general(
            a, bf, _dot_dims(trans_a, trans_b),
            preferred_element_type=acc_dtype)


def mpgemm_kernel(
    *refs,
    nk: int,
    k_rem: int,
    trans_a: bool,
    trans_b: bool,
    acc_dtype,
    alpha: float,
    beta: float,
    has_bias: bool,
    activation: Optional[str],
    has_scale: bool,
    packed_b: bool = False,
    tile_scaled: bool = False,
):
    """Grid = (M/bm, N/bn, K/bk), K innermost ('arbitrary')."""
    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    ts_ref = refs[idx] if tile_scaled else None
    idx += 1 if tile_scaled else 0
    c_ref = refs[idx] if beta != 0.0 else None
    idx += 1 if beta != 0.0 else 0
    bias_ref = refs[idx] if has_bias else None
    idx += 1 if has_bias else 0
    scale_ref = refs[idx] if has_scale else None
    idx += 1 if has_scale else 0
    out_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    # Packed B: the payload block is a pre-transposed, zero-padded (bk, bn)
    # tile behind a leading (1, 1) tile index — an identity index map, no
    # strided DMA, no on-the-fly transposition.
    b = b_ref[0, 0] if packed_b else b_ref[...]
    if k_rem:
        # Paper's predicate registers: mask the K tail so pipeline pad
        # garbage (possibly NaN) never pollutes the accumulator.  Packed
        # payload tiles were zero-padded at pack time, so only A needs the
        # predicate on that path.
        valid = jnp.where(k == nk - 1, k_rem, a.shape[0 if trans_a else 1])
        a = _mask_contract(a, 0 if trans_a else 1, valid)
        if not packed_b:
            b = _mask_contract(b, 1 if trans_b else 0, valid)

    ts = ts_ref[0, 0] if tile_scaled else None
    _accumulate(acc_ref, a, b, ts, trans_a, trans_b, acc_dtype)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_scale:
            # int8 dequant / general scaling: acc(i32|f32) * scalar -> f32.
            acc = acc.astype(jnp.float32) * scale_ref[0]
        if alpha != 1.0:
            acc = acc * jnp.asarray(alpha, acc.dtype)
        if has_bias:
            acc = acc + bias_ref[...].astype(acc.dtype)
        acc = _ACTIVATIONS[activation](acc)
        if beta != 0.0:
            acc = acc + jnp.asarray(beta, acc.dtype) * c_ref[...].astype(acc.dtype)
        out_ref[...] = acc.astype(out_ref.dtype)


def _compiler_params(interpret: bool, grid_rank: int = 3):
    """Grid semantics: every axis parallel except the K-innermost one."""
    if interpret or pltpu is None:
        return None
    cls = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams", None
    )
    if cls is None:
        return None
    semantics = ("parallel",) * (grid_rank - 1) + ("arbitrary",)
    try:
        return cls(dimension_semantics=semantics)
    except Exception:  # pragma: no cover
        return None


def _packed_plan(m: int, k: int, n: int, layout, a_dtype, out_dtype,
                 trans_a: bool, beta: float, g: int = 1) -> GemmPlan:
    """Resolve a plan for a packed-B GEMM: tuned (packed-layout namespace)
    if its blocks agree with the payload layout, else the analytic solve
    with (bn, bk) pinned to the layout — the payload's tiling IS the block
    decision, only bm stays free.  Per-tile-scaled payloads force an f32
    accumulator (scales vary per K step, so int32 accumulation across
    blocks is no longer exact)."""
    from repro.tuning.plan_cache import lookup_plan
    acc = "float32" if layout.per_tile_scales else None
    plan = lookup_plan(
        m, n, k, a_dtype, layout.dtype, out_dtype,
        trans_a=trans_a, trans_b=False, beta=beta, g=g, layout=layout.tag,
    )
    if plan is not None and (plan.bn, plan.bk) != (layout.bn, layout.bk):
        plan = None  # tuned entry from a different payload tiling
    if plan is None:
        base = plan_gemm(m, n, k, a_dtype, layout.dtype,
                         out_dtype=out_dtype, acc_dtype=acc, beta=beta)
        plan = plan_with_blocks(
            m, n, k, base.bm, layout.bn, layout.bk, a_dtype, layout.dtype,
            out_dtype, acc, beta=beta, notes="packed-b",
        )
        if g != 1:
            plan = grouped_plan_from_2d(plan, g)
    if layout.per_tile_scales and plan.acc_dtype != "float32":
        import dataclasses
        plan = dataclasses.replace(plan, acc_dtype="float32")
    return plan


def mpgemm_pallas(
    a: jax.Array,
    b: Optional[jax.Array] = None,
    c: Optional[jax.Array] = None,
    *,
    b_packed: Optional[PackedOperand] = None,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
    plan: Optional[GemmPlan] = None,
    interpret: bool = False,
) -> jax.Array:
    """out = activation(alpha * op(a) @ op(b) * scale + bias) + beta * c.

    ``b_packed`` replaces ``b`` with a pre-packed operand (repro.packing):
    the kernel reads the (bk, bn)-tiled payload through identity index
    maps — no strided DMA, no on-the-fly transposition (it was resolved at
    pack time), and for int8 payloads the per-tile dequant rides the
    accumulation.  Mutually exclusive with ``b``/``trans_b``.
    """
    if (b is None) == (b_packed is None):
        raise ValueError("exactly one of b / b_packed is required")
    layout = b_packed.layout if b_packed is not None else None
    if layout is not None and layout.g != 1:
        raise ValueError("grouped payload: use mpgemm_grouped_pallas")
    m = a.shape[1] if trans_a else a.shape[0]
    ka = a.shape[0] if trans_a else a.shape[1]
    if layout is not None:
        n, kb = layout.n, layout.k
        trans_b = False  # resolved at pack time
    else:
        n = b.shape[0] if trans_b else b.shape[1]
        kb = b.shape[1] if trans_b else b.shape[0]
    if ka != kb:
        bshape = layout.payload_shape if layout is not None else b.shape
        raise ValueError(f"contraction mismatch: {a.shape} x {bshape}")
    k = ka
    if plan is not None and layout is not None and (
            (plan.bn, plan.bk) != (layout.bn, layout.bk)):
        raise ValueError(
            f"plan blocks ({plan.bn}, {plan.bk}) incompatible with packed "
            f"layout ({layout.bn}, {layout.bk})")
    if plan is None and layout is not None:
        plan = _packed_plan(m, k, n, layout, a.dtype, out_dtype,
                            trans_a, beta)
    if plan is None:
        # Closed-loop planning: a tuned plan from the persistent cache wins
        # over the analytic model (repro.tuning populates it; lazy import
        # keeps the kernel layer free of a hard tuning dependency).
        from repro.tuning.plan_cache import lookup_plan
        plan = lookup_plan(
            m, n, k, a.dtype, b.dtype, out_dtype,
            trans_a=trans_a, trans_b=trans_b, beta=beta,
        )
    if plan is None:
        plan = plan_gemm(
            m, n, k, a.dtype, b.dtype, out_dtype=out_dtype, beta=beta
        )
    out_dtype = jnp.dtype(out_dtype or plan.out_dtype)
    acc_dtype = jnp.dtype(plan.acc_dtype)
    if layout is not None and layout.per_tile_scales:
        # Per-tile scales accumulate scaled f32 partials — coerce even for
        # an explicitly supplied plan (mirrors _packed_plan; an int32
        # accumulator would reject the scaled stores deep inside Pallas).
        acc_dtype = jnp.dtype(jnp.float32)
    bm, bn, bk = plan.bm, plan.bn, plan.bk
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    a_spec = (
        pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i))
        if trans_a
        else pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    )
    if layout is not None:
        # Identity tile read: grid step (i, j, kk) fetches payload tile
        # (kk, j) — one contiguous DMA, the payoff of ahead-of-time packing.
        b_spec = pl.BlockSpec((1, 1, bk, bn), lambda i, j, kk: (kk, j, 0, 0))
        inputs = [a, b_packed.payload]
    else:
        b_spec = (
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk))
            if trans_b
            else pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
        )
        inputs = [a, b]
    in_specs = [a_spec, b_spec]
    tile_scaled = layout is not None and layout.per_tile_scales
    if tile_scaled:
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, kk: (kk, j)))
        inputs.append(b_packed.scales)
    if beta != 0.0:
        if c is None:
            raise ValueError("beta != 0 requires c")
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        inputs.append(c)
    if bias is not None:
        bias2d = bias.reshape(1, -1)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        inputs.append(bias2d)
    if scale is not None:
        scale1d = jnp.asarray(scale, jnp.float32).reshape(1)
        in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM if (pltpu and not interpret) else None))
        inputs.append(scale1d)

    scratch = [pltpu.VMEM((bm, bn), acc_dtype)] if pltpu else [
        pl.BlockSpec(memory_space=pl.ANY)
    ]

    kernel = functools.partial(
        mpgemm_kernel,
        nk=grid[2],
        k_rem=plan.k_rem,
        trans_a=trans_a,
        trans_b=trans_b,
        acc_dtype=acc_dtype,
        alpha=float(alpha),
        beta=float(beta),
        has_bias=bias is not None,
        activation=activation,
        has_scale=scale is not None,
        packed_b=layout is not None,
        tile_scaled=tile_scaled,
    )

    kwargs = {}
    params = _compiler_params(interpret)
    if params is not None:
        kwargs["compiler_params"] = params

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*inputs)


# --- grouped / batched variant -----------------------------------------------

def mpgemm_grouped_kernel(
    *refs,
    nk: int,
    k_rem: int,
    trans_a: bool,
    trans_b: bool,
    acc_dtype,
    alpha: float,
    has_bias: bool,
    activation: Optional[str],
    has_scale: bool,
    packed_b: bool = False,
    tile_scaled: bool = False,
):
    """Grid = (G, M/bm, N/bn, K/bk), K innermost ('arbitrary').

    Identical contract to :func:`mpgemm_kernel` per group — the leading
    grid axis only selects which problem the (bm, bn) accumulator serves.
    Block refs carry a size-1 group dim; the accumulator scratch does not
    (it is recycled across groups because K is the only revisiting axis).
    """
    idx = 0
    a_ref = refs[idx]; idx += 1
    b_ref = refs[idx]; idx += 1
    ts_ref = refs[idx] if tile_scaled else None
    idx += 1 if tile_scaled else 0
    bias_ref = refs[idx] if has_bias else None
    idx += 1 if has_bias else 0
    scale_ref = refs[idx] if has_scale else None
    idx += 1 if has_scale else 0
    out_ref = refs[idx]; idx += 1
    acc_ref = refs[idx]

    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[0]
    b = b_ref[0, 0, 0] if packed_b else b_ref[0]
    if k_rem:
        valid = jnp.where(k == nk - 1, k_rem, a.shape[0 if trans_a else 1])
        a = _mask_contract(a, 0 if trans_a else 1, valid)
        if not packed_b:
            b = _mask_contract(b, 1 if trans_b else 0, valid)

    ts = ts_ref[0, 0, 0] if tile_scaled else None
    _accumulate(acc_ref, a, b, ts, trans_a, trans_b, acc_dtype)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...]
        if has_scale:
            acc = acc.astype(jnp.float32) * scale_ref[0]
        if alpha != 1.0:
            acc = acc * jnp.asarray(alpha, acc.dtype)
        if has_bias:
            acc = acc + bias_ref[0].astype(acc.dtype)
        acc = _ACTIVATIONS[activation](acc)
        out_ref[...] = acc.astype(out_ref.dtype)[None]


def mpgemm_grouped_pallas(
    a: jax.Array,
    b: Optional[jax.Array] = None,
    *,
    b_packed: Optional[PackedOperand] = None,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    bias: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    activation: Optional[str] = None,
    out_dtype=None,
    plan: Optional[GemmPlan] = None,
    interpret: bool = False,
) -> jax.Array:
    """out[g] = activation(alpha * op(a[g]) @ op(b[g]) * scale + bias[g]).

    ``a``: (G, M, K) — or (G, K, M) under ``trans_a``; ``b``: (G, K, N) —
    or (G, N, K) under ``trans_b``; ``bias``: (G, N) or (N,) broadcast to
    every group; output (G, M, N).  The G expert/batch problems share one
    kernel launch with the group as the leading (parallel) grid axis, so
    small per-expert GEMMs amortize launch and pipeline ramp-up instead of
    paying them G times — the grouped-GEMM-on-SME pattern (LOHO, Hello
    SME!) in TPU form.  No beta/C term: no grouped caller accumulates into
    an existing output (use the 2-D kernel for that).

    ``b_packed`` replaces ``b`` with a grouped packed operand (payload
    ``(G, nkb, nnb, bk, bn)``): identity tile reads per group, transpose
    resolved at pack time, per-tile int8 dequant riding the accumulation —
    the pre-packed-expert-weights serving configuration.
    """
    if (b is None) == (b_packed is None):
        raise ValueError("exactly one of b / b_packed is required")
    layout = b_packed.layout if b_packed is not None else None
    if layout is not None and layout.g == 1:
        raise ValueError("2-D payload: use mpgemm_pallas")
    if a.ndim != 3 or (b is not None and b.ndim != 3):
        raise ValueError(f"grouped operands must be rank-3: got a={a.shape}")
    g = a.shape[0]
    if layout is not None and layout.g != g:
        raise ValueError(f"group mismatch: a has {g}, payload {layout.g}")
    if b is not None and b.shape[0] != g:
        raise ValueError(f"group mismatch: {a.shape} x {b.shape}")
    m = a.shape[2] if trans_a else a.shape[1]
    ka = a.shape[1] if trans_a else a.shape[2]
    if layout is not None:
        n, kb = layout.n, layout.k
        trans_b = False  # resolved at pack time
    else:
        n = b.shape[1] if trans_b else b.shape[2]
        kb = b.shape[2] if trans_b else b.shape[1]
    if ka != kb:
        raise ValueError(f"contraction mismatch: a={a.shape}, k_b={kb}")
    k = ka
    if plan is not None and layout is not None and (
            (plan.bn, plan.bk) != (layout.bn, layout.bk)):
        raise ValueError(
            f"plan blocks ({plan.bn}, {plan.bk}) incompatible with packed "
            f"layout ({layout.bn}, {layout.bk})")
    if plan is None and layout is not None:
        plan = _packed_plan(m, k, n, layout, a.dtype, out_dtype,
                            trans_a, 0.0, g=g)
    if plan is None:
        from repro.tuning.plan_cache import lookup_plan
        plan = lookup_plan(
            m, n, k, a.dtype, b.dtype, out_dtype,
            trans_a=trans_a, trans_b=trans_b, g=g,
        )
    if plan is None:
        plan = plan_grouped_gemm(g, m, n, k, a.dtype, b.dtype,
                                 out_dtype=out_dtype)
    out_dtype = jnp.dtype(out_dtype or plan.out_dtype)
    acc_dtype = jnp.dtype(plan.acc_dtype)
    if layout is not None and layout.per_tile_scales:
        # Per-tile scales accumulate scaled f32 partials — coerce even for
        # an explicitly supplied plan (mirrors _packed_plan; an int32
        # accumulator would reject the scaled stores deep inside Pallas).
        acc_dtype = jnp.dtype(jnp.float32)
    bm, bn, bk = plan.bm, plan.bn, plan.bk
    grid = (g, pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    a_spec = (
        pl.BlockSpec((1, bk, bm), lambda gg, i, j, kk: (gg, kk, i))
        if trans_a
        else pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk))
    )
    if layout is not None:
        b_spec = pl.BlockSpec((1, 1, 1, bk, bn),
                              lambda gg, i, j, kk: (gg, kk, j, 0, 0))
        inputs = [a, b_packed.payload]
    else:
        b_spec = (
            pl.BlockSpec((1, bn, bk), lambda gg, i, j, kk: (gg, j, kk))
            if trans_b
            else pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j))
        )
        inputs = [a, b]
    in_specs = [a_spec, b_spec]
    tile_scaled = layout is not None and layout.per_tile_scales
    if tile_scaled:
        in_specs.append(pl.BlockSpec((1, 1, 1),
                                     lambda gg, i, j, kk: (gg, kk, j)))
        inputs.append(b_packed.scales)
    if bias is not None:
        bias3d = jnp.broadcast_to(
            bias.reshape((1, -1) if bias.ndim == 1 else (g, -1))[:, None, :],
            (g, 1, n),
        )
        in_specs.append(pl.BlockSpec((1, 1, bn), lambda gg, i, j, kk: (gg, 0, j)))
        inputs.append(bias3d)
    if scale is not None:
        scale1d = jnp.asarray(scale, jnp.float32).reshape(1)
        in_specs.append(pl.BlockSpec(
            memory_space=pltpu.SMEM if (pltpu and not interpret) else None))
        inputs.append(scale1d)

    scratch = [pltpu.VMEM((bm, bn), acc_dtype)] if pltpu else [
        pl.BlockSpec(memory_space=pl.ANY)
    ]

    kernel = functools.partial(
        mpgemm_grouped_kernel,
        nk=grid[3],
        k_rem=plan.k_rem,
        trans_a=trans_a,
        trans_b=trans_b,
        acc_dtype=acc_dtype,
        alpha=float(alpha),
        has_bias=bias is not None,
        activation=activation,
        has_scale=scale is not None,
        packed_b=layout is not None,
        tile_scaled=tile_scaled,
    )

    kwargs = {}
    params = _compiler_params(interpret, grid_rank=4)
    if params is not None:
        kwargs["compiler_params"] = params

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), out_dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(*inputs)
