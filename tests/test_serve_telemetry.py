"""ServeEngine per-wave telemetry (serve/engine.py::WaveTelemetry) — the
first serving observability surface: tokens/s, slot occupancy, queue depth,
and the on_wave streaming callback."""
import numpy as np
import pytest

import jax

from repro.configs import base as cb
from repro.models.transformer import build_model
from repro.serve.engine import Request, ServeEngine, WaveTelemetry


@pytest.fixture(scope="module")
def engine_setup():
    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, max_new=4):
    rng = np.random.default_rng(0)
    return [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab, (8,)).astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def test_per_wave_telemetry(engine_setup):
    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, batch_size=2, max_len=32)
    out = eng.generate(_requests(cfg, 3))
    # 3 requests / batch 2 -> two waves
    assert len(eng.telemetry) == 2
    w0, w1 = eng.telemetry
    assert isinstance(w0, WaveTelemetry)
    assert (w0.wave, w1.wave) == (0, 1)
    assert (w0.requests, w1.requests) == (2, 1)
    # tokens accounted exactly: per-wave tokens sum to the emitted total
    assert w0.tokens + w1.tokens == sum(len(v) for v in out.values())
    # queue drains monotonically: 1 request left after wave 0, 0 after 1
    assert (w0.queue_depth, w1.queue_depth) == (1, 0)
    for t in (w0, w1):
        assert t.wall_s > 0 and t.tokens_per_s > 0
        assert 0 < t.prefill_s < t.wall_s
        assert 0 < t.slot_occupancy <= 1.0
        assert t.decode_steps >= 0
    # wave 0 pays jit compilation inside prefill; wave 1 reuses both
    # executables, so its prefill must be cheaper
    assert w1.prefill_s < w0.prefill_s
    # wave 1 runs half-empty -> occupancy can never exceed 1/2
    assert w1.slot_occupancy <= 0.5 + 1e-9


def test_generate_resets_telemetry_and_streams(engine_setup):
    cfg, model, params = engine_setup
    seen = []
    eng = ServeEngine(model, params, batch_size=2, max_len=32,
                      on_wave=seen.append)
    eng.generate(_requests(cfg, 2))
    assert len(eng.telemetry) == 1 and len(seen) == 1
    assert seen[0] is eng.telemetry[0]
    # a second generate() starts a fresh telemetry list
    eng.generate(_requests(cfg, 2))
    assert len(eng.telemetry) == 1 and len(seen) == 2
    assert eng.telemetry[0].wave == 0
