"""Batched serving example: mixed-precision policies side by side.

Continuous batching with a paged KV cache, comparing the bf16 and int8
serving policies (the paper's Section V surface) on the same prompts.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.configs import base as cb
from repro.models.transformer import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    rng = np.random.default_rng(0)
    cfg = cb.get("granite-moe-1b-a400m", smoke=True)   # MoE serving
    prompts = [rng.integers(2, cfg.vocab, (rng.integers(4, 24),))
               .astype(np.int32) for _ in range(6)]

    for policy in ("bf16", "int8"):
        model = build_model(cfg, policy=policy, remat=False)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model, params, max_batch=4, max_len=128,
                          page_size=16)
        reqs = [Request(uid=i, prompt=p, max_new_tokens=12)
                for i, p in enumerate(prompts)]
        t0 = time.time()
        out = eng.generate(reqs)
        dt = time.time() - t0
        n_tok = sum(len(v) for v in out.values())
        steps = eng.step_telemetry
        peak = max((t.pages_in_use for t in steps), default=0)
        print(f"[{policy:5s}] {len(reqs)} requests in {len(steps)} steps, "
              f"{n_tok} tokens, peak {peak} KV pages, {dt:.1f}s")
        for uid in sorted(out)[:2]:
            print(f"   req{uid}: {out[uid]}")
    print("OK")


if __name__ == "__main__":
    main()
