"""Attention variants vs dense reference + flash Pallas kernel sweeps."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import (
    banded_window_attention, chunked_attention, decode_attention,
    dense_attention,
)


def _qkv(rng, b, h, hkv, tq, tk, d, dtype="float32"):
    q = jnp.asarray(rng.standard_normal((b, h, tq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, tk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, tk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("b,h,hkv,tq,tk,d,causal,window", [
    (2, 4, 2, 256, 256, 64, True, None),
    (1, 4, 1, 200, 200, 64, True, None),       # irregular T
    (2, 8, 2, 128, 384, 64, True, None),       # right-aligned continuation
    (1, 2, 2, 256, 256, 64, True, 96),         # sliding window
    (1, 4, 4, 160, 160, 128, False, None),     # cross-attention style
    (1, 2, 1, 1, 300, 64, True, None),         # single-token decode
])
def test_flash_kernel_vs_oracle(rng, b, h, hkv, tq, tk, d, causal, window):
    q, k, v = _qkv(rng, b, h, hkv, tq, tk, d)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=128, interpret=True)
    kr = jnp.repeat(k, h // hkv, axis=1)
    vr = jnp.repeat(v, h // hkv, axis=1)
    ref = flash_attention_ref(q, kr, vr, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


@pytest.mark.parametrize("dtype,atol", [("float32", 1e-5), ("bfloat16", 2e-2)])
def test_flash_kernel_dtypes(rng, dtype, atol):
    q, k, v = _qkv(rng, 1, 4, 2, 192, 192, 64, dtype)
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    kr = jnp.repeat(k, 2, axis=1)
    vr = jnp.repeat(v, 2, axis=1)
    ref = flash_attention_ref(q, kr, vr)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


def test_chunked_matches_dense(rng):
    q, k, v = _qkv(rng, 2, 4, 2, 300, 300, 64)
    ref = dense_attention(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=96)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_chunked_grads_match_dense(rng):
    q, k, v = _qkv(rng, 1, 2, 2, 128, 128, 32)
    g1 = jax.grad(lambda q: jnp.sum(
        chunked_attention(q, k, v, q_chunk=32, kv_chunk=32) ** 2))(q)
    g2 = jax.grad(lambda q: jnp.sum(dense_attention(q, k, v) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=5e-4)


def test_banded_matches_dense_window(rng):
    q, k, v = _qkv(rng, 2, 4, 2, 300, 300, 64)
    w = 64
    ref = dense_attention(q, k, v, causal=True, window=w)
    out = banded_window_attention(q, k, v, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)


def test_banded_flops_subquadratic():
    """The banded path's HLO must NOT contain a T x T logits tensor."""
    t, w = 4096, 256
    q = jnp.zeros((1, 2, t, 64))
    txt = jax.jit(lambda q: banded_window_attention(q, q, q, window=w)) \
        .lower(q).as_text()
    assert f"{t},{t}" not in txt  # no quadratic intermediate


def test_decode_matches_dense(rng):
    q, k, v = _qkv(rng, 2, 4, 2, 1, 300, 64)
    lengths = jnp.array([200, 300])
    out = decode_attention(q, k, v, lengths)
    for i, L in enumerate([200, 300]):
        ref = dense_attention(q[i:i + 1], k[i:i + 1, :, :L], v[i:i + 1, :, :L],
                              causal=False)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref[0]),
                                   atol=1e-5)


# --- ops.py wrapper: explicit, logged-once ref fallback ----------------------

def _traced_pallas_call(fn, *args, **kwargs):
    """Does tracing fn(*args, **kwargs) reach a pallas_call primitive?"""
    import functools as _ft
    jaxpr = jax.make_jaxpr(_ft.partial(fn, **kwargs))(*args)

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                return True
            for sub in jax.core.jaxprs_in_params(eqn.params):
                if walk(sub):
                    return True
        return False

    return walk(jaxpr.jaxpr)


def test_ops_flash_attention_path_traced(rng, caplog):
    import logging

    from repro.kernels import ops

    q, k, v = _qkv(rng, 1, 4, 2, 16, 16, 64)

    # Kernel path: the traced computation contains the pallas_call.
    assert _traced_pallas_call(ops.flash_attention, q, k, v, interpret=True)
    # Explicit XLA request: reference path, and NOT an implicit fallback.
    ops._FALLBACKS_LOGGED.discard("flash_attention")
    with caplog.at_level(logging.WARNING, logger="repro.kernels.ops"):
        assert not _traced_pallas_call(ops.flash_attention, q, k, v,
                                       backend="xla")
    assert not caplog.records

    # Implicit fallback (non-float operands): reference path, logged ONCE.
    qi = jnp.zeros(q.shape, jnp.int32)
    ki = jnp.zeros(k.shape, jnp.int32)
    vi = jnp.zeros(v.shape, jnp.int32)
    reason = ops.flash_attention_fallback_reason(
        qi.dtype, ki.dtype, vi.dtype, interpret=True, backend="pallas")
    assert reason is not None and "non-float" in reason
    with caplog.at_level(logging.WARNING, logger="repro.kernels.ops"):
        assert not _traced_pallas_call(ops.flash_attention, qi, ki, vi,
                                       interpret=True)
        assert not _traced_pallas_call(ops.flash_attention, qi, ki, vi,
                                       interpret=True, causal=False)
    fallback_logs = [r for r in caplog.records if "reference path" in r.message]
    assert len(fallback_logs) == 1  # logged once, later fallbacks silent


def test_ops_flash_attention_fallback_matches_kernel(rng):
    from repro.kernels import ops

    q, k, v = _qkv(rng, 1, 4, 2, 16, 16, 64)
    y_kernel = ops.flash_attention(q, k, v, interpret=True)
    y_ref = ops.flash_attention(q, k, v, backend="xla")
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=2e-5, rtol=2e-5)


def test_ops_flash_attention_gqa_mismatch_raises(rng):
    from repro.kernels import ops

    q, k, v = _qkv(rng, 1, 4, 2, 16, 16, 64)
    with pytest.raises(ValueError, match="GQA requires"):
        ops.flash_attention(q[:, :3], k, v, interpret=True)
