"""Structured tracing spans with Chrome/Perfetto ``trace.json`` export.

``span("pack", bytes=...)`` opens a named region on the current thread;
spans nest through a contextvar, so a serve step renders as a real
timeline (``serve.step`` ⊃ ``serve.admit`` ⊃ ``gemm.launch`` …) when the
exported file is loaded into Perfetto / ``chrome://tracing``.

Timestamps are host-side (``perf_counter_ns`` relative to tracer start);
modeled bytes/FLOPs from the GemmPlan ride along as span args — on CPU
the wall clocks are noise but the modeled terms localize where traffic
goes, which is the paper's Section 3 methodology applied at runtime.

Tracing is OFF by default (the ambient tracer is None and the module
helpers are no-ops); ``launch/serve.py --trace-out`` or ``set_tracer``
turn it on.  Events accumulate in memory — the tracer is a recorder for
bounded runs (a serve smoke, a bench), not a streaming profiler.
"""
from __future__ import annotations

import contextlib
import contextvars
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "Tracer",
    "annotate",
    "get_tracer",
    "instant",
    "set_tracer",
    "span",
    "tracing_enabled",
]

# Innermost-open-span stack for the current context (thread/task-local).
_span_stack: contextvars.ContextVar[Tuple[dict, ...]] = \
    contextvars.ContextVar("repro_obs_span_stack", default=())


class Tracer:
    """Collects complete ('X') and instant ('i') Chrome trace events."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._t0_ns = time.perf_counter_ns()
        self._pid = os.getpid()
        self._tid_names: Dict[int, int] = {}

    # -- internals ------------------------------------------------------------

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            if ident not in self._tid_names:
                self._tid_names[ident] = len(self._tid_names)
            return self._tid_names[ident]

    # -- recording ------------------------------------------------------------

    @contextlib.contextmanager
    def span(self, name: str, category: str = "repro", **args: Any):
        rec = {"name": name, "cat": category, "args": dict(args),
               "ts": self._now_us(), "tid": self._tid()}
        stack = _span_stack.get()
        token = _span_stack.set(stack + (rec,))
        try:
            yield rec
        finally:
            _span_stack.reset(token)
            dur = self._now_us() - rec["ts"]
            event = {"ph": "X", "name": name, "cat": category,
                     "ts": rec["ts"], "dur": dur, "pid": self._pid,
                     "tid": rec["tid"], "args": rec["args"]}
            with self._lock:
                self._events.append(event)

    def instant(self, name: str, category: str = "repro",
                **args: Any) -> None:
        event = {"ph": "i", "s": "t", "name": name, "cat": category,
                 "ts": self._now_us(), "pid": self._pid,
                 "tid": self._tid(), "args": dict(args)}
        with self._lock:
            self._events.append(event)

    def annotate(self, **args: Any) -> None:
        """Attach args to the innermost open span (no-op at top level)."""
        stack = _span_stack.get()
        if stack:
            stack[-1]["args"].update(args)

    # -- export ---------------------------------------------------------------

    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def chrome_trace(self) -> dict:
        """The ``trace.json`` payload Perfetto / chrome://tracing load."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
            f.write("\n")

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


# --- the ambient tracer (None == tracing off) --------------------------------

_ambient_lock = threading.Lock()
_ambient: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _ambient


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install ``tracer`` as ambient (None disables); returns previous."""
    global _ambient
    with _ambient_lock:
        prev = _ambient
        _ambient = tracer
    return prev


def tracing_enabled() -> bool:
    return _ambient is not None


_NULL_CM = contextlib.nullcontext()


def span(name: str, category: str = "repro", **args: Any):
    """Span on the ambient tracer; a shared no-op when tracing is off."""
    tracer = _ambient
    if tracer is None:
        return _NULL_CM
    return tracer.span(name, category, **args)


def instant(name: str, category: str = "repro", **args: Any) -> None:
    tracer = _ambient
    if tracer is not None:
        tracer.instant(name, category, **args)


def annotate(**args: Any) -> None:
    tracer = _ambient
    if tracer is not None:
        tracer.annotate(**args)
