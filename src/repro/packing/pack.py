"""Pack / unpack kernels: ahead-of-time tile packing with on-the-fly
transposition (the paper's §IV-C packing pass, run ONCE instead of per call).

``pack_operand`` reorders a weight into the plan's (bk, bn)-tiled block
layout described by :class:`repro.packing.layout.PackedLayout`:

* edge tiles are ZERO-padded (so the GEMM's K-tail needs no B-side
  predication and M/N-edge garbage cannot leak through the masked store),
* a ``trans_w`` source (stored (n, k)) is transposed DURING the pack —
  the paper's on-the-fly transposition, paid once,
* ``dtype="int8"`` quantizes each (bk, bn) tile symmetrically with its own
  f32 scale (per-tile, finer than ``core/quantization.py``'s per-tensor
  scheme) so the dequant rides the GEMM per tile.

Two implementations with identical semantics:

* a Pallas kernel (grid = tile grid, one tile per step) — the production
  path, used on the ``pallas``/``interpret`` backends;
* a pure-jnp reference (pad + reshape + transpose) — used on the ``xla``
  backend and under ``vmap`` (stacked-layer packing in ``params.py``).

``unpack_operand`` is the exact inverse (modulo quantization rounding) and
is what non-kernel backends and the backward pass use to recover a dense
operand.

Beyond int8, two sub-byte/low-precision codecs (``core.codecs``) share the
per-tile-scale machinery:

* ``int4`` — tiles quantize to +-7 and two K-adjacent values interleave
  into one payload byte (low nibble = even k, high nibble = odd k), so
  the payload moves HALF the bytes of int8.  ``unpack_nibbles`` is the
  in-register decode the GEMM kernel rides (sign-extending shifts).
* ``fp8e4m3`` — tiles scale by ``amax/448`` and saturating-cast to
  e4m3 (native ``jnp.float8_e4m3fn`` where available, emulated uint8
  bit codes otherwise — emulated payloads unpack on the XLA path only).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import obs
from repro.core import config as cfg
from repro.core.blocking import GemmPlan
from repro.core.codecs import (
    FP8_E4M3_MAX, HAS_JNP_FP8, canonical_payload_dtype, emulated_fp8_decode,
    emulated_fp8_encode, get_codec,
)
from repro.packing.layout import PackedLayout, PackedOperand


def _blocks_of(plan_or_blocks) -> Tuple[int, int]:
    if isinstance(plan_or_blocks, GemmPlan):
        return plan_or_blocks.bk, plan_or_blocks.bn
    bk, bn = plan_or_blocks
    return int(bk), int(bn)


def _layout_for(w, bk: int, bn: int, *, trans_w: bool, dtype,
                grouped: bool) -> PackedLayout:
    shape = w.shape[1:] if grouped else w.shape
    if len(shape) != 2:
        raise ValueError(f"pack_operand expects a 2-D (or grouped 3-D) "
                         f"operand, got {w.shape}")
    k, n = (shape[1], shape[0]) if trans_w else shape
    # Clamp blocks to the problem extent (mirrors plan_with_blocks): a tiny
    # operand packs as a single exact-fit tile instead of a mostly-pad one.
    return PackedLayout(
        k=k, n=n, bk=min(bk, k), bn=min(bn, n),
        dtype=canonical_payload_dtype(dtype if dtype is not None else w.dtype),
        orig_dtype=str(jnp.dtype(w.dtype)), trans_w=trans_w,
        g=w.shape[0] if grouped else 1,
    )


def _strip_group(layout: PackedLayout) -> PackedLayout:
    return dataclasses.replace(layout, g=1)


# --- pure-jnp reference (xla backend, vmap-able) ------------------------------

def _pack_dense_ref(w2d, layout: PackedLayout):
    """(k, n) / (n, k) source -> zero-padded (nkb, nnb, bk, bn) tiles."""
    if layout.trans_w:
        w2d = w2d.T
    k, n, bk, bn = layout.k, layout.n, layout.bk, layout.bn
    wp = jnp.pad(w2d, ((0, layout.nkb * bk - k), (0, layout.nnb * bn - n)))
    return wp.reshape(layout.nkb, bk, layout.nnb, bn).transpose(0, 2, 1, 3)


def pack_nibbles(q):
    """Interleave K-adjacent int4 values into bytes along the tile's K
    axis: (..., bk, bn) int8 values in [-7, 7] -> (..., ceil(bk/2), bn)
    int8 bytes, low nibble = even k, high nibble = odd k (odd bk zero-pads
    the dangling high nibble)."""
    bk = q.shape[-2]
    if bk % 2:
        q = jnp.pad(q, [(0, 0)] * (q.ndim - 2) + [(0, 1), (0, 0)])
    lo = q[..., 0::2, :]
    hi = q[..., 1::2, :]
    return ((hi << 4) | (lo & 0xF)).astype(jnp.int8)


def unpack_nibbles(b, rows: int):
    """Inverse of :func:`pack_nibbles` — the in-register decode the GEMM
    kernel uses: sign-extend each nibble with arithmetic shifts, then
    interleave back to ``rows`` logical K rows."""
    lo = (b << 4) >> 4                    # int8 shifts sign-extend
    hi = b >> 4
    pair = jnp.stack((lo, hi), axis=-2)   # (..., hk, 2, bn)
    full = pair.reshape(*b.shape[:-2], 2 * b.shape[-2], b.shape[-1])
    return full[..., :rows, :]


def _encode_quant_tiles(tiles, codec):
    """Per-tile symmetric quantization for one codec: (..., bk, bn) ->
    (payload tiles in the codec's storage dtype, f32 scales).  int4
    payloads are nibble-packed (physical rows = ceil(bk/2))."""
    t32 = tiles.astype(jnp.float32)
    amax = jnp.max(jnp.abs(t32), axis=(-2, -1))
    scales = jnp.maximum(amax, 1e-8) / codec.qmax
    scaled = t32 / scales[..., None, None]
    if codec.integer:
        q = jnp.clip(jnp.round(scaled),
                     -codec.qmax, codec.qmax).astype(jnp.int8)
        if codec.elems_per_byte > 1:
            q = pack_nibbles(q)
        return q, scales.astype(jnp.float32)
    # fp8e4m3: saturating cast — e4m3fn has no inf, so clamp to the max
    # finite magnitude instead of overflowing to NaN.
    q = jnp.clip(scaled, -FP8_E4M3_MAX, FP8_E4M3_MAX)
    if HAS_JNP_FP8:
        return q.astype(jnp.float8_e4m3fn), scales.astype(jnp.float32)
    return emulated_fp8_encode(q), scales.astype(jnp.float32)


def _quantize_tiles_ref(tiles):
    """int8 per-tile quantization — the tile-sparse path's fixed codec
    (sparse payloads stay int8; :func:`_encode_quant_tiles` is the
    codec-general form the packed path uses)."""
    return _encode_quant_tiles(tiles, get_codec("int8"))


def decode_payload_tiles(payload, layout: PackedLayout):
    """Payload tiles -> per-element values (pre-scale): int4 nibbles
    sign-extend and interleave back to bk rows, emulated-fp8 bit codes
    decode to f32, byte-native payloads pass through."""
    codec = layout.codec
    if codec is None:
        return payload
    if codec.elems_per_byte > 1:
        return unpack_nibbles(payload, layout.bk)
    if not codec.integer and not codec.kernel_native:
        return emulated_fp8_decode(payload)
    return payload


def pack_reference(w, layout: PackedLayout):
    """The jnp pack: (payload, scales|None).  Also the payload-cotangent
    map used by the packed ops' VJP (linear for float payloads)."""
    if layout.g != 1:
        tiles = jax.vmap(
            lambda x: _pack_dense_ref(x, _strip_group(layout)))(w)
    else:
        tiles = _pack_dense_ref(w, layout)
    if layout.per_tile_scales:
        return _encode_quant_tiles(tiles, layout.codec)
    return tiles.astype(layout.storage_dtype), None


def _unpack_tiles_ref(tiles, layout: PackedLayout):
    full = tiles.transpose(0, 2, 1, 3).reshape(
        layout.nkb * layout.bk, layout.nnb * layout.bn)
    return full[: layout.k, : layout.n]


def unpack_reference(payload, scales, layout: PackedLayout, dtype):
    tiles = decode_payload_tiles(payload, layout)
    if scales is not None:
        tiles = tiles.astype(jnp.float32) * scales[..., None, None]
    if layout.g != 1:
        inner = _strip_group(layout)
        return jax.vmap(
            lambda t: _unpack_tiles_ref(t, inner))(tiles).astype(dtype)
    return _unpack_tiles_ref(tiles, layout).astype(dtype)


# --- Pallas kernels -----------------------------------------------------------

def _masked_tile(src_ref, i, j, layout: PackedLayout):
    """Read one source tile at tile-grid (i, j), transpose-resolved, with
    out-of-bounds lanes zeroed: edge tiles of a non-multiple operand read
    pipeline pad garbage (possibly NaN) which must never reach the payload
    — zero pads are what let the GEMM skip B-side K-edge predication."""
    tile = src_ref[...].reshape(src_ref.shape[-2:])
    if layout.trans_w:
        tile = tile.T                      # (bn, bk) storage -> (bk, bn)
    rows = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, tile.shape, 1)
    valid_r = layout.k - i * layout.bk
    valid_c = layout.n - j * layout.bn
    return jnp.where((rows < valid_r) & (cols < valid_c), tile,
                     jnp.zeros_like(tile))


def _tile_ids(grouped: bool):
    return ((pl.program_id(1), pl.program_id(2)) if grouped
            else (pl.program_id(0), pl.program_id(1)))


def _pack_kernel(src_ref, out_ref, *, layout: PackedLayout, grouped: bool):
    tile = _masked_tile(src_ref, *_tile_ids(grouped), layout)
    out_ref[...] = tile.astype(out_ref.dtype).reshape(out_ref.shape)


def _pack_quant_kernel(src_ref, out_ref, scale_ref, *, layout: PackedLayout,
                       grouped: bool):
    codec = layout.codec
    tile = _masked_tile(src_ref, *_tile_ids(grouped), layout)
    tile = tile.astype(jnp.float32)
    amax = jnp.max(jnp.abs(tile))
    scale = jnp.maximum(amax, 1e-8) / codec.qmax
    scaled = tile / scale
    if codec.integer:
        q = jnp.clip(jnp.round(scaled),
                     -codec.qmax, codec.qmax).astype(jnp.int8)
        if codec.elems_per_byte > 1:
            q = pack_nibbles(q)
    else:
        # Saturating e4m3 cast (kernel path requires the native dtype).
        q = jnp.clip(scaled, -FP8_E4M3_MAX,
                     FP8_E4M3_MAX).astype(out_ref.dtype)
    out_ref[...] = q.astype(out_ref.dtype).reshape(out_ref.shape)
    scale_ref[...] = jnp.full(scale_ref.shape, scale, jnp.float32)


def _unpack_kernel(payload_ref, out_ref, *, dtype):
    out_ref[...] = payload_ref[...].reshape(out_ref.shape).astype(dtype)


def _unpack_quant_kernel(payload_ref, scale_ref, out_ref, *, dtype,
                         layout: PackedLayout):
    tile = payload_ref[...].reshape(payload_ref.shape[-2:])
    tile = decode_payload_tiles(tile, layout).astype(jnp.float32)
    tile = tile * scale_ref[0].reshape(-1)[0]
    out_ref[...] = tile.astype(dtype).reshape(out_ref.shape)


def _src_spec(layout: PackedLayout, grouped: bool):
    bk, bn = layout.bk, layout.bn
    if layout.trans_w:
        block, imap = (bn, bk), lambda i, j: (j, i)
    else:
        block, imap = (bk, bn), lambda i, j: (i, j)
    if grouped:
        return pl.BlockSpec((1,) + block,
                            lambda g, i, j: (g,) + imap(i, j))
    return pl.BlockSpec(block, imap)


def _payload_spec(layout: PackedLayout, grouped: bool):
    tile = layout.payload_tile
    if grouped:
        return pl.BlockSpec((1, 1, 1) + tile,
                            lambda g, i, j: (g, i, j, 0, 0))
    return pl.BlockSpec((1, 1) + tile, lambda i, j: (i, j, 0, 0))


def _scales_spec(grouped: bool):
    if grouped:
        return pl.BlockSpec((1, 1, 1), lambda g, i, j: (g, i, j))
    return pl.BlockSpec((1, 1), lambda i, j: (i, j))


def _pack_pallas(w, layout: PackedLayout, *, interpret: bool):
    grouped = layout.g != 1
    grid = ((layout.g,) if grouped else ()) + (layout.nkb, layout.nnb)
    src_spec = _src_spec(layout, grouped)
    payload_spec = _payload_spec(layout, grouped)
    if not layout.per_tile_scales:
        kernel = functools.partial(_pack_kernel, layout=layout,
                                   grouped=grouped)
        payload = pl.pallas_call(
            kernel, grid=grid, in_specs=[src_spec], out_specs=payload_spec,
            out_shape=jax.ShapeDtypeStruct(layout.payload_shape,
                                           layout.storage_dtype),
            interpret=interpret,
        )(w)
        return payload, None
    kernel = functools.partial(_pack_quant_kernel, layout=layout,
                               grouped=grouped)
    payload, scales = pl.pallas_call(
        kernel, grid=grid, in_specs=[src_spec],
        out_specs=[payload_spec, _scales_spec(grouped)],
        out_shape=[
            jax.ShapeDtypeStruct(layout.payload_shape, layout.storage_dtype),
            jax.ShapeDtypeStruct(layout.scales_shape, jnp.float32),
        ],
        interpret=interpret,
    )(w)
    return payload, scales


def _unpack_pallas(p: PackedOperand, dtype, *, interpret: bool):
    layout = p.layout
    grouped = layout.g != 1
    grid = ((layout.g,) if grouped else ()) + (layout.nkb, layout.nnb)
    out_spec = pl.BlockSpec(
        ((1,) if grouped else ()) + (layout.bk, layout.bn),
        (lambda g, i, j: (g, i, j)) if grouped else (lambda i, j: (i, j)))
    out_shape = jax.ShapeDtypeStruct(
        ((layout.g,) if grouped else ()) + (layout.k, layout.n),
        jnp.dtype(dtype))
    if p.scales is None:
        kernel = functools.partial(_unpack_kernel, dtype=jnp.dtype(dtype))
        return pl.pallas_call(
            kernel, grid=grid, in_specs=[_payload_spec(layout, grouped)],
            out_specs=out_spec, out_shape=out_shape, interpret=interpret,
        )(p.payload)
    kernel = functools.partial(_unpack_quant_kernel, dtype=jnp.dtype(dtype),
                               layout=layout)
    return pl.pallas_call(
        kernel, grid=grid,
        in_specs=[_payload_spec(layout, grouped), _scales_spec(grouped)],
        out_specs=out_spec, out_shape=out_shape, interpret=interpret,
    )(p.payload, p.scales)


# --- public API ---------------------------------------------------------------

def _resolve_method(backend: Optional[str]) -> str:
    backend = backend or cfg.get_gemm_backend()
    return backend if backend in ("pallas", "interpret", "xla") else "xla"


def pack_operand(
    w,
    plan_or_blocks: Union[GemmPlan, Tuple[int, int]],
    *,
    trans_w: bool = False,
    dtype=None,
    backend: Optional[str] = None,
) -> PackedOperand:
    """Pack a (k, n) / (n, k) weight — or a grouped (g, ., .) stack — into
    the (bk, bn)-tiled block layout of ``plan_or_blocks``.

    ``dtype`` selects the payload: a float dtype stores cast tiles; a
    codec name (``"int8"`` / ``"int4"`` / ``"fp8e4m3"``, aliases like
    ``"fp8"`` accepted) stores per-tile symmetrically-quantized tiles plus
    f32 scales — int4 nibble-packs two K-adjacent values per byte.
    Defaults to the source dtype.  The result is a :class:`PackedOperand`
    consumable by ``mp_dot(x, packed)`` / ``mpgemm_pallas(a, packed)``.
    """
    bk, bn = _blocks_of(plan_or_blocks)
    grouped = w.ndim == 3
    layout = _layout_for(w, bk, bn, trans_w=trans_w, dtype=dtype,
                         grouped=grouped)
    method = _resolve_method(backend)
    if not layout.kernel_native:
        method = "xla"          # emulated fp8 encodes via the jnp table
    with obs.span("pack", dtype=str(layout.dtype), bk=bk, bn=bn,
                  g=layout.g, method=method):
        if method == "xla":
            payload, scales = pack_reference(w, layout)
        else:
            payload, scales = _pack_pallas(w, layout,
                                           interpret=(method == "interpret"))
        obs.annotate(payload_bytes=int(payload.size)
                     * jnp.dtype(payload.dtype).itemsize)
    return PackedOperand(payload, scales, layout)


def unpack_operand(p: PackedOperand, *, dtype=None,
                   backend: Optional[str] = None):
    """Inverse of :func:`pack_operand`: dense (k, n) (grouped: (g, k, n)),
    transpose already resolved.  Quantized payloads (int8/int4/fp8e4m3)
    dequantize per tile; float payloads round-trip exactly.  ``dtype``
    defaults to the payload dtype (quantized codecs: the source dtype
    recorded at pack time)."""
    layout = p.layout
    if dtype is None:
        dtype = layout.orig_dtype if layout.per_tile_scales else layout.dtype
    method = _resolve_method(backend)
    if not layout.kernel_native:
        method = "xla"          # emulated fp8 decodes via the jnp table
    if method == "xla":
        return unpack_reference(p.payload, p.scales, layout, dtype)
    return _unpack_pallas(p, dtype, interpret=(method == "interpret"))
