import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the FULL-config model (ShapeDtypeStruct only — no allocation),
  2. constructs in/out NamedShardings from distributed/sharding.py rules,
  3. jits train_step (train shapes) or prefill/decode (serve shapes),
  4. ``.lower().compile()`` on the 16x16 (single-pod, 256 chips) or
     2x16x16 (multi-pod, 512 chips) mesh,
  5. records memory_analysis, XLA cost_analysis, and our while-aware HLO
     cost model (core/hlo_analysis) + roofline terms (core/roofline)
     to experiments/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
  python -m repro.launch.dryrun --summary          # print roofline table
"""
import argparse
import functools
import json
import time
import traceback
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cb
from repro.core import roofline as rf
from repro.core.hlo_analysis import analyze_hlo_text
from repro.distributed import act
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step, pick_microbatches

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
OUT_DIR = os.path.abspath(OUT_DIR)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg, shape: cb.ShapeConfig, kind: str, model) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if kind == "train":
        batch = {"tokens": _sds((b, s + 1), jnp.int32)}
    elif kind == "prefill":
        batch = {"tokens": _sds((b, s), jnp.int32)}
    else:  # decode
        batch = {"tokens": _sds((b, 1), jnp.int32)}
    if cfg.family == "vlm" and kind != "decode":
        batch["image_embeds"] = _sds(
            (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio" and kind != "decode":
        batch["audio_embeds"] = _sds(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def _mem_dict(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_est": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  - ma.alias_size_in_bytes
                                  + ma.temp_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             policy_train: str = "bf16", policy_serve: str = "bf16_serve",
             quant: bool = False, save: bool = True) -> Dict:
    mesh_name = "multi" if multi_pod else "single"
    if quant:
        mesh_name += "-int8"
    shape = cb.SHAPES[shape_name]
    cfg = cb.get(arch)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "status": "ok"}
    ok, reason = cb.supports_shape(cfg, shape)
    if not ok:
        result.update(status="skip", reason=reason)
        if save:
            _save(result)
        return result

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    ddp = n_chips // mesh.shape["model"]
    policy = policy_train if shape.kind == "train" else policy_serve
    model = build_model(cfg, policy=policy, remat=(shape.kind == "train"))

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.kind != "train":
        params_shape = jax.tree_util.tree_map(
            lambda x: _sds(x.shape, jnp.bfloat16), params_shape)
        if quant:
            from repro.core.quantization import quantize_params
            params_shape = jax.eval_shape(quantize_params, params_shape)
    p_shard = sh.params_shardings(params_shape, cfg, mesh)
    batch = input_specs(cfg, shape, shape.kind, model)
    b_shard = sh.batch_shardings(batch, mesh)
    repl = sh.replicated(mesh)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        opt_shard = type(opt_shape)(step=repl, m=p_shard, v=p_shard)
        micro = pick_microbatches(cfg, shape, ddp)
        result["microbatches"] = micro
        step_fn = make_train_step(model, AdamWConfig(), microbatches=micro,
                                  grad_shardings=p_shard)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, opt_shard, b_shard),
            out_shardings=(p_shard, opt_shard, None),
            donate_argnums=(0, 1),
        )
        with mesh, act.use_mesh(mesh):
            lowered = jitted.lower(params_shape, opt_shape, batch)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        prefill = functools.partial(model.prefill, max_len=shape.seq_len)
        caches_shape = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len))
        c_shard = sh.caches_shardings(caches_shape, cfg, mesh)
        da = sh.batch_axes(mesh)
        logits_shard = NamedSharding(
            mesh, sh._guard(mesh, (da if len(da) > 1 else da[0], "model"),
                            (shape.global_batch, 1)))
        jitted = jax.jit(prefill, out_shardings=(logits_shard, c_shard),
                         in_shardings=(p_shard, b_shard))
        with mesh, act.use_mesh(mesh):
            lowered = jitted.lower(params_shape, batch)
            compiled = lowered.compile()
    else:  # decode
        caches_shape = jax.eval_shape(
            lambda: model.init_caches(shape.global_batch, shape.seq_len))
        c_shard = sh.caches_shardings(caches_shape, cfg, mesh)
        da = sh.batch_axes(mesh)
        logits_shard = NamedSharding(
            mesh, sh._guard(mesh, (da if len(da) > 1 else da[0], "model"),
                            (shape.global_batch, 1)))
        token = _sds((shape.global_batch, 1), jnp.int32)
        pos = _sds((), jnp.int32)
        step = lambda p, t, c, q: model.decode_step(p, t, c, q)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, sh.batch_shardings(token, mesh),
                          c_shard, repl),
            out_shardings=(logits_shard, c_shard),
            donate_argnums=(2,),
        )
        with mesh, act.use_mesh(mesh):
            lowered = jitted.lower(params_shape, token, caches_shape, pos)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    result["compile_s"] = round(compile_s, 2)
    result["memory"] = _mem_dict(compiled)
    try:
        ca = compiled.cost_analysis()
        result["xla_cost"] = {k: float(v) for k, v in ca.items()
                              if "flops" in k or k == "bytes accessed"}
    except Exception as e:
        result["xla_cost"] = {"error": str(e)}

    hlo_text = compiled.as_text()
    result["hlo_chars"] = len(hlo_text)
    hlo = analyze_hlo_text(hlo_text)
    report = rf.build_report(
        arch=arch, shape_cfg=shape, mesh_name=mesh_name, n_chips=n_chips,
        hlo=hlo, cfg=cfg, kind=shape.kind, policy="bf16")
    result["hlo_cost"] = {
        "flops": hlo.flops, "dot_flops": hlo.dot_flops,
        "hbm_bytes": hlo.hbm_bytes, "upcast_bytes": hlo.upcast_bytes,
        "collective_bytes": hlo.collective_bytes,
        "collective_by_kind": hlo.collective_by_kind,
        "n_while": hlo.n_while, "trip_counts": hlo.trip_counts[:64],
    }
    result["roofline"] = rf.report_to_dict(report)
    if save:
        _save(result)
    return result


def _save(result: Dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    with open(os.path.join(OUT_DIR, name), "w") as f:
        json.dump(result, f, indent=1, default=float)


def summary(mesh_filter: str = "single"):
    rows = []
    for fname in sorted(os.listdir(OUT_DIR)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(OUT_DIR, fname)) as f:
            r = json.load(f)
        if r["mesh"] != mesh_filter:
            continue
        if r["status"] == "skip":
            rows.append(f"{r['arch']:>22s} {r['shape']:>12s}  SKIP ({r['reason'][:40]})")
            continue
        if r["status"] != "ok":
            rows.append(f"{r['arch']:>22s} {r['shape']:>12s}  FAIL")
            continue
        ro = r["roofline"]
        rows.append(
            f"{r['arch']:>22s} {r['shape']:>12s} "
            f"comp={ro['compute_s']:9.4f} mem={ro['memory_s']:9.4f} "
            f"coll={ro['collective_s']:9.4f} -> {ro['bottleneck']:10s} "
            f"useful={ro['useful_ratio']:6.3f} "
            f"mem/dev={r['memory'].get('peak_bytes_est', 0)/2**30:6.2f}GiB")
    print("\n".join(rows))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--summary", action="store_true")
    ap.add_argument("--quant", action="store_true",
                    help="static-int8 weights for serve cells")
    args = ap.parse_args()
    if args.summary:
        summary("single")
        print("\n--- multi-pod ---")
        summary("multi")
        return
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = cb.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(cb.SHAPES) if (args.all or not args.shape) else [args.shape]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'multi' if mp else 'single'}"
                try:
                    r = run_cell(arch, shape, mp, quant=args.quant)
                    status = r["status"]
                    extra = (f" compile={r.get('compile_s')}s"
                             f" bottleneck={r.get('roofline', {}).get('bottleneck')}"
                             if status == "ok" else r.get("reason", ""))
                    print(f"[dryrun] {tag}: {status}{extra}", flush=True)
                except Exception:
                    print(f"[dryrun] {tag}: EXCEPTION", flush=True)
                    traceback.print_exc()
                    _save({"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "kind": cb.SHAPES[shape].kind,
                           "status": "error",
                           "error": traceback.format_exc()[-2000:]})


if __name__ == "__main__":
    main()
