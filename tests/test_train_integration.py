"""End-to-end training integration: loss decreases, checkpoint/resume is
bit-exact on the data stream, microbatching equals full-batch grads."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.configs.base import ShapeConfig
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _tiny_shape():
    return ShapeConfig("tiny", seq_len=32, global_batch=4, kind="train")


def test_loss_decreases(tmp_path):
    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="fp32", remat=False)
    tcfg = TrainerConfig(steps=90, log_every=1000, opt=AdamWConfig(lr=5e-3))
    trainer = Trainer(model, _tiny_shape(), tcfg)
    trainer.run()
    losses = [m["loss"] for m in trainer.metrics_log]
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    assert last < first - 0.2, (losses[:3], losses[-3:])


def test_checkpoint_resume_bit_exact(tmp_path):
    cfg = cb.get("h2o-danube3-4b", smoke=True)
    model = build_model(cfg, policy="fp32", remat=False)
    ck = str(tmp_path / "ckpt")

    # run 8 steps with checkpointing every 4
    tcfg = TrainerConfig(steps=8, checkpoint_every=4, checkpoint_dir=ck,
                         log_every=1000, opt=AdamWConfig(lr=1e-3))
    tr1 = Trainer(model, _tiny_shape(), tcfg)
    p1, o1 = tr1.run()

    # restore at step 4, rerun 4 steps -> identical params
    tr2 = Trainer(model, _tiny_shape(), tcfg)
    params_like, opt_like = tr2.init_state()
    p2, o2, step = tr2.restore(params_like, opt_like, step=4)
    assert step == 4
    p2, o2 = tr2.run(p2, o2, start_step=4)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatching_matches_full_batch(rng):
    cfg = cb.get("starcoder2-3b", smoke=True)
    model = build_model(cfg, policy="fp32", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 33)),
                                   "int32")}
    s1 = make_train_step(model, AdamWConfig(lr=1e-3), microbatches=1)
    s2 = make_train_step(model, AdamWConfig(lr=1e-3), microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p2, _, m2 = jax.jit(s2)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-6, rtol=2e-5)
