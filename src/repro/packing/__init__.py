"""Packed-operand subsystem — ahead-of-time tile packing (paper §IV-C).

The layer between planning and execution: weights that never change are
reorganized ONCE into the kernel-native (bk, bn) tile layout the block
planner chose, so the GEMM inner loop stops paying per-call layout costs
(strided DMA, on-the-fly transposition, compute-dtype casts, dequant
materialization).

    core/blocking.py (plan)                 repro.tuning (tuned plan)
            └──────────────┬───────────────────────┘
                           ▼
    repro.packing: pack_operand / pack_params      <- THIS SUBSYSTEM
            │  PackedOperand (payload + per-tile scales + PackedLayout)
            │  PackedWeightCache (REPRO_PACK_CACHE, pack once per
            │                     checkpoint x plan)
            ▼
    mp_dot / mp_dot_grouped (x, PackedOperand)
            ▼
    kernels/mpgemm.py  mpgemm_pallas(a, packed)  — identity tile reads

Public API: :func:`pack_operand`, :func:`unpack_operand`,
:func:`pack_params`, :class:`PackedOperand`, :class:`PackedLayout`,
:class:`PackedWeightCache`, :func:`get_pack_cache`, :func:`set_pack_cache`,
:func:`make_weight_key`, :func:`is_packed`.
See docs/packing.md for layout diagrams and the when-does-it-pay analysis.
"""
from repro.packing.cache import (
    PackedWeightCache, get_pack_cache, make_weight_key, set_pack_cache,
    weight_digest,
)
from repro.packing.layout import PackedLayout, PackedOperand, is_packed
from repro.packing.pack import pack_operand, pack_reference, unpack_operand
from repro.packing.params import pack_params, packed_param_bytes

__all__ = [
    "PackedLayout", "PackedOperand", "PackedWeightCache",
    "get_pack_cache", "is_packed", "make_weight_key", "pack_operand",
    "pack_params", "pack_reference", "packed_param_bytes", "set_pack_cache",
    "unpack_operand", "weight_digest",
]
