"""Per-architecture smoke + consistency tests: every assigned arch runs one
forward/train step on CPU (reduced config), asserts shapes + finiteness, and
checks prefill->decode consistency against a longer prefill."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models.transformer import build_model


def _batch(rng, cfg, b, s):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), "int32")}
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_image_tokens, cfg.d_model)), "float32")
    if cfg.family == "audio":
        batch["audio_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), "float32")
    return batch


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_arch_train_step_smoke(rng, arch):
    cfg = cb.get(arch, smoke=True)
    model = build_model(cfg, policy="bf16")
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg, 2, 65)
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert jnp.isfinite(loss), arch
    assert 2.0 < float(loss) < 12.0, f"{arch}: loss {loss} not ~ln(V)"
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch
    gnorm = float(jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                               for g in leaves)))
    assert gnorm > 1e-4, f"{arch}: gradients all ~zero"


@pytest.mark.parametrize("arch", cb.ARCH_IDS)
def test_arch_prefill_decode_consistency(rng, arch):
    """decode_step(token_S | prefill(tokens[:S])) must match
    prefill(tokens[:S+1]) last-token logits."""
    cfg = cb.get(arch, smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    s = 48
    batch = _batch(rng, cfg, 2, s + 1)
    short = dict(batch, tokens=batch["tokens"][:, :s])
    full = dict(batch)
    logits_full, _ = model.prefill(params, full, max_len=s + 9)
    _, caches = model.prefill(params, short, max_len=s + 9)
    logits_dec, _ = model.decode_step(
        params, batch["tokens"][:, s:s + 1], caches, jnp.int32(s))
    lf = np.asarray(logits_full[:, :cfg.vocab], np.float32)
    ld = np.asarray(logits_dec[:, :cfg.vocab], np.float32)
    # bf16 paths differ (chunked vs single-token) — compare normalized.
    denom = np.maximum(np.abs(lf).max(), 1.0)
    np.testing.assert_allclose(ld / denom, lf / denom, atol=6e-2)
    # top-1 agreement on most rows
    agree = (lf.argmax(-1) == ld.argmax(-1)).mean()
    assert agree >= 0.5, f"{arch}: decode/prefill top-1 agreement {agree}"


@pytest.mark.parametrize("arch", ["h2o-danube3-4b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "mixtral-8x22b"])
def test_multistep_decode_stability(rng, arch):
    cfg = cb.get(arch, smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(rng, cfg, 2, 16)
    logits, caches = model.prefill(params, batch, max_len=48)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for step in range(6):
        logits, caches = model.decode_step(params, tok, caches,
                                           jnp.int32(16 + step))
        assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab])))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_full_configs_param_counts():
    """Full (non-smoke) configs match their published parameter scale."""
    expected = {
        "h2o-danube3-4b": (3.0e9, 5.0e9),
        "starcoder2-3b": (2.4e9, 4.0e9),
        "phi3-mini-3.8b": (3.0e9, 4.6e9),
        "phi3-medium-14b": (11e9, 16e9),
        "mixtral-8x22b": (120e9, 160e9),
        "granite-moe-1b-a400m": (0.9e9, 1.7e9),
        "llama-3.2-vision-11b": (8e9, 13e9),
        "rwkv6-1.6b": (1.2e9, 2.2e9),
        "whisper-medium": (0.55e9, 1.1e9),
        "recurrentgemma-2b": (2.0e9, 3.6e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = cb.get(arch)
        total = cfg.total_params()
        assert lo <= total <= hi, f"{arch}: {total/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"


def test_long_context_applicability():
    long = cb.SHAPES["long_500k"]
    runnable = {a for a in cb.ARCH_IDS
                if cb.supports_shape(cb.get(a), long)[0]}
    assert runnable == {"h2o-danube3-4b", "mixtral-8x22b", "rwkv6-1.6b",
                        "recurrentgemma-2b"}
