"""Model assembly: pattern-segmented layer stacks (scan over repeating units)
and the LM class exposing init / loss_fn / prefill / decode_step.

Heterogeneous architectures (vision cross-attn every 5th layer,
recurrentgemma's rglru/rglru/attn pattern) are handled by finding the
smallest repeating *unit* of the block pattern and scanning over units, with
any remainder layers applied unscanned — HLO stays compact (one unit body)
regardless of depth, which keeps 56-layer × 512-device AOT compiles cheap.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import act
from repro.models import blocks as B
from repro.models import recurrent as R
from repro.models.layers import (
    embed_init, logits_from_hidden, rope_frequencies,
)
from repro.models.losses import chunked_softmax_xent

INIT = {
    "dense": B.init_dense,
    "attn_local": B.init_dense,
    "moe": B.init_moe,
    "cross": B.init_cross,
    "encdec": B.init_encdec,
    "rwkv": R.init_rwkv,
    "rglru": R.init_rglru,
}


def _kind_window(cfg, kind):
    if kind == "attn_local":
        return cfg.local_attn_window
    return cfg.window


def block_fwd(kind, params, x, ctx):
    cfg = ctx["cfg"]
    if kind in ("dense", "attn_local"):
        return B.dense_fwd(params, x, ctx, window=_kind_window(cfg, kind))
    if kind == "moe":
        return B.moe_fwd(params, x, ctx, window=cfg.window)
    if kind == "cross":
        return B.cross_fwd(params, x, ctx)
    if kind == "encdec":
        return B.encdec_fwd(params, x, ctx)
    if kind == "rwkv":
        return R.rwkv_fwd(params, x, ctx)
    if kind == "rglru":
        return R.rglru_fwd(params, x, ctx)
    raise ValueError(kind)


def block_decode(kind, params, x, cache, ctx):
    if kind in ("dense", "attn_local"):
        return B.dense_decode(params, x, cache, ctx)
    if kind == "moe":
        return B.moe_decode(params, x, cache, ctx)
    if kind == "cross":
        return B.cross_decode(params, x, cache, ctx)
    if kind == "encdec":
        return B.encdec_decode(params, x, cache, ctx)
    if kind == "rwkv":
        return R.rwkv_decode(params, x, cache, ctx)
    if kind == "rglru":
        return R.rglru_decode(params, x, cache, ctx)
    raise ValueError(kind)


def block_init_cache(kind, cfg, batch, max_len, dtype=jnp.bfloat16):
    if kind in ("dense", "attn_local"):
        return B.dense_init_cache(cfg, batch, max_len, dtype,
                                  window=_kind_window(cfg, kind))
    if kind == "moe":
        return B.moe_init_cache(cfg, batch, max_len, dtype, window=cfg.window)
    if kind == "cross":
        return B.cross_init_cache(cfg, batch, max_len, dtype)
    if kind == "encdec":
        return B.encdec_init_cache(cfg, batch, max_len, dtype)
    if kind == "rwkv":
        return R.rwkv_init_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return R.rglru_init_cache(cfg, batch, max_len, dtype)
    raise ValueError(kind)


# Block kinds the paged-KV serving path supports (recurrent state and
# cross-attention caches are not paged — those archs serve via the wave
# engine; see docs/serving.md).
PAGED_KINDS = ("dense", "attn_local", "moe")


def block_paged_step(kind, params, x, cache, ctx):
    cfg = ctx["cfg"]
    if kind in ("dense", "attn_local"):
        return B.dense_paged_step(params, x, cache, ctx,
                                  window=_kind_window(cfg, kind))
    if kind == "moe":
        return B.moe_paged_step(params, x, cache, ctx, window=cfg.window)
    raise ValueError(
        f"block kind {kind!r} has no paged-KV step (supported: "
        f"{PAGED_KINDS}); serve this arch with the wave engine")


def segment_pattern(pattern: Tuple[str, ...]):
    """-> (unit, n_units, remainder): smallest unit P<=8 such that the
    pattern is unit-periodic with a unit-prefix remainder."""
    L = len(pattern)
    for p in range(1, min(8, L) + 1):
        n_units = L // p
        if n_units == 0:
            continue
        if all(pattern[i] == pattern[i % p] for i in range(n_units * p)):
            rem = pattern[n_units * p:]
            if all(rem[i] == pattern[i] for i in range(len(rem))):
                return pattern[:p], n_units, rem
    return pattern, 1, ()


@dataclasses.dataclass
class LM:
    """Decoder LM (optionally with encoder / cross-attention inputs)."""

    cfg: ArchConfig
    policy: str = "bf16"
    remat: bool = True
    act_dtype: Any = None

    def __post_init__(self):
        self.unit, self.n_units, self.rem = segment_pattern(self.cfg.pattern)
        if self.act_dtype is None:
            from repro.core.policy import get_policy
            self.act_dtype = jnp.dtype(get_policy(self.policy).out_dtype)

    # ------------------------------ init ------------------------------------

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to 128 so embed/head shard cleanly over the
        'model' axis (granite's 49155, whisper's 51865...).  The loss and
        serve logits mask the padding."""
        return ((self.cfg.vocab + 127) // 128) * 128

    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        vp = self.vocab_padded
        keys = jax.random.split(key, 8)
        params: Dict[str, Any] = {
            "embed": embed_init(keys[0], vp, cfg.d_model),
            "final_norm": B.init_norm(cfg),
        }
        if not cfg.tie_embeddings:
            params["head"] = (
                jax.random.normal(keys[1], (cfg.d_model, vp)) * 0.02
            ).astype(jnp.float32)
        if cfg.pos_embed == "learned":
            params["pos_embed"] = embed_init(keys[2], 32768 + 8, cfg.d_model)
        # main stack
        stack = []
        for p, kind in enumerate(self.unit):
            ks = jax.random.split(jax.random.fold_in(keys[3], p), self.n_units)
            stack.append(jax.vmap(lambda k: INIT[kind](k, cfg))(ks))
        params["stack"] = stack
        params["tail"] = [
            INIT[kind](jax.random.fold_in(keys[4], i), cfg)
            for i, kind in enumerate(self.rem)
        ]
        if cfg.encoder_layers:
            ks = jax.random.split(keys[5], cfg.encoder_layers)
            params["encoder"] = jax.vmap(lambda k: B.init_dense(k, cfg))(ks)
            params["enc_norm"] = B.init_norm(cfg)
            params["enc_pos"] = embed_init(keys[6], cfg.encoder_seq, cfg.d_model)
        return params

    # ------------------------------ helpers ---------------------------------

    def _ctx(self, seq_len, *, collect_cache=False, cache_len=0, pos=None,
             cross_states=None, rope_rows=None):
        cfg = self.cfg
        rope = None
        if cfg.pos_embed == "rope":
            if rope_rows is not None:
                rope = rope_rows          # precomputed rows (decode)
            else:
                rope = rope_frequencies(cfg.head_dim, seq_len, cfg.rope_theta)
        return {
            "cfg": cfg, "policy": self.policy, "backend": None,
            "rope": rope, "positions": None, "causal": cfg.causal,
            "collect_cache": collect_cache, "cache_len": cache_len,
            "cache_dtype": self.act_dtype, "pos": pos,
            "cross_states": cross_states,
        }

    def _decode_rope(self, pos):
        cfg = self.cfg
        hd = cfg.head_dim
        inv = 1.0 / (cfg.rope_theta ** (
            jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
        ang = pos.astype(jnp.float32) * inv          # (hd/2,)
        return jnp.cos(ang)[None], jnp.sin(ang)[None]  # single-row tables

    def _embed(self, params, tokens):
        x = params["embed"][tokens].astype(self.act_dtype)
        if self.cfg.pos_embed == "learned":
            t = tokens.shape[1]
            x = x + params["pos_embed"][:t][None].astype(self.act_dtype)
        return act.constrain(x, "batch", None, None)

    def _encode(self, params, audio_embeds):
        """Whisper encoder: non-causal dense stack over stubbed frame embeds."""
        cfg = self.cfg
        x = audio_embeds.astype(self.act_dtype)
        x = x + params["enc_pos"][: x.shape[1]][None].astype(self.act_dtype)
        ctx = self._ctx(x.shape[1])
        ctx["causal"] = False
        ctx["rope"] = None

        def body(x, layer_params):
            y, _, _ = B.dense_fwd(layer_params, x, ctx, window=None)
            return y, None

        body = jax.checkpoint(body) if self.remat else body
        x, _ = jax.lax.scan(body, x, params["encoder"])
        return B.norm(params["enc_norm"], x, cfg)

    def _run_stack(self, params, x, ctx):
        """-> (x, aux, caches|None)"""
        unit, rem = self.unit, self.rem
        collect = ctx["collect_cache"]

        def body(carry, unit_params):
            x, aux = carry
            caches = []
            for p, kind in enumerate(unit):
                x, a, c = block_fwd(kind, unit_params[p], x, ctx)
                # NOTE(perf-log H1, refuted): constraining x to
                # ('batch','model',None) here (sequence-parallel residual)
                # made the collective term 4.7x WORSE under GSPMD — it
                # reshards around every block-internal op instead of
                # forming reduce-scatter/all-gather pairs.  See
                # EXPERIMENTS.md §Perf.
                x = act.constrain(x, "batch", None, None)
                aux = aux + a
                caches.append(c)
            return (x, aux), (caches if collect else 0)

        scan_body = jax.checkpoint(body) if self.remat else body
        (x, aux), stack_caches = jax.lax.scan(
            scan_body, (x, jnp.float32(0.0)), params["stack"])
        tail_caches = []
        for i, kind in enumerate(rem):
            x, a, c = block_fwd(kind, params["tail"][i], x, ctx)
            aux = aux + a
            tail_caches.append(c)
        caches = None
        if collect:
            caches = {"stack": stack_caches, "tail": tail_caches}
        return x, aux, caches

    def _final_hidden(self, params, x):
        return B.norm(params["final_norm"], x, self.cfg)

    def _head(self, params):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return params["embed"], True
        return params["head"], False

    def _cross_states(self, params, batch):
        if self.cfg.family == "vlm":
            return batch["image_embeds"].astype(self.act_dtype)
        if self.cfg.family == "audio":
            return self._encode(params, batch["audio_embeds"])
        return None

    # ------------------------------ training --------------------------------

    def loss_fn(self, params, batch):
        """batch: tokens (B, S+1) [+ image_embeds / audio_embeds]."""
        tokens = batch["tokens"]
        x = self._embed(params, tokens[:, :-1])
        labels = tokens[:, 1:]
        ctx = self._ctx(x.shape[1])
        ctx["cross_states"] = self._cross_states(params, batch)
        x, aux, _ = self._run_stack(params, x, ctx)
        x = self._final_hidden(params, x)
        head, tied = self._head(params)
        loss = chunked_softmax_xent(x, head, labels, tied=tied,
                                    policy=self.policy,
                                    valid_vocab=self.cfg.vocab)
        return loss + aux

    # ------------------------------ serving ---------------------------------

    def init_caches(self, batch_size: int, max_len: int):
        caches_stack = []
        for p, kind in enumerate(self.unit):
            one = block_init_cache(kind, self.cfg, batch_size, max_len,
                                   self.act_dtype)
            stacked = jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.n_units,) + a.shape), one)
            caches_stack.append(stacked)
        tail = [block_init_cache(kind, self.cfg, batch_size, max_len,
                                 self.act_dtype) for kind in self.rem]
        return {"stack": caches_stack, "tail": tail}

    def prefill(self, params, batch, max_len: Optional[int] = None):
        """-> (last-token logits (B, V), caches)."""
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        x = self._embed(params, tokens)
        ctx = self._ctx(s, collect_cache=True, cache_len=max_len)
        ctx["moe_capacity"] = 4.0   # serve-time: effectively dropless
        ctx["cross_states"] = self._cross_states(params, batch)
        x, _, caches = self._run_stack(params, x, ctx)
        x = self._final_hidden(params, x[:, -1:])
        head, tied = self._head(params)
        logits = self._mask_logits(
            logits_from_hidden(x, head, tied=tied, policy=self.policy))
        return logits[:, 0], caches

    def _mask_logits(self, logits):
        vp = self.vocab_padded
        if vp == self.cfg.vocab:
            return logits
        valid = jnp.arange(vp) < self.cfg.vocab
        return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))

    def decode_step(self, params, token, caches, pos, batch=None):
        """token: (B, 1) int32; pos: scalar int32 count of tokens seen.
        -> (logits (B, V), new caches)."""
        cfg = self.cfg
        x = params["embed"][token].astype(self.act_dtype)
        if cfg.pos_embed == "learned":
            pe = params["pos_embed"][
                jnp.minimum(pos, params["pos_embed"].shape[0] - 1)]
            x = x + pe[None, None].astype(self.act_dtype)
        rope_rows = self._decode_rope(pos) if cfg.pos_embed == "rope" else None
        ctx = self._ctx(1, pos=pos, rope_rows=rope_rows)
        ctx["rope_single_row"] = True
        ctx["moe_capacity"] = 4.0   # serve-time: effectively dropless

        def body(x, xs):
            unit_params, unit_caches = xs
            new = []
            for p, kind in enumerate(self.unit):
                x, c = block_decode(kind, unit_params[p], x, unit_caches[p], ctx)
                new.append(c)
            return x, new

        x, new_stack = jax.lax.scan(
            body, x, (params["stack"], caches["stack"]))
        new_tail = []
        for i, kind in enumerate(self.rem):
            x, c = block_decode(kind, params["tail"][i], x,
                                caches["tail"][i], ctx)
            new_tail.append(c)
        x = self._final_hidden(params, x)
        head, tied = self._head(params)
        logits = self._mask_logits(
            logits_from_hidden(x, head, tied=tied, policy=self.policy))
        return logits[:, 0], {"stack": new_stack, "tail": new_tail}

    # ------------------------- paged serving (continuous batching) ----------

    def paged_unsupported_reason(self) -> Optional[str]:
        """None if every block kind has a paged-KV step, else why not."""
        bad = sorted({k for k in (*self.unit, *self.rem)
                      if k not in PAGED_KINDS})
        if bad:
            return (f"block kinds {bad} have no paged-KV step (supported: "
                    f"{PAGED_KINDS}); serve this arch with the wave engine")
        return None

    def init_paged_caches(self, num_pages: int, page_size: int):
        """Pooled KV pages, one (num_pages, Hkv, page_size, hd) pair per
        layer; block tables are shared across layers so the layer axis
        lives here, exactly like init_caches stacks ring caches."""
        reason = self.paged_unsupported_reason()
        if reason:
            raise ValueError(reason)
        caches_stack = []
        for _kind in self.unit:
            one = B.attn_paged_init_cache(self.cfg, num_pages, page_size,
                                          self.act_dtype)
            caches_stack.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (self.n_units,) + a.shape),
                one))
        tail = [B.attn_paged_init_cache(self.cfg, num_pages, page_size,
                                        self.act_dtype) for _ in self.rem]
        return {"stack": caches_stack, "tail": tail}

    def paged_step(self, params, tokens, caches, block_tables, q_start,
                   n_valid):
        """One continuous-batching step over paged KV.

        tokens: (B, C) int32 — C == 1 is a pure decode step, C > 1 a
        chunked-prefill step (decode rows just use one valid column).
        block_tables: (B, W) int32 physical page ids (0 = scratch pad);
        q_start: (B,) absolute position of each row's first token;
        n_valid: (B,) valid tokens per row (0 = idle slot).
        -> (logits (B, V) at each row's LAST valid token, new caches).
        """
        cfg = self.cfg
        b, c = tokens.shape
        ps = caches["stack"][0]["k_pages"].shape[-2] if caches["stack"] \
            else caches["tail"][0]["k_pages"].shape[-2]
        w = block_tables.shape[1]
        max_pos = w * ps
        positions = jnp.clip(
            q_start[:, None] + jnp.arange(c)[None, :], 0, max_pos - 1)
        x = params["embed"][tokens].astype(self.act_dtype)
        if cfg.pos_embed == "learned":
            pe = params["pos_embed"]
            x = x + pe[jnp.minimum(positions, pe.shape[0] - 1)].astype(
                self.act_dtype)
        rope_rows = None
        if cfg.pos_embed == "rope":
            rope_rows = rope_frequencies(cfg.head_dim, max_pos,
                                         cfg.rope_theta)
        ctx = self._ctx(c, rope_rows=rope_rows)
        ctx["positions"] = positions
        ctx["moe_capacity"] = 4.0   # serve-time: effectively dropless
        ctx["paged"] = {
            "block_tables": block_tables.astype(jnp.int32),
            "q_start": q_start.astype(jnp.int32),
            "n_valid": n_valid.astype(jnp.int32),
            "lengths": (q_start + n_valid).astype(jnp.int32),
        }

        def body(x, xs):
            unit_params, unit_caches = xs
            new = []
            for p, kind in enumerate(self.unit):
                x, cc = block_paged_step(kind, unit_params[p], x,
                                         unit_caches[p], ctx)
                new.append(cc)
            return x, new

        x, new_stack = jax.lax.scan(
            body, x, (params["stack"], caches["stack"]))
        new_tail = []
        for i, kind in enumerate(self.rem):
            x, cc = block_paged_step(kind, params["tail"][i], x,
                                     caches["tail"][i], ctx)
            new_tail.append(cc)
        # Each row's next-token logits live at its LAST valid position
        # (idle rows clamp to column 0 — the engine ignores them).
        idx = jnp.clip(n_valid - 1, 0, c - 1)
        x = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B, 1, d)
        x = self._final_hidden(params, x)
        head, tied = self._head(params)
        logits = self._mask_logits(
            logits_from_hidden(x, head, tied=tied, policy=self.policy))
        return logits[:, 0], {"stack": new_stack, "tail": new_tail}


def build_model(cfg: ArchConfig, policy: str = "bf16", remat: bool = True) -> LM:
    # audio (enc-dec) archs use the 'encdec' block kind for decoder layers.
    if cfg.family == "audio" and not cfg.block_pattern:
        cfg = dataclasses.replace(cfg, block_pattern=("encdec",) * cfg.n_layers)
    return LM(cfg, policy=policy, remat=remat)
