"""Sparsify model parameter trees at load time — the serving-side entry
point, mirroring ``repro.packing.params.pack_params`` one subsystem over.

``sparsify_params`` walks a parameter pytree with the same walk discipline
as ``pack_params`` / ``quantize_params`` and replaces every eligible GEMM
weight with its :class:`~repro.sparse.layout.TileSparseOperand` form:

    policy fp32 / bf16 / bf16_serve  ->  float payload in the policy's
                                         compute dtype
    policy int8                      ->  int8 payload + per-tile scales

Eligibility reuses ``quantization.QUANT_LEAVES``.  The same three
structural cases as ``pack_params``, with one sparse-specific twist:

* plain 2-D weight                       -> 2-D sparsify
* scanned-stack leaf ("stack"/"encoder") -> ONE pattern SHARED across the
      layer axis (tile scores averaged over layers), so the stacked
      payload keeps a leading layer axis that ``lax.scan`` slices away
      while the static layout stays layer-invariant — per-layer patterns
      would give per-layer payload shapes, which scan cannot stack.
* MoE expert weight (trailing 3-D)       -> grouped sparsify, per-expert
      patterns folded into one flat schedule (stacked MoE combines both:
      shared-over-layers pattern + grouped payload)

Every sparsify goes through the process-global packed-weight cache
(``repro.packing.cache``, ``REPRO_PACK_CACHE``): the cache key carries the
sparse layout's tag — density, blocks, payload dtype AND the pattern
digest — so sparse-packed and dense-packed payloads of the same weight can
never alias (see the cache-key regression tests).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocking import plan_gemm
from repro.core.policy import get_policy
from repro.core.quantization import QUANT_LEAVES
from repro.packing.cache import PackedWeightCache, get_pack_cache
from repro.packing.params import (
    MOE_GROUPED_LEAVES, _is_stacked, _leaf_name, _path_str,
)
from repro.sparse.layout import TileSparseLayout, TileSparseOperand
from repro.sparse.sparsify import (
    _core_dims, _keep_to_structure, build_payload, magnitude_mask, nm_mask,
    tile_scores,
)

METHODS = ("magnitude", "nm")


def _payload_dtype(policy) -> str:
    return "int8" if policy.quantized else str(jnp.dtype(policy.compute_dtype))


def _shared_mask(leaf, blocks, *, lead_axes: int, method: str,
                 density: float, nm: Tuple[int, int]):
    """Tile keep-mask for one leaf: scores averaged over any leading
    scanned-layer axes so the pattern (and therefore the static layout) is
    layer-invariant."""
    arr = np.asarray(leaf, np.float32)
    arr = arr.reshape((-1,) + arr.shape[lead_axes:])
    scores = np.stack([
        tile_scores(arr[i], blocks) for i in range(arr.shape[0])
    ]).mean(axis=0)
    if method == "magnitude":
        return magnitude_mask(scores, density)
    n_keep, m_block = nm
    return nm_mask(scores, n_keep, m_block)


def _leaf_layout(leaf, blocks, *, dtype, lead_axes: int, grouped: bool,
                 method: str, density: float, nm: Tuple[int, int]
                 ) -> TileSparseLayout:
    """The shared static layout for one (stacked/grouped) leaf."""
    core = leaf
    for _ in range(lead_axes):
        core = core[0]
    bk, bn = blocks
    k, n, g = _core_dims(core, trans_w=False, grouped=grouped)
    bk, bn = min(bk, k), min(bn, n)
    keep = _shared_mask(leaf, (bk, bn), lead_axes=lead_axes,
                        method=method, density=density, nm=nm)
    indptr, indices = _keep_to_structure(keep)
    return TileSparseLayout(
        k=k, n=n, bk=bk, bn=bn, dtype=str(jnp.dtype(dtype)),
        orig_dtype=str(jnp.dtype(leaf.dtype)),
        indptr=indptr, indices=indices, g=g,
    )


def _build_operand(leaf, layout: TileSparseLayout,
                   lead_axes: int) -> TileSparseOperand:
    build = lambda w: build_payload(w, layout)  # noqa: E731
    for _ in range(lead_axes):
        build = jax.vmap(build)
    payload, scales = build(leaf)
    return TileSparseOperand(payload, scales, layout)


def sparsify_params(
    params,
    *,
    density: float = 0.5,
    method: str = "magnitude",
    nm: Tuple[int, int] = (2, 4),
    policy="bf16",
    m_hint: int = 256,
    blocks: Optional[Tuple[int, int]] = None,
    cache: Optional[PackedWeightCache] = None,
    leaves: Optional[Sequence[str]] = None,
):
    """Replace eligible GEMM weights in ``params`` with tile-sparse operands.

    ``density`` is the kept-tile fraction for the magnitude method;
    ``method="nm"`` uses the structured ``nm=(n_keep, m_block)`` pattern
    instead.  ``m_hint``/``policy`` seed the block planner exactly as in
    ``pack_params`` (bk/bn — the axes the sparse layout pins — are driven
    by (N, K, dtype)); ``blocks=(bk, bn)`` overrides the planner — the
    sparsity GRANULARITY knob: smaller tiles prune finer (better accuracy
    per dropped FLOP) at the cost of a longer schedule.  Run this on the
    UNQUANTIZED checkpoint: under the int8 policy the sparsify itself
    performs per-tile quantization of the surviving tiles.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}; valid: {METHODS}")
    policy = get_policy(policy)
    dtype = _payload_dtype(policy)
    a_dtype = "int8" if policy.quantized else policy.compute_dtype
    eligible = frozenset(leaves) if leaves is not None else QUANT_LEAVES
    cache = cache if cache is not None else get_pack_cache()

    def _blocks(k: int, n: int):
        if blocks is not None:
            return int(blocks[0]), int(blocks[1])
        plan = plan_gemm(m_hint, n, k, a_dtype, dtype)
        return plan.bk, plan.bn

    def _leaf(path, leaf):
        name = _leaf_name(path)
        if (name not in eligible or not hasattr(leaf, "ndim")
                or isinstance(leaf, TileSparseOperand)):
            return leaf
        if jnp.dtype(leaf.dtype).kind != "f":
            return leaf
        stacked = _is_stacked(path)
        eff_ndim = leaf.ndim - (1 if stacked else 0)
        if eff_ndim == 2:
            grouped = False
        elif eff_ndim == 3 and name in MOE_GROUPED_LEAVES:
            grouped = True
        else:
            return leaf
        k, n = leaf.shape[-2], leaf.shape[-1]
        blocks = _blocks(k, n)
        lead = 1 if stacked else 0
        layout = _leaf_layout(leaf, blocks, dtype=dtype, lead_axes=lead,
                              grouped=grouped, method=method,
                              density=density, nm=nm)
        if cache is None:
            return _build_operand(leaf, layout, lead)
        # The cache key carries the layout tag (blocks, payload dtype, nnz
        # AND the pattern digest), so the cheap host-side pattern step runs
        # before the probe; the payload build is what a hit skips.
        return cache.get_or_build(
            _path_str(path), leaf, layout,
            lambda: _build_operand(leaf, layout, lead))

    return jax.tree_util.tree_map_with_path(_leaf, params)


def sparse_param_bytes(params) -> int:
    """Total bytes of sparse payloads in a tree (serving-footprint report)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, TileSparseOperand)):
        if isinstance(leaf, TileSparseOperand):
            total += leaf.nbytes
    return total


def sparse_param_density(params) -> float:
    """nnz / dense tile count over every sparse leaf (1.0 when none)."""
    nnz = ntiles = 0
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, TileSparseOperand)):
        if isinstance(leaf, TileSparseOperand):
            nnz += leaf.layout.nnz
            ntiles += leaf.layout.ntiles
    return nnz / ntiles if ntiles else 1.0
