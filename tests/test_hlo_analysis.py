"""The while-aware HLO cost model vs XLA cost_analysis ground truth."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.hlo_analysis import analyze_hlo_text


def _compiled(f, *args):
    return jax.jit(f).lower(*args).compile()


def _xla_cost(c):
    """jax >= 0.4.3x returns a one-element list from cost_analysis()."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_loopfree_flops_match_xla():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2

    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in [(256, 512), (512, 1024), (1024, 128)]]
    c = _compiled(f, *args)
    mine = analyze_hlo_text(c.as_text())
    cost = _xla_cost(c)
    xla = cost["flops"]
    assert abs(mine.dot_flops - xla) / xla < 0.01
    assert abs(mine.hbm_bytes - cost["bytes accessed"]) \
        / cost["bytes accessed"] < 0.05


def test_scan_trip_count_multiplication():
    def g(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), 0
        return jax.lax.scan(body, x, ws)[0]

    for L in (3, 10, 17):
        c = _compiled(g, jax.ShapeDtypeStruct((128, 128), jnp.float32),
                      jax.ShapeDtypeStruct((L, 128, 128), jnp.float32))
        mine = analyze_hlo_text(c.as_text())
        assert mine.dot_flops == pytest.approx(2 * 128 ** 3 * L, rel=0.01), L
        assert L in mine.trip_counts


def test_nested_scan_trip_counts():
    def h(x, ws):
        def outer(x, wpair):
            def inner(x, w):
                return jnp.tanh(x @ w), 0
            return jax.lax.scan(inner, x, wpair)[0], 0
        return jax.lax.scan(outer, x, ws)[0]

    c = _compiled(h, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((5, 4, 64, 64), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    assert mine.dot_flops == pytest.approx(2 * 64 ** 3 * 20, rel=0.01)


def test_dus_not_billed_in_full():
    """A scan writing one row per step must not bill the whole output
    buffer every iteration."""
    n, d = 64, 256

    def f(xs):
        def body(buf, i):
            buf = jax.lax.dynamic_update_slice(buf, xs[i][None], (i, 0))
            return buf, 0
        buf = jnp.zeros((n, d))
        return jax.lax.scan(body, buf, jnp.arange(n))[0]

    c = _compiled(f, jax.ShapeDtypeStruct((n, d), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    full_every_step = n * (n * d * 4)
    assert mine.hbm_bytes < full_every_step * 0.5


def test_collective_bytes_detected():
    # single-device program has no collectives
    c = _compiled(lambda x: x * 2, jax.ShapeDtypeStruct((8, 8), jnp.float32))
    mine = analyze_hlo_text(c.as_text())
    assert mine.collective_bytes == 0
