"""Losses.  The vocab cross-entropy is chunked over the sequence so the
(B, S, V) logits tensor is never materialized — essential for the 32k-seq
shapes with 32k-256k vocabularies (checkpointed scan; backward recomputes
each chunk's logits)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gemm import mp_dot
from repro.distributed import act


def chunked_softmax_xent(
    hidden,            # (B, S, d)
    head,              # (d, V) or, tied, (V, d)
    labels,            # (B, S) int32
    *,
    tied: bool = False,
    policy="bf16",
    chunk: int = 512,
    mask=None,         # (B, S) 0/1 valid-token mask
    valid_vocab=None,  # mask padded vocab columns beyond this index
):
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    nc = hidden.shape[1] // chunk
    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        h, lab, mk = xs
        h = act.constrain(h, "batch", None, None)
        logits = mp_dot(h, head, policy=policy, trans_w=tied).astype(jnp.float32)
        logits = act.constrain(logits, "batch", None, "model")
        vp = logits.shape[-1]
        if valid_vocab is not None and valid_vocab < vp:
            logits = jnp.where(jnp.arange(vp) < valid_vocab, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mk
        return (tot + nll.sum(), cnt + mk.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(step), (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)
