"""Empirical characterization of the MPGEMM kernel — the paper's Section III.

The paper's methodology is *measure first, then model*: sweep load widths,
tile counts, and blocking factors on real hardware, distill the sweeps into
guidelines, and only then fix the kernel design.  This module is that
characterization loop for the TPU port:

* :func:`sweep` times ``mpgemm_pallas`` over a **bounded lattice** of
  ``(bm, bn, bk)`` block shapes seeded by the analytic optimum from
  ``plan_gemm`` — the discrete neighborhood search "Hello SME!" (Remke &
  Breuer 2024) showed recovers performance a fixed analytic model leaves
  behind.
* :func:`sweep_axis` is the 1-D form (vary ``bk`` with ``bm/bn`` pinned,
  etc.) mirroring the paper's load-width and tile-count sweeps.
* :func:`tune_gemm` runs a sweep, picks the winner, and persists it into a
  :class:`~repro.tuning.plan_cache.PlanCache` so every later ``mp_dot`` on
  the same GEMM instance transparently picks it up.

Measurement modes
-----------------
``compiled``   real ``pallas_call`` lowering — the numbers that matter;
               requires a TPU runtime.
``interpret``  Pallas interpret mode on CPU.  Wall time is dominated by the
               Python grid interpreter, so it is a *structural* signal
               (pipeline/grid overheads), not MXU throughput.
``modeled``    no execution: candidates are scored by the roofline time of
               their modeled HBM traffic.  Deterministic; the CPU-container
               default.
``auto``       ``compiled`` on TPU, else ``modeled``.

Each measurement records both the wall clock *and* the model prediction, so
the characterization report (tuning/report.py) can show where the analytic
model and the hardware disagree — the paper's Fig. 10/11 story.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs

from repro.core.blocking import (
    GemmPlan, enumerate_block_lattice, grouped_plan_from_2d, plan_gemm,
    plan_with_blocks, vmem_working_set,
)
from repro.core.constants import DEFAULT_HW, HardwareSpec
from repro.core.gemm_spec import EpilogueSpec, get_epilogue
from repro.kernels.mpgemm import mpgemm_grouped_pallas, mpgemm_pallas
from repro.tuning.plan_cache import PlanCache, get_plan_cache, make_key

MODES = ("auto", "compiled", "interpret", "modeled")


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One lattice point: the plan tried and what it cost."""

    plan: GemmPlan
    mode: str            # how wall_us was obtained (compiled/interpret/modeled)
    wall_us: float       # measured wall clock (== modeled_us in modeled mode)
    modeled_us: float    # roofline prediction from the plan's traffic model

    @property
    def blocks(self) -> Tuple[int, int, int]:
        return (self.plan.bm, self.plan.bn, self.plan.bk)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Outcome of :func:`tune_gemm` for one GEMM instance."""

    key: str
    analytic: Measurement          # the planner's open-loop choice
    best: Measurement              # the sweep winner (may equal analytic)
    measurements: Tuple[Measurement, ...]

    @property
    def speedup(self) -> float:
        """Measured analytic-time / best-time (>= 1.0 by construction)."""
        return self.analytic.wall_us / max(self.best.wall_us, 1e-9)

    @property
    def tuned_differs(self) -> bool:
        return self.best.blocks != self.analytic.blocks


def _resolve_mode(mode: str) -> str:
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; valid: {MODES}")
    if mode == "auto":
        return "compiled" if jax.default_backend() == "tpu" else "modeled"
    return mode


def _modeled_us(plan: GemmPlan, hw: HardwareSpec) -> float:
    if plan.a_dtype == "int8":
        peak = hw.peak_ops_int8
    elif plan.a_dtype in ("bfloat16", "float16"):
        peak = hw.peak_flops_bf16
    else:
        peak = hw.peak_flops_fp32
    return max(plan.flops / peak, plan.hbm_bytes / hw.hbm_bw) * 1e6


def _operands(m: int, n: int, k: int, plan: GemmPlan,
              trans_a: bool, trans_b: bool, seed: int = 0,
              g: Optional[int] = None):
    """Random operands for one (optionally grouped: ``g`` leading dim) GEMM."""
    rng = np.random.default_rng(seed)
    lead = () if g is None else (g,)

    def _mk(shape, dtype):
        if jnp.dtype(dtype).kind == "i":
            return jnp.asarray(rng.integers(-127, 127, shape), dtype)
        return jnp.asarray(rng.standard_normal(shape), dtype)

    a = _mk(lead + ((k, m) if trans_a else (m, k)), plan.a_dtype)
    b = _mk(lead + ((n, k) if trans_b else (k, n)), plan.b_dtype)
    return a, b


def _epilogue_kwargs(epilogue: Optional[EpilogueSpec], m: int, n: int,
                     plan: GemmPlan, seed: int = 0,
                     g: Optional[int] = None) -> dict:
    """Kernel kwargs + synthesized operands so the sweep launches the SPEC
    it will actually serve: fused epilogues stream extra (M, N) operands
    (gate/residual/C), so measuring the bare GEMM would tune the wrong
    kernel.  Returns {} for the default (linear, no-op) epilogue."""
    if epilogue is None:
        return {}
    rng = np.random.default_rng(seed + 1)
    lead = () if g is None else (g,)

    def _mn():
        return jnp.asarray(rng.standard_normal(lead + (m, n)),
                           plan.out_dtype)

    kw = {"activation": epilogue.activation, "alpha": epilogue.alpha}
    for name in get_epilogue(epilogue.kind).extra_operands:
        kw[name] = _mn()
    if epilogue.beta != 0.0:
        kw["beta"] = epilogue.beta
        kw["c"] = _mn()
    if epilogue.has_bias:
        rngb = np.random.default_rng(seed + 2)
        bias = jnp.asarray(rngb.standard_normal((n,)), plan.out_dtype)
        kw["bias"] = (jnp.broadcast_to(bias[None], (g, n))
                      if g is not None else bias)
    return kw


def _time_best(run, iters: int, warmup: int) -> float:
    """Best-of-``iters`` wall microseconds for ``run()`` (post-warmup)."""
    for _ in range(warmup):
        jax.block_until_ready(run())
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(run())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def measure_plan(
    a: jax.Array,
    b: jax.Array,
    plan: GemmPlan,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    mode: str = "auto",
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    epilogue_kwargs: Optional[dict] = None,
) -> Measurement:
    """Time ``mpgemm_pallas`` under one forced plan (best-of-``iters``).

    ``epilogue_kwargs`` (from :func:`_epilogue_kwargs`) makes the timed
    launch carry the fused epilogue the tuned plan will serve.
    """
    mode = _resolve_mode(mode)
    modeled = _modeled_us(plan, hw)
    if mode == "modeled":
        return Measurement(plan=plan, mode=mode, wall_us=modeled,
                           modeled_us=modeled)

    def run():
        return mpgemm_pallas(
            a, b, trans_a=trans_a, trans_b=trans_b,
            out_dtype=plan.out_dtype, plan=plan,
            interpret=(mode == "interpret"),
            **(epilogue_kwargs or {}),
        )

    return Measurement(plan=plan, mode=mode,
                       wall_us=_time_best(run, iters, warmup),
                       modeled_us=modeled)


def candidate_plans(
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    *,
    beta: float = 0.0,
    extra_mn_inputs: int = 0,
    hw: HardwareSpec = DEFAULT_HW,
    radius: int = 1,
    max_candidates: int = 24,
    vmem_budget_frac: float = 0.75,
) -> List[GemmPlan]:
    """The bounded sweep lattice: analytic optimum ± ``radius`` ladder steps.

    Candidates come from :func:`enumerate_block_lattice` (so they always
    satisfy the kernel's alignment floors), are filtered by the VMEM budget
    (paper eq (1)), deduplicated after clamping, and capped at
    ``max_candidates`` nearest-to-seed points.  The analytic plan itself is
    always candidate 0, which makes ``tune_gemm``'s speedup >= 1 by
    construction.  ``extra_mn_inputs`` counts fused-epilogue (M, N)
    operands so the traffic/working-set pricing matches the launched spec.
    """
    seed_plan = plan_gemm(m, n, k, a_dtype, b_dtype, out_dtype,
                          beta=beta, extra_mn_inputs=extra_mn_inputs, hw=hw)
    bm_axis, bn_axis, bk_axis = enumerate_block_lattice(
        m, n, k, a_dtype, b_dtype, hw=hw
    )

    def _window(axis: Sequence[int], center: int) -> List[int]:
        if center in axis:
            i = axis.index(center)
        else:  # clamped seed; nearest lattice point
            i = min(range(len(axis)), key=lambda j: abs(axis[j] - center))
        lo, hi = max(0, i - radius), min(len(axis), i + radius + 1)
        return list(axis[lo:hi])

    combos = itertools.product(
        _window(bm_axis, seed_plan.bm),
        _window(bn_axis, seed_plan.bn),
        _window(bk_axis, seed_plan.bk),
    )
    plans: List[GemmPlan] = [seed_plan]
    seen = {(seed_plan.bm, seed_plan.bn, seed_plan.bk)}
    budget = int(hw.vmem_bytes * vmem_budget_frac)
    for bm, bn, bk in combos:
        cand = plan_with_blocks(m, n, k, bm, bn, bk, a_dtype, b_dtype,
                                out_dtype, beta=beta,
                                extra_mn_inputs=extra_mn_inputs, hw=hw,
                                notes="tuned")
        blocks = (cand.bm, cand.bn, cand.bk)
        if blocks in seen or cand.vmem_bytes > budget:
            continue
        seen.add(blocks)
        plans.append(cand)
    # Nearest-to-seed first keeps the sweep bounded AND centered.
    anchor = (seed_plan.bm, seed_plan.bn, seed_plan.bk)
    plans[1:] = sorted(
        plans[1:],
        key=lambda p: (abs(p.bm - anchor[0]) + abs(p.bn - anchor[1])
                       + abs(p.bk - anchor[2])),
    )
    return plans[:max_candidates]


def sweep(
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    beta: float = 0.0,
    epilogue: Optional[EpilogueSpec] = None,
    mode: str = "auto",
    radius: int = 1,
    max_candidates: int = 24,
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    seed: int = 0,
) -> List[Measurement]:
    """Measure every candidate plan for one GEMM instance.

    ``epilogue`` makes the sweep launch the fused spec it will actually
    serve (extra gated/residual/C operands synthesized per candidate).

    Runnable on CPU (uses ``mode="modeled"`` resolution by default there)::

        >>> from repro.tuning import sweep
        >>> ms = sweep(256, 256, 512, "float32", mode="interpret",
        ...            max_candidates=4, iters=1)
        >>> sorted(ms, key=lambda m: m.wall_us)[0].blocks  # doctest: +SKIP
        (256, 256, 512)
    """
    n_extra = len(epilogue.extra_operands) if epilogue is not None else 0
    if epilogue is not None and epilogue.beta != 0.0:
        beta = epilogue.beta
    plans = candidate_plans(
        m, n, k, a_dtype, b_dtype, out_dtype, beta=beta,
        extra_mn_inputs=n_extra, hw=hw,
        radius=radius, max_candidates=max_candidates,
    )
    resolved = _resolve_mode(mode)
    if resolved == "modeled":
        return [measure_plan(None, None, p, mode="modeled", hw=hw)
                for p in plans]
    a, b = _operands(m, n, k, plans[0], trans_a, trans_b, seed)
    ep_kw = _epilogue_kwargs(epilogue, m, n, plans[0], seed)
    return [
        measure_plan(a, b, p, trans_a=trans_a, trans_b=trans_b,
                     mode=resolved, iters=iters, warmup=warmup, hw=hw,
                     epilogue_kwargs=ep_kw)
        for p in plans
    ]


def sweep_axis(
    m: int,
    n: int,
    k: int,
    axis: str,
    a_dtype="float32",
    *,
    mode: str = "auto",
    hw: HardwareSpec = DEFAULT_HW,
    vmem_budget_frac: float = 0.75,
    iters: int = 3,
    warmup: int = 1,
    seed: int = 0,
) -> List[Measurement]:
    """1-D characterization sweep: vary one block axis, pin the other two.

    ``axis="bk"`` is the paper's load-width sweep (bk sets the contiguous
    bytes per DMA row of A); ``axis="bm"``/``"bn"`` are the tile-count
    sweeps (they set how many accumulator tiles the grid walks).
    """
    if axis not in ("bm", "bn", "bk"):
        raise ValueError(f"axis must be bm|bn|bk, got {axis!r}")
    seed_plan = plan_gemm(m, n, k, a_dtype, hw=hw)
    axes = dict(zip(("bm", "bn", "bk"),
                    enumerate_block_lattice(m, n, k, a_dtype, hw=hw)))
    resolved = _resolve_mode(mode)
    out = []
    a = b = None
    if resolved != "modeled":
        a, b = _operands(m, n, k, seed_plan, False, False, seed)
    budget = int(hw.vmem_bytes * vmem_budget_frac)
    for v in axes[axis]:
        blocks = {ax: (v if ax == axis else getattr(seed_plan, ax))
                  for ax in ("bm", "bn", "bk")}
        plan = plan_with_blocks(m, n, k, blocks["bm"], blocks["bn"],
                                blocks["bk"], a_dtype, hw=hw, notes="sweep")
        if plan.vmem_bytes > budget:
            continue
        if resolved == "modeled":
            out.append(measure_plan(None, None, plan, mode="modeled", hw=hw))
        else:
            out.append(measure_plan(a, b, plan, mode=resolved, hw=hw,
                                    iters=iters, warmup=warmup))
    return out


def _obs_tune(fn):
    """Wrap a ``tune_*`` entrypoint in an ``obs.span("tune")`` — the tune
    leg of the plan → pack → tune → launch trace chain.  The winning key
    and wall time land on the span via :func:`_persist_best`'s annotate."""
    @functools.wraps(fn)
    def wrapped(*args, **kwargs):
        with obs.span("tune", op=fn.__name__):
            return fn(*args, **kwargs)
    return wrapped


def _persist_best(key: str, measurements, cache: Optional[PlanCache],
                  save: bool, extra_meta: Optional[dict] = None) -> TuneResult:
    """Shared tune-result tail: pick the winner, write it to the cache.

    ``measurements[0]`` must be the analytic seed (candidate_plans puts it
    first), which makes ``TuneResult.speedup >= 1`` by construction.
    """
    analytic = measurements[0]
    best = min(measurements, key=lambda mm: mm.wall_us)
    obs.annotate(key=key, mode=best.mode, wall_us=best.wall_us,
                 candidates=len(measurements))
    if cache is None:
        cache = get_plan_cache()
    if cache is not None:
        meta = {
            "mode": best.mode,
            "wall_us": best.wall_us,
            "modeled_us": best.modeled_us,
            "analytic_wall_us": analytic.wall_us,
            "analytic_blocks": list(analytic.blocks),
            "candidates": len(measurements),
        }
        meta.update(extra_meta or {})
        cache.put(key, best.plan, meta=meta)
        if save:
            cache.save()
    return TuneResult(key=key, analytic=analytic, best=best,
                      measurements=tuple(measurements))


@_obs_tune
def tune_gemm(
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    beta: float = 0.0,
    epilogue: Optional[EpilogueSpec] = None,
    mode: str = "auto",
    radius: int = 1,
    max_candidates: int = 24,
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    cache: Optional[PlanCache] = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """Sweep, pick the measured winner, persist it to the plan cache.

    ``epilogue`` tunes THE spec the op layer will launch (the sweep carries
    the fused operands) and persists under the epilogue-tagged key
    (``make_key(..., epilogue=...)``) so fused and unfused tunings never
    collide.

    ``cache=None`` uses the process-global cache, so the very next
    ``mp_dot`` on this shape consumes the tuned plan — plans resolve at
    trace time, so functions jit-compiled *before* tuning keep their old
    executable until ``jax.clear_caches()`` (see docs/autotuning.md).
    Pass an explicit :class:`PlanCache` for isolated runs.  ``save=True``
    also flushes an on-disk cache.

    .. warning:: the entry is stored under ``hw.name``, but the read path
       (``mp_dot`` / ``mpgemm_pallas``) always keys with ``DEFAULT_HW`` —
       plans tuned for a non-default :class:`HardwareSpec` are only served
       where that spec IS the default (i.e. on the machine they describe).
       Pass ``plan=`` explicitly to force one elsewhere.

    Runnable on CPU::

        >>> from repro.tuning import PlanCache, tune_gemm
        >>> cache = PlanCache(None)            # in-memory
        >>> r = tune_gemm(128, 128, 256, "float32", mode="interpret",
        ...               max_candidates=3, iters=1, cache=cache)
        >>> r.speedup >= 1.0 and len(cache) == 1
        True
    """
    measurements = sweep(
        m, n, k, a_dtype, b_dtype, out_dtype,
        trans_a=trans_a, trans_b=trans_b, beta=beta, epilogue=epilogue,
        mode=mode, radius=radius, max_candidates=max_candidates,
        iters=iters, warmup=warmup, hw=hw, seed=seed,
    )
    if epilogue is not None and epilogue.beta != 0.0:
        beta = epilogue.beta
    key = make_key(m, n, k, a_dtype, b_dtype, out_dtype,
                   trans_a=trans_a, trans_b=trans_b, beta=beta, hw=hw,
                   epilogue=epilogue.tag if epilogue is not None else "")
    return _persist_best(key, measurements, cache, save)


# --- tile-sparse instances ----------------------------------------------------

@_obs_tune
def tune_sparse_gemm(
    m: int,
    a,
    b_sparse,
    *,
    out_dtype=None,
    trans_a: bool = False,
    epilogue: Optional[EpilogueSpec] = None,
    mode: str = "auto",
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    cache: Optional[PlanCache] = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """:func:`tune_gemm` for a tile-sparse operand (repro.sparse).

    The stored-tile layout pins (bn, bk) — the payload's tiling IS the
    block decision — so the sweep walks only the ``bm`` ladder, measuring
    the actual sparse launch (``mpgemm_pallas(a, sparse)`` — grouped
    operands go through ``mpgemm_grouped_pallas``): the stored-tile
    schedule, not a dense proxy.  ``epilogue`` makes the sweep launch the
    fused spec it will serve (extra gated/residual/C operands synthesized,
    exactly as in :func:`tune_gemm`).  Winners persist under the FULL key
    the launch-side resolver (``kernels/mpgemm.py::_layout_plan``) reads
    back — ``make_key(..., g=layout.g, epilogue=tag,
    sparsity=layout.tag)`` — so a fused or grouped serving launch sees the
    tuned plan, not just the linear 2-D case.  In ``modeled`` mode
    candidates are scored by the density-priced roofline.
    """
    from repro.core.blocking import grouped_plan_from_2d, plan_with_blocks
    layout = b_sparse.layout
    n, k, g = layout.n, layout.k, layout.g
    a_dtype = a.dtype if a is not None else layout.dtype
    n_extra = len(epilogue.extra_operands) if epilogue is not None else 0
    ep_beta = epilogue.beta if epilogue is not None else 0.0
    base = plan_gemm(m, n, k, a_dtype, layout.dtype, out_dtype,
                     beta=ep_beta, extra_mn_inputs=n_extra,
                     density=layout.density, hw=hw)
    bm_axis, _, _ = enumerate_block_lattice(m, n, k, a_dtype, layout.dtype,
                                            hw=hw)
    budget = int(hw.vmem_bytes * 0.75)
    cands, seen = [], set()
    for bm in [base.bm] + list(bm_axis):
        cand = plan_with_blocks(
            m, n, k, bm, layout.bn, layout.bk, a_dtype, layout.dtype,
            out_dtype, "float32" if layout.per_tile_scales else None,
            beta=ep_beta, extra_mn_inputs=n_extra, density=layout.density,
            hw=hw, notes="tile-sparse tuned")
        if cand.bm not in seen:
            seen.add(cand.bm)
            cands.append(cand)
    # Same capacity filter as candidate_plans: an over-budget candidate
    # cannot allocate its VMEM working set on hardware (and must never win
    # in modeled mode and get persisted as the served plan).  If the
    # layout-pinned bk·bn puts EVERY ladder point over budget, keep the
    # smallest working set so the sweep still returns a layout-compatible
    # plan rather than crashing.
    plans = [p for p in cands if p.vmem_bytes <= budget] \
        or [min(cands, key=lambda p: p.vmem_bytes)]
    if g != 1:
        plans = [grouped_plan_from_2d(p, g) for p in plans]
    resolved = _resolve_mode(mode)
    if resolved == "modeled":
        measurements = [measure_plan(None, None, p, mode="modeled", hw=hw)
                        for p in plans]
    else:
        from repro.kernels.mpgemm import (
            mpgemm_grouped_pallas, mpgemm_pallas,
        )
        launch = mpgemm_pallas if g == 1 else mpgemm_grouped_pallas
        ep_kw = _epilogue_kwargs(epilogue, m, n, plans[0], seed,
                                 g=None if g == 1 else g)
        measurements = []
        for p in plans:
            def run(p=p):
                return launch(
                    a, b_sparse, trans_a=trans_a,
                    out_dtype=p.out_dtype, plan=p,
                    interpret=(resolved == "interpret"), **ep_kw)
            measurements.append(Measurement(
                plan=p, mode=resolved,
                wall_us=_time_best(run, iters, warmup),
                modeled_us=_modeled_us(p, hw)))
    key = make_key(m, n, k, a_dtype, layout.dtype, out_dtype,
                   trans_a=trans_a, trans_b=False, beta=ep_beta, hw=hw,
                   g=g, epilogue=epilogue.tag if epilogue is not None else "",
                   sparsity=layout.tag)
    return _persist_best(key, measurements, cache, save,
                         extra_meta={"sparsity": layout.tag,
                                     "density": layout.density, "g": g})


# --- grouped / batched instances ---------------------------------------------

def measure_grouped_plan(
    a: jax.Array,
    b: jax.Array,
    plan: GemmPlan,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    mode: str = "auto",
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    epilogue_kwargs: Optional[dict] = None,
) -> Measurement:
    """Time ``mpgemm_grouped_pallas`` under one forced plan.

    ``plan.flops``/``plan.hbm_bytes`` already cover all G groups (see
    :func:`~repro.core.blocking.grouped_plan_from_2d`), so the modeled
    roofline time is launch-total, directly comparable to the wall clock.
    """
    mode = _resolve_mode(mode)
    modeled = _modeled_us(plan, hw)
    if mode == "modeled":
        return Measurement(plan=plan, mode=mode, wall_us=modeled,
                           modeled_us=modeled)

    def run():
        return mpgemm_grouped_pallas(
            a, b, trans_a=trans_a, trans_b=trans_b,
            out_dtype=plan.out_dtype, plan=plan,
            interpret=(mode == "interpret"),
            **(epilogue_kwargs or {}),
        )

    return Measurement(plan=plan, mode=mode,
                       wall_us=_time_best(run, iters, warmup),
                       modeled_us=modeled)


@_obs_tune
def tune_grouped_gemm(
    g: int,
    m: int,
    n: int,
    k: int,
    a_dtype="float32",
    b_dtype=None,
    out_dtype=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    epilogue: Optional[EpilogueSpec] = None,
    mode: str = "auto",
    radius: int = 1,
    max_candidates: int = 24,
    iters: int = 3,
    warmup: int = 1,
    hw: HardwareSpec = DEFAULT_HW,
    cache: Optional[PlanCache] = None,
    save: bool = True,
    seed: int = 0,
) -> TuneResult:
    """:func:`tune_gemm` for a grouped instance (G x (M, N, K)).

    Candidates are the 2-D lattice neighborhood lifted per-group (the group
    axis adds grid steps, not working set, so the candidate space is the
    same), measured through the grouped kernel launch — carrying
    ``epilogue``'s fused operands when given (e.g. the MoE gated-SwiGLU
    spec) — and persisted under the grouped cache key (``g…`` prefix, plus
    the epilogue tag) that ``mp_dot_grouped`` / ``mpgemm_grouped_pallas``
    read back.

    Runnable on CPU::

        >>> from repro.tuning import PlanCache, tune_grouped_gemm
        >>> cache = PlanCache(None)
        >>> r = tune_grouped_gemm(4, 64, 64, 128, "float32", mode="modeled",
        ...                       max_candidates=3, cache=cache)
        >>> r.best.plan.g
        4
    """
    n_extra = len(epilogue.extra_operands) if epilogue is not None else 0
    ep_beta = epilogue.beta if epilogue is not None else 0.0
    plans = [
        grouped_plan_from_2d(p, g)
        for p in candidate_plans(
            m, n, k, a_dtype, b_dtype, out_dtype, hw=hw,
            beta=ep_beta, extra_mn_inputs=n_extra,
            radius=radius, max_candidates=max_candidates,
        )
    ]
    resolved = _resolve_mode(mode)
    if resolved == "modeled":
        measurements = [
            measure_grouped_plan(None, None, p, mode="modeled", hw=hw)
            for p in plans
        ]
    else:
        a, b = _operands(m, n, k, plans[0], trans_a, trans_b, seed, g=g)
        ep_kw = _epilogue_kwargs(epilogue, m, n, plans[0], seed, g=g)
        measurements = [
            measure_grouped_plan(a, b, p, trans_a=trans_a, trans_b=trans_b,
                                 mode=resolved, iters=iters, warmup=warmup,
                                 hw=hw, epilogue_kwargs=ep_kw)
            for p in plans
        ]
    key = make_key(m, n, k, a_dtype, b_dtype, out_dtype,
                   trans_a=trans_a, trans_b=trans_b, beta=ep_beta, hw=hw,
                   g=g, epilogue=epilogue.tag if epilogue is not None else "")
    return _persist_best(key, measurements, cache, save, extra_meta={"g": g})
