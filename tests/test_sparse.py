"""Tile-sparse subsystem: layout/schedule invariants, sparsifier patterns,
sparse-vs-dense-masked parity through mp_dot/mp_dot_grouped (fwd + bwd, all
policies, every registry epilogue, both backends), the tile-visit trace
gate, density-aware planning, the sparsity plan-key namespace, the
packed-weight-cache no-alias regression, and the sparsify_params walker."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.blocking import plan_gemm
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.kernels.mpgemm import mpgemm_grouped_pallas, mpgemm_pallas
from repro.packing import (
    PackedWeightCache, make_weight_key, pack_operand,
)
from repro.sparse import (
    TileSparseLayout, TileSparseOperand, build_schedule, densify_operand,
    is_sparse, magnitude_mask, nm_mask, payload_cotangent, sparsify_magnitude,
    sparsify_nm, sparsify_params, sparsify_with_mask, tile_scores,
    sparse_param_density,
)
from repro.tuning import make_key

G, M, K, N = 3, 24, 40, 24
BLOCKS = (16, 8)   # (bk, bn) -> lattice (nkb, nnb) = (3, 3)


@pytest.fixture
def ops(rng):
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    return x, w


@pytest.fixture
def gops(rng):
    x = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    return x, w


def _sp(w, density=0.5, **kw):
    return sparsify_magnitude(w, BLOCKS, density=density, **kw)


# --- layout / schedule invariants --------------------------------------------

def test_layout_properties(ops):
    _, w = ops
    sp = _sp(w)
    lay = sp.layout
    assert (lay.nkb, lay.nnb) == (3, 3)
    assert lay.nnz == 5                   # ceil(0.5 * 9)
    assert lay.ntiles == 9
    assert lay.density == pytest.approx(5 / 9)
    assert sp.payload.shape == (lay.nnz + 1, 16, 8)
    # trailing zero tile is exactly zero
    assert np.all(np.asarray(sp.payload[-1]) == 0)


def test_layout_validation():
    mk = dict(k=32, n=16, bk=16, bn=8, dtype="float32",
              orig_dtype="float32")
    with pytest.raises(ValueError, match="indptr must have"):
        TileSparseLayout(**mk, indptr=(0, 1), indices=(0,))
    with pytest.raises(ValueError, match="end at len"):
        TileSparseLayout(**mk, indptr=(0, 1, 1), indices=())
    with pytest.raises(ValueError, match="outside"):
        TileSparseLayout(**mk, indptr=(0, 1, 1), indices=(5,))
    with pytest.raises(ValueError, match="ascending"):
        TileSparseLayout(**mk, indptr=(0, 2, 2), indices=(1, 1))


def test_schedule_covers_every_column(ops):
    _, w = ops
    keep = np.zeros((3, 3), bool)
    keep[0, 0] = keep[2, 0] = keep[1, 2] = True   # column 1 EMPTY
    sp = sparsify_with_mask(w, BLOCKS, keep)
    lay = sp.layout
    assert lay.nnz == 3 and lay.schedule_len == 4  # +1 anchor
    s = build_schedule(lay)
    assert sorted(set(s.jj.tolist())) == [0, 1, 2]  # every column visited
    # anchor of the empty column points at the zero tile
    anchor = int(np.nonzero(s.jj == 1)[0][0])
    assert s.slot[anchor] == lay.nnz
    # first/last flags partition the walk into per-column runs
    assert s.first.sum() == s.last.sum() == 3


def test_tag_separates_patterns(ops):
    _, w = ops
    a = _sp(w, density=0.5)
    b = _sp(w, density=0.8)
    keep = np.zeros((3, 3), bool)
    keep[np.unravel_index(range(5), (3, 3))] = True  # 5 tiles, diff pattern
    c = sparsify_with_mask(w, BLOCKS, keep)
    assert a.layout.tag != b.layout.tag
    assert a.layout.nnz == c.layout.nnz
    assert a.layout.tag != c.layout.tag   # same nnz, different pattern


# --- sparsifiers --------------------------------------------------------------

def test_magnitude_keeps_strongest_tiles(rng):
    w = np.ones((K, N), np.float32) * 0.01
    w[16:32, 8:16] = 5.0     # tile (1,1)
    w[0:16, 16:24] = 3.0     # tile (0,2)
    sp = sparsify_magnitude(jnp.asarray(w), BLOCKS, density=2 / 9)
    assert sp.layout.nnz == 2
    d = np.asarray(densify_operand(sp))
    assert np.all(d[16:32, 8:16] == 5.0) and np.all(d[0:16, 16:24] == 3.0)
    assert np.all(d[0:16, 0:8] == 0)


def test_magnitude_prunes_hard_zero_tiles(ops):
    _, w = ops
    wz = np.asarray(w).copy()
    wz[0:16, 0:8] = 0.0
    sp = sparsify_magnitude(jnp.asarray(wz), BLOCKS, density=1.0)
    assert sp.layout.nnz == 8  # the zero tile dropped even at density 1


def test_nm_structure(rng):
    w = jnp.asarray(rng.standard_normal((64, 16)), "float32")  # nkb=4, nnb=2
    sp = sparsify_nm(w, BLOCKS, n_keep=1, m_block=2)
    lay = sp.layout
    # every column: 2 chunks of 2 k-tiles, 1 kept each -> 2 per column
    for c in range(lay.nnb):
        kept = lay.indices[lay.indptr[c]: lay.indptr[c + 1]]
        assert len(kept) == 2
        assert sum(1 for kk in kept if kk < 2) == 1  # one per m-block chunk
    with pytest.raises(ValueError, match="n_keep"):
        nm_mask(np.ones((1, 4, 2)), 3, 2)


def test_densify_equals_masked_reference(ops):
    _, w = ops
    keep = magnitude_mask(tile_scores(w, BLOCKS), 0.5)
    sp = sparsify_with_mask(w, BLOCKS, keep)
    ref = np.zeros((K, N), np.float32)
    wnp = np.asarray(w)
    for kk in range(3):
        for j in range(3):
            if keep[0, kk, j]:
                ref[kk * 16:(kk + 1) * 16, j * 8:(j + 1) * 8] = \
                    wnp[kk * 16:(kk + 1) * 16, j * 8:(j + 1) * 8]
    np.testing.assert_array_equal(np.asarray(densify_operand(sp)), ref)


def test_trans_w_resolved(ops):
    x, w = ops
    wt = jnp.asarray(np.asarray(w).T)              # stored (N, K)
    sp = sparsify_magnitude(wt, BLOCKS, density=0.6, trans_w=True)
    y = mp_dot(x, sp, policy="fp32", trans_w=True, backend="interpret")
    ref = np.asarray(x) @ np.asarray(densify_operand(sp))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    with pytest.raises(ValueError, match="trans_w"):
        mp_dot(x, sp, policy="fp32", trans_w=False, backend="interpret")


# --- sparse vs dense-masked parity (fwd) -------------------------------------

@pytest.mark.parametrize("backend", ["interpret", "xla"])
@pytest.mark.parametrize("policy,pdt", [("fp32", "float32"),
                                        ("bf16", "bfloat16"),
                                        ("int8", "int8")])
def test_mp_dot_sparse_matches_masked_dense(ops, policy, pdt, backend):
    """The acceptance gate: mp_dot(b_sparse=...) == dense mp_dot on the
    stored tiles, within policy tolerance, forward."""
    x, w = ops
    sp = _sp(w, dtype=pdt)
    wm = densify_operand(sp)
    y = np.asarray(mp_dot(x, b_sparse=sp, policy=policy, backend=backend),
                   np.float32)
    yd = np.asarray(mp_dot(x, wm, policy=policy, backend=backend),
                    np.float32)
    ref = np.asarray(x) @ np.asarray(wm, np.float32)
    if policy == "fp32":
        np.testing.assert_allclose(y, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(y, ref, atol=0.15)
    else:
        assert np.abs(y - ref).max() < 0.05 * np.abs(ref).max() + 1e-6
    assert np.abs(y - yd).max() <= max(1e-5, 0.05 * np.abs(ref).max())


@pytest.mark.parametrize("kind,act", [
    ("linear", "relu"), ("gated", "silu"), ("residual", "gelu"),
])
@pytest.mark.parametrize("grouped", [False, True])
def test_sparse_epilogue_parity(rng, ops, gops, kind, act, grouped):
    """Sparse composes with every registry epilogue, 2-D and grouped."""
    x, w = gops if grouped else ops
    lead = (G,) if grouped else ()
    e = jnp.asarray(rng.standard_normal(lead + (M, N)), "float32")
    sp = _sp(w, density=0.6)
    wm = densify_operand(sp)
    kw = {"gate": e} if kind == "gated" else (
        {"residual": e} if kind == "residual" else {})
    op = mp_dot_grouped if grouped else mp_dot
    y = op(x, sp, policy="fp32", backend="interpret", activation=act, **kw)
    yd = op(x, wm, policy="fp32", backend="interpret", activation=act, **kw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=1e-5)


def test_kernel_wrapper_epilogue_combo(rng, ops):
    """mpgemm_pallas(b_sparse=) with bias + beta*C + activation."""
    x, w = ops
    sp = _sp(w)
    wm = np.asarray(densify_operand(sp))
    bias = jnp.asarray(rng.standard_normal((N,)), "float32")
    cmat = jnp.asarray(rng.standard_normal((M, N)), "float32")
    y = mpgemm_pallas(x, b_sparse=sp, c=cmat, bias=bias, beta=0.5,
                      activation="relu", interpret=True)
    ref = np.maximum(np.asarray(x) @ wm + np.asarray(bias)[None], 0) \
        + 0.5 * np.asarray(cmat)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)


def test_empty_column_still_gets_epilogue(rng, ops):
    """A fully pruned output column must still run bias/activation."""
    x, w = ops
    keep = np.ones((3, 3), bool)
    keep[:, 1] = False
    sp = sparsify_with_mask(w, BLOCKS, keep)
    bias = jnp.asarray(rng.standard_normal((N,)), "float32")
    y = np.asarray(mp_dot(x, sp, bias=bias, policy="fp32",
                          backend="interpret", activation="relu"))
    ref = np.maximum(
        np.asarray(x) @ np.asarray(densify_operand(sp))
        + np.asarray(bias)[None], 0)
    np.testing.assert_allclose(y, ref, atol=1e-5)
    # the empty column is pure epilogue-of-zero
    np.testing.assert_allclose(
        y[:, 8:16],
        np.broadcast_to(np.maximum(np.asarray(bias)[8:16], 0), (M, 8)),
        atol=1e-6)


def test_fully_empty_operand(ops):
    x, w = ops
    sp = sparsify_with_mask(w, BLOCKS, np.zeros((3, 3), bool))
    assert sp.layout.nnz == 0 and sp.layout.schedule_len == 3
    y = mp_dot(x, sp, policy="fp32", backend="interpret")
    assert np.all(np.asarray(y) == 0)


# --- grouped ------------------------------------------------------------------

def test_grouped_sparse_matches_masked_dense(gops):
    x, w = gops
    sp = _sp(w, density=0.4)
    wm = densify_operand(sp)
    y = mp_dot_grouped(x, b_sparse=sp, policy="fp32", backend="interpret")
    ref = np.einsum("gmk,gkn->gmn", np.asarray(x), np.asarray(wm))
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)


def test_grouped_ragged_masking(gops):
    x, w = gops
    sp = _sp(w, density=0.4)
    sizes = jnp.asarray([M, M // 2, 0], jnp.int32)
    y = np.asarray(mp_dot_grouped(x, sp, policy="fp32", backend="interpret",
                                  group_sizes=sizes))
    assert np.all(y[2] == 0) and np.all(y[1, M // 2:] == 0)
    assert np.any(y[1, : M // 2] != 0)


def test_grouped_wrapper_and_group_mismatch(gops):
    x, w = gops
    sp = _sp(w, density=0.5)
    y = mpgemm_grouped_pallas(x, b_sparse=sp, interpret=True)
    assert y.shape == (G, M, N)
    with pytest.raises(ValueError, match="group mismatch"):
        mp_dot_grouped(x[:2], sp, backend="interpret")
    with pytest.raises(ValueError, match="use mpgemm_grouped_pallas"):
        mpgemm_pallas(x[0], b_sparse=sp, interpret=True)


# --- gradients ----------------------------------------------------------------

@pytest.mark.parametrize("policy,tol", [("fp32", 1e-4), ("bf16", 0.3)])
def test_grad_masked_to_stored_tiles(ops, policy, tol):
    """Backward acceptance gate: payload cotangent == dense gradient
    gathered on the stored tiles; pruned tiles / the anchor tile get none;
    dx matches the dense path."""
    x, w = ops
    pdt = "float32" if policy == "fp32" else "bfloat16"
    sp = _sp(w, dtype=pdt)
    wm = densify_operand(sp)

    def loss_sparse(payload, x):
        op = TileSparseOperand(payload, None, sp.layout)
        y = mp_dot(x, op, policy=policy, backend="interpret")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    def loss_dense(wm, x):
        y = mp_dot(x, wm, policy=policy, backend="interpret")
        return jnp.sum(y.astype(jnp.float32) ** 2)

    gp, gx = jax.grad(loss_sparse, argnums=(0, 1))(sp.payload, x)
    gw, gxd = jax.grad(loss_dense, argnums=(0, 1))(wm, x)
    gw_masked = payload_cotangent(gw.astype(gp.dtype), sp.layout)
    np.testing.assert_allclose(np.asarray(gp, np.float32),
                               np.asarray(gw_masked, np.float32), atol=tol)
    assert np.all(np.asarray(gp[-1]) == 0)          # anchor tile frozen
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gxd), atol=tol)


def test_grad_through_gated_epilogue(rng, ops):
    x, w = ops
    sp = _sp(w)
    gate = jnp.asarray(rng.standard_normal((M, N)), "float32")
    wm = densify_operand(sp)

    def f(op_or_w, gate):
        return jnp.sum(mp_dot(x, op_or_w, policy="fp32",
                              backend="interpret", activation="silu",
                              gate=gate) ** 2)

    gs, ggs = jax.grad(f, argnums=(0, 1))(sp, gate)
    gd, ggd = jax.grad(f, argnums=(0, 1))(wm, gate)
    np.testing.assert_allclose(np.asarray(ggs), np.asarray(ggd), atol=1e-4)
    masked = payload_cotangent(gd, sp.layout)
    np.testing.assert_allclose(np.asarray(gs.payload), np.asarray(masked),
                               atol=1e-4)


def test_int8_payload_frozen(ops):
    x, w = ops
    sp8 = _sp(w, dtype="int8")

    def loss(op, x):
        return jnp.sum(mp_dot(x, op, policy="bf16",
                              backend="interpret").astype(jnp.float32))

    g, gx = jax.grad(loss, argnums=(0, 1), allow_int=True)(sp8, x)
    assert g.payload.dtype == jax.dtypes.float0
    assert np.all(np.asarray(g.scales) == 0)
    assert bool(jnp.all(jnp.isfinite(gx)))


def test_grouped_grad(gops):
    x, w = gops
    sp = _sp(w, density=0.5)
    wm = densify_operand(sp)

    def f(op_or_w):
        return jnp.sum(mp_dot_grouped(x, op_or_w, policy="fp32",
                                      backend="interpret") ** 2)

    gs = jax.grad(f)(sp)
    gd = jax.grad(f)(wm)
    masked = payload_cotangent(gd, sp.layout)
    np.testing.assert_allclose(np.asarray(gs.payload), np.asarray(masked),
                               atol=1e-4)


# --- the tile-visit gate ------------------------------------------------------

def _sparse_grid(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr

    def find(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                return eqn.params["grid_mapping"].grid
            for sub in jax.core.jaxprs_in_params(eqn.params):
                g = find(sub)
                if g is not None:
                    return g
        return None

    return find(jaxpr)


def test_traced_grid_visits_only_stored_tiles(ops):
    """The jaxpr proof: the sparse launch's grid is (M/bm, schedule_len) —
    pruned tiles are not in the iteration space at all."""
    x, w = ops
    sp = _sp(w, density=0.5)

    def f(x, payload):
        op = TileSparseOperand(payload, None, sp.layout)
        return mp_dot(x, op, policy="fp32", backend="interpret")

    grid = _sparse_grid(f, x, sp.payload)
    assert grid is not None
    m_blocks, visits = grid
    assert visits == sp.layout.schedule_len == 5
    # dense K grid on the same lattice would visit nkb * nnb = 9 tiles
    assert visits < sp.layout.ntiles


def test_traced_grid_shrinks_with_density(ops):
    x, w = ops
    visits = []
    for d in (1.0, 0.6, 0.3):
        sp = _sp(w, density=d)

        def f(x, payload, sp=sp):
            op = TileSparseOperand(payload, None, sp.layout)
            return mp_dot(x, op, policy="fp32", backend="interpret")

        visits.append(_sparse_grid(f, x, sp.payload)[1])
    assert visits[0] > visits[1] > visits[2]


# --- planning / tuning --------------------------------------------------------

def test_density_priced_plan():
    dense = plan_gemm(256, 512, 1024, "bfloat16")
    sparse = plan_gemm(256, 512, 1024, "bfloat16", density=0.25)
    assert sparse.hbm_bytes < dense.hbm_bytes
    assert sparse.flops == dense.flops // 4
    assert "density=0.25" in sparse.notes
    # default stays byte-stable
    assert plan_gemm(256, 512, 1024, "bfloat16").hbm_bytes == dense.hbm_bytes


def test_make_key_sparsity_namespace(ops):
    _, w = ops
    sp = _sp(w)
    base = make_key(M, N, K, "float32")
    tagged = make_key(M, N, K, "float32", sparsity=sp.layout.tag)
    assert tagged != base and tagged.endswith(f"|sp={sp.layout.tag}")
    assert make_key(M, N, K, "float32", sparsity="") == base
    other = _sp(w, density=0.8)
    assert tagged != make_key(M, N, K, "float32", sparsity=other.layout.tag)


def test_tune_sparse_gemm_closes_the_loop(ops):
    """tune_sparse_gemm persists under the sparsity-namespaced key, with
    blocks pinned to the stored-tile layout, and the launch reads it back
    (proven by poisoning the analytic planner: a hit never calls it)."""
    import repro.kernels.mpgemm as km
    from repro.tuning import PlanCache, set_plan_cache, tune_sparse_gemm
    x, w = ops
    sp = _sp(w)
    cache = PlanCache(None)
    r = tune_sparse_gemm(M, x, sp, mode="modeled", cache=cache, save=False)
    assert r.key.endswith(f"|sp={sp.layout.tag}")
    assert (r.best.plan.bn, r.best.plan.bk) == (sp.layout.bn, sp.layout.bk)
    assert r.best.plan.flops == int(2 * M * N * K * sp.layout.density)
    prev = set_plan_cache(cache)
    real_plan_gemm = km.plan_gemm

    def poisoned(*a, **k):
        raise AssertionError("analytic planner called despite tuned plan")

    try:
        km.plan_gemm = poisoned
        y = mp_dot(x, sp, policy="fp32", backend="interpret")
        ref = np.asarray(x) @ np.asarray(densify_operand(sp))
        np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    finally:
        km.plan_gemm = real_plan_gemm
        set_plan_cache(prev)


def test_tune_sparse_gemm_fused_and_grouped_keys(rng, ops, gops):
    """Regression (review): the tuned key must carry the SAME epilogue/g
    components the launch-side lookup uses — a gated-epilogue or grouped
    sparse launch must consume its tuned plan, not miss to the analytic
    fallback."""
    import repro.kernels.mpgemm as km
    from repro.core.gemm_spec import EpilogueSpec
    from repro.tuning import PlanCache, set_plan_cache, tune_sparse_gemm
    x, w = ops
    gx, gw = gops
    gate = jnp.asarray(rng.standard_normal((M, N)), "float32")
    ep = EpilogueSpec(kind="gated", activation="silu")
    sp = _sp(w)
    spg = _sp(gw, density=0.5)
    cache = PlanCache(None)
    r_ep = tune_sparse_gemm(M, x, sp, epilogue=ep, mode="modeled",
                            cache=cache, save=False)
    assert f"|ep={ep.tag}|" in r_ep.key + "|"
    assert f"|sp={sp.layout.tag}" in r_ep.key
    r_g = tune_sparse_gemm(M, gx, spg, mode="modeled", cache=cache,
                           save=False)
    assert r_g.key.startswith(f"g{G}|") and r_g.best.plan.g == G
    prev = set_plan_cache(cache)
    real_plan_gemm = km.plan_gemm

    def poisoned(*a, **k):
        raise AssertionError("analytic planner called despite tuned plan")

    yd = mp_dot(x, densify_operand(sp), policy="fp32",
                backend="interpret", activation="silu", gate=gate)
    ygd = mp_dot_grouped(gx, densify_operand(spg), policy="fp32",
                         backend="interpret")
    try:
        km.plan_gemm = poisoned   # sparse launches must HIT the cache
        y = mp_dot(x, sp, policy="fp32", backend="interpret",
                   activation="silu", gate=gate)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yd), atol=1e-5)
        yg = mp_dot_grouped(gx, spg, policy="fp32", backend="interpret")
        np.testing.assert_allclose(np.asarray(yg), np.asarray(ygd),
                                   atol=1e-5)
    finally:
        km.plan_gemm = real_plan_gemm
        set_plan_cache(prev)


def test_sparse_plan_pins_layout_blocks(ops):
    """A plan incompatible with the stored-tile lattice must be rejected."""
    x, w = ops
    sp = _sp(w)
    bad = plan_gemm(M, N, K, "float32")
    bad = dataclasses.replace(bad, bn=sp.layout.bn * 2)
    with pytest.raises(ValueError, match="incompatible"):
        mpgemm_pallas(x, b_sparse=sp, plan=bad, interpret=True)


# --- packed-weight cache: sparse/dense no-alias regression --------------------

def test_cache_key_separates_sparse_and_dense(ops):
    """Regression (PR 5 satellite): sparse-packed and dense-packed payloads
    of the SAME weight must have distinct cache keys — the layout tag
    (incl. the sparsity pattern digest) is part of the key."""
    _, w = ops
    packed = pack_operand(w, BLOCKS, backend="xla")
    sp = _sp(w)
    kd = make_weight_key("mlp/w_up", w, packed.layout)
    ks = make_weight_key("mlp/w_up", w, sp.layout)
    assert kd != ks
    # and two different patterns of the same weight differ too
    ks2 = make_weight_key("mlp/w_up", w, _sp(w, density=0.8).layout)
    assert ks != ks2


def test_cache_roundtrips_sparse_operand(tmp_path, ops):
    _, w = ops
    cache = PackedWeightCache(tmp_path)
    sp = _sp(w, dtype="int8")
    built = cache.get_or_build("mlp/w_up", w, sp.layout, lambda: sp)
    assert built is sp and cache.misses == 1
    # same layout -> hit from memory
    again = cache.get_or_build("mlp/w_up", w, sp.layout, lambda: None)
    assert again is sp and cache.hits == 1
    # fresh cache object -> disk round trip, type + layout preserved
    cold = PackedWeightCache(tmp_path)
    restored = cold.get_or_build(
        "mlp/w_up", w, sp.layout,
        lambda: pytest.fail("disk hit expected, build_fn called"))
    assert is_sparse(restored)
    assert restored.layout == sp.layout
    np.testing.assert_array_equal(np.asarray(restored.payload),
                                  np.asarray(sp.payload))
    np.testing.assert_allclose(np.asarray(restored.scales),
                               np.asarray(sp.scales))


def test_cache_dense_and_sparse_coexist(tmp_path, ops):
    _, w = ops
    cache = PackedWeightCache(tmp_path)
    packed = cache.get_or_pack("w", w, BLOCKS, backend="xla")
    sp = _sp(w)
    sparse = cache.get_or_build("w", w, sp.layout, lambda: sp)
    assert len(cache) == 2
    cold = PackedWeightCache(tmp_path)
    assert not is_sparse(cold.get(make_weight_key("w", w, packed.layout)))
    assert is_sparse(cold.get(make_weight_key("w", w, sp.layout)))


# --- sparsify_params walker ---------------------------------------------------

def test_sparsify_params_tree(rng):
    d, f, e, L = 32, 64, 4, 2
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)
    params = {
        "stack": {
            "mlp": {
                "w_up": jax.random.normal(ks[0], (L, d, f)),
                "w_down": jax.random.normal(ks[1], (L, f, d)),
            },
            "moe": {"w_gate": jax.random.normal(ks[2], (L, e, d, f))},
            "ln1": {"scale": jnp.zeros((L, d))},
        },
        "w_up": jax.random.normal(ks[3], (d, f)),
        "w_gate": jax.random.normal(ks[4], (e, d, f)),   # MoE grouped
        "embed": jax.random.normal(ks[5], (64, d)),
        "router": jax.random.normal(ks[6], (d, e)),
    }
    out = sparsify_params(params, density=0.5, policy="bf16", cache=None,
                          blocks=(16, 16))
    assert is_sparse(out["w_up"]) and out["w_up"].layout.g == 1
    assert is_sparse(out["w_gate"]) and out["w_gate"].layout.g == e
    # stacked leaves: leading layer axis on the payload, shared layout
    st = out["stack"]["mlp"]["w_up"]
    assert is_sparse(st) and st.payload.shape[0] == L
    assert st.payload.shape[1] == st.layout.nnz + 1
    stm = out["stack"]["moe"]["w_gate"]
    assert is_sparse(stm) and stm.payload.shape[0] == L \
        and stm.layout.g == e
    # non-eligible leaves untouched
    assert not is_sparse(out["embed"]) and not is_sparse(out["router"])
    assert not is_sparse(out["stack"]["ln1"]["scale"])
    assert 0.4 <= sparse_param_density(out) <= 0.6


def test_sparsify_params_stacked_scan_slices(rng):
    """A scan over the stacked payload must hand each layer a consumable
    2-D sparse operand."""
    d, f, L = 32, 48, 3
    w = jax.random.normal(jax.random.PRNGKey(1), (L, d, f))
    out = sparsify_params({"stack": {"w_up": w}}, density=0.5, policy="bf16",
                          cache=None, blocks=(16, 16))
    sp = out["stack"]["w_up"]
    x = jnp.asarray(np.random.default_rng(0).standard_normal((5, d)),
                    jnp.float32)

    def body(carry, layer_op):
        y = mp_dot(carry, layer_op, policy="fp32", backend="interpret")
        return carry, y

    _, ys = jax.lax.scan(body, x, sp)
    assert ys.shape == (L, 5, f)
    for i in range(L):
        per_layer = TileSparseOperand(
            sp.payload[i], None if sp.scales is None else sp.scales[i],
            sp.layout)
        ref = mp_dot(x, per_layer, policy="fp32", backend="interpret")
        np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(ref),
                                   atol=1e-5)


def test_sparsify_params_uses_cache(rng, ops):
    _, w = ops
    cache = PackedWeightCache(None)
    tree = {"w_up": w}
    kw = dict(policy="bf16", cache=cache, blocks=BLOCKS)
    sparsify_params(tree, density=0.5, **kw)
    assert cache.misses == 1
    sparsify_params(tree, density=0.5, **kw)
    assert cache.hits == 1
    # a different density is a different key -> miss, never an alias
    sparsify_params(tree, density=0.8, **kw)
    assert cache.misses == 2


# --- op-level validation ------------------------------------------------------

def test_mp_dot_operand_validation(ops):
    x, w = ops
    sp = _sp(w)
    with pytest.raises(ValueError, match="exactly one"):
        mp_dot(x, w, b_sparse=sp)
    with pytest.raises(ValueError, match="exactly one"):
        mp_dot(x)
    with pytest.raises(ValueError, match="use mp_dot_grouped"):
        gw = jnp.asarray(np.random.default_rng(0)
                         .standard_normal((G, K, N)), "float32")
        mp_dot(x, _sp(gw, density=0.5))
