"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000,
    window=4096,                 # mistral-style SWA
    rope_theta=10000.0, mlp="swiglu", norm="rms",
    source="arXiv:2401.16818",
)

SMOKE = ArchConfig(
    name="h2o-danube3-4b-smoke", family="dense",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512, window=64,
    mlp="swiglu", norm="rms",
)
