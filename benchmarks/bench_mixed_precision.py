"""Paper Fig. 14: mixed-precision GEMM throughput (FP32 vs FP16->FP32 vs
INT8->INT32; here fp32 / bf16->f32 / int8->i32).

Reports modeled roofline time per precision on the paper workloads and the
achieved fraction of each precision's peak — the paper's 94%-of-peak
claim (their IDs 14, 18) is the reference point, checked on the same IDs."""
import numpy as np

from benchmarks.common import PAPER_WORKLOADS, emit, modeled_time_s, record
from repro.core.blocking import plan_gemm
from repro.core.constants import DEFAULT_HW


def run():
    hw = DEFAULT_HW
    peaks = {"float32": hw.peak_flops_fp32, "bfloat16": hw.peak_flops_bf16,
             "int8": hw.peak_ops_int8}
    for wid, m, n, k in PAPER_WORKLOADS:
        times = {}
        for dtype in ("float32", "bfloat16", "int8"):
            plan = plan_gemm(m, n, k, dtype)
            times[dtype] = modeled_time_s(plan.flops, plan.hbm_bytes, dtype)
        frac = {d: (2 * m * n * k / times[d]) / peaks[d] for d in times}
        emit(f"mixed_precision_{wid:02d}", 0.0,
             f"bf16_speedup_vs_f32={times['float32']/times['bfloat16']:.2f};"
             f"int8_speedup_vs_bf16={times['bfloat16']/times['int8']:.2f};"
             f"peak_frac_f32={frac['float32']:.2f};"
             f"peak_frac_bf16={frac['bfloat16']:.2f};"
             f"peak_frac_int8={frac['int8']:.2f}")
        record(f"mixed_precision_{wid:02d}", "gemm",
               workload={"paper_workload": wid, "m": m, "n": n, "k": k},
               metrics={"bf16_speedup_vs_f32":
                        times["float32"] / times["bfloat16"],
                        "int8_speedup_vs_bf16":
                        times["bfloat16"] / times["int8"],
                        "peak_frac_f32": frac["float32"],
                        "peak_frac_bf16": frac["bfloat16"],
                        "peak_frac_int8": frac["int8"]})
    # paper's 94%-of-peak reference cells
    for wid, m, n, k in [PAPER_WORKLOADS[13], PAPER_WORKLOADS[17]]:
        plan = plan_gemm(m, n, k, "int8")
        t = modeled_time_s(plan.flops, plan.hbm_bytes, "int8")
        frac = (2 * m * n * k / t) / peaks["int8"]
        emit(f"mixed_precision_peakcheck_id{wid}", 0.0,
             f"int8_peak_fraction={frac:.3f};paper_reference=0.94")
        record(f"mixed_precision_peakcheck_id{wid}", "gemm",
               workload={"paper_workload": wid, "m": m, "n": n, "k": k,
                         "paper_reference": 0.94},
               metrics={"int8_peak_frac": frac})


if __name__ == "__main__":
    run()
