"""Paper Fig. 15: optimization breakdown.

Cumulative modeled effect of each MPGEMM-TPU optimization on the paper
workloads, mirroring the paper's three bars:
  1. cache-aware partitioning + dual packing  (analytic plan vs naive 256^3)
  2. wide loads (four-Z analogue)             (>=512B minor rows vs 64B rows)
  3. first-round online packing               (fused epilogue/cast vs a
                                               separate memory pass over C)
"""
import dataclasses

import numpy as np

from benchmarks.common import PAPER_WORKLOADS, emit, modeled_time_s, record
from repro.core.blocking import naive_plan, plan_gemm
from repro.core.constants import DEFAULT_HW, HardwareSpec


def run(dtype="float32"):
    hw = DEFAULT_HW
    narrow_hw = dataclasses.replace(hw, min_dma_row_bytes=64)
    gains = {"partition": [], "wide_loads": [], "online_pack": []}
    for wid, m, n, k in PAPER_WORKLOADS:
        naive = naive_plan(m, n, k, dtype)
        # stage 0: naive blocks + narrow rows + separate epilogue pass
        eff64 = 64 / (64 + hw.min_dma_row_bytes)
        t0 = max(naive.flops / hw.peak_flops_fp32,
                 naive.hbm_bytes / (hw.hbm_bw * eff64)) \
            + 2 * m * n * 4 / hw.hbm_bw          # separate C pass
        # stage 1: + analytic partitioning (paper's biggest bar, 1.62x avg)
        plan = plan_gemm(m, n, k, dtype)
        t1 = max(plan.flops / hw.peak_flops_fp32,
                 plan.hbm_bytes / (hw.hbm_bw * eff64)) \
            + 2 * m * n * 4 / hw.hbm_bw
        # stage 2: + wide rows (planner enforces >=512B minor spans)
        row = min(plan.bk, plan.bn) * 4
        eff = row / (row + hw.min_dma_row_bytes)
        t2 = max(plan.flops / hw.peak_flops_fp32,
                 plan.hbm_bytes / (hw.hbm_bw * eff)) \
            + 2 * m * n * 4 / hw.hbm_bw
        # stage 3: + fused epilogue (no separate C pass)
        t3 = max(plan.flops / hw.peak_flops_fp32,
                 plan.hbm_bytes / (hw.hbm_bw * eff))
        gains["partition"].append(t0 / t1)
        gains["wide_loads"].append(t1 / t2)
        gains["online_pack"].append(t2 / t3)
        emit(f"breakdown_{wid:02d}", 0.0,
             f"partition={t0/t1:.2f};wide_loads={t1/t2:.2f};"
             f"online_pack={t2/t3:.2f};total={t0/t3:.2f}")
        record(f"breakdown_{wid:02d}", "gemm",
               workload={"paper_workload": wid, "m": m, "n": n, "k": k},
               metrics={"partition_gain": t0 / t1,
                        "wide_loads_gain": t1 / t2,
                        "online_pack_gain": t2 / t3,
                        "total_gain": t0 / t3})
    for k_, v in gains.items():
        record(f"breakdown_geomean_{k_}", "gemm",
               workload={"stage": k_, "workloads": len(PAPER_WORKLOADS)},
               metrics={"geomean": float(np.exp(np.mean(np.log(v))))})
        emit(f"breakdown_geomean_{k_}", 0.0,
             f"geomean={np.exp(np.mean(np.log(v))):.3f};"
             f"paper_reference={'1.62' if k_=='partition' else '1.17' if k_=='wide_loads' else '~1.0x(limited)'}")


if __name__ == "__main__":
    run()
