"""Hypothesis property tests for the MPGEMM kernel itself: random shapes,
dtypes, and transposes against the oracle, in interpret mode."""
import pytest

hp = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import numpy as np

import jax.numpy as jnp

from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref

dims = st.integers(min_value=1, max_value=300)


@hp.given(m=dims, n=dims, k=dims,
          dtype=st.sampled_from(["float32", "bfloat16"]),
          trans_a=st.booleans(), trans_b=st.booleans(),
          seed=st.integers(0, 2 ** 16))
@hp.settings(max_examples=25, deadline=None)
def test_mpgemm_random_shapes(m, n, k, dtype, trans_a, trans_b, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.standard_normal((k, m) if trans_a else (m, k)), dtype)
    b = jnp.asarray(rng.standard_normal((n, k) if trans_b else (k, n)), dtype)
    out = mpgemm_pallas(a, b, trans_a=trans_a, trans_b=trans_b,
                        interpret=True)
    ref = mpgemm_ref(a, b, trans_a=trans_a, trans_b=trans_b)
    tol = (1e-5 if dtype == "float32" else 4e-2) * max(1.0, k / 64)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64),
                               atol=tol, rtol=2e-2)


@hp.given(m=dims, n=dims, k=dims, seed=st.integers(0, 2 ** 16))
@hp.settings(max_examples=15, deadline=None)
def test_mpgemm_int8_random_shapes(m, n, k, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-127, 127, (m, k)), "int8")
    b = jnp.asarray(rng.integers(-127, 127, (k, n)), "int8")
    out = mpgemm_pallas(a, b, interpret=True)
    ref = mpgemm_ref(a, b)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
