"""AdamW in pure JAX, with global-norm clipping and decoupled weight decay.

Optimizer state inherits the parameters' sharding (ZeRO-style: since params
are FSDP-sharded over the 'data' axis, so are m/v — no replicated optimizer
memory)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # int32 scalar
    m: Any                   # like params (f32)
    v: Any                   # like params (f32)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig,
                 lr_scale=1.0):
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "clip_scale": scale}
    return new_p, AdamWState(step, new_m, new_v), metrics


def cosine_schedule(step, *, base_lr=1.0, warmup=100, total=10000,
                    min_frac=0.1):
    """Multiplicative lr scale in [min_frac, 1]."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * warm * cos
