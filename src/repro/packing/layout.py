"""Packed-operand layout metadata and the :class:`PackedOperand` pytree.

The paper's third pillar — "efficient data packing with on-the-fly
transposition" — packs operand blocks into micro-kernel-native layouts
*once*, so the GEMM inner loop reads contiguous, transpose-resolved tiles.
This module defines the TPU form of that layout:

    logical weight  w[k, n]   (or w[n, k] under ``trans_w``)
        │  pack (repro.packing.pack): tile, pad edges with ZEROS,
        │  resolve the transpose, optionally per-tile int8 quantize
        ▼
    payload[nkb, nnb, bk, bn]          (grouped: [g, nkb, nnb, bk, bn])
    scales [nkb, nnb] f32 (int8 only)  (grouped: [g, nkb, nnb])

Every (bk, bn) tile is **contiguous in HBM** and sits exactly where the
kernel's (kk, j) grid step needs it, so the pack-aware MPGEMM path
(``kernels/mpgemm.py::mpgemm_pallas(a, packed)``) reads it with an
*identity* BlockSpec index map — no strided DMA, no on-the-fly
transposition, no per-call dequant/cast materialization.

:class:`PackedLayout` is the static (hashable) description; it travels as
pytree aux data, so :class:`PackedOperand` can sit inside model parameter
trees, be sliced by ``lax.scan`` over stacked layers (the payload simply
carries a leading layer axis), and cross jit boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static description of one packed operand (pytree aux data).

    ``k``/``n`` are the LOGICAL GEMM dims (contraction x output columns) —
    the transpose of a ``trans_w`` source is already resolved, so consumers
    never see the storage orientation.  ``dtype`` is the payload dtype
    (``int8`` implies per-tile scales); ``orig_dtype`` is the source
    array's dtype (the unpack target for float payloads).  ``g`` > 1 marks
    a grouped operand (MoE experts / batched weights).
    """

    k: int
    n: int
    bk: int
    bn: int
    dtype: str
    orig_dtype: str
    trans_w: bool = False
    g: int = 1

    @property
    def nkb(self) -> int:
        return _cdiv(self.k, self.bk)

    @property
    def nnb(self) -> int:
        return _cdiv(self.n, self.bn)

    @property
    def per_tile_scales(self) -> bool:
        return self.dtype == "int8"

    @property
    def payload_shape(self) -> Tuple[int, ...]:
        base = (self.nkb, self.nnb, self.bk, self.bn)
        return (self.g,) + base if self.g != 1 else base

    @property
    def scales_shape(self) -> Optional[Tuple[int, ...]]:
        if not self.per_tile_scales:
            return None
        base = (self.nkb, self.nnb)
        return (self.g,) + base if self.g != 1 else base

    @property
    def tag(self) -> str:
        """Plan-cache layout tag (tuning/plan_cache.py::make_key(layout=)).

        Identifies the packed-B access pattern so packed and unpacked
        tunings never collide: the packed kernel's B-side DMA behavior
        depends only on (bk, bn, payload dtype), never on the resolved-away
        source transpose.
        """
        return f"packB{self.bk}x{self.bn}{self.dtype}"

    def describe(self) -> str:
        shape = f"{self.k}x{self.n}"
        if self.g != 1:
            shape = f"{self.g}x{shape}"
        t = "ᵀ" if self.trans_w else ""
        return (f"PackedLayout[{shape}{t} {self.orig_dtype}->{self.dtype} "
                f"tiles=({self.bk},{self.bn})x({self.nkb},{self.nnb})]")


class PackedOperand:
    """A pre-packed GEMM operand: payload + optional per-tile scales + layout.

    Registered as a pytree (payload/scales are children, layout is aux), so
    it flows through jit, scan (stacked layers: payload gets an extra
    leading axis that scan slices away), and optimizer/param trees.  The
    consuming ops (``mp_dot`` / ``mp_dot_grouped`` / ``mpgemm_pallas``)
    dispatch on the type.
    """

    __slots__ = ("payload", "scales", "layout")

    def __init__(self, payload, scales, layout: PackedLayout):
        self.payload = payload
        self.scales = scales
        self.layout = layout

    # -- conveniences --------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        """The LOGICAL (transpose-resolved) operand shape: (k, n) / (g, k, n)."""
        base = (self.layout.k, self.layout.n)
        return (self.layout.g,) + base if self.layout.g != 1 else base

    @property
    def dtype(self):
        return jnp.dtype(self.layout.dtype)

    @property
    def nbytes(self) -> int:
        total = self.payload.size * self.payload.dtype.itemsize
        if self.scales is not None:
            total += self.scales.size * self.scales.dtype.itemsize
        return total

    def astype(self, dtype) -> "PackedOperand":
        """Payload cast for float payloads (no-op when dtypes already match).

        Packing with the policy's compute dtype avoids this; the cast exists
        so a mismatched payload stays *correct* (it costs one materialized
        copy per call — exactly what packing is meant to avoid).
        """
        dtype = jnp.dtype(dtype)
        if self.layout.per_tile_scales or self.payload.dtype == dtype:
            return self
        layout = dataclasses.replace(self.layout, dtype=str(dtype))
        return PackedOperand(self.payload.astype(dtype), None, layout)

    def __repr__(self) -> str:
        return self.layout.describe().replace("PackedLayout", "PackedOperand")


def _flatten(p: PackedOperand):
    return (p.payload, p.scales), p.layout


def _unflatten(layout: PackedLayout, children) -> PackedOperand:
    payload, scales = children
    return PackedOperand(payload, scales, layout)


jax.tree_util.register_pytree_node(PackedOperand, _flatten, _unflatten)


def is_packed(w) -> bool:
    return isinstance(w, PackedOperand)
