"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  See DESIGN.md §6 for the mapping
to the paper's tables and EXPERIMENTS.md for methodology (CPU wall-time is
a sanity signal; modeled roofline terms are the graded numbers).

Beyond the CSV, the harness owns the perf-trajectory artifacts
(docs/perf_trajectory.md):

  --emit            install a Recorder and write one versioned
                    ``BENCH_<area>.json`` per area to --out
  --diff DIR        compare the emitted files against the baselines in DIR
                    (benchmarks/baselines in CI); exit 1 on any regression
  --only AREA [...] run only the named areas (gemm / packing / quant /
                    sparse / serve / distributed / obs)
  --smoke           reduced workloads (small shapes, no wall clocks) — the
                    configuration the committed baselines are built from,
                    so ``--smoke --emit --diff benchmarks/baselines`` is
                    deterministic and CI-fast
"""
import argparse
import os
import sys

# Idempotent path setup: repo root (for `benchmarks.*`) and src/ (for
# `repro.*`), prepended once — re-imports and nested invocations must not
# grow sys.path.
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
for _p in (os.path.join(_ROOT, "src"), _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

AREAS = ("gemm", "packing", "quant", "sparse", "serve", "distributed",
         "obs")


def run_gemm(smoke: bool = False) -> None:
    from benchmarks import (
        bench_autotune, bench_breakdown, bench_epilogue,
        bench_gemm_workloads, bench_irregular, bench_loads,
        bench_mixed_precision, bench_tiles, roofline_report,
    )
    bench_tiles.run()                      # paper Fig. 2
    bench_loads.run()                      # paper Fig. 3
    # paper Table III + Fig. 10/11 (+ Fig. 12 ladder, MoE expert shapes);
    # wall clocks are emit-noise, skip them under --smoke
    bench_gemm_workloads.run("float32", wall=not smoke)
    bench_gemm_workloads.run("bfloat16", wall=False)
    bench_gemm_workloads.run_grouped(wall=False)
    bench_irregular.run(check_kernel=not smoke)   # paper Fig. 13
    bench_mixed_precision.run()            # paper Fig. 14
    bench_breakdown.run()                  # paper Fig. 15
    roofline_report.run()                  # beyond-paper: dry-run roofline
    bench_autotune.run()                   # beyond-paper: Sec. III closed loop
    bench_epilogue.run(smoke=smoke)        # beyond-paper: fused epilogues
    bench_epilogue.run_trace_gate()
    if not smoke:
        bench_epilogue.run_wall_sanity()


def run_packing(smoke: bool = False) -> None:
    from benchmarks import bench_packing
    from benchmarks.common import MOE_GROUPED_WORKLOADS, PAPER_WORKLOADS
    # The emit path keeps the packed-zeros footprint small: 2-D workloads
    # from the paper's decode rows, grouped shapes from the small-expert
    # configs (granite / deepseek) — the mixtral packs are multi-GiB.
    work_2d = PAPER_WORKLOADS[:3] if smoke else None
    work_g = MOE_GROUPED_WORKLOADS[2:4] if smoke else None
    for policy in ("bf16", "int8"):        # beyond-paper: §IV-C AOT packing
        bench_packing.run(policy, work=work_2d)
        bench_packing.run_grouped(policy, work=work_g)
    bench_packing.run("bf16", trans_w=True, work=work_2d)
    if not smoke:
        bench_packing.run_wall_sanity()


def run_quant(smoke: bool = False) -> None:
    from benchmarks import bench_quant
    rows = bench_quant.run(smoke=smoke)   # precision-ladder weight traffic
    bench_quant.check_gate(rows)
    bench_quant.run_trace_gate(assert_gate=True)


def run_sparse(smoke: bool = False) -> None:
    from benchmarks import bench_sparse
    bench_sparse.run()                     # beyond-paper: tile-sparse MPGEMM
    bench_sparse.run_trace_gate(m_tokens=128 if smoke else 512)
    if not smoke:
        bench_sparse.run_wall()


def run_serve(smoke: bool = False) -> None:
    from benchmarks import bench_serve
    bench_serve.run()                      # beyond-paper: paged vs dense KV
    bench_serve.run_trace_gate(assert_gate=smoke)
    bench_serve.run_e2e(assert_gate=smoke)


def run_distributed(smoke: bool = False) -> None:
    from benchmarks import bench_distributed
    bench_distributed.run()                # beyond-paper: mesh scale-out
    # The collective-schedule gate re-execs under forced host devices when
    # the host has fewer than 4, so the emitted records are device-count
    # independent; the multi-device parity smoke runs only via
    # `bench_distributed --smoke` (the CI multidevice job).
    bench_distributed.run_trace_gate(assert_gate=smoke)


def run_obs(smoke: bool = False) -> None:
    from benchmarks import bench_obs
    # The transparency gate is exact (modeled payload bytewise-identical
    # with the registry/tracer on vs off), so it is always asserted; the
    # counter_inc wall timing is emit-noise, skipped under --smoke.
    bench_obs.run(smoke=smoke)


AREA_RUNNERS = {
    "gemm": run_gemm,
    "packing": run_packing,
    "quant": run_quant,
    "sparse": run_sparse,
    "serve": run_serve,
    "distributed": run_distributed,
    "obs": run_obs,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--only", nargs="+", choices=AREAS, default=None,
                    metavar="AREA",
                    help=f"run only these areas (default: all of {AREAS})")
    ap.add_argument("--emit", action="store_true",
                    help="record structured results and write "
                         "BENCH_<area>.json files to --out")
    ap.add_argument("--out", default=os.path.join(_ROOT, "bench_out"),
                    help="directory for emitted BENCH files "
                         "(default: <repo>/bench_out)")
    ap.add_argument("--diff", metavar="BASELINE_DIR", default=None,
                    help="after emitting, diff against the BENCH files in "
                         "this directory; exit 1 on regressions "
                         "(implies --emit)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced workloads, no wall clocks (deterministic "
                         "— what the committed baselines use)")
    args = ap.parse_args(argv)
    if args.diff:
        args.emit = True

    areas = tuple(args.only) if args.only else AREAS

    recorder = None
    if args.emit:
        from benchmarks import common
        from repro.perf.trajectory import Recorder
        recorder = Recorder()
        common.set_recorder(recorder)
    try:
        for area in areas:
            AREA_RUNNERS[area](smoke=args.smoke)
    finally:
        if args.emit:
            from benchmarks import common
            common.set_recorder(None)

    if recorder is None:
        return 0

    paths = recorder.write_all(args.out)
    for area, path in sorted(paths.items()):
        print(f"bench_emit,{area},{path}")

    if not args.diff:
        return 0

    from repro.perf.diff import diff_paths, markdown_report
    from repro.perf.trajectory import bench_path
    results = []
    missing_emit = [a for a in areas if a not in paths]
    if missing_emit:
        print(f"bench_diff,ERROR,areas emitted no records: {missing_emit}")
        return 1
    for area in areas:
        baseline = bench_path(args.diff, area)
        if not baseline.exists():
            print(f"bench_diff,{area},no_baseline({baseline})")
            continue
        results.append(diff_paths(baseline, paths[area]))
    report = markdown_report(results)
    report_path = os.path.join(args.out, "bench_diff.md")
    with open(report_path, "w") as f:
        f.write(report)
    print(report)
    print(f"bench_diff_report,{report_path}")
    return 0 if all(r.ok for r in results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
