"""whisper-medium — encoder-decoder; conv frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]

24L is interpreted as 24 encoder + 24 decoder blocks (whisper-medium's
published layout); decode shapes exercise the decoder's self-attn KV cache +
static cross-attn cache."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865,
    encoder_layers=24, encoder_seq=1500,
    pos_embed="learned", causal=True,
    mlp="gelu", mlp_bias=True, norm="layer",
    source="arXiv:2212.04356",
)

SMOKE = ArchConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab=512,
    encoder_layers=2, encoder_seq=64,
    pos_embed="learned", mlp="gelu", mlp_bias=True, norm="layer",
)
