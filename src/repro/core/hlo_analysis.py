"""HLO-text cost model: FLOPs / HBM bytes / collective bytes with
while-loop trip-count multiplication.

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, but this framework scans layer stacks / microbatches / KV chunks, so
raw cost_analysis under-reports a 56-layer model by ~56x.  This module
parses ``compiled.as_text()`` (post-SPMD, per-device HLO), recovers each
while loop's trip count from its condition computation, and accumulates:

  * flops             — dot_general exactly (2*B*M*N*K from dimension
                        numbers), elementwise/reduce approximately
                        (1 flop/elem), multiplied through nested loops;
  * hbm_bytes         — operand+output bytes at fusion boundaries (each
                        fusion = one kernel pass over its I/O), x trips;
  * collective_bytes  — per-device operand bytes of all-gather /
                        all-reduce / reduce-scatter / all-to-all /
                        collective-permute, x trips (per kind, too).

Validated against cost_analysis on loop-free programs (tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "custom-call", "iota", "while", "conditional", "call",
}

_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs",
    "logistic", "cosine", "sine", "select", "compare", "and", "or", "xor",
    "clamp", "floor", "ceil", "round-nearest-afz", "sign", "atan2",
    "exponential-minus-one", "log-plus-one", "cbrt", "erf",
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dtype, dims = m.groups()
    return dtype, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str              # everything after the opening paren
    operands: List[str]
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]
    is_entry: bool = False


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if cur is None:
            s = line.strip()
            if s.endswith("{") and "->" in s and (
                    s.startswith("%") or s.startswith("ENTRY")):
                m = _COMP_NAME_RE.match(s)
                if m:
                    cur = Computation(m.group(1), [], {},
                                      is_entry=s.startswith("ENTRY"))
            continue
        if line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        # Split operands at top paren level.  jax >= 0.4.3x prints operand
        # TYPES inline, e.g. ``dot(f32[128,128]{1,0} %x, ...)`` — both the
        # shape brackets and the layout braces contain commas, so bracket
        # and brace depth must nest like paren depth or every typed operand
        # shears the list (and with it every positional billing rule).
        depth, buf, ops = 0, "", []
        for ch in rest:
            if ch in "({[":
                depth += 1
                buf += ch
            elif ch in ")}]":
                if ch == ")" and depth == 0:
                    break
                depth -= 1
                buf += ch
            elif ch == "," and depth == 0:
                ops.append(buf.strip())
                buf = ""
            else:
                buf += ch
        if buf.strip():
            ops.append(buf.strip())
        operand_names = []
        for o in ops:
            mm = re.search(r"%([\w\.\-]+)", o)
            operand_names.append(mm.group(1) if mm else o)
        inst = Instr(name, type_str.strip(), opcode, rest, operand_names,
                     is_root=line.lstrip().startswith("ROOT"))
        cur.instrs.append(inst)
        cur.by_name[name] = inst
    return comps


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=\{([0-9,]*)\}", rest)
    return m.group(1) if m else None


def _attr_name(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _dot_flops(inst: Instr, comp: Computation) -> float:
    out_elems = _shape_elems(inst.type_str)
    lhs = comp.by_name.get(inst.operands[0]) if inst.operands else None
    k = 1
    cdims = _attr(inst.rest, "lhs_contracting_dims")
    if lhs is not None and cdims:
        _, dims = _first_shape_dims(lhs.type_str)
        for ci in cdims.split(","):
            if ci != "" and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _conv_flops(inst: Instr, comp: Computation) -> float:
    # flops ~= 2 * out_elems * (kernel spatial * in_features)
    out_elems = _shape_elems(inst.type_str)
    rhs = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
    if rhs is None:
        return 2.0 * out_elems
    _, kd = _first_shape_dims(rhs.type_str)
    kprod = 1
    for d in kd[:-1]:
        kprod *= d
    return 2.0 * out_elems * kprod


def _trip_count(while_inst: Instr, comps: Dict[str, Computation]) -> int:
    """Recover trip count from the while condition: compare(iv, constant).

    Post-optimization HLO wraps the compare in a kLoop fusion, so we collect
    integer scalar constants across the condition computation AND any
    computations it calls; the loop bound is (heuristically) the largest.
    Adds 1 for LE comparisons found anywhere in the region.
    """
    cond = comps.get(_attr_name(while_inst.rest, "condition") or "")
    if cond is None:
        return 1
    region = [cond]
    for inst in cond.instrs:
        sub = comps.get(_attr_name(inst.rest, "calls") or "")
        if sub is not None:
            region.append(sub)
    consts: List[int] = []
    has_le = False
    for comp in region:
        for inst in comp.instrs:
            if inst.opcode == "constant" and inst.type_str.startswith("s"):
                mm = re.search(r"constant\((-?\d+)\)",
                               f"constant({inst.rest}")
                if mm:
                    consts.append(int(mm.group(1)))
            if inst.opcode == "compare" and "direction=LE" in inst.rest:
                has_le = True
    if not consts:
        return 1
    return max(1, max(consts) + (1 if has_le else 0))


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    # Bytes of pure dtype-upcast converts feeding dot ops.  XLA:CPU upcasts
    # bf16 dot operands to f32 (DotThunk wants f32); the TPU MXU reads bf16
    # natively, so these conversions would not exist in the target program.
    # Reported separately and EXCLUDED from the roofline memory term.
    upcast_bytes: float = 0.0
    collective_bytes: float = 0.0
    # Wire-cost weighted: ring all-reduce moves ~2x its operand bytes over
    # the links; reduce-scatter / all-gather / all-to-all move ~1x.  The
    # roofline collective term uses this.
    wire_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)


def _operand_bytes(inst: Instr, comp: Computation) -> float:
    total = 0.0
    for op in inst.operands:
        src = comp.by_name.get(op)
        if src is not None:
            total += _shape_bytes(src.type_str)
    return total


def _sliced_io_bytes(inst: Instr, comp: Computation) -> float:
    """Bytes for ops that touch only a slice of big buffers.

    dynamic-slice reads output-size bytes; dynamic-update-slice reads+writes
    the update operand's size (the big buffer is aliased in place).  Without
    this, a 30-layer stacked KV cache gets billed in full on every layer's
    slice — ~100x over-count.
    """
    if inst.opcode == "dynamic-slice":
        return 2.0 * _shape_bytes(inst.type_str)
    if inst.opcode == "dynamic-update-slice":
        upd = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
        ub = _shape_bytes(upd.type_str) if upd else _shape_bytes(inst.type_str)
        return 2.0 * ub
    return -1.0


def _fusion_bytes(inst: Instr, comp: Computation,
                  fused: Computation) -> float:
    """Fusion boundary bytes with slice-awareness.

    An operand whose in-fusion parameter feeds ONLY dynamic-slice ops is
    billed at the slice sizes; a fusion whose root is dynamic-update-slice
    writes only the update (buffer aliased)."""
    params: Dict[int, Instr] = {}
    for fi in fused.instrs:
        if fi.opcode == "parameter":
            m = re.match(r"\s*(\d+)", fi.rest)
            if m:
                params[int(m.group(1))] = fi
    billed = []  # (full_bytes, billed_bytes) per operand
    for idx, opname in enumerate(inst.operands):
        src = comp.by_name.get(opname)
        full = _shape_bytes(src.type_str) if src else 0.0
        bill = full
        p = params.get(idx)
        if p is not None:
            users = [u for u in fused.instrs if p.name in u.operands]
            if users and all(u.opcode in ("dynamic-slice",
                                          "dynamic-update-slice", "convert")
                             for u in users):
                b = 0.0
                for u in users:
                    if u.opcode == "dynamic-slice":
                        b += _shape_bytes(u.type_str)
                    elif u.opcode == "convert":
                        b += full  # resolved below for DUS-rooted fusions
                    else:  # DUS against this param: writes update only
                        upd = fused.by_name.get(u.operands[1]) \
                            if len(u.operands) > 1 else None
                        b += _shape_bytes(upd.type_str) if upd else full
                bill = min(full, b)
        billed.append((full, bill))
    total = sum(b for _, b in billed)
    root = next((fi for fi in fused.instrs if fi.is_root), None)
    # Unwrap dtype/layout-only root wrappers.  XLA:CPU's float
    # normalization legalizes bf16 dynamic-update-slice as
    # convert->f32 DUS->convert; the TPU program updates bf16 in place.
    while root is not None and root.opcode in ("bitcast", "copy",
                                               "convert") and root.operands:
        root = fused.by_name.get(root.operands[0], None)
    if root is not None and root.opcode == "dynamic-update-slice":
        upd_name = root.operands[1] if len(root.operands) > 1 else None
        upd = fused.by_name.get(upd_name)
        # The update may itself be a convert of a parameter.
        while upd is not None and upd.opcode in ("convert", "bitcast") \
                and upd.operands:
            nxt = fused.by_name.get(upd.operands[0])
            if nxt is None:
                break
            upd = nxt
        upd_bytes = _shape_bytes(upd.type_str) if upd else _shape_bytes(inst.type_str)
        # True cost of an in-place sliced update: read+write the update.
        slice_cost = 2.0 * upd_bytes + sum(
            f for f, _ in billed if f < upd_bytes * 4 + 64)  # scalars etc.
        full_cost = total + _shape_bytes(inst.type_str)
        return (min(slice_cost, full_cost),
                max(0.0, full_cost - slice_cost))
    total += _shape_bytes(inst.type_str)
    return max(total, 0.0), 0.0


def _is_pure_convert_fusion(fused: Computation) -> bool:
    """Fusions that only change dtype/layout (convert/bitcast/copy/gather of
    a converted buffer) — the CPU-backend dot-operand upcast pattern."""
    body = [i for i in fused.instrs if i.opcode != "parameter"]
    return bool(body) and all(
        i.opcode in ("convert", "bitcast", "copy") for i in body)


def _users_map(comp: Computation) -> Dict[str, List[str]]:
    users: Dict[str, List[str]] = {}
    for inst in comp.instrs:
        for op in inst.operands:
            users.setdefault(op, []).append(inst.opcode)
    return users


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        cost: HloCost, mult: float, fused: bool = False,
                        _seen=None):
    users = _users_map(comp) if not fused else {}
    for inst in comp.instrs:
        op = inst.opcode
        if op == "while":
            trips = _trip_count(inst, comps)
            cost.n_while += 1
            cost.trip_counts.append(trips)
            body = comps.get(_attr_name(inst.rest, "body"))
            if body is not None:
                analyze_computation(body, comps, cost, mult * trips)
            continue
        if op in ("call", "conditional"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "branch_computations"):
                sub = comps.get(_attr_name(inst.rest, key) or "")
                if sub is not None:
                    analyze_computation(sub, comps, cost, mult)
            continue
        if op == "fusion":
            sub = comps.get(_attr_name(inst.rest, "calls") or "")
            if sub is not None:
                # flops from inside the fusion; bytes at the boundary.
                analyze_computation(sub, comps, cost, mult, fused=True)
                b, up = _fusion_bytes(inst, comp, sub)
                if (_is_pure_convert_fusion(sub)
                        and users.get(inst.name)
                        and all(u == "dot" for u in users[inst.name])):
                    cost.upcast_bytes += mult * b
                else:
                    cost.hbm_bytes += mult * b
                cost.upcast_bytes += mult * up
            else:
                cost.hbm_bytes += mult * (
                    _operand_bytes(inst, comp) + _shape_bytes(inst.type_str))
            continue
        if op in COLLECTIVES or any(op.startswith(c) for c in COLLECTIVES):
            kind = next((c for c in COLLECTIVES if op.startswith(c)), op)
            b = _operand_bytes(inst, comp) or _shape_bytes(inst.type_str)
            cost.collective_bytes += mult * b
            cost.wire_bytes += mult * b * (2.0 if kind == "all-reduce" else 1.0)
            cost.collective_by_kind[kind] = (
                cost.collective_by_kind.get(kind, 0.0) + mult * b)
            cost.collective_count += int(mult)
            continue
        # flops
        if op == "dot":
            f = _dot_flops(inst, comp) * mult
            cost.flops += f
            cost.dot_flops += f
        elif op == "convolution":
            cost.flops += _conv_flops(inst, comp) * mult
        elif op in _ELEMENTWISE_FLOPS:
            cost.flops += _shape_elems(inst.type_str) * mult
        elif op in ("reduce", "reduce-window"):
            src = comp.by_name.get(inst.operands[0]) if inst.operands else None
            cost.flops += (_shape_elems(src.type_str) if src else
                           _shape_elems(inst.type_str)) * mult
        # bytes (only at kernel boundaries, i.e. non-fused level)
        if not fused and op not in _SKIP_BYTES and op not in COLLECTIVES:
            sliced = _sliced_io_bytes(inst, comp)
            b = mult * sliced if sliced >= 0 else mult * (
                _operand_bytes(inst, comp) + _shape_bytes(inst.type_str))
            if (op == "convert" and users.get(inst.name)
                    and all(u == "dot" for u in users[inst.name])):
                cost.upcast_bytes += b
            else:
                cost.hbm_bytes += b
        elif not fused and op == "custom-call":
            # CPU lowers some dots to library custom-calls; count I/O.
            cost.hbm_bytes += mult * (
                _operand_bytes(inst, comp) + _shape_bytes(inst.type_str))


def analyze_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:  # fallback: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.instrs))
    cost = HloCost()
    analyze_computation(entry, comps, cost, 1.0)
    return cost
