"""Per-layer blocks with a uniform interface used by the stack machinery in
``models/transformer.py``:

  init_<kind>(key, cfg)                         -> params
  <kind>_fwd(params, x, ctx)                    -> (x, aux, cache|None)
  <kind>_decode(params, x, cache, ctx)          -> (x, cache)
  <kind>_init_cache(cfg, batch, max_len, dtype) -> cache (static shapes)

``ctx`` keys: cfg, policy, backend, rope=(cos,sin)|None, positions, causal,
collect_cache (bool), cache_len (int), pos (decode-time scalar),
cross_states (B,Tsrc,d) for cross/enc-dec kinds.

``aux`` is a scalar f32 auxiliary loss contribution (MoE load-balance +
router z-loss; 0 elsewhere).  Every matmul routes through mp_dot — the
paper's GEMM technique is the substrate of every block.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import config as gemm_cfg
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.distributed import act
from repro.models import attention as attn
from repro.models.layers import (
    apply_rope, dense_init, gelu_mlp, init_gelu_mlp, init_swiglu, layernorm,
    rmsnorm, swiglu_mlp,
)

ZERO = jnp.float32(0.0)


def norm(params, x, cfg):
    if cfg.norm == "layer":
        return layernorm(x, params["scale"], params["bias"])
    return rmsnorm(x, params["scale"])


def init_norm(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layer":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


def _mlp(params, x, cfg, policy, residual=None):
    """MLP with the block residual riding the down-projection's epilogue
    (``residual=`` — models/layers.py); callers pass the pre-norm stream."""
    if cfg.mlp == "gelu":
        return gelu_mlp(params, x, policy, residual=residual)
    return swiglu_mlp(params, x, policy, residual=residual)


def _init_mlp(key, cfg):
    if cfg.mlp == "gelu":
        return init_gelu_mlp(key, cfg.d_model, cfg.d_ff, bias=cfg.mlp_bias)
    return init_swiglu(key, cfg.d_model, cfg.d_ff)


# --- attention plumbing --------------------------------------------------------

def init_attn(key, cfg, d_kv: Optional[int] = None):
    d, hd = cfg.d_model, cfg.head_dim
    d_kv = d_kv or d
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d, cfg.n_heads * hd),
        "wk": dense_init(k2, d_kv, cfg.n_kv_heads * hd),
        "wv": dense_init(k3, d_kv, cfg.n_kv_heads * hd),
        "wo": dense_init(k4, cfg.n_heads * hd, d),
    }


def _split_heads(x, n_heads, hd):
    b, t, _ = x.shape
    return x.reshape(b, t, n_heads, hd).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * hd)


def attn_qkv(params, x, cfg, ctx, kv_source=None):
    policy = ctx["policy"]
    hd = cfg.head_dim
    q = _split_heads(mp_dot(x, params["wq"], policy=policy), cfg.n_heads, hd)
    src = kv_source if kv_source is not None else x
    k = _split_heads(mp_dot(src, params["wk"], policy=policy), cfg.n_kv_heads, hd)
    v = _split_heads(mp_dot(src, params["wv"], policy=policy), cfg.n_kv_heads, hd)
    return q, k, v


def _self_attention(params, h, ctx, window):
    """Normed input -> attention output (+ optional (k, v) for caching)."""
    cfg = ctx["cfg"]
    q, k, v = attn_qkv(params, h, cfg, ctx)
    if ctx.get("rope") is not None:
        cos, sin = ctx["rope"]
        q = apply_rope(q, cos, sin, ctx.get("positions"))
        k = apply_rope(k, cos, sin, ctx.get("positions"))
    o = attn.attention_core(
        q, k, v, causal=ctx.get("causal", True), window=window,
        backend=ctx.get("backend"),
    )
    out = mp_dot(_merge_heads(o), params["wo"], policy=ctx["policy"])
    kv = (k, v) if ctx.get("collect_cache") else None
    return out, kv


def _kv_to_ring_cache(kv, cache_len: int, dtype):
    """Pack prefill K/V (B,Hkv,S,hd) into a ring cache of size cache_len.

    Position p lands in slot p % cache_len, matching decode's ring write."""
    k, v = kv
    s = k.shape[2]
    if s <= cache_len:
        pad = [(0, 0), (0, 0), (0, cache_len - s), (0, 0)]
        return {"k": jnp.pad(k, pad).astype(dtype),
                "v": jnp.pad(v, pad).astype(dtype)}
    k_tail = k[:, :, s - cache_len:]
    v_tail = v[:, :, s - cache_len:]
    slots = (jnp.arange(cache_len) + (s - cache_len)) % cache_len
    zk = jnp.zeros(k_tail.shape, dtype)
    return {"k": zk.at[:, :, slots].set(k_tail.astype(dtype)),
            "v": zk.at[:, :, slots].set(v_tail.astype(dtype))}


def attn_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window=None):
    hd = cfg.head_dim
    cache_len = min(window, max_len) if window else max_len
    shape = (batch, cfg.n_kv_heads, cache_len, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(params, x, cache, ctx):
    """x: (B,1,d) normed input -> (attn output, updated ring cache)."""
    cfg = ctx["cfg"]
    pos = ctx["pos"]
    q, k, v = attn_qkv(params, x, cfg, ctx)
    if ctx.get("rope") is not None:
        cos, sin = ctx["rope"]
        if ctx.get("rope_single_row"):
            pidx = jnp.zeros((x.shape[0], 1), jnp.int32)  # row 0 = current pos
        else:
            pidx = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = apply_rope(q, cos, sin, pidx)
        k = apply_rope(k, cos, sin, pidx)
    mesh = act.current_mesh()
    if mesh is not None and attn.can_flash_decode(q, cache["k"], mesh):
        # Sequence-parallel flash decode (EXPERIMENTS.md §Perf hillclimb 2):
        # cond-guarded local ring write + LSE psum combine.
        o, kc, vc = attn.flash_decode_sharded(
            q, cache["k"], cache["v"], k, v, pos, mesh)
    else:
        s_max = cache["k"].shape[2]
        slot = pos % s_max
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, slot, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, slot, 0))
        lengths = jnp.minimum(pos + 1, s_max) * jnp.ones(
            (x.shape[0],), jnp.int32)
        o = attn.decode_attention(q, kc, vc, lengths)
    out = mp_dot(_merge_heads(o), params["wo"], policy=ctx["policy"])
    return out, {"k": kc, "v": vc}


def attn_paged_init_cache(cfg, num_pages, page_size, dtype=jnp.bfloat16):
    """Pooled KV pages shared by every request (serve/kv_cache.py owns the
    allocation of the leading page axis; page 0 is reserved scratch)."""
    shape = (num_pages, cfg.n_kv_heads, page_size, cfg.head_dim)
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def attn_paged_step(params, x, cache, ctx, window=None):
    """x: (B, C, d) normed chunk -> (attn output, updated page pool).

    One code path serves both decode (C == 1) and chunked prefill (C ==
    chunk): rows past ctx["paged"]["n_valid"][b] are dead padding, routed
    to the scratch page on write and masked out of the softmax by the
    logical-position bounds."""
    cfg = ctx["cfg"]
    pg = ctx["paged"]
    q, k, v = attn_qkv(params, x, cfg, ctx)
    if ctx.get("rope") is not None:
        cos, sin = ctx["rope"]
        q = apply_rope(q, cos, sin, ctx["positions"])
        k = apply_rope(k, cos, sin, ctx["positions"])
    kc, vc = attn.paged_kv_write(
        cache["k_pages"], cache["v_pages"], k, v,
        pg["block_tables"], pg["q_start"], pg["n_valid"])
    o = attn.paged_attention(
        q, kc, vc, pg["block_tables"], pg["q_start"], pg["lengths"],
        window=window, backend=ctx.get("backend"))
    out = mp_dot(_merge_heads(o), params["wo"], policy=ctx["policy"])
    return out, {"k_pages": kc, "v_pages": vc}


# =============================== dense =========================================

def init_dense(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg), "attn": init_attn(k1, cfg),
            "ln2": init_norm(cfg), "mlp": _init_mlp(k2, cfg)}


def _dense_window(cfg, kind):
    return cfg.local_attn_window if kind == "attn_local" else cfg.window


def dense_fwd(params, x, ctx, *, window=None):
    cfg = ctx["cfg"]
    o, kv = _self_attention(params["attn"], norm(params["ln1"], x, cfg), ctx, window)
    x = x + o
    x = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"],
             residual=x)
    cache = None
    if kv is not None:
        cache = _kv_to_ring_cache(kv, ctx["cache_len"] if window is None
                                  else min(window, ctx["cache_len"]),
                                  ctx.get("cache_dtype", jnp.bfloat16))
    return x, ZERO, cache


def dense_decode(params, x, cache, ctx):
    cfg = ctx["cfg"]
    o, cache = attn_decode(params["attn"], norm(params["ln1"], x, cfg), cache, ctx)
    x = x + o
    x = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"],
             residual=x)
    return x, cache


def dense_paged_step(params, x, cache, ctx, *, window=None):
    cfg = ctx["cfg"]
    o, cache = attn_paged_step(params["attn"], norm(params["ln1"], x, cfg),
                               cache, ctx, window=window)
    x = x + o
    x = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"],
             residual=x)
    return x, cache


def dense_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window=None):
    return attn_init_cache(cfg, batch, max_len, dtype, window=window)


# =============================== cross (VLM) ===================================

def init_cross(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_norm(cfg), "xattn": init_attn(k1, cfg),
        "ln2": init_norm(cfg), "mlp": _init_mlp(k2, cfg),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _cross_attention(params, h, ctx, kv=None):
    cfg = ctx["cfg"]
    if kv is None:
        q, k, v = attn_qkv(params, h, cfg, ctx, kv_source=ctx["cross_states"])
    else:
        q = _split_heads(
            mp_dot(h, params["wq"], policy=ctx["policy"]), cfg.n_heads, cfg.head_dim)
        k, v = kv
    o = attn.attention_core(q, k.astype(q.dtype), v.astype(q.dtype),
                            causal=False, backend=ctx.get("backend"))
    return mp_dot(_merge_heads(o), params["wo"], policy=ctx["policy"]), (k, v)


def cross_fwd(params, x, ctx):
    cfg = ctx["cfg"]
    o, kv = _cross_attention(params["xattn"], norm(params["ln1"], x, cfg), ctx)
    x = x + jnp.tanh(params["gate_attn"]).astype(o.dtype) * o
    m = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"])
    x = x + jnp.tanh(params["gate_mlp"]).astype(m.dtype) * m
    cache = None
    if ctx.get("collect_cache"):
        dt = ctx.get("cache_dtype", jnp.bfloat16)
        cache = {"k": kv[0].astype(dt), "v": kv[1].astype(dt)}
    return x, ZERO, cache


def cross_decode(params, x, cache, ctx):
    cfg = ctx["cfg"]
    o, _ = _cross_attention(params["xattn"], norm(params["ln1"], x, cfg), ctx,
                            kv=(cache["k"], cache["v"]))
    x = x + jnp.tanh(params["gate_attn"]).astype(o.dtype) * o
    m = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"])
    x = x + jnp.tanh(params["gate_mlp"]).astype(m.dtype) * m
    return x, cache


def cross_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    shape = (batch, cfg.n_kv_heads, cfg.n_image_tokens, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# =============================== MoE ===========================================

def _expert_dot(ebuf, w, policy, **fusion):
    """(e, n, d) x (e, d, f) -> (e, n, f) through the grouped MPGEMM op.

    One kernel launch for all E experts (group = leading grid axis), under
    the layer policy with f32 outputs (accumulator precision is kept
    between the expert GEMMs and the combine).  The op's custom VJP runs
    the backward contractions as fused-transpose grouped GEMMs with bf16
    partial sums on the XLA backend, so the dbuf/dW EP/TP all-reduces move
    bf16 on the wire (the mixtral-hillclimb optimization that einsum-based
    dispatch could not express — see EXPERIMENTS.md §Perf).

    ``fusion`` forwards registry-epilogue operands (``activation=``,
    ``gate=`` — core/gemm_spec.py), which is how the MoE SwiGLU gating
    rides the gate GEMM's store below.

    ``w`` may be a grouped :class:`repro.packing.PackedOperand` — expert
    weights packed once at load time (``pack_params``): mp_dot_grouped
    then reads the pre-tiled per-expert payload with identity index maps
    instead of re-laying the experts out on every launch.  It may also be
    a grouped :class:`repro.sparse.TileSparseOperand` (``sparsify_params``)
    — the launch then walks only the union of every expert's stored tiles,
    so tile-pruned experts shrink the grid itself."""
    return mp_dot_grouped(ebuf, w, policy=policy, out_dtype=jnp.float32,
                          **fusion)


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    scale = (2.0 / (d + f)) ** 0.5
    return {
        "ln1": init_norm(cfg), "attn": init_attn(k1, cfg),
        "ln2": init_norm(cfg),
        "router": dense_init(k2, d, e),
        "w_gate": (jax.random.normal(k3, (e, d, f)) * scale).astype(jnp.float32),
        "w_up": (jax.random.normal(k4, (e, d, f)) * scale).astype(jnp.float32),
        "w_down": (jax.random.normal(k5, (e, f, d)) * scale).astype(jnp.float32),
    }


def moe_mlp(params, x, cfg, policy, capacity_factor: float = 1.25):
    """Top-k MoE with GROUP-LOCAL sort-based dispatch.

    Groups = sequences (the batch dim), which is the data-sharded axis, so
    the argsort/scatter dispatch never crosses shards — no global sort
    collectives.  The expert GEMMs run as grouped MPGEMM launches
    (mp_dot_grouped: group = expert, K-innermost accumulator, fused-
    transpose backward) contracting (e, b*C, d) x (e, d, f); with
    experts sharded over 'model' (EP) GSPMD inserts the all-to-all style
    resharding between the data-sharded buffer and model-sharded experts,
    exactly the EP communication pattern.  Gathers/scatters carry no fake
    FLOPs into the roofline (vs. one-hot dispatch einsums).
    Returns (out, aux_scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    logits = mp_dot(x, params["router"], policy="fp32").astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                 # (b,s,e)
    topw, topi = jax.lax.top_k(gates, k)                    # (b,s,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    cap = max(1, int(round(capacity_factor * k * s / e)))

    def route(tokens, ti, tw):
        """Per-sequence dispatch: tokens (s,d), ti/tw (s,k).

        GATHER-based: the only scatters are tiny int32 index maps; the
        (e*C, d) payload moves via gathers (scatter lowering on big payload
        buffers costs full-buffer sort passes + index companions).

        Capacity slots are assigned NEWEST-token-first, so under overflow
        the most recent positions (the ones decode consistency depends on)
        keep their experts."""
        rev = jnp.arange(s - 1, -1, -1)
        slot_e = ti[rev].reshape(-1)                        # (s*k,)
        slot_t = jnp.repeat(rev, k)
        order = jnp.argsort(slot_e)
        se, st = slot_e[order], slot_t[order]
        counts = jnp.bincount(slot_e, length=e)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.arange(s * k) - starts[se]
        keep = rank < cap
        dest = jnp.where(keep, se * cap + rank, e * cap)    # overflow slot
        # slot -> source token (int32 scatter, payload-free)
        src = jnp.full((e * cap + 1,), s, jnp.int32).at[dest].set(
            st.astype(jnp.int32))[:-1]
        tok_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)])
        buf = tok_pad[src]                                  # payload gather
        # (token,choice) -> slot, back in original token order
        dest_tok = jnp.zeros((s * k,), jnp.int32).at[order].set(
            dest.astype(jnp.int32)).reshape(s, k)[rev].reshape(-1)
        return buf.reshape(e, cap, d), dest_tok

    buf, dest_tok = jax.vmap(route)(x, topi, topw)          # (b,e,C,d)
    buf = act.constrain(buf, "batch", None, None, None)

    # Fold b into the capacity dim: ONE grouped GEMM (e, b*C, d) x (e, d, f)
    # per projection — group = expert — through mp_dot_grouped, which owns
    # the policy cast, static-int8 dequant, and the shard-local down-cast
    # barrier (inside its custom VJP, where no differentiation rule for the
    # barrier is ever needed).
    ebuf = buf.transpose(1, 0, 2, 3).reshape(e, b * cap, d)
    h_up = _expert_dot(ebuf, params["w_up"], policy)
    if gemm_cfg.fused_epilogues():
        # Gated epilogue: silu(gate GEMM) · up rides the gate GEMM's
        # accumulator store — one grouped launch, no elementwise pass.
        h = _expert_dot(ebuf, params["w_gate"], policy,
                        activation="silu", gate=h_up)
    else:
        h_gate = _expert_dot(ebuf, params["w_gate"], policy)
        h = jax.nn.silu(h_gate) * h_up                      # f32 activations
    y = _expert_dot(h, params["w_down"], policy)  # (e,n,f) x (e,f,d) -> (e,n,d)
    y = y.reshape(e, b, cap, d).transpose(1, 0, 2, 3)       # (b,e,C,d)

    def combine(y_g, dest_tok_g, tw_g):
        """Pure-gather combine: out[t] = sum_j w_j * y[slot(t, j)]."""
        flat = y_g.reshape(e * cap, d)
        y_pad = jnp.concatenate([flat, jnp.zeros((1, d), flat.dtype)])
        contrib = y_pad[dest_tok_g].reshape(s, k, d)        # payload gather
        kept = (dest_tok_g < e * cap).reshape(s, k).astype(jnp.float32)
        w = tw_g.astype(jnp.float32) * kept
        return jnp.einsum("skd,sk->sd", contrib.astype(jnp.float32), w)

    out = jax.vmap(combine)(y, dest_tok, topw)              # (b,s,d)

    me = gates.mean((0, 1))
    ce = jnp.bincount(topi.reshape(-1), length=e).astype(jnp.float32) / (b * s * k)
    aux = e * jnp.sum(me * ce)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return out.astype(x.dtype), 0.01 * aux + 0.001 * zloss


def moe_fwd(params, x, ctx, *, window=None):
    cfg = ctx["cfg"]
    o, kv = _self_attention(params["attn"], norm(params["ln1"], x, cfg), ctx, window)
    x = x + o
    y, aux = moe_mlp(params, norm(params["ln2"], x, cfg), cfg, ctx["policy"],
                     capacity_factor=ctx.get("moe_capacity", 1.25))
    x = x + y
    cache = None
    if kv is not None:
        cache = _kv_to_ring_cache(kv, ctx["cache_len"] if window is None
                                  else min(window, ctx["cache_len"]),
                                  ctx.get("cache_dtype", jnp.bfloat16))
    return x, aux, cache


def moe_decode(params, x, cache, ctx):
    cfg = ctx["cfg"]
    o, cache = attn_decode(params["attn"], norm(params["ln1"], x, cfg), cache, ctx)
    x = x + o
    y, _ = moe_mlp(params, norm(params["ln2"], x, cfg), cfg, ctx["policy"],
                   capacity_factor=ctx.get("moe_capacity", 1.25))
    return x + y, cache


def moe_paged_step(params, x, cache, ctx, *, window=None):
    cfg = ctx["cfg"]
    o, cache = attn_paged_step(params["attn"], norm(params["ln1"], x, cfg),
                               cache, ctx, window=window)
    x = x + o
    y, _ = moe_mlp(params, norm(params["ln2"], x, cfg), cfg, ctx["policy"],
                   capacity_factor=ctx.get("moe_capacity", 1.25))
    return x + y, cache


def moe_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window=None):
    return attn_init_cache(cfg, batch, max_len, dtype, window=window)


# =============================== enc-dec (whisper) =============================

def init_encdec(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_norm(cfg), "attn": init_attn(k1, cfg),
        "lnx": init_norm(cfg), "xattn": init_attn(k2, cfg),
        "ln2": init_norm(cfg), "mlp": _init_mlp(k3, cfg),
    }


def encdec_fwd(params, x, ctx):
    """Decoder block: causal self-attn + cross-attn to encoder states."""
    cfg = ctx["cfg"]
    o, kv = _self_attention(params["attn"], norm(params["ln1"], x, cfg), ctx, None)
    x = x + o
    o, xkv = _cross_attention(params["xattn"], norm(params["lnx"], x, cfg), ctx)
    x = x + o
    x = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"],
             residual=x)
    cache = None
    if kv is not None:
        dt = ctx.get("cache_dtype", jnp.bfloat16)
        cache = {"self": _kv_to_ring_cache(kv, ctx["cache_len"], dt),
                 "cross": {"k": xkv[0].astype(dt), "v": xkv[1].astype(dt)}}
    return x, ZERO, cache


def encdec_decode(params, x, cache, ctx):
    cfg = ctx["cfg"]
    o, self_cache = attn_decode(params["attn"], norm(params["ln1"], x, cfg),
                                cache["self"], ctx)
    x = x + o
    o, _ = _cross_attention(params["xattn"], norm(params["lnx"], x, cfg), ctx,
                            kv=(cache["cross"]["k"], cache["cross"]["v"]))
    x = x + o
    x = _mlp(params["mlp"], norm(params["ln2"], x, cfg), cfg, ctx["policy"],
             residual=x)
    return x, {"self": self_cache, "cross": cache["cross"]}


def encdec_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    return {
        "self": attn_init_cache(cfg, batch, max_len, dtype),
        "cross": {"k": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq,
                                  cfg.head_dim), dtype),
                  "v": jnp.zeros((batch, cfg.n_kv_heads, cfg.encoder_seq,
                                  cfg.head_dim), dtype)},
    }
