"""Sharding rules validated on a real (small) mesh.

The 8-device execution check runs in-process when the suite was started
with ``REPRO_FORCE_HOST_DEVICES=8`` (the CI multidevice job — see
tests/conftest.py), and otherwise re-execs the same check in a child
interpreter with the device-forcing flag passed through its environment,
so the default single-device pytest process never mutates its own
``XLA_FLAGS``."""
import inspect
import os
import subprocess
import sys

import pytest

import jax

from repro.configs import base as cb
from repro.distributed.sharding import param_pspec


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("path,shape,expect", [
    ("embed", (49280, 1024), ("model", "data")),
    ("head", (1024, 49280), ("data", "model")),
    ("stack/0/attn/wq", (24, 1024, 1024), (None, "data", "model")),
    ("stack/0/attn/wo", (24, 1024, 1024), (None, "model", "data")),
    ("stack/0/mlp/w_gate", (24, 1024, 512), (None, "data", "model")),
    # granite experts: E=32 divisible by model=16 -> expert parallelism
    ("stack/0/w_gate", (24, 32, 1024, 512), (None, "model", "data", None)),
    # mixtral experts: E=8 not divisible -> TP inside experts
    ("stack/0/w_up", (56, 8, 6144, 16384), (None, None, "data", "model")),
    ("stack/0/ln1/scale", (24, 1024), (None, None)),
    # vocab NOT divisible: guard drops the axis
    ("embed_odd", (49155, 1024), (None, "data")),
])
def test_param_rules(path, shape, expect):
    cfg = cb.get("granite-moe-1b-a400m")
    name = "embed" if path == "embed_odd" else path
    spec = param_pspec(name, shape, cfg, _FakeMesh())
    assert tuple(spec) == expect, (path, tuple(spec))


def _sharded_check():
    # Self-contained (shipped to a child interpreter via getsource when the
    # parent has fewer than 8 devices): train + decode one smoke step on a
    # real (2, 2, 2) pod/data/model mesh.
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import base as cb
    from repro.distributed import act, sharding as sh
    from repro.launch.mesh import make_test_mesh
    from repro.models.transformer import build_model

    mesh = make_test_mesh(2, 2, multi_pod=True)   # (2,2,2) pod/data/model
    cfg = cb.get("granite-moe-1b-a400m", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    shard = sh.params_shardings(params, cfg, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, shard)
    batch = {"tokens": jnp.zeros((4, 17), jnp.int32)}
    bshard = sh.batch_shardings(batch, mesh)
    batch = jax.tree_util.tree_map(jax.device_put, batch, bshard)
    with mesh, act.use_mesh(mesh):
        loss = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), loss
    # decode path on the mesh
    caches = model.init_caches(4, 32)
    cshard = sh.caches_shardings(jax.eval_shape(lambda: caches), cfg, mesh)
    caches = jax.tree_util.tree_map(jax.device_put, caches, cshard)
    with mesh, act.use_mesh(mesh):
        logits, caches = jax.jit(model.decode_step)(
            params, jnp.zeros((4, 1), jnp.int32), caches, jnp.int32(0))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("SHARDED_OK", float(loss))


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="in-process variant needs 8 devices "
                           "(REPRO_FORCE_HOST_DEVICES=8)")
def test_sharded_execution_8dev_inprocess():
    _sharded_check()


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="covered by the in-process variant")
def test_sharded_execution_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    child = inspect.getsource(_sharded_check) + "\n_sharded_check()\n"
    out = subprocess.run([sys.executable, "-c", child], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
