"""Paper Table III + Figs 10/11: the 24 DeepSeek/LLaMA GEMM workloads.

For every workload: the analytic plan's modeled roofline time (MPGEMM) vs
the naive fixed-tile baseline's (the open-source-library stand-in), plus a
CPU XLA wall-time sanity number.  Derived column = modeled speedup (the
paper's headline metric shape: MPGEMM vs baselines)."""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import PAPER_WORKLOADS, emit, modeled_time_s, wall_time_us
from repro.core.blocking import naive_plan, plan_gemm
from repro.core.constants import DEFAULT_HW


def run(dtype="float32", wall: bool = True):
    rng = np.random.default_rng(0)
    speedups = []
    for wid, m, n, k in PAPER_WORKLOADS:
        plan = plan_gemm(m, n, k, dtype)
        naive = naive_plan(m, n, k, dtype)
        t_plan = modeled_time_s(plan.flops, plan.hbm_bytes, dtype)
        t_naive = modeled_time_s(naive.flops, naive.hbm_bytes, dtype)
        speedup = t_naive / t_plan
        speedups.append(speedup)
        us = 0.0
        # CPU wall time is a sanity signal only; restrict to small cells so
        # the harness stays fast on one core.
        if wall and m * n * k <= 1.2e9:
            a = jnp.asarray(rng.standard_normal((m, k)), dtype)
            b = jnp.asarray(rng.standard_normal((k, n)), dtype)
            f = jax.jit(lambda a, b: a @ b)
            us = wall_time_us(f, a, b, iters=1)
        emit(f"gemm_workload_{wid:02d}_{dtype}", us,
             f"modeled_speedup_vs_naive={speedup:.3f};"
             f"blocks=({plan.bm}x{plan.bn}x{plan.bk});cmr={plan.cmr:.1f};"
             f"modeled_us={t_plan*1e6:.1f}")
    emit(f"gemm_workloads_geomean_{dtype}", 0.0,
         f"modeled_speedup_geomean={np.exp(np.mean(np.log(speedups))):.3f}")
    return speedups


if __name__ == "__main__":
    run()
