"""Continuous-batching serve: modeled paged-vs-dense KV bytes, the
page-visit gate, and the end-to-end engine smoke.

Three measurement families (area ``serve``, -> ``BENCH_serve.json``):

  * ``serve_model_*``  — pure-arithmetic KV accounting per arch and request
                         mix: the dense wave engine holds ``max_batch x
                         max_len`` tokens of KV per layer regardless of the
                         actual lengths; the paged store holds
                         ``ceil(len/ps)*ps`` per request.  Deterministic —
                         the paged-vs-dense memory story the redesign ships.
  * ``serve_trace_*``  — the **page-visit gate**: the traced jaxpr of the
                         paged flash-attention launch has grid
                         ``(B, Hkv, G, nq, W)`` with W the block-table
                         width, so the number of KV pages each query block
                         walks is a trace-time fact — ``--smoke`` asserts
                         it equals the table width and SHRINKS with
                         narrower tables (exactly the stored-tile schedule
                         argument bench_sparse.py makes for MPGEMM).
  * ``serve_e2e_*``    — a real continuous-batching run (smoke model):
                         short requests must retire strictly before a long
                         co-scheduled one (no head-of-line stall), the
                         paged KV footprint must undercut the dense
                         allocation at EVERY step, prefix sharing must
                         reuse full prompt pages, and the allocator
                         invariants must hold at exit.  Step counts and
                         tokens/s are run-dependent -> recorded as noisy.

``--smoke`` runs the hard gates and exits nonzero on any failure.  Set
``REPRO_SERVE_OUT`` to also write ``serve_report.md``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, record
from repro.obs import audit
from repro.serve.kv_cache import cdiv

# (mix name, max_batch, max_len, page_size, request lengths at peak) —
# prompt+generated tokens held per live request, a decode-heavy snapshot.
SERVE_MIXES = [
    ("chat", 8, 2048, 16, (128, 384, 640, 896, 1152, 1408, 1664, 1920)),
    ("ragged", 8, 2048, 16, (64, 64, 96, 128, 160, 192, 224, 1984)),
    ("short", 8, 2048, 16, (48, 64, 80, 96, 112, 128, 144, 160)),
]

SERVE_ARCHS = ("phi3-mini-3.8b", "granite-moe-1b-a400m")


def _token_bytes(cfg, itemsize: int = 2) -> int:
    """Modeled KV bytes one token holds across all paged-attention layers
    (bf16 activations; mirrors serve/engine.py::_kv_token_bytes)."""
    from repro.models.transformer import PAGED_KINDS
    layers = sum(1 for kind in cfg.pattern if kind in PAGED_KINDS)
    return 2 * cfg.n_kv_heads * cfg.head_dim * itemsize * max(layers, 1)


def run(rows=None):
    """Modeled KV accounting: paged vs dense bytes per arch and mix."""
    from repro.configs import base as cb

    rows = rows if rows is not None else []
    for arch in SERVE_ARCHS:
        cfg = cb.get(arch)
        tb = _token_bytes(cfg)
        for mix, max_batch, max_len, ps, lengths in SERVE_MIXES:
            dense = max_batch * max_len * tb
            paged = sum(cdiv(n, ps) * ps for n in lengths) * tb
            saving = 1 - paged / dense
            rows.append(dict(arch=arch, mix=mix, token_bytes=tb,
                             kv_bytes_dense=dense, kv_bytes_paged=paged,
                             saving=saving))
            emit(f"serve_model_{arch}_{mix}", 0.0,
                 f"paged={paged};dense={dense};saving={saving:.2f};"
                 f"token_bytes={tb}")
            record(f"serve_model_{arch}_{mix}", "serve",
                   workload={"arch": arch, "max_batch": max_batch,
                             "max_len": max_len, "page_size": ps,
                             "lengths": list(lengths)},
                   metrics={"kv_bytes_dense": float(dense),
                            "kv_bytes_paged": float(paged),
                            "token_bytes": float(tb),
                            "kv_saving_frac": saving})
    return rows


def _traced_page_visits(b, hkv, g, tq, d, ps, width) -> tuple:
    """The pallas grid of a paged flash-attention launch (trace only)."""
    from repro.kernels.flash_attention import paged_flash_attention

    n_pages = 1 + b * width
    args = (
        jax.ShapeDtypeStruct((b, hkv * g, tq, d), jnp.float32),
        jax.ShapeDtypeStruct((n_pages, hkv, ps, d), jnp.float32),
        jax.ShapeDtypeStruct((n_pages, hkv, ps, d), jnp.float32),
        jax.ShapeDtypeStruct((b, width), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    return audit.first_pallas_grid(audit.trace(
        lambda *a: paged_flash_attention(*a, interpret=True), *args))


def run_trace_gate(assert_gate: bool = False):
    """The jaxpr proof that the kernel walks the BLOCK TABLE, not the pool:
    the innermost grid axis is the table width, so shrinking the table
    shrinks the traced KV walk while the page pool stays put."""
    b, hkv, g, tq, d, ps = 2, 2, 2, 8, 64, 8
    visits = {}
    for width in (8, 4, 2):
        grid = _traced_page_visits(b, hkv, g, tq, d, ps, width)
        visits[width] = grid[-1]
        emit(f"serve_trace_w{width}", 0.0,
             f"grid={grid};page_visits={grid[-1]};table_width={width}")
        record(f"serve_trace_w{width}", "serve", kind="trace",
               workload={"b": b, "hkv": hkv, "g": g, "tq": tq, "d": d,
                         "page_size": ps, "table_width": width},
               metrics={"page_visits": float(grid[-1]),
                        "grid_steps": float(int(np.prod(grid)))})
        if assert_gate:
            assert grid[-1] == width, (
                f"traced grid walks {grid[-1]} pages per query block, "
                f"block table has {width} — the launch is not steered by "
                f"the scalar-prefetched table")
    if assert_gate:
        assert visits[8] > visits[4] > visits[2], (
            f"page visits {visits} not shrinking with the block table")
    return visits


def run_e2e(assert_gate: bool = False):
    """Real continuous-batching smoke: no head-of-line stall, paged < dense
    KV at every step, prefix reuse, allocator invariants."""
    from repro.configs import base as cb
    from repro.models.transformer import build_model
    from repro.serve.engine import ServeEngine

    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)

    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab, (20,)).astype(np.int32)
    long_uid = eng.add_request(prompt, max_new_tokens=24)
    shorts = [eng.add_request(
        rng.integers(2, cfg.vocab, (6,)).astype(np.int32), max_new_tokens=3)
        for _ in range(2)]

    finish = {}
    step = 0
    while eng.pending:
        for req in eng.step():
            finish[req.uid] = step
        step += 1
        assert step < 300, "engine failed to drain"
    # Prefix sharing: re-serve the long prompt after its pages are indexed.
    eng.add_request(prompt, max_new_tokens=2)
    while eng.pending:
        eng.step()
    eng.kv.check_invariants()

    steps = eng.step_telemetry
    peak_pages = max(s.pages_in_use for s in steps)
    peak_kv = max(s.kv_bytes for s in steps)
    dense_kv = steps[0].kv_bytes_dense
    shared = eng.kv.stats.prefix_hit_tokens
    stall_gap = finish[long_uid] - max(finish[u] for u in shorts)
    emit("serve_e2e_smoke", 0.0,
         f"steps={len(steps)};peak_pages={peak_pages};peak_kv={peak_kv};"
         f"dense_kv={dense_kv};prefix_hit_tokens={shared};"
         f"stall_gap={stall_gap}")
    record("serve_e2e_smoke", "serve", kind="wall",
           workload={"arch": "phi3-mini-3.8b", "smoke": True, "max_len": 64,
                     "max_batch": 3, "page_size": 8},
           metrics={"kv_bytes_dense": float(dense_kv)},
           noisy={"steps": float(len(steps)),
                  "peak_pages": float(peak_pages),
                  "peak_kv_bytes": float(peak_kv),
                  "prefix_hit_tokens": float(shared),
                  "preemptions": float(sum(s.preemptions for s in steps)),
                  "stall_gap_steps": float(stall_gap)})
    if assert_gate:
        assert all(finish[u] < finish[long_uid] for u in shorts), (
            f"head-of-line stall: shorts finished at "
            f"{[finish[u] for u in shorts]}, long at {finish[long_uid]}")
        assert all(s.kv_bytes < s.kv_bytes_dense for s in steps), (
            "paged KV footprint did not undercut the dense allocation")
        assert shared >= eng.page_size, (
            f"prefix sharing reused only {shared} tokens")
    return dict(steps=len(steps), peak_pages=peak_pages, peak_kv=peak_kv,
                dense_kv=dense_kv, prefix_hit_tokens=shared,
                stall_gap=stall_gap)


def write_report(rows, visits, e2e, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "serve_report.md")
    lines = [
        "# Continuous-batching serve: paged KV, end to end",
        "",
        "Modeled terms are pure KV accounting (dense wave allocation vs "
        "page-rounded actual lengths); page visits are trace-time facts "
        "from the paged flash-attention grid; e2e numbers are one smoke "
        "run of the continuous engine on CPU.",
        "",
        "| arch | mix | paged KV | dense KV | saving |",
        "|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['mix']} | {r['kv_bytes_paged']:,} "
            f"| {r['kv_bytes_dense']:,} | {r['saving']:.0%} |")
    lines += ["", "## Page-visit gate (traced grid)", ""]
    for width, v in visits.items():
        lines.append(f"- table width {width}: {v} page visits per "
                     f"query block")
    lines += [
        "",
        "## Engine smoke",
        "",
        f"- {e2e['steps']} steps; peak {e2e['peak_pages']} pages "
        f"({e2e['peak_kv']:,} B vs {e2e['dense_kv']:,} B dense)",
        f"- {e2e['prefix_hit_tokens']} prompt tokens prefix-shared",
        f"- short requests retired {e2e['stall_gap']} steps before the "
        f"long co-scheduled request",
        "",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="hard gates: page visits == table width and "
                         "shrink with it, no head-of-line stall, paged < "
                         "dense KV every step (CI gate)")
    args = ap.parse_args()

    rows = run()
    visits = run_trace_gate(assert_gate=args.smoke)
    e2e = run_e2e(assert_gate=args.smoke)

    out_dir = os.environ.get("REPRO_SERVE_OUT")
    if out_dir:
        print(f"report: {write_report(rows, visits, e2e, out_dir)}")


if __name__ == "__main__":
    main()
