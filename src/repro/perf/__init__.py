"""Perf-trajectory subsystem: metrics accounting, versioned BENCH_*.json
emission, baseline diffing, and the offline plan-cache sweep.

The source paper's contribution is a *measured* one — characterization
drives every design decision — but measurements that die with the process
can't catch regressions.  This package makes the repo's perf numbers a
first-class, versioned, diffable artifact:

* :mod:`repro.perf.metrics` — the accounting core: FLOPs / HBM bytes /
  tile-visit counts for 2-D, grouped, packed, and density-priced sparse
  GEMMs (cross-checked against ``core/blocking.py``'s traffic model), the
  llm-profiler-style per-phase fwd/bwd FLOPs breakdown for a model config,
  and the :class:`~repro.perf.metrics.WorkloadRecord` every benchmark
  emits.
* :mod:`repro.perf.trajectory` — versioned-schema ``BENCH_<area>.json``
  writer/reader with environment stamping, plus the :class:`Recorder`
  the benchmark harness streams records through.
* :mod:`repro.perf.diff` — baseline comparison with per-metric relative
  tolerances, metric-direction awareness (a *faster* time is an
  improvement, not a change to fail on), and a markdown regression report.
* :mod:`repro.perf.sweep` — the offline plan-cache sweep: enumerate every
  shipped (model config × policy × layout × epilogue) GEMM instance from
  ``configs/`` and pre-populate the PlanCache so first-call serving never
  plans cold (``python -m repro.perf.sweep``).

See docs/perf_trajectory.md for the workflow.
"""
from repro.perf.diff import (
    DiffResult, MetricDelta, diff_bench, diff_paths, markdown_report,
    metric_direction,
)
from repro.perf.metrics import (
    PhaseFlops, WorkloadRecord, collective_bytes, gemm_bytes, gemm_flops,
    modeled_collective_us, modeled_gemm_us, modeled_overlap, phase_flops,
    record_from_plan, sharded_gemm_comm_bytes, tile_visits, total_flops,
)
from repro.perf.trajectory import (
    SCHEMA_VERSION, BenchFile, Recorder, bench_path, environment_stamp,
    read_bench, validate_bench_dict, validate_record_dict, write_bench,
)

__all__ = [
    "DiffResult", "MetricDelta", "diff_bench", "diff_paths",
    "markdown_report", "metric_direction",
    "PhaseFlops", "WorkloadRecord", "collective_bytes", "gemm_bytes",
    "gemm_flops", "modeled_collective_us", "modeled_gemm_us",
    "modeled_overlap", "phase_flops", "record_from_plan",
    "sharded_gemm_comm_bytes", "tile_visits", "total_flops",
    "SCHEMA_VERSION", "BenchFile", "Recorder", "bench_path",
    "environment_stamp", "read_bench", "validate_bench_dict",
    "validate_record_dict", "write_bench",
]
