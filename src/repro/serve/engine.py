"""Serving engine: batched prefill + decode with KV caches.

Continuous-batching-lite: requests are grouped into a fixed batch; each
decode step advances every live sequence one token; finished sequences
(EOS or length) free their slot for queued requests (slot reuse keeps the
compiled decode_step's shapes static — the production pattern)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int, max_len: int,
                 eos_id: int = 1, greedy: bool = True):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Static-batch generation with slot reuse between waves."""
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        while queue:
            wave = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            results.update(self._run_wave(wave))
        return results

    def _run_wave(self, wave: List[Request]) -> Dict[int, List[int]]:
        b = self.batch_size
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.model.cfg
        # Stubbed modality frontends (per assignment): frame/patch embeds.
        if cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        logits, caches = self._prefill(self.params, batch)
        out = {r.uid: [] for r in wave}
        live = np.array([True] * len(wave) + [False] * (b - len(wave)))
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        pos = plen
        for step in range(max_new):
            tok_np = np.asarray(token[:, 0])
            for i, r in enumerate(wave):
                if live[i]:
                    out[r.uid].append(int(tok_np[i]))
                    if (int(tok_np[i]) == self.eos_id
                            or len(out[r.uid]) >= r.max_new_tokens):
                        live[i] = False
            if not live.any() or pos >= self.max_len - 1:
                break
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(pos))
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        return out
