"""Serving entrypoint: continuous-batching generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --smoke --requests 6 --policy int8

The default ``--engine continuous`` runs the paged-KV continuous-batching
engine (requests admitted/retired every step, chunked prefill, prefix
sharing); ``--engine wave`` keeps the legacy static-batch wave engine.
"""
import argparse
import os
import time
import warnings

import numpy as np

import jax

from repro.configs import base as cb
from repro.models.transformer import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m",
                    choices=cb.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="bf16",
                    choices=["bf16", "bf16_serve", "int8"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "wave"],
                    help="continuous: paged-KV continuous batching "
                         "(admit/retire every step); wave: the legacy "
                         "static-batch wave engine")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="concurrent sequence slots (default 2)")
    ap.add_argument("--batch", type=int, default=None,
                    help="DEPRECATED alias for --max-batch (with the old "
                         "wave-engine default semantics; prefer --max-batch"
                         " and, if you want waves, --engine wave)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV page size in tokens (continuous engine)")
    ap.add_argument("--max-pages", type=int, default=None,
                    help="KV pool size in pages incl. the scratch page "
                         "(default: dense-equivalent capacity; smaller "
                         "values exercise preemption)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens prefilled per step (continuous "
                         "engine; default max(page_size, 8))")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--warm-prefix", type=int, default=0, metavar="N",
                    help="pre-populate the paged-KV prefix index with an "
                         "N-token synthetic system prompt before serving "
                         "(continuous engine only); every request then "
                         "prepends that prompt and shares its pages "
                         "instead of re-prefilling them")
    ap.add_argument("--pack", action="store_true",
                    help="pack static weights into kernel-native tile "
                         "layouts at load time (repro.packing; cache via "
                         "REPRO_PACK_CACHE)")
    ap.add_argument("--pack-format", default=None,
                    choices=["int8", "int4", "fp8"],
                    help="payload codec for --pack (the precision ladder): "
                         "int8 per-tile quantized, int4 nibble-packed "
                         "(halves weight HBM traffic), fp8 e4m3 scaled. "
                         "Default: the policy's payload dtype")
    ap.add_argument("--sparsity", type=float, default=0.0,
                    help="fraction of weight TILES to prune at load time "
                         "(repro.sparse tile-magnitude pruning; 0 = off). "
                         "The sparse MPGEMM path then skips pruned tiles "
                         "entirely — grid, DMA, and MACs all shrink")
    ap.add_argument("--sparsity-method", default="magnitude",
                    choices=["magnitude", "nm"],
                    help="tile sparsifier: global magnitude top-k per "
                         "operand, or structured N:M over k-tiles (N of "
                         "every 4 kept, N derived from --sparsity — the "
                         "level quantizes to multiples of 1/4)")
    ap.add_argument("--sparsity-blocks", type=int, nargs=2, default=None,
                    metavar=("BK", "BN"),
                    help="tile size of the sparsity lattice (default: the "
                         "block planner's choice — which for SMALL weights "
                         "can be one whole-matrix tile, making pruning "
                         "all-or-nothing; pass smaller blocks for finer "
                         "granularity)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable the fused gated-activation/residual "
                         "epilogues (core/gemm_spec.py) — the unfused A/B "
                         "baseline benchmarks/bench_epilogue.py measures")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="PORT",
                    help="serve the obs metrics registry over HTTP on this "
                         "port (/metrics Prometheus text, /metrics.json, "
                         "/trace Chrome trace; 0 = ephemeral port, printed "
                         "at startup); a summary snapshot prints at exit")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="record obs tracing spans (serve step phases, "
                         "GEMM plan/pack/launch legs) and write a "
                         "Perfetto/chrome://tracing trace.json to FILE")
    args = ap.parse_args()

    tracer = None
    if args.trace_out:
        from repro.obs import trace as obs_trace
        tracer = obs_trace.Tracer()
        obs_trace.set_tracer(tracer)
    server = None
    if args.metrics_port is not None:
        from repro.obs.server import start_metrics_server
        server = start_metrics_server(port=args.metrics_port)
        print(f"[serve] metrics server on {server.url} "
              f"(/metrics, /metrics.json, /trace)")

    if args.batch is not None:
        print("[serve] --batch is deprecated; use --max-batch "
              "(and --engine wave for the legacy wave engine)")
        if args.max_batch is None:
            args.max_batch = args.batch
    max_batch = args.max_batch if args.max_batch is not None else 2

    if args.no_fuse:
        # Read lazily at trace time by models/layers.py via
        # core.config.fused_epilogues(), so setting it before build works.
        os.environ["REPRO_FUSED_EPILOGUE"] = "0"

    if not 0.0 <= args.sparsity < 1.0:
        raise SystemExit(f"--sparsity must be in [0, 1) — a fraction of "
                         f"tiles to prune, got {args.sparsity}")
    if args.pack and args.sparsity > 0:
        raise SystemExit("--pack and --sparsity are mutually exclusive "
                         "(a weight is stored packed-dense OR tile-sparse)")
    if args.pack_format is not None and not args.pack:
        raise SystemExit("--pack-format requires --pack")

    cfg = cb.get(args.arch, smoke=args.smoke)
    model = build_model(cfg, policy=args.policy, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.pack:
        from repro.packing import pack_params, packed_param_bytes
        params = pack_params(params, policy=args.policy,
                             m_hint=max_batch * 32,
                             pack_format=args.pack_format)
        fmt = f", format={args.pack_format}" if args.pack_format else ""
        print(f"[serve] packed static weights: "
              f"{packed_param_bytes(params)/2**20:.1f} MiB payload{fmt}")
    if args.sparsity > 0:
        from repro.sparse import (
            sparse_param_bytes, sparse_param_density, sparsify_params,
        )
        # The N:M pattern keeps n_keep of every 4 k-tiles: the requested
        # prune level quantizes to the NEAREST multiple of 1/4 (4:4 == a
        # tiny request honestly rounds to "prune nothing", never silently
        # over-prunes).
        m_block = 4
        n_keep = max(1, round((1.0 - args.sparsity) * m_block))
        if args.sparsity_method == "nm":
            print(f"[serve] N:M sparsity: keeping {n_keep} of every "
                  f"{m_block} k-tiles (requested prune {args.sparsity:.2f}"
                  f" -> effective {1 - n_keep / m_block:.2f})")
        params = sparsify_params(params, density=1.0 - args.sparsity,
                                 method=args.sparsity_method,
                                 nm=(n_keep, m_block),
                                 blocks=args.sparsity_blocks,
                                 policy=args.policy, m_hint=max_batch * 32)
        density = sparse_param_density(params)
        print(f"[serve] tile-sparse static weights: "
              f"{sparse_param_bytes(params)/2**20:.1f} MiB payload, "
              f"tile density {density:.2f} ({args.sparsity_method})")
        if density > (1.0 - args.sparsity) + 0.1:
            print(f"[serve] WARNING: effective tile density {density:.2f} "
                  f"is well above the requested {1 - args.sparsity:.2f} — "
                  f"the planner's tile lattice is too coarse for these "
                  f"weight shapes (pruning is per whole tile). Pass "
                  f"--sparsity-blocks with smaller BK BN for finer "
                  f"granularity.")
    if args.engine == "wave":
        with warnings.catch_warnings():
            # The CLI chose the wave engine explicitly; the constructor's
            # deprecation warning targets programmatic batch_size= callers.
            warnings.simplefilter("ignore", DeprecationWarning)
            eng = ServeEngine(model, params, batch_size=max_batch,
                              max_len=args.max_len)
    else:
        eng = ServeEngine(model, params, max_len=args.max_len,
                          max_batch=max_batch, page_size=args.page_size,
                          max_pages=args.max_pages,
                          prefill_chunk=args.prefill_chunk)
    rng = np.random.default_rng(0)
    warm = None
    if args.warm_prefix > 0:
        if args.engine == "wave":
            raise SystemExit("--warm-prefix requires --engine continuous "
                             "(prefix sharing lives in the paged KV cache)")
        if args.warm_prefix + 32 + args.max_new >= args.max_len:
            raise SystemExit(
                f"--warm-prefix {args.warm_prefix} leaves no room for "
                f"request tails under --max-len {args.max_len} — raise "
                f"--max-len")
        warm = rng.integers(2, cfg.vocab,
                            (args.warm_prefix,)).astype(np.int32)
        t_w = time.time()
        new_pages = eng.warm_prefixes([warm])
        print(f"[serve] warmed {new_pages} prefix pages from a "
              f"{args.warm_prefix}-token system prompt in "
              f"{time.time() - t_w:.1f}s")

    def _prompt():
        tail = rng.integers(2, cfg.vocab,
                            (int(rng.integers(4, 32)),)).astype(np.int32)
        return tail if warm is None else np.concatenate([warm, tail])

    reqs = [Request(uid=i, prompt=_prompt(), max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"[serve] {args.requests} requests, {n_tok} tokens, {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s CPU, policy={args.policy})")
    if args.engine == "wave":
        for t in eng.telemetry:
            print(f"  wave{t.wave}: {t.requests} reqs, {t.tokens} tok, "
                  f"{t.tokens_per_s:.1f} tok/s, occupancy "
                  f"{t.slot_occupancy:.2f}, queue {t.queue_depth}")
    else:
        steps = eng.step_telemetry
        peak_pages = max((s.pages_in_use for s in steps), default=0)
        peak_kv = max((s.kv_bytes for s in steps), default=0)
        dense_kv = steps[0].kv_bytes_dense if steps else 0
        preempt = sum(s.preemptions for s in steps)
        shared = steps[-1].prefix_hit_tokens if steps else 0
        print(f"  {len(steps)} steps "
              f"({sum(1 for s in steps if s.phase != 'decode')} with "
              f"prefill), peak {peak_pages} pages "
              f"({peak_kv/2**20:.2f} MiB KV vs {dense_kv/2**20:.2f} MiB "
              f"dense), {preempt} preemptions, {shared} prompt tokens "
              f"prefix-shared")
        for s in steps[-3:]:
            print(f"  step{s.step}: {s.phase}, live {s.live}, "
                  f"queue {s.queue_depth}, {s.tokens} tok, "
                  f"pages {s.pages_in_use} ({s.page_occupancy:.2f}), "
                  f"{s.tokens_per_s:.1f} tok/s")
    for uid in sorted(out):
        print(f"  req{uid}: {out[uid][:10]}")

    if server is not None:
        # Scrape our own endpoint so the snapshot below exercised the full
        # HTTP path, not just the in-process registry.
        import urllib.request
        with urllib.request.urlopen(server.url + "/metrics") as resp:
            text = resp.read().decode()
        series = [ln for ln in text.splitlines()
                  if ln and not ln.startswith("#")]
        print(f"[serve] /metrics snapshot: {len(series)} series")
        for ln in series:
            if ln.startswith(("gemm_launches_total", "plan_cache_",
                              "paged_kv_", "serve_steps_total",
                              "serve_tokens_total")):
                print(f"  {ln}")
        server.close()
    if tracer is not None:
        tracer.export(args.trace_out)
        print(f"[serve] wrote {len(tracer)} trace events to "
              f"{args.trace_out}")


if __name__ == "__main__":
    main()
