"""Tile-sparse operand layout metadata and the :class:`TileSparseOperand`
pytree.

The cache-aware partitioning of every GEMM in this framework already
decomposes the B operand into a (bk, bn) tile lattice (core/blocking.py),
and the packed-operand subsystem (repro.packing) stores those tiles
contiguously.  This module is the sparse sibling: when weight pruning or
MoE routing leaves whole tiles zero, only the NONZERO tiles are stored —
and, downstream, only the nonzero tiles are ever visited by the kernel
("LOw-cOst yet High-Performant Sparse Matrix-Matrix Multiplication on Arm
SME Architectures" shows tile granularity is the sparsity level that
matches outer-product tile hardware; the layout-metadata approach follows
"Fast Matrix Multiplication via Compiler-only Layered Data Reorganization
and Intrinsic Lowering": no new kernel family, just new index maps).

    logical weight  w[k, n]   (or w[n, k] under ``trans_w``)
        │  sparsify (repro.sparse.sparsify): tile on the plan's (bk, bn)
        │  lattice, score tiles, drop the weak ones, zero-pad edges,
        │  resolve the transpose, optionally per-tile int8 quantize
        ▼
    payload[nnz + 1, bk, bn]     — stored tiles in column-major (g, j)
                                   order, plus ONE trailing all-zero tile
                                   shared by anchor visits (see below)
    scales [nnz + 1, 1] f32      — int8 payloads only
    TileSparseLayout             — BSR-style (indptr, indices) over the
                                   tile lattice, static/hashable aux data

The BSR structure is **column-major over output-tile columns**: column
``c`` (= group ``c // nnb``, n-tile ``c % nnb``) stores the k-tile indices
``indices[indptr[c]:indptr[c+1]]`` (ascending).  That is exactly the order
the output-stationary kernel wants: all stored tiles of one accumulator
column are consecutive, so the K loop becomes a walk over a contiguous
slice of the schedule.

**Anchor visits.**  A column with NO stored tiles would never be visited
by a stored-tiles-only grid, leaving its output block unwritten (and its
epilogue — bias, activation, residual — unapplied).  The schedule
therefore inserts one *anchor* entry per empty column, pointing at the
shared trailing zero tile: the column is visited once, accumulates zero,
and the epilogue runs.  ``schedule_len = nnz + n_empty_columns``.

:class:`TileSparseLayout` is static (hashable — the index arrays are
tuples), so it travels as pytree aux data and the Pallas grid derived from
it is a **trace-time constant**: the traced jaxpr of a sparse GEMM
literally has ``grid = (M/bm, schedule_len)``, which is how the benchmark
gate (benchmarks/bench_sparse.py) proves zero tiles are skipped.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class TileSparseLayout:
    """Static description of one tile-sparse operand (pytree aux data).

    ``k``/``n`` are the LOGICAL GEMM dims — a ``trans_w`` source has its
    transpose resolved at sparsify time, exactly like
    :class:`repro.packing.PackedLayout`.  ``indptr``/``indices`` are the
    BSR column structure over the (bk, bn) tile lattice (column-major over
    ``g * nnb`` output-tile columns; see module docstring).  ``dtype`` is
    the payload dtype (``int8`` implies per-tile scales); ``g`` > 1 marks
    a grouped operand (MoE experts / batched weights) whose per-group
    patterns are folded into the single flat column structure.
    """

    k: int
    n: int
    bk: int
    bn: int
    dtype: str
    orig_dtype: str
    indptr: Tuple[int, ...]
    indices: Tuple[int, ...]
    trans_w: bool = False
    g: int = 1

    def __post_init__(self):
        object.__setattr__(self, "indptr", tuple(int(i) for i in self.indptr))
        object.__setattr__(self, "indices",
                          tuple(int(i) for i in self.indices))
        ncols = self.g * self.nnb
        if len(self.indptr) != ncols + 1:
            raise ValueError(
                f"indptr must have g*nnb+1 = {ncols + 1} entries, got "
                f"{len(self.indptr)}")
        if self.indptr[0] != 0 or self.indptr[-1] != len(self.indices):
            raise ValueError("indptr must start at 0 and end at len(indices)")
        for c in range(ncols):
            lo, hi = self.indptr[c], self.indptr[c + 1]
            if hi < lo:
                raise ValueError("indptr must be non-decreasing")
            col = self.indices[lo:hi]
            if any(kk < 0 or kk >= self.nkb for kk in col):
                raise ValueError(
                    f"column {c} has k-tile index outside [0, {self.nkb})")
            if any(col[i] >= col[i + 1] for i in range(len(col) - 1)):
                raise ValueError(
                    f"column {c} k-tile indices must be strictly ascending")

    @property
    def nkb(self) -> int:
        return _cdiv(self.k, self.bk)

    @property
    def nnb(self) -> int:
        return _cdiv(self.n, self.bn)

    @property
    def nnz(self) -> int:
        """Stored (nonzero) tile count across all groups/columns."""
        return len(self.indices)

    @property
    def ntiles(self) -> int:
        """Dense tile count of the lattice: what a dense K grid would visit."""
        return self.g * self.nkb * self.nnb

    @property
    def density(self) -> float:
        return self.nnz / max(1, self.ntiles)

    @property
    def per_tile_scales(self) -> bool:
        return self.dtype == "int8"

    @property
    def payload_shape(self) -> Tuple[int, ...]:
        # +1: the shared trailing zero tile anchor visits read.
        return (self.nnz + 1, self.bk, self.bn)

    @property
    def scales_shape(self) -> Optional[Tuple[int, ...]]:
        if not self.per_tile_scales:
            return None
        return (self.nnz + 1, 1)

    @property
    def schedule_len(self) -> int:
        """Grid extent of the sparse tile walk: nnz + one anchor per empty
        column (the kernel's innermost grid axis — the tile-visit count the
        benchmark gate asserts on)."""
        empty = sum(
            1 for c in range(self.g * self.nnb)
            if self.indptr[c] == self.indptr[c + 1])
        return self.nnz + empty

    @property
    def pattern_digest(self) -> str:
        """Short content fingerprint of the sparsity pattern."""
        h = hashlib.sha256()
        h.update(repr((self.indptr, self.indices)).encode())
        return h.hexdigest()[:8]

    @property
    def tag(self) -> str:
        """Layout namespace tag.

        Used by the packed-weight cache key (sparse-packed and dense-packed
        payloads of the same weight must never alias — the pattern digest
        separates even two sparsifications at the same nnz) and by the plan
        cache's ``make_key(..., sparsity=...)`` namespace (a sparse launch
        has a different measured optimum than the dense-K grid).
        """
        return (f"spB{self.bk}x{self.bn}{self.dtype}"
                f"-nnz{self.nnz}of{self.ntiles}-{self.pattern_digest}")

    def describe(self) -> str:
        shape = f"{self.k}x{self.n}"
        if self.g != 1:
            shape = f"{self.g}x{shape}"
        t = "ᵀ" if self.trans_w else ""
        return (f"TileSparseLayout[{shape}{t} {self.orig_dtype}->{self.dtype}"
                f" tiles=({self.bk},{self.bn}) nnz={self.nnz}/{self.ntiles}"
                f" d={self.density:.2f}]")


@dataclasses.dataclass(frozen=True)
class SparseSchedule:
    """The scalar-prefetch arrays one :class:`TileSparseLayout` lowers to.

    One entry per tile VISIT, column-major over (g, j): stored tiles in
    k-ascending order, plus one anchor entry per empty column pointing at
    the trailing zero payload tile.  All arrays are int32 of length
    ``layout.schedule_len``; they are passed to the kernel as
    scalar-prefetch operands so the BlockSpec index maps can steer every
    DMA from them (the paper's scalar-prefetched gather, TPU form).
    """

    kk: np.ndarray      # k-tile index of the visit (A-side index map + K-tail)
    jj: np.ndarray      # n-tile column of the visit (output/bias/extras maps)
    gg: np.ndarray      # group of the visit (grouped operands; zeros for 2-D)
    slot: np.ndarray    # payload tile to read (zero tile for anchors)
    first: np.ndarray   # 1 == first visit of its column (accumulator init)
    last: np.ndarray    # 1 == last visit of its column (epilogue + store)


@functools.lru_cache(maxsize=256)
def build_schedule(layout: TileSparseLayout) -> SparseSchedule:
    """Lower a layout's BSR structure to the kernel's visit schedule.

    Cached on the (hashable) layout: every launch of the same operand
    reuses the same host arrays.
    """
    nnb = layout.nnb
    zero_slot = layout.nnz
    kk, jj, gg, slot, first, last = [], [], [], [], [], []
    for c in range(layout.g * nnb):
        lo, hi = layout.indptr[c], layout.indptr[c + 1]
        col = layout.indices[lo:hi] if hi > lo else (0,)  # anchor visit
        for i, kt in enumerate(col):
            kk.append(kt)
            jj.append(c % nnb)
            gg.append(c // nnb)
            slot.append(lo + i if hi > lo else zero_slot)
            first.append(1 if i == 0 else 0)
            last.append(1 if i == len(col) - 1 else 0)
    as32 = lambda v: np.asarray(v, np.int32)  # noqa: E731
    return SparseSchedule(kk=as32(kk), jj=as32(jj), gg=as32(gg),
                          slot=as32(slot), first=as32(first), last=as32(last))


class TileSparseOperand:
    """A tile-sparse GEMM operand: stored tiles + optional per-tile scales
    + layout.

    Registered as a pytree (payload/scales are children, layout is aux), so
    it flows through jit, ``lax.scan`` (a stacked-layer operand carries a
    leading layer axis on the payload that scan slices away — the shared
    pattern lives in the aux layout), and parameter trees.  The consuming
    ops (``mp_dot`` / ``mp_dot_grouped`` / ``mpgemm_pallas``) dispatch on
    the type.
    """

    __slots__ = ("payload", "scales", "layout")

    def __init__(self, payload, scales, layout: TileSparseLayout):
        self.payload = payload
        self.scales = scales
        self.layout = layout

    # -- conveniences --------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        """The LOGICAL (transpose-resolved) operand shape: (k, n) / (g, k, n)."""
        base = (self.layout.k, self.layout.n)
        return (self.layout.g,) + base if self.layout.g != 1 else base

    @property
    def dtype(self):
        return jnp.dtype(self.layout.dtype)

    @property
    def nbytes(self) -> int:
        total = self.payload.size * self.payload.dtype.itemsize
        if self.scales is not None:
            total += self.scales.size * self.scales.dtype.itemsize
        return total

    def astype(self, dtype) -> "TileSparseOperand":
        """Payload cast for float payloads (no-op when dtypes match) —
        mirrors :meth:`repro.packing.PackedOperand.astype`."""
        dtype = jnp.dtype(dtype)
        if self.layout.per_tile_scales or self.payload.dtype == dtype:
            return self
        layout = dataclasses.replace(self.layout, dtype=str(dtype))
        return TileSparseOperand(self.payload.astype(dtype), None, layout)

    def __repr__(self) -> str:
        return self.layout.describe().replace("TileSparseLayout",
                                              "TileSparseOperand")


def _flatten(p: TileSparseOperand):
    return (p.payload, p.scales), p.layout


def _unflatten(layout: TileSparseLayout, children) -> TileSparseOperand:
    payload, scales = children
    return TileSparseOperand(payload, scales, layout)


jax.tree_util.register_pytree_node(TileSparseOperand, _flatten, _unflatten)


def is_sparse(w) -> bool:
    return isinstance(w, TileSparseOperand)
