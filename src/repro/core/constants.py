"""Hardware constants for the TPU v5e target and roofline math.

The container is CPU-only; these constants describe the TARGET hardware used
for the analytic block planner (core/blocking.py) and the roofline report
(core/roofline.py).  They are overridable for other TPU generations.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    # Compute.
    peak_flops_bf16: float  # FLOP/s per chip (MXU, bf16 inputs / f32 acc)
    peak_flops_fp32: float  # FLOP/s per chip for fp32 inputs
    peak_ops_int8: float    # OP/s per chip for int8 inputs / i32 acc
    # Memory.
    hbm_bytes: int
    hbm_bw: float           # bytes/s per chip
    vmem_bytes: int         # software-managed vector memory per core
    # Interconnect.
    ici_bw: float           # bytes/s per link (roofline uses chips x link_bw)
    # Tiling granularity of the vector/matrix units.
    mxu_dim: int = 128      # MXU systolic array is mxu_dim x mxu_dim
    lane: int = 128         # VREG lane count
    # Minimum efficient contiguous DMA row, in bytes.  This is the TPU
    # analogue of the paper's "four-Z-register (256B) grouped loads": narrow
    # rows waste descriptor bandwidth exactly like single-Z loads waste bus
    # beats on SME.
    min_dma_row_bytes: int = 512

    def sublane(self, dtype_bytes: int) -> int:
        """Second-minor tiling granularity for a dtype ((8,128) f32 etc.)."""
        return max(8, 32 // max(1, dtype_bytes))


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops_bf16=197e12,
    peak_flops_fp32=197e12 / 4,   # fp32 MXU passes cost ~4x bf16 (cf. paper's
                                  # FP64 = 1/4 FP32 observation on SME)
    peak_ops_int8=394e12,         # int8 2x bf16 (paper: SMOPA 2x FMOPA)
    hbm_bytes=16 * 2**30,
    hbm_bw=819e9,
    vmem_bytes=16 * 2**20,
    ici_bw=50e9,
)

# Default spec used across the framework.
DEFAULT_HW = TPU_V5E
