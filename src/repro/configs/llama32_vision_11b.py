"""llama-3.2-vision-11b — text backbone with gated cross-attention layers
every 5th layer; vision frontend STUBBED (input_specs provides precomputed
patch embeddings, per the assignment).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    cross_attn_every=5, n_image_tokens=1600,
    rope_theta=500000.0, mlp="swiglu", norm="rms",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

SMOKE = ArchConfig(
    name="llama-vision-smoke", family="vlm",
    n_layers=5, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab=512,
    cross_attn_every=5, n_image_tokens=32,
    mlp="swiglu", norm="rms",
)
