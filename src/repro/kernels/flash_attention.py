"""Blocked online-softmax attention (flash attention) as a Pallas TPU kernel.

This is the attention hot-spot counterpart of the MPGEMM kernel and follows
the same design discipline derived from the paper:

* resident accumulators in VMEM scratch across the KV (reduction) loop —
  the "all ZA tiles" rule applied to (acc, m, l);
* KV streamed in wide blocks (lane dim = head_dim, >=512B rows);
* predication (iota masks) for causal / sliding-window / KV-tail edges,
  the paper's predicate-register edge handling;
* GQA handled by a 5-D grid (b, kv_head, group, q_block, kv_block) so KV
  blocks are fetched once per group without materializing repeats.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, nk: int, bq: int, bk: int, tq: int, tk: int,
    causal: bool, window: Optional[int], scale: float, kv_rem: int,
):
    kb = pl.program_id(4)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)     # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)        # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)        # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, bk)

    qb = pl.program_id(3)
    qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + (tk - tq)
    ki = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = ki < tk                              # KV tail predication
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, NEG_INF)
    if kv_rem:
        # Zero padded V rows so 0 * NaN(pipeline pad) never reaches acc.
        vrow = jax.lax.broadcasted_iota(jnp.int32, v.shape, 0) + kb * bk
        v = jnp.where(vrow < tk, v, 0.0)

    m_prev = m_ref[:, :1]                       # (bq, 1)
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                      # (bq, bk)
    l_ref[...] = jnp.broadcast_to(
        l_ref[:, :1] * alpha + p.sum(axis=1, keepdims=True), l_ref.shape
    )
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(kb == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,       # (B, H, Tq, D)
    k: jax.Array,       # (B, Hkv, Tk, D)
    v: jax.Array,       # (B, Hkv, Tk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, tq, d = q.shape
    _, hkv, tk, _ = k.shape
    if h % hkv:
        raise ValueError(f"GQA requires H % Hkv == 0, got {h} % {hkv}")
    g = h // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(block_q, max(8, tq))
    bk = min(block_k, max(128, tk))
    nq = pl.cdiv(tq, bq)
    nk = pl.cdiv(tk, bk)

    q5 = q.reshape(b, hkv, g, tq, d)
    grid = (b, hkv, g, nq, nk)

    kernel = functools.partial(
        _flash_kernel, nk=nk, bq=bq, bk=bk, tq=tq, tk=tk,
        causal=causal, window=window, scale=scale, kv_rem=tk % bk,
    )
    kwargs = {}
    if not interpret and pltpu is not None:
        cls = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams", None
        )
        if cls is not None:
            try:
                kwargs["compiler_params"] = cls(
                    dimension_semantics=("parallel",) * 4 + ("arbitrary",)
                )
            except Exception:  # pragma: no cover
                pass

    out5 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, d), lambda b_, h_, g_, i, j: (b_, h_, g_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, g_, i, j: (b_, h_, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, g_, i, j: (b_, h_, j, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, bq, d), lambda b_, h_, g_, i, j: (b_, h_, g_, i, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, tq, d), q.dtype),
        scratch_shapes=(
            [
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, 128), jnp.float32),
                pltpu.VMEM((bq, d), jnp.float32),
            ]
            if pltpu
            else []
        ),
        interpret=interpret,
        **kwargs,
    )(q5, k, v)
    return out5.reshape(b, h, tq, d)


def _paged_flash_kernel(
    bt_ref, qs_ref, len_ref,            # scalar-prefetch: block table, q_start, lengths
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, npages: int, bq: int, ps: int,
    causal: bool, window: Optional[int], scale: float,
):
    b_ = pl.program_id(0)
    qb = pl.program_id(3)
    j = pl.program_id(4)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)     # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)        # (ps, d)
    v = v_ref[0, 0].astype(jnp.float32)        # (ps, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                   # (bq, ps)

    # Positions are LOGICAL: page j holds kv tokens [j*ps, (j+1)*ps) of this
    # request's stream regardless of which physical page bt[b, j] names.
    length = len_ref[b_]
    qi = qs_ref[b_] + qb * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, ps), 0)
    ki = j * ps + jax.lax.broadcasted_iota(jnp.int32, (bq, ps), 1)
    mask = ki < length                          # ragged-length predication
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    s = jnp.where(mask, s, NEG_INF)
    # Zero V rows past the valid length: scratch-page garbage (and pipeline
    # pad NaNs) must never reach acc, even weighted by p == 0.
    vrow = j * ps + jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
    v = jnp.where(vrow < length, v, 0.0)

    m_prev = m_ref[:, :1]
    m_cur = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)
    l_ref[...] = jnp.broadcast_to(
        l_ref[:, :1] * alpha + p.sum(axis=1, keepdims=True), l_ref.shape
    )
    m_ref[...] = jnp.broadcast_to(m_cur, m_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )

    @pl.when(j == npages - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0, 0] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)
        ).astype(o_ref.dtype)


def paged_flash_attention(
    q: jax.Array,             # (B, H, Tq, D) — current-chunk queries
    k_pages: jax.Array,       # (P, Hkv, page_size, D) — pooled KV pages
    v_pages: jax.Array,       # (P, Hkv, page_size, D)
    block_tables: jax.Array,  # (B, W) int32 physical page ids, 0-padded
    q_start: jax.Array,       # (B,) int32 absolute position of q row 0
    lengths: jax.Array,       # (B,) int32 total valid KV tokens
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention whose KV blocks are gathered through a block table.

    The grid is ``(B, Hkv, G, nq, W)`` with the page axis innermost; the
    K/V index maps read the scalar-prefetched block table — the same
    stored-schedule gather ``mpgemm``'s sparse launch uses for its tile
    schedule, so every grid step DMAs exactly the page the table names.
    Dead table slots point at the reserved scratch page (id 0): the DMA
    stays in-bounds and the logical-position mask (``ki < lengths[b]``)
    zeroes their contribution.
    """
    b, h, tq, d = q.shape
    p_pages, hkv, ps, dk = k_pages.shape
    if d != dk:
        raise ValueError(f"head_dim mismatch: q has {d}, pages have {dk}")
    if h % hkv:
        raise ValueError(f"GQA requires H % Hkv == 0, got {h} % {hkv}")
    if k_pages.shape != v_pages.shape:
        raise ValueError("k_pages / v_pages shape mismatch")
    g = h // hkv
    w = block_tables.shape[1]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    bq = min(block_q, max(8, tq))
    nq = pl.cdiv(tq, bq)

    if pltpu is None:  # pragma: no cover
        raise NotImplementedError(
            "paged_flash_attention needs pallas TPU scalar prefetch; use "
            "models.attention.paged_attention_ref on this backend")

    q5 = q.reshape(b, hkv, g, tq, d)
    grid = (b, hkv, g, nq, w)
    kernel = functools.partial(
        _paged_flash_kernel, npages=w, bq=bq, ps=ps,
        causal=causal, window=window, scale=scale,
    )

    # Index maps see the grid indices plus the scalar-prefetch refs; the
    # flattened block table is indexed exactly like the sparse launch's
    # slot[] schedule.
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec(
                (1, 1, 1, bq, d),
                lambda b_, h_, g_, i, j, bt, qs, ln: (b_, h_, g_, i, 0)),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda b_, h_, g_, i, j, bt, qs, ln: (bt[b_ * w + j], h_, 0, 0)),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda b_, h_, g_, i, j, bt, qs, ln: (bt[b_ * w + j], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, bq, d),
            lambda b_, h_, g_, i, j, bt, qs, ln: (b_, h_, g_, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
    )
    kwargs = {}
    if not interpret:
        cls = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams", None
        )
        if cls is not None:
            try:
                kwargs["compiler_params"] = cls(
                    dimension_semantics=("parallel",) * 4 + ("arbitrary",)
                )
            except Exception:  # pragma: no cover
                pass

    out5 = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, tq, d), q.dtype),
        interpret=interpret,
        **kwargs,
    )(
        block_tables.reshape(-1).astype(jnp.int32),
        q_start.astype(jnp.int32),
        lengths.astype(jnp.int32),
        q5, k_pages, v_pages,
    )
    return out5.reshape(b, h, tq, d)
