"""Serving engine: batched prefill + decode with KV caches.

Continuous-batching-lite: requests are grouped into a fixed batch; each
decode step advances every live sequence one token; finished sequences
(EOS or length) free their slot for queued requests (slot reuse keeps the
compiled decode_step's shapes static — the production pattern).

``generate()`` emits per-wave telemetry (:class:`WaveTelemetry`:
tokens/s, slot occupancy, queue depth) into ``engine.telemetry`` — the
first observability surface toward production serving: occupancy says
whether the static batch is sized right, queue depth whether admission is
falling behind, tokens/s is the throughput SLO number.  An optional
``on_wave`` callback streams each record as it completes (metrics
export)."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray           # (T,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[List[int]] = None


@dataclasses.dataclass(frozen=True)
class WaveTelemetry:
    """Observability record for ONE wave of batched generation.

    ``wall_s`` (and therefore ``tokens_per_s``) covers prefill + decode —
    and, for the FIRST wave after process start or a shape change, the
    jax.jit compilation of the prefill/decode executables.  ``prefill_s``
    isolates the prefill(+compile) portion so metrics consumers can
    baseline steady-state decode throughput (``tokens / (wall_s -
    prefill_s)``) or drop the wave-0 outlier.
    """

    wave: int                # 0-based wave index within this generate() call
    requests: int            # requests admitted into the wave
    tokens: int              # tokens emitted by the wave
    decode_steps: int        # decode iterations the wave ran
    wall_s: float            # wave wall time (prefill + decode)
    prefill_s: float         # prefill wall time (incl. compile on wave 0)
    tokens_per_s: float      # tokens / wall_s
    slot_occupancy: float    # mean live-slot fraction over decode steps
    queue_depth: int         # requests still queued when the wave finished


class ServeEngine:
    def __init__(self, model, params, *, batch_size: int, max_len: int,
                 eos_id: int = 1, greedy: bool = True,
                 on_wave: Optional[Callable[[WaveTelemetry], None]] = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.on_wave = on_wave
        self.telemetry: List[WaveTelemetry] = []
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=max_len))
        self._decode = jax.jit(model.decode_step)

    def generate(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Static-batch generation with slot reuse between waves.

        Resets and repopulates ``self.telemetry`` with one
        :class:`WaveTelemetry` per wave (and streams each record through
        ``on_wave`` when configured).
        """
        results: Dict[int, List[int]] = {}
        queue = list(requests)
        self.telemetry = []
        wave_idx = 0
        while queue:
            wave = queue[: self.batch_size]
            queue = queue[self.batch_size:]
            t0 = time.perf_counter()
            out, steps, occupancy, prefill_s = self._run_wave(wave)
            wall = time.perf_counter() - t0
            n_tok = sum(len(v) for v in out.values())
            record = WaveTelemetry(
                wave=wave_idx, requests=len(wave), tokens=n_tok,
                decode_steps=steps, wall_s=wall, prefill_s=prefill_s,
                tokens_per_s=n_tok / wall if wall > 0 else 0.0,
                slot_occupancy=occupancy, queue_depth=len(queue),
            )
            self.telemetry.append(record)
            if self.on_wave is not None:
                self.on_wave(record)
            results.update(out)
            wave_idx += 1
        return results

    def _run_wave(self, wave: List[Request]):
        b = self.batch_size
        plen = max(len(r.prompt) for r in wave)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        cfg = self.model.cfg
        # Stubbed modality frontends (per assignment): frame/patch embeds.
        if cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (b, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        t_pf = time.perf_counter()
        logits, caches = self._prefill(self.params, batch)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t_pf
        out = {r.uid: [] for r in wave}
        live = np.array([True] * len(wave) + [False] * (b - len(wave)))
        token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in wave)
        pos = plen
        occ_sum = 0.0
        emit_steps = 0
        decode_steps = 0
        for step in range(max_new):
            # Slot occupancy is sampled at emission time: live slots doing
            # useful work this step over the static batch width.
            occ_sum += float(live.sum()) / b
            emit_steps += 1
            tok_np = np.asarray(token[:, 0])
            for i, r in enumerate(wave):
                if live[i]:
                    out[r.uid].append(int(tok_np[i]))
                    if (int(tok_np[i]) == self.eos_id
                            or len(out[r.uid]) >= r.max_new_tokens):
                        live[i] = False
            if not live.any() or pos >= self.max_len - 1:
                break
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.int32(pos))
            decode_steps += 1
            token = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        occupancy = occ_sum / emit_steps if emit_steps else 0.0
        return out, decode_steps, occupancy, prefill_s
