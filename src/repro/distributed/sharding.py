"""Logical sharding rules -> NamedSharding for every param / input / cache.

Scheme (DESIGN.md §3):
  * 'model' axis: tensor parallelism — d_ff, attention-head projections,
    vocab dim of embed/head, expert dim (EP) when divisible, KV-cache
    sequence dim (sequence-parallel decode).
  * 'data' axis: data parallelism for activations AND FSDP for weights —
    every weight matrix also shards its non-TP dim over 'data', so optimizer
    state is fully sharded (ZeRO-3 flavored; XLA inserts the per-layer
    weight all-gathers).
  * 'pod' axis (multi-pod mesh): pure DP — batch sharded, weights replicated
    across pods, gradients all-reduced hierarchically.

Every rule is divisibility-guarded: a dim that does not divide by its mesh
axis falls back to replication on that axis (e.g. granite's vocab 49155,
whisper's encoder_seq 1500).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _guard(mesh: Mesh, spec: Tuple, shape: Tuple[int, ...]) -> P:
    """Drop axes whose size does not divide the corresponding dim."""
    fixed = []
    for dim, axis in zip(shape, spec):
        if axis is not None and dim % _axis_size(mesh, axis) == 0:
            fixed.append(axis)
        else:
            fixed.append(None)
    return P(*fixed)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def batch_axes(mesh: Mesh):
    """The composite data-parallel axis: ('pod','data') on multi-pod."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def param_pspec(path: str, shape: Tuple[int, ...], cfg, mesh: Mesh) -> P:
    """Sharding rule for one parameter leaf, identified by its tree path."""
    d = cfg.d_model
    parts = path.split("/")
    name = parts[-1]
    if name == "q" and len(parts) >= 2:   # static-int8 weight payload
        name = parts[-2]
    elif name == "scale" and len(parts) >= 2 and parts[-2] in (
            "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "ck", "cv",
            "cr", "wr", "wg", "w_x", "w_y", "w_out", "w_gate_r", "w_gate_i",
            "head"):
        return P()                         # scalar scale: replicated
    # Leading stacked-layer dim (scan units) is never sharded.
    stacked = path.split("/")[0] in ("stack", "encoder") or "stack/" in path
    lead = (None,) if (stacked and len(shape) >= 1) else ()
    core_shape = shape[len(lead):]

    def spec(*axes):
        return _guard(mesh, lead + axes, shape)

    if name in ("embed",):
        return spec("model", "data")
    if name == "head":
        return spec("data", "model")
    if name in ("pos_embed", "enc_pos"):
        return spec(None, "data")
    if name in ("scale", "bias", "w_base", "lambda_p", "conv_b", "b_down",
                "gate_attn", "gate_mlp", "mu", "mu_c", "u", "gn_scale"):
        return spec(*([None] * len(core_shape)))
    if name == "b_up":
        return spec("model")
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "ck", "cr", "wr",
                "w_x", "w_y", "w_gate_r", "w_gate_i", "lora_a", "w_lora_a"):
        if len(core_shape) == 3:  # MoE experts (E, d, f)
            if core_shape[0] % _axis_size(mesh, "model") == 0:
                return spec("model", "data", None)      # expert parallelism
            return spec(None, "data", "model")          # TP within experts
        return spec("data", "model")
    if name in ("wo", "w_down", "cv", "w_out", "w_lora_b"):
        if len(core_shape) == 3:  # (E, f, d)
            if core_shape[0] % _axis_size(mesh, "model") == 0:
                return spec("model", None, "data")
            return spec(None, "model", "data")
        return spec("model", "data")
    if name in ("router",):
        return spec("data", None)
    if name in ("conv_w",):
        return spec(None, "model")
    if name in ("lora_b",):
        return spec(None, None, "data")
    if name in ("wg", "wk2",):
        return spec("data", "model")
    # Default: replicate.
    return P(*([None] * len(shape)))


def params_shardings(params_shape: Any, cfg, mesh: Mesh):
    """Pytree of NamedShardings matching a params (shape-)pytree."""

    def one(path, leaf):
        return NamedSharding(
            mesh, param_pspec(_path_str(path), leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, params_shape)


def cache_pspec(path: str, shape: Tuple[int, ...], cfg, mesh: Mesh) -> P:
    """KV caches: batch over data axes, sequence dim over 'model'
    (sequence-parallel decode; softmax reductions over the sharded KV axis
    become all-reduces).  Recurrent states: width/head dims over 'model'."""
    da = batch_axes(mesh)
    da = da if len(da) > 1 else da[0]
    name = path.split("/")[-1]
    stacked = path.startswith("stack")
    lead = (None,) if stacked else ()

    def spec(*axes):
        return _guard(mesh, lead + axes, shape)

    core = shape[len(lead):]
    if name in ("k", "v") and len(core) == 4:      # (B, Hkv, S, hd)
        return spec(da, None, "model", None)
    if name == "state" and len(core) == 4:          # rwkv (B, H, dk, dv)
        return spec(da, "model", None, None)
    if name in ("shift_t", "shift_c", "h"):         # (B, d|w)
        return spec(da, "model")
    if name == "conv":                               # (B, K-1, w)
        return spec(da, None, "model")
    return spec(da, *([None] * (len(core) - 1)))


def caches_shardings(caches_shape: Any, cfg, mesh: Mesh):
    def one(path, leaf):
        return NamedSharding(
            mesh, cache_pspec(_path_str(path), leaf.shape, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, caches_shape)


def batch_shardings(batch_shape: Any, mesh: Mesh):
    """Input batches: leading batch dim over the composite data axes."""
    da = batch_axes(mesh)
    da = da if len(da) > 1 else da[0]

    def one(leaf):
        spec = _guard(mesh, (da,) + (None,) * (len(leaf.shape) - 1), leaf.shape)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, batch_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
