"""Fault-tolerance drill: crash a training run mid-flight, restore, verify
the resumed run matches an uninterrupted one bit-for-bit; then exercise the
straggler detector and the elastic re-mesh planner.

    PYTHONPATH=src python examples/fault_tolerance_drill.py
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax

from repro.configs import base as cb
from repro.configs.base import ShapeConfig
from repro.distributed.fault_tolerance import (
    FailureEvent, plan_elastic_mesh, simulate_failures,
)
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    cfg = cb.get("starcoder2-3b", smoke=True)
    model = build_model(cfg, policy="fp32", remat=False)
    shape = ShapeConfig("tiny", 32, 4, "train")
    ckdir = tempfile.mkdtemp(prefix="ft_drill_")

    # 1. Uninterrupted 12-step run (the reference).
    tcfg = TrainerConfig(steps=12, checkpoint_every=6, checkpoint_dir=ckdir,
                         log_every=1000, opt=AdamWConfig(lr=1e-3))
    ref = Trainer(model, shape, tcfg)
    p_ref, _ = ref.run()

    # 2. "Crash" after step 6 (we restore from the step-6 checkpoint) and
    #    resume to step 12 — must equal the reference exactly.
    tr = Trainer(model, shape, tcfg)
    p_like, o_like = tr.init_state()
    p, o, step = tr.restore(p_like, o_like, step=6)
    print(f"crashed @ step ~9, restored checkpoint @ step {step}")
    p, o = tr.run(p, o, start_step=step)
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("resume is BIT-EXACT vs uninterrupted run")

    # 3. Straggler + crash simulation through the controller contract.
    saved = {"step": 0}
    log = simulate_failures(
        lambda s: 1.0, total_steps=24,
        events=[FailureEvent(step=9, kind="crash"),
                FailureEvent(step=15, kind="straggle", magnitude=8)],
        checkpoint_every=6,
        save=lambda s: saved.update(step=s), restore=lambda: saved["step"])
    print("failure-sim log:", log)

    # 4. Elastic re-mesh plan after losing chips.
    for chips in (256, 240, 128, 17):
        print(f"elastic plan for {chips} chips:", plan_elastic_mesh(chips))

    shutil.rmtree(ckdir, ignore_errors=True)
    print("OK")


if __name__ == "__main__":
    main()
