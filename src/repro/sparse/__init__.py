"""Tile-sparse operand subsystem — skip zero tiles end-to-end.

The layer between pruning and execution: weight tiles that are (or are
made) zero on the plan's (bk, bn) lattice are dropped from storage AND
from the kernel's tile walk — the dense K grid is replaced by a
scalar-prefetched per-column tile list, so a pruned tile costs neither
HBM bytes nor an MXU pass.

    core/blocking.py (plan, density-priced)   repro.tuning (sparsity-keyed)
            └──────────────┬───────────────────────┘
                           ▼
    repro.sparse: sparsify_magnitude / sparsify_nm / sparsify_params
            │  TileSparseOperand (stored tiles + per-tile scales +
            │                     TileSparseLayout BSR metadata)
            │  payload cache: repro.packing.PackedWeightCache (the layout
            │                 tag — pattern digest included — keys it)
            ▼
    mp_dot / mp_dot_grouped (x, TileSparseOperand) — polymorphic b operand
            ▼
    kernels/mpgemm.py  mpgemm_pallas(a, sparse) — grid (M/bm, nnz),
                       scalar-prefetched index maps, zero tiles never
                       visited (the jaxpr-verifiable tile-visit gate)

Public API: :func:`sparsify_magnitude`, :func:`sparsify_nm`,
:func:`sparsify_with_mask`, :func:`sparsify_params`,
:func:`densify_operand`, :class:`TileSparseOperand`,
:class:`TileSparseLayout`, :func:`is_sparse`, :func:`build_schedule`.
See docs/sparse.md for the layout format and the accuracy/perf trade-off.
"""
from repro.sparse.layout import (
    SparseSchedule, TileSparseLayout, TileSparseOperand, build_schedule,
    is_sparse,
)
from repro.sparse.params import (
    sparse_param_bytes, sparse_param_density, sparsify_params,
)
from repro.sparse.sparsify import (
    build_payload, densify_operand, magnitude_mask, nm_mask,
    payload_cotangent, sparsify_magnitude, sparsify_nm, sparsify_with_mask,
    tile_scores,
)

__all__ = [
    "SparseSchedule", "TileSparseLayout", "TileSparseOperand",
    "build_payload", "build_schedule", "densify_operand", "is_sparse",
    "magnitude_mask", "nm_mask", "payload_cotangent", "sparse_param_bytes",
    "sparse_param_density", "sparsify_magnitude", "sparsify_nm",
    "sparsify_params", "sparsify_with_mask", "tile_scores",
]
