"""Tests for the perf-trajectory metrics core (repro.perf).

Pins the accounting down: FLOPs / HBM-bytes / tile-visit counts for 2-D,
grouped, packed, and density-priced sparse GEMMs — cross-checked against
``core/blocking.py``'s ``modeled_traffic_bytes`` AND hand-computed values
for the paper's Table III workloads 1, 13, 19 — plus the BENCH file
schema round-trip and the diff's tolerance/direction logic.
"""
import math

import pytest

from repro.core.blocking import modeled_traffic_bytes, plan_gemm, plan_grouped_gemm
from repro.core.constants import DEFAULT_HW
from repro.perf.diff import (
    DEFAULT_REL_TOL, diff_bench, markdown_report, metric_direction,
    resolve_tolerance,
)
from repro.perf.metrics import (
    PhaseFlops, WorkloadRecord, gemm_bytes, gemm_flops, modeled_gemm_us,
    phase_flops, plan_provenance, record_from_plan, tile_visits, total_flops,
)
from repro.perf.trajectory import (
    SCHEMA_VERSION, BenchFile, Recorder, bench_path, read_bench,
    validate_bench_dict, validate_record_dict, write_bench,
)

# Paper Table III reference workloads: decode-skinny (1), square training
# (13), and the LLaMA low-rank shape (19).
W1 = (64, 2112, 7168)
W13 = (4096, 2112, 7168)
W19 = (4096, 256, 4096)


# --- FLOPs accounting --------------------------------------------------------

class TestGemmFlops:
    def test_hand_computed_paper_workloads(self):
        # 2*m*n*k, computed by hand for the three reference shapes
        assert gemm_flops(*W1) == 2 * 64 * 2112 * 7168 == 1_937_768_448
        assert gemm_flops(*W13) == 2 * 4096 * 2112 * 7168 == 124_017_180_672
        assert gemm_flops(*W19) == 2 * 4096 * 256 * 4096 == 8_589_934_592

    def test_grouped_scales_by_g(self):
        assert gemm_flops(*W19, g=8) == 8 * gemm_flops(*W19)

    def test_density_prices_sparse(self):
        assert gemm_flops(*W19, density=0.25) == gemm_flops(*W19) // 4

    def test_matches_planner(self):
        for (m, n, k) in (W1, W13, W19):
            plan = plan_gemm(m, n, k, "bfloat16")
            assert gemm_flops(m, n, k) == plan.flops

    def test_grouped_matches_planner(self):
        m, n, k, g = 480, 1408, 2048, 64
        plan = plan_grouped_gemm(g, m, n, k, "bfloat16")
        assert gemm_flops(m, n, k, g=g) == plan.flops

    def test_validation(self):
        with pytest.raises(ValueError):
            gemm_flops(64, 64, 64, g=0)
        with pytest.raises(ValueError):
            gemm_flops(64, 64, 64, density=0.0)
        with pytest.raises(ValueError):
            gemm_flops(64, 64, 64, density=1.5)


# --- HBM-bytes accounting ----------------------------------------------------

class TestGemmBytes:
    def test_hand_computed_w1(self):
        # K-innermost revisiting grid: A re-read per column block, B per
        # row block, C written once.  With blocks (bm, bn) covering the
        # whole extent, every operand moves exactly once.
        m, n, k = W1
        got = gemm_bytes(m, n, k, bm=m, bn=n,
                         a_dtype="bfloat16", out_dtype="bfloat16")
        assert got == (m * k + k * n + m * n) * 2

    def test_hand_computed_w13_with_reread(self):
        # bm = 1024, bn = 1056 -> 4 row blocks x 2 column blocks
        m, n, k = W13
        bm, bn = 1024, 1056
        expect = (m * k * 2) * 2 + (k * n * 2) * 4 + m * n * 2
        assert gemm_bytes(m, n, k, bm=bm, bn=bn, a_dtype="bfloat16") == expect

    def test_cross_check_modeled_traffic(self):
        # Must delegate EXACTLY to core/blocking's model for any blocks.
        for (m, n, k) in (W1, W13, W19):
            plan = plan_gemm(m, n, k, "bfloat16")
            assert gemm_bytes(m, n, k, bm=plan.bm, bn=plan.bn,
                              a_dtype="bfloat16") == plan.hbm_bytes
            assert gemm_bytes(m, n, k, bm=plan.bm, bn=plan.bn,
                              a_dtype="bfloat16") == modeled_traffic_bytes(
                m, n, k, plan.bm, plan.bn, 2, 2, 2)

    def test_packed_mixed_dtypes(self):
        # Packed int8 payload under a bf16 activation: B moves 1 byte/elem.
        m, n, k = W19
        got = gemm_bytes(m, n, k, bm=m, bn=n,
                         a_dtype="bfloat16", b_dtype="int8",
                         out_dtype="bfloat16")
        assert got == m * k * 2 + k * n * 1 + m * n * 2

    def test_grouped_lift(self):
        m, n, k = W19
        one = gemm_bytes(m, n, k, bm=512, bn=256, a_dtype="bfloat16")
        assert gemm_bytes(m, n, k, bm=512, bn=256, a_dtype="bfloat16",
                          g=8) == 8 * one

    def test_density_priced_sparse(self):
        # A and B terms shrink with density; the C write does not.
        m, n, k = W19
        bm, bn = 512, 256
        dense = gemm_bytes(m, n, k, bm=bm, bn=bn, a_dtype="bfloat16")
        half = gemm_bytes(m, n, k, bm=bm, bn=bn, a_dtype="bfloat16",
                          density=0.5)
        c_term = m * n * 2
        assert half - c_term == pytest.approx((dense - c_term) / 2)
        # and agrees with the planner's density-priced plan
        plan = plan_gemm(m, n, k, "bfloat16", density=0.5)
        assert gemm_bytes(m, n, k, bm=plan.bm, bn=plan.bn,
                          a_dtype="bfloat16", density=0.5) == plan.hbm_bytes

    def test_epilogue_operands_and_beta(self):
        m, n, k = W19
        base = gemm_bytes(m, n, k, bm=m, bn=n, a_dtype="bfloat16")
        gated = gemm_bytes(m, n, k, bm=m, bn=n, a_dtype="bfloat16",
                           extra_mn_inputs=1)
        assert gated - base == m * n * 2       # one streamed (M, N) operand
        with_c = gemm_bytes(m, n, k, bm=m, bn=n, a_dtype="bfloat16",
                            beta=1.0)
        assert with_c - base == m * n * 2      # C read once more


# --- tile-visit accounting ---------------------------------------------------

class TestTileVisits:
    def test_dense_2d(self):
        m, n, k = W19
        plan = plan_gemm(m, n, k, "bfloat16")
        expect = (math.ceil(m / plan.bm) * math.ceil(n / plan.bn)
                  * math.ceil(k / plan.bk))
        assert tile_visits(m, n, k, plan.bm, plan.bn, plan.bk) == expect
        # cross-check against the plan's own grid
        assert expect == plan.grid[0] * plan.grid[1] * plan.grid[2]

    def test_grouped(self):
        assert tile_visits(128, 256, 512, 64, 128, 128, g=8) \
            == 8 * tile_visits(128, 256, 512, 64, 128, 128)

    def test_sparse_schedule(self):
        # Sparse grid is (m/bm, schedule_len): visits follow the schedule,
        # not the dense lattice.
        assert tile_visits(4096, 256, 4096, 512, 256, 512,
                           schedule_len=4) == 8 * 4
        dense = tile_visits(4096, 256, 4096, 512, 256, 512)
        assert dense == 8 * 1 * 8


# --- roofline time + per-phase model accounting ------------------------------

class TestModeledTime:
    def test_roofline_max_of_terms(self):
        hw = DEFAULT_HW
        flops, bytes_ = 1e12, 1e9
        t = modeled_gemm_us(flops, bytes_, "bfloat16", hw)
        assert t == pytest.approx(
            max(flops / hw.peak_flops_bf16, bytes_ / hw.hbm_bw) * 1e6)

    def test_int8_uses_int8_peak(self):
        hw = DEFAULT_HW
        assert modeled_gemm_us(1e12, 1, "int8", hw) == pytest.approx(
            1e12 / hw.peak_ops_int8 * 1e6)


class TestPhaseFlops:
    def test_dense_decomposition(self):
        from repro.configs import base as cb
        cfg = cb.get("h2o-danube3-4b", smoke=True)
        tokens, seq = 128, 128
        phases = phase_flops(cfg, tokens, seq)
        by = {p.name: p for p in phases}
        d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
        L = len(cfg.pattern)
        qkv_w = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
        assert by["attn_qkv"].fwd == 2 * tokens * qkv_w * L
        assert by["mlp"].fwd == 2 * tokens * 3 * d * f * L  # swiglu: 3 mats
        assert by["logits"].fwd == 2 * tokens * d * cfg.vocab
        assert by["embed"].fwd == 0 and by["embed"].bwd == 0
        # bwd = 2x fwd for every GEMM phase
        for p in phases:
            if p.fwd:
                assert p.bwd == 2 * p.fwd

    def test_moe_counts_active_experts(self):
        from repro.configs import base as cb
        cfg = cb.get("granite-moe-1b-a400m", smoke=True)
        phases = {p.name: p for p in phase_flops(cfg, 64, 64)}
        assert "moe_router" in phases and "moe_experts" in phases
        L = len(cfg.pattern)
        assert phases["moe_router"].fwd == \
            2 * 64 * cfg.d_model * cfg.n_experts * L
        assert phases["moe_experts"].fwd == \
            2 * 64 * 3 * cfg.d_model * cfg.d_ff * cfg.experts_per_token * L

    def test_totals(self):
        phases = [PhaseFlops("a", 10, 20), PhaseFlops("b", 1, 2)]
        assert total_flops(phases) == {"fwd": 11, "bwd": 22, "total": 33}

    def test_round_trip(self):
        p = PhaseFlops("mlp", 123, 246)
        assert PhaseFlops.from_dict(p.to_dict()) == p


# --- record + schema round-trip ----------------------------------------------

class TestRecordSchema:
    def test_record_round_trip(self):
        rec = WorkloadRecord(
            name="w1", area="gemm", kind="model",
            workload={"m": 64, "n": 2112, "k": 7168},
            metrics={"flops": 1.9e9, "modeled_us": 12.5},
            noisy={"wall_us": 1234.5},
            phases=[PhaseFlops("mlp", 10, 20)],
        )
        back = WorkloadRecord.from_dict(rec.to_dict())
        assert back.to_dict() == rec.to_dict()
        assert validate_record_dict(rec.to_dict()) == []

    def test_record_from_plan_carries_roofline_terms(self):
        plan = plan_gemm(*W19, "bfloat16")
        rec = record_from_plan("w19", "gemm", plan)
        assert rec.metrics["flops"] == plan.flops
        assert rec.metrics["hbm_bytes"] == plan.hbm_bytes
        assert rec.metrics["cmr"] == pytest.approx(plan.cmr)
        assert rec.metrics["tile_visits"] == \
            plan.grid[0] * plan.grid[1] * plan.grid[2]
        assert rec.plan["blocks"] == [plan.bm, plan.bn, plan.bk]
        assert rec.plan["source"] == "analytic"

    def test_invalid_kind_rejected(self):
        with pytest.raises(ValueError):
            WorkloadRecord(name="x", area="gemm", kind="vibes")

    def test_validate_catches_bad_metrics(self):
        bad = {"name": "x", "area": "gemm", "kind": "model",
               "metrics": {"us": "fast"}, "noisy": {}, "workload": {}}
        assert any("not numeric" in p for p in validate_record_dict(bad))

    def test_bench_file_round_trip(self, tmp_path):
        recs = [
            record_from_plan("w19", "gemm", plan_gemm(*W19, "bfloat16")),
            WorkloadRecord(name="aaa_first", area="gemm",
                           metrics={"x": 1.0}),
        ]
        path = write_bench(tmp_path, "gemm", recs,
                           environment={"host": "test"})
        assert path == bench_path(tmp_path, "gemm")
        bf = read_bench(path)
        assert isinstance(bf, BenchFile)
        assert bf.schema_version == SCHEMA_VERSION
        assert bf.area == "gemm"
        # records come back name-sorted
        assert [r.name for r in bf.records] == ["aaa_first", "w19"]
        assert bf.by_name()["w19"].metrics["flops"] == \
            float(gemm_flops(*W19))

    def test_write_is_deterministic(self, tmp_path):
        recs = [WorkloadRecord(name="a", area="gemm", metrics={"x": 1.5})]
        p1 = write_bench(tmp_path / "one", "gemm", recs,
                         environment={"e": "1"})
        p2 = write_bench(tmp_path / "two", "gemm", recs,
                         environment={"e": "1"})
        assert p1.read_bytes() == p2.read_bytes()

    def test_duplicate_names_rejected(self, tmp_path):
        recs = [WorkloadRecord(name="a", area="gemm"),
                WorkloadRecord(name="a", area="gemm")]
        with pytest.raises(ValueError, match="duplicate"):
            write_bench(tmp_path, "gemm", recs)

    def test_read_rejects_bad_schema_version(self, tmp_path):
        path = write_bench(tmp_path, "gemm",
                           [WorkloadRecord(name="a", area="gemm")])
        import json
        raw = json.loads(path.read_text())
        raw["schema_version"] = 99
        path.write_text(json.dumps(raw))
        assert validate_bench_dict(raw)
        with pytest.raises(ValueError, match="schema_version"):
            read_bench(path)

    def test_recorder_replaces_same_name(self, tmp_path):
        rec = Recorder()
        rec.add(WorkloadRecord(name="a", area="gemm", metrics={"x": 1.0}))
        rec.add(WorkloadRecord(name="a", area="gemm", metrics={"x": 2.0}))
        rec.add(WorkloadRecord(name="b", area="sparse"))
        assert len(rec) == 2
        assert rec.records("gemm")[0].metrics["x"] == 2.0
        paths = rec.write_all(tmp_path)
        assert sorted(paths) == ["gemm", "sparse"]


# --- diff tolerance / direction logic ----------------------------------------

def _bench(metrics, area="gemm", name="w"):
    return BenchFile(area=area, schema_version=SCHEMA_VERSION,
                     environment={},
                     records=[WorkloadRecord(name=name, area=area,
                                             metrics=metrics)])


class TestDiff:
    def test_direction_table(self):
        assert metric_direction("modeled_us") == "lower"
        assert metric_direction("hbm_bytes") == "lower"
        assert metric_direction("tile_visits") == "lower"
        assert metric_direction("modeled_speedup_vs_naive") == "higher"
        assert metric_direction("cmr") == "higher"
        assert metric_direction("peak_frac_int8") == "higher"
        assert metric_direction("mystery_number") == "both"

    def test_unchanged_passes(self):
        r = diff_bench(_bench({"modeled_us": 10.0}),
                       _bench({"modeled_us": 10.0}))
        assert r.ok and r.unchanged_count == 1 and not r.regressions

    def test_regression_in_bad_direction(self):
        r = diff_bench(_bench({"modeled_us": 10.0}),
                       _bench({"modeled_us": 11.0}))
        assert not r.ok
        assert r.regressions[0].metric == "modeled_us"
        assert r.regressions[0].status == "regression"

    def test_improvement_not_failed(self):
        r = diff_bench(_bench({"modeled_us": 10.0}),
                       _bench({"modeled_us": 9.0}))
        assert r.ok and len(r.improvements) == 1

    def test_higher_is_better_direction(self):
        worse = diff_bench(_bench({"speedup": 2.0}),
                           _bench({"speedup": 1.5}))
        assert not worse.ok
        better = diff_bench(_bench({"speedup": 2.0}),
                            _bench({"speedup": 2.5}))
        assert better.ok and len(better.improvements) == 1

    def test_within_tolerance(self):
        r = diff_bench(_bench({"modeled_us": 100.0}),
                       _bench({"modeled_us": 101.0}),
                       tolerances={"modeled_us": 0.02})
        assert r.ok and len(r.within_tol) == 1 and not r.regressions

    def test_beyond_tolerance_fails(self):
        r = diff_bench(_bench({"modeled_us": 100.0}),
                       _bench({"modeled_us": 103.0}),
                       tolerances={"modeled_us": 0.02})
        assert not r.ok

    def test_unknown_metric_two_sided(self):
        # deterministic unknown metrics must not drift in EITHER direction
        for cur in (0.9, 1.1):
            r = diff_bench(_bench({"mystery_number": 1.0}),
                           _bench({"mystery_number": cur}))
            assert not r.ok

    def test_new_metric_reported_not_failed(self):
        r = diff_bench(_bench({"a_us": 1.0}),
                       _bench({"a_us": 1.0, "b_us": 2.0}))
        assert r.ok and r.new_metrics == [("w", "b_us")]

    def test_missing_metric_fails(self):
        r = diff_bench(_bench({"a_us": 1.0, "b_us": 2.0}),
                       _bench({"a_us": 1.0}))
        assert not r.ok and r.missing_metrics == [("w", "b_us")]

    def test_missing_record_fails_new_record_does_not(self):
        base = _bench({"a_us": 1.0})
        cur = BenchFile(area="gemm", schema_version=SCHEMA_VERSION,
                        environment={},
                        records=[WorkloadRecord(name="other", area="gemm",
                                                metrics={"a_us": 1.0})])
        r = diff_bench(base, cur)
        assert not r.ok and r.missing_records == ["w"]
        assert r.new_records == ["other"]

    def test_noisy_never_gated(self):
        base = _bench({"a_us": 1.0})
        base.records[0].noisy = {"wall_us": 100.0}
        cur = _bench({"a_us": 1.0})
        cur.records[0].noisy = {"wall_us": 9999.0}
        assert diff_bench(base, cur).ok

    def test_area_mismatch_raises(self):
        with pytest.raises(ValueError, match="area mismatch"):
            diff_bench(_bench({}, area="gemm"), _bench({}, area="sparse"))

    def test_tolerance_resolution(self):
        tols = {"modeled": 0.05, "modeled_us": 0.01}
        assert resolve_tolerance("modeled_us", tols, 0.0) == 0.01  # exact
        assert resolve_tolerance("modeled_speedup", tols, 0.0) == 0.05
        assert resolve_tolerance("other", tols, 0.0) == 0.0
        assert resolve_tolerance("x", None, DEFAULT_REL_TOL) \
            == DEFAULT_REL_TOL

    def test_markdown_report_verdicts(self):
        ok = diff_bench(_bench({"a_us": 1.0}), _bench({"a_us": 1.0}))
        assert "**PASS**" in markdown_report([ok])
        bad = diff_bench(_bench({"a_us": 1.0}), _bench({"a_us": 2.0}))
        text = markdown_report([bad])
        assert "**FAIL**" in text and "a_us" in text


def test_plan_provenance_json_safe():
    import json
    plan = plan_gemm(*W1, "bfloat16")
    json.dumps(plan_provenance(plan))  # must not raise
