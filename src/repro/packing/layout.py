"""Packed-operand layout metadata and the :class:`PackedOperand` pytree.

The paper's third pillar — "efficient data packing with on-the-fly
transposition" — packs operand blocks into micro-kernel-native layouts
*once*, so the GEMM inner loop reads contiguous, transpose-resolved tiles.
This module defines the TPU form of that layout:

    logical weight  w[k, n]   (or w[n, k] under ``trans_w``)
        │  pack (repro.packing.pack): tile, pad edges with ZEROS,
        │  resolve the transpose, optionally per-tile int8 quantize
        ▼
    payload[nkb, nnb, tk, bn]          (grouped: [g, nkb, nnb, tk, bn])
    scales [nkb, nnb] f32 (quantized codecs)   (grouped: [g, nkb, nnb])

``tk`` is the PHYSICAL tile row count: ``bk`` for byte-or-wider payloads,
``ceil(bk/2)`` for int4 (two K-adjacent nibbles share a byte — see
``core.codecs`` for the codec table).

Every (bk, bn) tile is **contiguous in HBM** and sits exactly where the
kernel's (kk, j) grid step needs it, so the pack-aware MPGEMM path
(``kernels/mpgemm.py::mpgemm_pallas(a, packed)``) reads it with an
*identity* BlockSpec index map — no strided DMA, no on-the-fly
transposition, no per-call dequant/cast materialization.

:class:`PackedLayout` is the static (hashable) description; it travels as
pytree aux data, so :class:`PackedOperand` can sit inside model parameter
trees, be sliced by ``lax.scan`` over stacked layers (the payload simply
carries a leading layer axis), and cross jit boundaries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.codecs import dtype_bits, get_codec, storage_dtype


def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PackedLayout:
    """Static description of one packed operand (pytree aux data).

    ``k``/``n`` are the LOGICAL GEMM dims (contraction x output columns) —
    the transpose of a ``trans_w`` source is already resolved, so consumers
    never see the storage orientation.  ``dtype`` is the payload dtype —
    either a plain float dtype or a quantized codec from
    ``core.codecs.CODECS`` (``int8`` / ``int4`` / ``fp8e4m3``, all of
    which imply per-tile scales); ``orig_dtype`` is the source array's
    dtype (the unpack target for quantized payloads).  ``g`` > 1 marks a
    grouped operand (MoE experts / batched weights).

    ``bits_per_element`` is the LOGICAL storage width of one weight
    element — 4 for int4 (two nibbles per payload byte), 8 for int8/fp8,
    ``itemsize * 8`` for float payloads.  It is derived from ``dtype``
    when left at the 0 sentinel, so layouts serialized before the field
    existed round-trip unchanged.
    """

    k: int
    n: int
    bk: int
    bn: int
    dtype: str
    orig_dtype: str
    trans_w: bool = False
    g: int = 1
    bits_per_element: int = 0

    def __post_init__(self):
        if self.bits_per_element == 0:
            object.__setattr__(self, "bits_per_element",
                               dtype_bits(self.dtype))

    @property
    def nkb(self) -> int:
        return _cdiv(self.k, self.bk)

    @property
    def nnb(self) -> int:
        return _cdiv(self.n, self.bn)

    @property
    def codec(self):
        """The :class:`~repro.core.codecs.PayloadCodec`, or None (float)."""
        return get_codec(self.dtype)

    @property
    def per_tile_scales(self) -> bool:
        return self.codec is not None

    @property
    def storage_dtype(self) -> jnp.dtype:
        """jnp dtype of the payload array (int8 bytes for int4 nibbles,
        float8_e4m3fn or emulated uint8 for fp8e4m3)."""
        return storage_dtype(self.dtype)

    @property
    def kernel_native(self) -> bool:
        """True when the Pallas kernel path can decode this payload
        in-register (False only for bit-emulated fp8 installs)."""
        codec = self.codec
        return codec is None or codec.kernel_native

    @property
    def payload_tile(self) -> Tuple[int, int]:
        """PHYSICAL payload-array dims of one (bk, bn) logical tile —
        sub-byte codecs pack along K, so int4 stores (ceil(bk/2), bn)
        bytes per tile."""
        codec = self.codec
        rows = codec.payload_rows(self.bk) if codec is not None else self.bk
        return (rows, self.bn)

    @property
    def payload_shape(self) -> Tuple[int, ...]:
        base = (self.nkb, self.nnb) + self.payload_tile
        return (self.g,) + base if self.g != 1 else base

    @property
    def scales_shape(self) -> Optional[Tuple[int, ...]]:
        if not self.per_tile_scales:
            return None
        base = (self.nkb, self.nnb)
        return (self.g,) + base if self.g != 1 else base

    @property
    def tag(self) -> str:
        """Plan-cache layout tag (tuning/plan_cache.py::make_key(layout=)).

        Identifies the packed-B access pattern so packed and unpacked
        tunings never collide: the packed kernel's B-side DMA behavior
        depends only on (bk, bn, payload dtype), never on the resolved-away
        source transpose.
        """
        return f"packB{self.bk}x{self.bn}{self.dtype}"

    def describe(self) -> str:
        shape = f"{self.k}x{self.n}"
        if self.g != 1:
            shape = f"{self.g}x{shape}"
        t = "ᵀ" if self.trans_w else ""
        return (f"PackedLayout[{shape}{t} {self.orig_dtype}->{self.dtype} "
                f"tiles=({self.bk},{self.bn})x({self.nkb},{self.nnb})]")


class PackedOperand:
    """A pre-packed GEMM operand: payload + optional per-tile scales + layout.

    Registered as a pytree (payload/scales are children, layout is aux), so
    it flows through jit, scan (stacked layers: payload gets an extra
    leading axis that scan slices away), and optimizer/param trees.  The
    consuming ops (``mp_dot`` / ``mp_dot_grouped`` / ``mpgemm_pallas``)
    dispatch on the type.
    """

    __slots__ = ("payload", "scales", "layout")

    def __init__(self, payload, scales, layout: PackedLayout):
        self.payload = payload
        self.scales = scales
        self.layout = layout

    # -- conveniences --------------------------------------------------------

    @property
    def shape(self) -> Tuple[int, ...]:
        """The LOGICAL (transpose-resolved) operand shape: (k, n) / (g, k, n)."""
        base = (self.layout.k, self.layout.n)
        return (self.layout.g,) + base if self.layout.g != 1 else base

    @property
    def dtype(self):
        """jnp dtype of the payload ARRAY (codec storage dtype — int4
        nibbles live in int8 bytes; the logical format is
        ``layout.dtype`` / ``layout.bits_per_element``)."""
        return self.layout.storage_dtype

    @property
    def nbytes(self) -> int:
        total = self.payload.size * self.payload.dtype.itemsize
        if self.scales is not None:
            total += self.scales.size * self.scales.dtype.itemsize
        return total

    def astype(self, dtype) -> "PackedOperand":
        """Payload cast for float payloads (no-op when dtypes already match).

        Packing with the policy's compute dtype avoids this; the cast exists
        so a mismatched payload stays *correct* (it costs one materialized
        copy per call — exactly what packing is meant to avoid).
        """
        dtype = jnp.dtype(dtype)
        if self.layout.per_tile_scales or self.payload.dtype == dtype:
            return self
        layout = dataclasses.replace(self.layout, dtype=str(dtype),
                                     bits_per_element=0)
        return PackedOperand(self.payload.astype(dtype), None, layout)

    def __repr__(self) -> str:
        return self.layout.describe().replace("PackedLayout", "PackedOperand")


def _flatten(p: PackedOperand):
    return (p.payload, p.scales), p.layout


def _unflatten(layout: PackedLayout, children) -> PackedOperand:
    payload, scales = children
    return PackedOperand(payload, scales, layout)


jax.tree_util.register_pytree_node(PackedOperand, _flatten, _unflatten)


def is_packed(w) -> bool:
    return isinstance(w, PackedOperand)
