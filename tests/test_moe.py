"""MoE dispatch: gather-based routing must equal the dense reference when
capacity is ample; capacity drops degrade gracefully; aux losses sane."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.models.blocks import init_moe, moe_mlp


def _dense_moe_ref(params, x, cfg):
    """Reference: every expert computes every token; combine by top-k gate."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    logits = (x.astype(jnp.float32).reshape(-1, d)
              @ params["router"].astype(jnp.float32)).reshape(b, s, e)
    gates = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / topw.sum(-1, keepdims=True)
    xc = x.astype(jnp.bfloat16).reshape(-1, d)
    ys = []
    for ei in range(e):   # per-expert 2-D dots (CPU thunk compatible)
        hg = jax.lax.dot(xc, params["w_gate"][ei].astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        hu = jax.lax.dot(xc, params["w_up"][ei].astype(jnp.bfloat16),
                         preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(hg) * hu).astype(jnp.bfloat16)
        ys.append(jax.lax.dot(hh, params["w_down"][ei].astype(jnp.bfloat16),
                              preferred_element_type=jnp.float32))
    y = jnp.stack(ys, 0).reshape(e, b, s, d)                 # (e,b,s,d)
    sel = jnp.stack([jnp.take_along_axis(
        y.transpose(1, 2, 0, 3), topi[..., j:j + 1, None], axis=2)[:, :, 0]
        for j in range(k)], axis=2)                          # (b,s,k,d)
    out = jnp.einsum("bskd,bsk->bsd", sel.astype(jnp.float32),
                     topw.astype(jnp.float32))
    return out


def test_moe_matches_dense_reference(rng):
    cfg = cb.get("granite-moe-1b-a400m", smoke=True)
    params = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), "float32") \
        .astype(jnp.bfloat16)
    out, aux = moe_mlp(params, x, cfg, "bf16", capacity_factor=8.0)
    ref = _dense_moe_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
    assert jnp.isfinite(aux) and float(aux) > 0


def test_moe_capacity_drops_are_partial(rng):
    """With tiny capacity, output degrades but stays finite and nonzero."""
    cfg = cb.get("mixtral-8x22b", smoke=True)
    params = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((1, 64, cfg.d_model)), "float32") \
        .astype(jnp.bfloat16)
    full, _ = moe_mlp(params, x, cfg, "bf16", capacity_factor=8.0)
    tight, _ = moe_mlp(params, x, cfg, "bf16", capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(tight)))
    # some tokens dropped -> outputs differ
    assert float(jnp.max(jnp.abs(full - tight))) > 0


def test_moe_grads_flow_to_all_parts(rng):
    cfg = cb.get("granite-moe-1b-a400m", smoke=True)
    params = init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), "float32") \
        .astype(jnp.bfloat16)

    def loss(p):
        out, aux = moe_mlp(p, x, cfg, "bf16")
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, f"no grad to {name}"
