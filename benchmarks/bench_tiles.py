"""Paper Fig. 2 analogue: accumulator residency vs throughput.

SME: throughput scales with the number of ZA tiles accumulating.  TPU: the
analogue is keeping the output tile resident in VMEM across the whole K
loop (K-innermost revisiting grid) vs spilling/reloading it per K step
(K-outermost).  We report the modeled HBM traffic ratio — the structural
equivalent of the paper's 4-tiles-vs-1 throughput gap — plus interpret-mode
equivalence of both schedules (correctness)."""
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, modeled_time_s, record
from repro.core.blocking import modeled_traffic_bytes, plan_gemm


def run():
    for (m, n, k) in [(4096, 4096, 7168), (128, 24576, 1536)]:
        plan = plan_gemm(m, n, k, "float32")
        resident = plan.hbm_bytes
        # K-outermost: C block spilled+reloaded every K step (no resident acc)
        ksteps = -(-k // plan.bk)
        spilled = resident + 2 * m * n * 4 * (ksteps - 1)
        ratio = spilled / resident
        t_res = modeled_time_s(plan.flops, resident, "float32")
        t_spill = modeled_time_s(plan.flops, spilled, "float32")
        emit(f"tiles_residency_{m}x{n}x{k}", 0.0,
             f"traffic_ratio_spill_vs_resident={ratio:.2f};"
             f"modeled_speedup={t_spill/t_res:.2f};ksteps={ksteps}")
        record(f"tiles_residency_{m}x{n}x{k}", "gemm",
               workload={"m": m, "n": n, "k": k, "dtype": "float32"},
               metrics={"resident_hbm_bytes": resident,
                        "spilled_hbm_bytes": spilled,
                        "modeled_speedup": t_spill / t_res,
                        "grid_steps_k": ksteps})


if __name__ == "__main__":
    run()
