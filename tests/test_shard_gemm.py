"""Sharded MPGEMM (distributed/shard_gemm.py): parity against the
single-device mp_dot/mp_dot_grouped oracle across mesh sizes and operand
encodings, operand-splitting error contracts, and the mesh namespace the
plan cache keys per-shard tunings under.

Mesh-backed tests skip below the needed device count — the CI multidevice
job runs the suite with REPRO_FORCE_HOST_DEVICES=8 (tests/conftest.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.distributed import (
    mesh_axis_size, mesh_plan_tag, mp_dot_grouped_sharded, mp_dot_sharded,
    shard_operand,
)
from repro.launch.mesh import make_tp_mesh
from repro.packing.pack import pack_operand
from repro.sparse.sparsify import sparsify_magnitude
from repro.tuning import current_mesh_namespace, mesh_namespace
from repro.tuning.plan_cache import make_key

# Paper Table III row 6 scaled to test size: decode M, K-major reduction.
M, N, K = 32, 128, 256


def _sizes(limit=8):
    return [p for p in (1, 2, 4, 8)
            if p <= min(limit, jax.device_count())]


def _need(p):
    return pytest.mark.skipif(
        jax.device_count() < p,
        reason=f"needs {p} devices (REPRO_FORCE_HOST_DEVICES=8)")


@pytest.fixture(scope="module")
def operands():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.standard_normal((M, K)), jnp.float32)
    b = jnp.asarray(r.standard_normal((K, N)), jnp.float32)
    bias = jnp.asarray(r.standard_normal((N,)), jnp.float32)
    return x, b, bias


# ---------------------------- dense parity -----------------------------------

@_need(2)
@pytest.mark.parametrize("partition", ["column", "row", "gather"])
@pytest.mark.parametrize("overlap", ["ring", "blocking"])
def test_dense_parity_all_partitions(operands, partition, overlap):
    x, b, bias = operands
    want = mp_dot(x, b, bias, policy="fp32", backend="xla")
    for p in _sizes():
        got = mp_dot_sharded(x, b, bias, mesh=make_tp_mesh(p),
                             partition=partition, overlap=overlap,
                             policy="fp32", backend="xla")
        assert got.shape == want.shape and got.dtype == want.dtype
        err = float(jnp.max(jnp.abs(got - want)))
        # row reassociates the K sum across ring chunks -> fp32 rounding
        assert err < 1e-3, f"p={p} {partition}/{overlap}: err={err}"


@_need(2)
def test_dense_parity_bf16_policy_and_no_bias(operands):
    x, b, _ = operands
    want = mp_dot(x, b, policy="bf16", backend="xla")
    for p in _sizes(4):
        got = mp_dot_sharded(x, b, mesh=make_tp_mesh(p), partition="row",
                             policy="bf16", backend="xla")
        assert got.dtype == want.dtype == jnp.bfloat16
        err = float(jnp.max(jnp.abs(
            got.astype(jnp.float32) - want.astype(jnp.float32))))
        assert err < 0.2, f"p={p}: err={err}"


@_need(4)
def test_dense_parity_paper_row_kernel_backend():
    # A real paper shape (row 6 decode, M=64 N=7168 K=2048) on the
    # interpret-mode kernel path: the per-shard mp_dot goes through the
    # pallas MPGEMM kernel, not the jnp fallback.
    r = np.random.default_rng(1)
    m, n, k = 64, 7168 // 16, 2048 // 4          # scaled: CI-sized, P | all
    x = jnp.asarray(r.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(r.standard_normal((k, n)), jnp.float32)
    want = mp_dot(x, b, policy="fp32", backend="interpret")
    got = mp_dot_sharded(x, b, mesh=make_tp_mesh(4), partition="column",
                         policy="fp32", backend="interpret")
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < 1e-3, f"kernel-path column parity: err={err}"


# ------------------------ packed / sparse parity -----------------------------

@_need(2)
def test_packed_column_parity(operands):
    x, b, bias = operands
    pk = pack_operand(b, (32, 16))
    want = mp_dot(x, pk, bias, policy="fp32")
    for p in _sizes(4):
        got = mp_dot_sharded(x, pk, bias, mesh=make_tp_mesh(p),
                             policy="fp32")
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-3, f"p={p} packed: err={err}"


@_need(2)
def test_sparse_column_parity(operands):
    x, b, bias = operands
    sp = sparsify_magnitude(b, (32, 16), density=0.5)
    want = mp_dot(x, sp, bias, policy="fp32")
    for p in _sizes(4):
        got = mp_dot_sharded(x, sp, bias, mesh=make_tp_mesh(p),
                             policy="fp32")
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-3, f"p={p} sparse: err={err}"


# ------------------------ expert-parallel grouped ----------------------------

@_need(2)
def test_grouped_expert_parallel_parity_ragged():
    r = np.random.default_rng(2)
    g, m, k, n = 8, 16, 64, 48
    x = jnp.asarray(r.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(r.standard_normal((g, k, n)), jnp.float32)
    # ragged: full, partial, and EMPTY expert batches
    gs = jnp.asarray([16, 7, 0, 12, 16, 1, 0, 9], jnp.int32)
    want = mp_dot_grouped(x, b, group_sizes=gs, policy="fp32",
                          backend="xla")
    for p in [q for q in _sizes() if g % q == 0]:
        got = mp_dot_grouped_sharded(x, b, mesh=make_tp_mesh(p),
                                     group_sizes=gs, policy="fp32",
                                     backend="xla")
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-3, f"p={p} expert-parallel: err={err}"
        # masked rows are exactly zero on every shard
        rows = np.arange(m)[None, :, None]
        np.testing.assert_array_equal(
            np.asarray(got) * (rows >= np.asarray(gs)[:, None, None]), 0.0)


@_need(2)
def test_grouped_packed_expert_parity():
    r = np.random.default_rng(3)
    g, m, k, n = 4, 8, 64, 32
    x = jnp.asarray(r.standard_normal((g, m, k)), jnp.float32)
    b = jnp.asarray(r.standard_normal((g, k, n)), jnp.float32)
    pk = pack_operand(b, (32, 16))
    want = mp_dot_grouped(x, pk, policy="fp32")
    for p in [q for q in _sizes(4) if g % q == 0]:
        got = mp_dot_grouped_sharded(x, pk, mesh=make_tp_mesh(p),
                                     policy="fp32")
        err = float(jnp.max(jnp.abs(got - want)))
        assert err < 1e-3, f"p={p} grouped packed: err={err}"


# --------------------------- shard_operand contracts -------------------------

def test_shard_operand_dense_and_errors(operands):
    _, b, _ = operands
    parts = shard_operand(b, 4)
    assert len(parts) == 4 and all(p.shape == (K, N // 4) for p in parts)
    np.testing.assert_array_equal(np.concatenate(
        [np.asarray(p) for p in parts], axis=1), np.asarray(b))
    assert shard_operand(b, 1) == (b,)
    with pytest.raises(ValueError, match="not divisible"):
        shard_operand(b, 3)
    with pytest.raises(ValueError, match="axis"):
        shard_operand(b, 2, axis="m")
    with pytest.raises(ValueError, match="shards"):
        shard_operand(b, 0)


def test_shard_operand_packed_tile_lattice(operands):
    _, b, _ = operands
    pk = pack_operand(b, (32, 16))
    parts = shard_operand(pk, 4)
    assert all(p.layout.n == N // 4 for p in parts)
    # shard boundary off the tile lattice: bn=16 doesn't divide N/8=16? it
    # does — force a misaligned case with a wider tile instead
    wide = pack_operand(b, (32, 64))
    with pytest.raises(ValueError, match="tile width"):
        shard_operand(wide, 4)                    # N/4 = 32 < bn = 64


def test_shard_operand_sparse_grouped_n_raises():
    r = np.random.default_rng(4)
    g, k, n = 2, 64, 64
    b = jnp.asarray(r.standard_normal((g, k, n)), jnp.float32)
    sp = sparsify_magnitude(b, (32, 16), density=0.5)
    with pytest.raises(ValueError, match="along G"):
        shard_operand(sp, 2, axis="n")
    parts = shard_operand(sp, 2, axis="g")        # G split is supported
    assert len(parts) == 2


# --------------------------- mesh plan namespace -----------------------------

def test_make_key_mesh_namespace_suffix():
    base = make_key(M, N, K, "float32")
    tagged = make_key(M, N, K, "float32", mesh="tp4[model]")
    assert tagged == base + "|mesh=tp4[model]"
    assert make_key(M, N, K, "float32", mesh="") == base
    # ambient namespace: make_key with mesh=None reads the context tag
    assert current_mesh_namespace() == ""
    with mesh_namespace("tp2[model]"):
        assert current_mesh_namespace() == "tp2[model]"
        assert make_key(M, N, K, "float32") == base + "|mesh=tp2[model]"
        with mesh_namespace("tp8[model]"):        # nesting restores
            assert make_key(M, N, K, "float32").endswith("tp8[model]")
        assert current_mesh_namespace() == "tp2[model]"
    assert make_key(M, N, K, "float32") == base


@_need(2)
def test_mesh_tag_matches_axis():
    mesh = make_tp_mesh(2)
    assert mesh_axis_size(mesh, "model") == 2
    assert mesh_plan_tag(mesh, "model") == "tp2[model]"
    mesh = make_tp_mesh(2, axis="tensor")
    assert mesh_plan_tag(mesh, "tensor") == "tp2[tensor]"
