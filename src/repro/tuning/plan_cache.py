"""Persistent GEMM plan cache — the read side of the closed planning loop.

The analytic planner (core/blocking.py) is open-loop: it predicts, it never
measures.  This module stores plans that *have* been measured (by
tuning/microbench.py) and serves them back to every GEMM in the framework:

    mp_dot / mpgemm_pallas
        └─ lookup_plan(...)      — hit  -> tuned GemmPlan (this module)
                                 — miss -> plan_gemm(...) analytic fallback

Keying.  A plan is valid for exactly one logical GEMM instance:
``(m, n, k, a_dtype, b_dtype, out_dtype, trans_a, trans_b, beta!=0, hw)``.
Transpose flags are part of the key because on-the-fly transposition changes
the stored-layout access pattern (and therefore the measured optimum) even
though the analytic model is transpose-blind.  The hardware name is part of
the key so a cache tuned on one TPU generation is never misapplied to
another.

Persistence.  JSON on disk, written atomically (tmp + rename).  The on-disk
schema is versioned; unknown versions are ignored rather than crashed on.
Process-global behavior is controlled by ``REPRO_PLAN_CACHE``:

    unset          — in-memory global cache (tune_gemm results are picked up
                     by later matmuls in the same process; nothing persists)
    <path>.json    — persistent cache at that path, loaded lazily
    "off" / "0"    — lookups disabled entirely (pure analytic planning)

See docs/autotuning.md for the end-to-end workflow.
"""
from __future__ import annotations

import contextlib
import json
import logging
import os
import re
import tempfile
import threading
from pathlib import Path
from typing import Dict, Optional

from repro.obs.registry import counter_inc

from repro.core.blocking import (
    GemmPlan, _resolve_dtypes, plan_from_dict, plan_to_dict,
)
from repro.core.constants import DEFAULT_HW, HardwareSpec

_SCHEMA_VERSION = 1

_OFF_VALUES = ("off", "0", "none", "disabled")

_log = logging.getLogger(__name__)

_GROUPED_KEY_RE = re.compile(r"^g\d+\|")


def key_namespace(key: str) -> str:
    """Coarse, bounded-cardinality namespace of a plan-cache key.

    Classifies by the structural key components (grouped prefix, layout /
    epilogue / sparsity / mesh suffixes) rather than their full tags, so
    the per-namespace metrics and the fallback log stay bounded no matter
    how many shapes flow through.  ``'default'`` is the plain dense 2-D
    GEMM namespace.
    """
    parts = []
    if _GROUPED_KEY_RE.match(key):
        parts.append("grouped")
    for marker, name in (("|lay=", "layout"), ("|ep=", "epilogue"),
                         ("|sp=", "sparse"), ("|mesh=", "mesh")):
        if marker in key:
            parts.append(name)
    return "+".join(parts) or "default"

# -- mesh namespace ----------------------------------------------------------
#
# Sharded GEMMs (distributed/shard_gemm.py) run the planner on the PER-DEVICE
# local (M, N, K) shard, inside shard_map.  A plan tuned for the local shard
# of a 4-way mesh is a different optimum than the single-device plan for the
# same local shape arrived at directly (the surrounding collective schedule
# changes the memory traffic), so mesh-sharded keys live in their own
# namespace: a ``|mesh=<tag>`` suffix.  The tag is ambient (thread-local)
# because the lookup happens deep inside the kernel launch path
# (``mpgemm_pallas_spec``) which has no mesh argument to thread through.

_mesh_ns = threading.local()


def current_mesh_namespace() -> str:
    """The ambient mesh namespace tag ('' == single-device)."""
    return getattr(_mesh_ns, "tag", "")


@contextlib.contextmanager
def mesh_namespace(tag: str):
    """Scope plan-cache keys to mesh namespace ``tag`` on this thread.

    ``distributed/shard_gemm.py`` wraps every sharded GEMM trace in this, so
    the trace-time :func:`lookup_plan` calls made by the kernel launch see
    per-shard shapes AND a per-mesh key namespace — tuned sharded plans never
    alias single-device ones.
    """
    prev = current_mesh_namespace()
    _mesh_ns.tag = str(tag)
    try:
        yield
    finally:
        _mesh_ns.tag = prev


@contextlib.contextmanager
def _file_lock(path: Path):
    """Advisory cross-process lock guarding read-merge-rename on ``path``.

    A sibling ``.lock`` file is flocked (never the data file itself — that
    gets atomically replaced, which would orphan the lock).  On platforms
    without fcntl the lock degrades to a no-op: saves stay atomic/torn-free,
    merely losing the concurrent-merge guarantee.
    """
    try:
        import fcntl
    except ImportError:  # pragma: no cover - non-POSIX
        yield
        return
    lock_path = path.with_name(path.name + ".lock")
    with open(lock_path, "w") as lf:
        fcntl.flock(lf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


def make_key(
    m: int,
    n: int,
    k: int,
    a_dtype,
    b_dtype=None,
    out_dtype=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    beta: float = 0.0,
    hw: HardwareSpec = DEFAULT_HW,
    g: int = 1,
    layout: str = "",
    epilogue: str = "",
    sparsity: str = "",
    mesh: Optional[str] = None,
) -> str:
    """Canonical cache key for one logical GEMM instance.

    Stable across processes and python versions (plain string, no hashing),
    so on-disk caches remain valid as long as the schema version holds.
    Grouped instances (``g > 1``) get a ``g…`` prefix; plain 2-D keys are
    byte-identical to the pre-grouped schema, so existing caches stay warm.

    ``layout`` tags a non-default operand layout (``repro.packing``'s
    ``PackedLayout.tag``): the packed-B kernel has a different measured
    optimum than the strided on-the-fly path, so packed and unpacked
    tunings must never collide.  Appended as a suffix only when set, so
    default (unpacked) keys stay byte-identical to the existing schema.

    ``epilogue`` tags a non-linear fused epilogue
    (``core/gemm_spec.py::EpilogueSpec.tag``, e.g. ``gated-silu``): fused
    epilogues stream extra (M, N) operands, which changes the measured
    optimum, so fused and unfused tunings must never collide either.  The
    linear family tags as ``""``, keeping pre-registry keys byte-stable.

    ``sparsity`` tags a tile-sparse B operand
    (``repro.sparse.TileSparseLayout.tag``): the sparse walk replaces the
    dense K grid with a stored-tile schedule, so its measured optimum is a
    different animal again — sparse and dense tunings must never collide,
    and neither must two different sparsity patterns.  Dense keys (the
    empty tag) stay byte-identical to the existing schema.

    ``mesh`` tags a sharded-GEMM instance (``distributed/shard_gemm.py``):
    the (m, n, k) in a sharded key are the PER-DEVICE local shard dims, and
    the surrounding collective schedule gives the same local shape a
    different measured optimum than a true single-device problem — so
    sharded and single-device tunings must never collide.  ``None`` (the
    default) reads the ambient :func:`mesh_namespace` on this thread, which
    makes every existing call site (tuner writes, kernel-launch reads)
    mesh-aware without threading a mesh argument through; pass ``""`` to
    opt out explicitly.  Un-namespaced keys stay byte-identical to the
    existing schema.
    """
    a_dtype, b_dtype, out_dtype, _ = _resolve_dtypes(a_dtype, b_dtype, out_dtype)
    if mesh is None:
        mesh = current_mesh_namespace()
    group = f"g{g}|" if g != 1 else ""
    lay = f"|lay={layout}" if layout else ""
    ep = f"|ep={epilogue}" if epilogue else ""
    sp = f"|sp={sparsity}" if sparsity else ""
    ns = f"|mesh={mesh}" if mesh else ""
    return (
        f"{group}m{m}n{n}k{k}"
        f"|a={a_dtype}|b={b_dtype}|out={out_dtype}"
        f"|ta={int(trans_a)}|tb={int(trans_b)}|beta={int(beta != 0.0)}"
        f"|hw={hw.name}{lay}{ep}{sp}{ns}"
    )


class PlanCache:
    """JSON-on-disk (or in-memory) map from GEMM key -> tuned :class:`GemmPlan`.

    Thread-safe.  ``path=None`` keeps the cache purely in memory — useful as
    the process-global default and in tests.

    Example (runnable on CPU)::

        >>> from repro.tuning import PlanCache, make_key
        >>> from repro.core.blocking import plan_gemm
        >>> cache = PlanCache("/tmp/plans.json")
        >>> key = make_key(256, 256, 256, "float32")
        >>> cache.put(key, plan_gemm(256, 256, 256, "float32"),
        ...           meta={"wall_us": 12.3})
        >>> cache.save()
        >>> PlanCache("/tmp/plans.json").get(key).bm
        256
    """

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = Path(path) if path is not None else None
        self._lock = threading.RLock()
        self._entries: Dict[str, dict] = {}
        self._loaded = False
        self._purge_on_save = False

    # -- persistence -------------------------------------------------------

    def _disk_entries(self) -> Dict[str, dict]:
        """Current on-disk entries; {} for missing/corrupt/foreign files."""
        if self.path is None or not self.path.exists():
            return {}
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, json.JSONDecodeError):
            return {}  # corrupt/unreadable cache == empty cache, never a crash
        if not isinstance(raw, dict) or raw.get("version") != _SCHEMA_VERSION:
            return {}
        entries = raw.get("entries")
        return dict(entries) if isinstance(entries, dict) else {}

    def _ensure_loaded(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        self._entries = self._disk_entries()

    def save(self) -> None:
        """Atomically persist to ``self.path`` (no-op for in-memory caches).

        Merges with entries other processes wrote since we loaded (ours win
        on key collision), so concurrent tuners sharing one path lose
        nothing — the atomic rename prevents torn files, the merge prevents
        lost updates.
        """
        if self.path is None:
            return
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self._lock, _file_lock(self.path):
            self._ensure_loaded()
            if self._purge_on_save:
                # clear() was called: this save is an intentional reset, so
                # do NOT resurrect concurrent writers' entries from disk.
                self._purge_on_save = False
            else:
                merged = dict(self._disk_entries())
                merged.update(self._entries)
                self._entries = merged
            payload = json.dumps(
                {"version": _SCHEMA_VERSION, "entries": self._entries},
                indent=1, sort_keys=True,
            )
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(payload)
                os.replace(tmp, self.path)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise

    # -- map interface -----------------------------------------------------

    def get(self, key: str) -> Optional[GemmPlan]:
        with self._lock:
            self._ensure_loaded()
            entry = self._entries.get(key)
            if entry is None:
                return None
            try:
                return plan_from_dict(entry["plan"])
            except (KeyError, TypeError):
                return None

    def get_meta(self, key: str) -> Optional[dict]:
        """Measurement metadata stored alongside the plan (wall_us, mode, …)."""
        with self._lock:
            self._ensure_loaded()
            entry = self._entries.get(key)
            return dict(entry.get("meta", {})) if entry else None

    def put(self, key: str, plan: GemmPlan, meta: Optional[dict] = None) -> None:
        with self._lock:
            self._ensure_loaded()
            self._entries[key] = {"plan": plan_to_dict(plan), "meta": meta or {}}

    def keys(self):
        with self._lock:
            self._ensure_loaded()
            return list(self._entries)

    def clear(self) -> None:
        """Drop all entries; the next :meth:`save` rewrites the file from
        scratch instead of merging disk state back in (cache invalidation)."""
        with self._lock:
            self._entries = {}
            self._loaded = True
            self._purge_on_save = True

    def __len__(self) -> int:
        with self._lock:
            self._ensure_loaded()
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            self._ensure_loaded()
            return key in self._entries


# -- process-global cache ----------------------------------------------------

_global_lock = threading.Lock()
_global_cache: Optional[PlanCache] = None
_global_configured = False


def _env_cache() -> Optional[PlanCache]:
    env = os.environ.get("REPRO_PLAN_CACHE", "").strip()
    if env.lower() in _OFF_VALUES:
        return None
    if env:
        return PlanCache(env)
    return PlanCache(None)  # in-memory process-global default


def get_plan_cache() -> Optional[PlanCache]:
    """The process-global cache every ``mp_dot`` consults (None == disabled)."""
    global _global_cache, _global_configured
    with _global_lock:
        if not _global_configured:
            _global_cache = _env_cache()
            _global_configured = True
        return _global_cache


def set_plan_cache(cache: Optional[PlanCache]) -> Optional[PlanCache]:
    """Install ``cache`` as the process-global cache; returns the previous one.

    ``None`` disables cached-plan lookup (pure analytic planning).
    """
    global _global_cache, _global_configured
    with _global_lock:
        prev = _global_cache if _global_configured else None
        _global_cache = cache
        _global_configured = True
    # A new cache is a new tuning world: drop memoized analytic fallbacks
    # so they can never shadow (or leak between) test-installed caches.
    clear_analytic_memo()
    return prev


# -- analytic-fallback memo + once-per-namespace logging ----------------------
#
# A tuned-cache miss falls back to the analytic planner (plan_gemm).  That
# used to be completely silent — an un-warmed production launch planned
# every layer analytically and nothing said so.  Now the kernel layer
# reports each fallback here: the plan is memoized under its full key (the
# key determines the analytic plan, so this is a pure cache — repeat
# lookups of the same instance become 'hit_analytic' instead of re-running
# the planner), the per-namespace counter increments, and the first
# fallback in each namespace logs a warning (mirroring
# ``kernels/ops.py::flash_attention_fallback_reason``'s once-per-process
# discipline).

_analytic_lock = threading.Lock()
_analytic_memo: Dict[str, GemmPlan] = {}
_fallback_logged_ns: set = set()


def cached_analytic(key: str) -> Optional[GemmPlan]:
    """A previously memoized analytic-fallback plan for ``key``, or None."""
    with _analytic_lock:
        return _analytic_memo.get(key)


def note_analytic_fallback(key: str, plan: GemmPlan) -> None:
    """Record one analytic-planner fallback for a tuned-cache miss.

    Counts ``plan_cache_analytic_fallback_total{namespace=...}``, warns
    once per process per key namespace, and memoizes the plan so repeat
    lookups of the same instance hit instead of silently re-falling-back.
    """
    ns = key_namespace(key)
    counter_inc("plan_cache_analytic_fallback_total",
                help="tuned-plan misses resolved by the analytic planner",
                namespace=ns)
    first = False
    with _analytic_lock:
        _analytic_memo[key] = plan
        if ns not in _fallback_logged_ns:
            _fallback_logged_ns.add(ns)
            first = True
    if first:
        _log.warning(
            "plan cache miss in namespace %r (key %s): falling back to the "
            "analytic planner. Tune this workload (repro.perf.sweep or "
            "tuning.microbench) to pin measured blocks; further %r "
            "fallbacks will be counted but not logged.", ns, key, ns)


def clear_analytic_memo() -> None:
    """Forget memoized analytic plans + per-namespace log dedup."""
    with _analytic_lock:
        _analytic_memo.clear()
        _fallback_logged_ns.clear()


def lookup_plan(
    m: int,
    n: int,
    k: int,
    a_dtype,
    b_dtype=None,
    out_dtype=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    beta: float = 0.0,
    hw: HardwareSpec = DEFAULT_HW,
    g: int = 1,
    layout: str = "",
    epilogue: str = "",
    sparsity: str = "",
    mesh: Optional[str] = None,
    analytic_memo: bool = False,
) -> Optional[GemmPlan]:
    """Tuned plan for this GEMM instance, or None (miss / cache disabled).

    This is the single read path behind the spec-driven kernel launch
    (``kernels/mpgemm.py::mpgemm_pallas_spec``), through which every
    ``mp_dot`` / ``mp_dot_grouped`` flows.  ``g > 1`` selects the
    grouped-instance namespace; ``layout`` the packed-operand namespace;
    ``epilogue`` the fused-epilogue namespace; ``sparsity`` the
    tile-sparse namespace; ``mesh`` (default: the ambient
    :func:`mesh_namespace`) the sharded-GEMM namespace (see
    :func:`make_key`).

    Every lookup lands in ``plan_cache_lookups_total{namespace, result}``
    with result ``hit_tuned`` / ``hit_analytic`` / ``miss`` /
    ``disabled``.  ``analytic_memo=True`` (the kernel launch path) also
    consults plans memoized by :func:`note_analytic_fallback`, so a
    repeated un-tuned instance hits the memo instead of re-running the
    analytic planner on every trace; direct callers (tests, tuning
    reports) keep the pure tuned-only semantics by default.
    """
    key = make_key(
        m, n, k, a_dtype, b_dtype, out_dtype,
        trans_a=trans_a, trans_b=trans_b, beta=beta, hw=hw, g=g,
        layout=layout, epilogue=epilogue, sparsity=sparsity, mesh=mesh,
    )
    ns = key_namespace(key)
    cache = get_plan_cache()
    if cache is None:
        _count_lookup(ns, "disabled")
        return None
    plan = cache.get(key)
    if plan is not None:
        _count_lookup(ns, "hit_tuned")
        return plan
    if analytic_memo:
        plan = cached_analytic(key)
        if plan is not None:
            _count_lookup(ns, "hit_analytic")
            return plan
    _count_lookup(ns, "miss")
    return None


def _count_lookup(namespace: str, result: str) -> None:
    counter_inc("plan_cache_lookups_total",
                help="plan-cache reads by namespace and outcome",
                namespace=namespace, result=result)
