"""The precision ladder on the paper's DeepSeek/LLaMA workloads.

What a narrower payload codec buys is WEIGHT-side HBM traffic — the term
that dominates the paper's skinny decode workloads (m = 64 rows stream a
k x n weight every call).  This benchmark prices each rung of the ladder
(int8 per-tile, int4 nibble-packed, fp8 e4m3 scaled) with the same
modeled-traffic accounting the planner optimizes:

  * ``weight_bytes``   — per-call B-side stream: payload (k*n at the
                         codec's bits-per-element) + per-tile f32 scales;
  * ``hbm_bytes``      — full modeled traffic of the revisiting grid
                         (``perf.metrics.gemm_bytes`` with the codec's
                         fractional byte width);
  * trace gates        — the int4 path must be ONE Pallas launch with
                         ZERO weight-sized dequant materializations
                         outside the kernel (the nibble decode rides the
                         accumulation loop), and the activation-quantized
                         ``quant_in`` GEMM must fuse quantize -> GEMM ->
                         dequant(+act) into ONE launch.

``--smoke`` runs workloads 1/13/19 (DeepSeek decode, DeepSeek prefill,
LLaMA decode) and hard-asserts the acceptance gates: int4 weight bytes
<= 0.55x int8 on every workload, and both launch-count gates.
"""
import argparse
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import PAPER_WORKLOADS, emit, record
from repro.core.blocking import plan_gemm
from repro.core.codecs import get_codec
from repro.core.gemm import mp_dot
from repro.obs import audit
from repro.packing import pack_operand
from repro.perf.metrics import gemm_bytes

# The ladder, narrowest payload last (int8 is the PR-8 baseline rung).
LADDER = ("int8", "fp8e4m3", "int4")

# Smoke rows: DeepSeek decode (1), DeepSeek prefill (13), LLaMA decode (19)
# — the hand-computed shapes tests/test_quant.py pins byte-for-byte.
SMOKE_WORKLOAD_IDS = (1, 13, 19)

# Acceptance gate: int4 per-call weight bytes vs int8 (payload exactly
# 0.5x; per-tile scale overhead must not eat the margin).
INT4_WEIGHT_RATIO_GATE = 0.55


def weight_stream_bytes(n: int, k: int, codec_name: str,
                        bk: int, bn: int) -> int:
    """Per-call B-side HBM bytes: nibble/byte payload + f32 tile scales.

    Matches ``PackedOperand.nbytes`` for a zero-padding-free shape:
    ``k*n`` elements at the codec's bits-per-element, plus one f32 scale
    per (bk, bn) tile.
    """
    codec = get_codec(codec_name)
    payload = (k * n * codec.bits) // 8
    tiles = math.ceil(k / bk) * math.ceil(n / bn)
    return payload + tiles * 4


def run(smoke: bool = False, rows=None):
    """Modeled weight/total traffic per codec on the paper workloads."""
    rows = rows if rows is not None else []
    work = [w for w in PAPER_WORKLOADS
            if not smoke or w[0] in SMOKE_WORKLOAD_IDS]
    for wid, m, n, k in work:
        per_codec = {}
        for codec in LADDER:
            # Each rung is priced at its own planner choice, exactly as
            # serving launches it (the payload dtype steers the lattice).
            plan = plan_gemm(m, n, k, "bfloat16", codec)
            wb = weight_stream_bytes(n, k, codec, plan.bk, plan.bn)
            total = gemm_bytes(m, n, k, plan.bm, plan.bn,
                               a_dtype="bfloat16", b_dtype=codec,
                               out_dtype="bfloat16")
            per_codec[codec] = (wb, total)
        ratio = per_codec["int4"][0] / per_codec["int8"][0]
        rows.append(dict(name=f"workload_{wid:02d}", m=m, n=n, k=k,
                         per_codec=per_codec, int4_weight_ratio=ratio))
        emit(f"quant_{wid:02d}_ladder", 0.0,
             ";".join(f"{c}_weight_bytes={per_codec[c][0]}"
                      for c in LADDER)
             + f";int4_over_int8={ratio:.3f}")
        record(f"quant_{wid:02d}_ladder", "quant", kind="model",
               workload={"paper_workload": wid, "m": m, "n": n, "k": k},
               metrics={
                   **{f"weight_bytes_{c}": float(per_codec[c][0])
                      for c in LADDER},
                   **{f"hbm_bytes_{c}": float(per_codec[c][1])
                      for c in LADDER},
                   "int4_weight_ratio": ratio,
               })
    return rows


def _dequant_materializations(jaxpr, weight_elems: int) -> int:
    """Weight-sized dequant intermediates OUTSIDE Pallas kernels.

    A separate dequant launch shows up as a (k*n)-element convert/scale
    output in the surrounding jaxpr; the fused path keeps the nibble
    decode inside the kernel body, which the audit walk deliberately
    skips (``skip_pallas_bodies=True``).
    """
    return audit.weight_sized_intermediates(
        jaxpr, weight_elems, prims=audit.DEQUANT_PRIMS,
        skip_pallas_bodies=True)[0]


def run_trace_gate(assert_gate: bool = True):
    """Launch-count gates from the traced jaxpr (exact, timing-free)."""
    m, n, k = 32, 256, 256
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    x = jax.ShapeDtypeStruct((m, k), jnp.bfloat16)
    results = {}
    for codec in ("int4", "fp8e4m3"):
        plan = plan_gemm(m, n, k, "bfloat16", codec)
        packed = pack_operand(w, plan, dtype=codec, backend="xla")

        def plain_fn(x, p):
            return mp_dot(x, p, policy="bf16", backend="interpret")

        def fused_fn(x, p):
            return mp_dot(x, p, policy="bf16", backend="interpret",
                          quant_in=True, activation="silu")

        jx = audit.trace(plain_fn, x, packed)
        results[codec] = dict(
            launches=audit.count_pallas(jx),
            dequants=_dequant_materializations(jx, k * n),
            launches_quant_in=audit.count_pallas(
                audit.trace(fused_fn, x, packed)),
        )
        emit(f"quant_trace_gate_{codec}", 0.0,
             f"pallas_launches={results[codec]['launches']};"
             f"dequant_materializations={results[codec]['dequants']};"
             f"quant_in_launches={results[codec]['launches_quant_in']}")
        record(f"quant_trace_gate_{codec}", "quant", kind="trace",
               workload={"m": m, "n": n, "k": k, "codec": codec},
               metrics={"pallas_launches": float(results[codec]["launches"]),
                        "dequant_materializations":
                            float(results[codec]["dequants"]),
                        "quant_in_pallas_launches":
                            float(results[codec]["launches_quant_in"])})
    if assert_gate:
        for codec, r in results.items():
            if r["launches"] != 1:
                raise SystemExit(
                    f"{codec} packed GEMM traced {r['launches']} Pallas "
                    f"launches, want exactly 1 (decode must ride the "
                    f"accumulation)")
            if r["dequants"] != 0:
                raise SystemExit(
                    f"{codec} path materializes {r['dequants']} "
                    f"weight-sized dequant intermediates outside the "
                    f"kernel, want 0")
            if r["launches_quant_in"] != 1:
                raise SystemExit(
                    f"quant_in {codec} GEMM traced "
                    f"{r['launches_quant_in']} Pallas launches — "
                    f"quantize/GEMM/dequant must be ONE fused launch")
    return results


def check_gate(rows) -> None:
    bad = [r for r in rows
           if r["int4_weight_ratio"] > INT4_WEIGHT_RATIO_GATE]
    if bad:
        raise SystemExit(
            f"int4 weight bytes exceed {INT4_WEIGHT_RATIO_GATE}x int8 on: "
            + ", ".join(f"{r['name']} ({r['int4_weight_ratio']:.3f})"
                        for r in bad))
    print(f"quant gate OK: {len(rows)} workloads, int4 weight bytes "
          f"<= {INT4_WEIGHT_RATIO_GATE}x int8 on all")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="workloads 1/13/19 + hard assertions (CI gate)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    check_gate(rows)
    run_trace_gate(assert_gate=True)
    print("quant trace gate OK: one launch per packed GEMM, zero "
          "out-of-kernel dequant, fused quant_in single-launch")


if __name__ == "__main__":
    main()
