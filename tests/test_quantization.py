"""Static int8 weight quantization (core/quantization.py) + mp_dot
integration, plus the numeric edge cases: all-zero tensors/tiles (the
scale-0 guard), subnormal inputs, and round-trips at tile boundaries."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.core.gemm import mp_dot
from repro.core.quantization import (
    dequantize_tensor, is_quantized, quantize_params, quantize_tensor,
)
from repro.models.transformer import build_model
from repro.packing import pack_operand, unpack_operand


def test_quantize_roundtrip(rng):
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    wd = quantize_tensor(w)
    assert wd["q"].dtype == jnp.int8
    back = dequantize_tensor(wd, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w),
                               atol=float(wd["scale"]) * 0.51)


def test_mp_dot_consumes_quantized(rng):
    x = jnp.asarray(rng.standard_normal((8, 64)), "bfloat16")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    y_ref = mp_dot(x, w, policy="bf16")
    y_q = mp_dot(x, quantize_tensor(w), policy="bf16")
    err = float(jnp.max(jnp.abs(y_q.astype(jnp.float32)
                                - y_ref.astype(jnp.float32))))
    assert err < 0.1 * float(jnp.max(jnp.abs(y_ref.astype(jnp.float32)))) + 0.1


def test_quantize_params_selective():
    cfg = cb.get("starcoder2-3b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    pq = quantize_params(params)
    # attn weights quantized; norms and embeddings untouched
    sample = jax.tree_util.tree_map(lambda x: x, pq["stack"][0])
    assert is_quantized(sample["attn"]["wq"])
    assert not is_quantized(sample["ln1"]["scale"]) \
        and sample["ln1"]["scale"].dtype != jnp.int8
    assert pq["embed"].dtype == params["embed"].dtype


def test_quantized_model_generates(rng):
    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    pq = quantize_params(jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a,
        params))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)), "int32")
    l_ref, c_ref = model.prefill(params, {"tokens": toks[:, :16]}, max_len=24)
    l_q, c_q = model.prefill(pq, {"tokens": toks[:, :16]}, max_len=24)
    a, b = [np.asarray(x[:, :cfg.vocab], np.float32) for x in (l_ref, l_q)]
    # weight-only int8 keeps top-1 on the vast majority of rows
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
    d_q, _ = model.decode_step(pq, toks[:, 16:17], c_q, jnp.int32(16))
    assert bool(jnp.all(jnp.isfinite(d_q[:, :cfg.vocab])))


# --- numeric edge cases -------------------------------------------------------

def test_all_zero_tensor_scale_guard():
    """amax == 0 must never produce a 0 (or NaN-generating) scale: the
    1e-8 floor keeps dequant finite and exactly zero."""
    wd = quantize_tensor(jnp.zeros((32, 16), jnp.float32))
    assert float(wd["scale"]) > 0
    back = dequantize_tensor(wd, jnp.float32)
    assert np.all(np.asarray(back) == 0)
    assert bool(jnp.all(jnp.isfinite(back)))


def test_all_zero_tile_per_tile_scale_guard(rng):
    """Per-tile quantization (packing + sparse payloads) hits the same
    guard PER TILE: a weight with one all-zero tile must quantize with
    finite positive scales everywhere and dequantize that tile to zero."""
    w = np.asarray(rng.standard_normal((32, 16)), np.float32)
    w[0:16, 0:8] = 0.0
    for backend in ("xla", "interpret"):
        p = pack_operand(jnp.asarray(w), (16, 8), dtype="int8",
                         backend=backend)
        scales = np.asarray(p.scales)
        assert np.all(scales > 0) and np.all(np.isfinite(scales))
        u = np.asarray(unpack_operand(p, backend=backend))
        assert np.all(u[0:16, 0:8] == 0)
        assert np.all(np.isfinite(u))
    # the tile-sparse int8 payload path shares the guard
    from repro.sparse import densify_operand, sparsify_with_mask
    sp = sparsify_with_mask(jnp.asarray(w), (16, 8),
                            np.ones((2, 2), bool), dtype="int8")
    assert np.all(np.asarray(sp.scales) > 0)
    d = np.asarray(densify_operand(sp))
    assert np.all(d[0:16, 0:8] == 0) and np.all(np.isfinite(d))


def test_subnormal_inputs_quantize_to_zero_not_nan():
    """Subnormal weights sit below the scale floor: they must flush to
    zero through the round-trip (never inf/NaN from a denormal divide)."""
    tiny = np.full((16, 16), 1e-42, np.float32)   # f32 subnormal range
    wd = quantize_tensor(jnp.asarray(tiny))
    assert bool(jnp.all(jnp.isfinite(wd["scale"])))
    back = np.asarray(dequantize_tensor(wd, jnp.float32))
    assert np.all(np.isfinite(back)) and np.abs(back).max() <= 1e-8
    # mp_dot on a subnormal-weight dict stays finite
    x = jnp.ones((4, 16), jnp.bfloat16)
    y = mp_dot(x, wd, policy="bf16")
    assert bool(jnp.all(jnp.isfinite(y)))


def test_int8_roundtrip_at_tile_boundaries(rng):
    """Non-multiple (k, n) shapes: the valid region of every EDGE tile must
    round-trip within its own tile's quantization step, and the pad region
    must stay exactly zero (the no-B-predication contract)."""
    k, n, bk, bn = 33, 17, 16, 8
    w = np.asarray(rng.standard_normal((k, n)), np.float32)
    p = pack_operand(jnp.asarray(w), (bk, bn), dtype="int8", backend="xla")
    u = np.asarray(unpack_operand(p, backend="xla"), np.float32)
    scales = np.asarray(p.scales)
    for ti in range(p.layout.nkb):
        for tj in range(p.layout.nnb):
            r0, c0 = ti * bk, tj * bn
            blk = slice(r0, min(r0 + bk, k)), slice(c0, min(c0 + bn, n))
            step = scales[ti, tj] * 0.51
            assert np.abs(u[blk] - w[blk]).max() <= step
    tiles = np.asarray(p.payload)
    assert np.all(tiles[-1, :, k % bk:, :] == 0)
    assert np.all(tiles[:, -1, :, n % bn:] == 0)
