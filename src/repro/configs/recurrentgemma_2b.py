"""recurrentgemma-2b — Griffin: RG-LRU recurrent blocks + local attention,
pattern (recurrent, recurrent, local-attn).  MQA kv=1, head_dim 256.
[arXiv:2402.19427; hf]"""
from repro.configs.base import ArchConfig

_PATTERN = tuple(
    ["rglru", "rglru", "attn_local"] * 8 + ["rglru", "rglru"]
)  # 26 layers

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000,
    head_dim=256, block_pattern=_PATTERN,
    local_attn_window=2048, lru_width=2560, conv_width=4,
    rope_theta=10000.0, mlp="swiglu", norm="rms",
    tie_embeddings=True,
    source="arXiv:2402.19427",
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke", family="hybrid",
    n_layers=5, d_model=128, n_heads=2, n_kv_heads=1,
    d_ff=256, vocab=512,
    head_dim=64, block_pattern=("rglru", "rglru", "attn_local", "rglru", "rglru"),
    local_attn_window=64, lru_width=128, conv_width=4,
    mlp="swiglu", norm="rms", tie_embeddings=True,
)
