"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_ACTIVATIONS = {
    None: lambda x: x,
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
}


def mpgemm_ref(
    a,
    b,
    c=None,
    *,
    trans_a: bool = False,
    trans_b: bool = False,
    alpha: float = 1.0,
    beta: float = 0.0,
    bias=None,
    scale=None,
    activation: Optional[str] = None,
    out_dtype=None,
    acc_dtype=None,
):
    """Oracle for kernels.mpgemm.mpgemm_pallas."""
    if acc_dtype is None:
        acc_dtype = jnp.int32 if jnp.dtype(a.dtype).kind == "i" else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if jnp.dtype(a.dtype).kind == "i" else a.dtype
    lhs = a.T if trans_a else a
    rhs = b.T if trans_b else b
    acc = jax.lax.dot(lhs, rhs, preferred_element_type=acc_dtype)
    if scale is not None:
        acc = acc.astype(jnp.float32) * jnp.asarray(scale, jnp.float32)
    if alpha != 1.0:
        acc = acc * jnp.asarray(alpha, acc.dtype)
    if bias is not None:
        acc = acc + bias.reshape(1, -1).astype(acc.dtype)
    acc = _ACTIVATIONS[activation](acc)
    if beta != 0.0:
        acc = acc + jnp.asarray(beta, acc.dtype) * c.astype(acc.dtype)
    return acc.astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, scale=None, bias=None):
    """Oracle for kernels.flash_attention (q,k,v: [T, H] per head, or batched)."""
    sm_scale = scale if scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("...qd,...kd->...qk", q, k).astype(jnp.float32) * sm_scale
    tq, tk = q.shape[-2], k.shape[-2]
    qi = jnp.arange(tq)[:, None] + (tk - tq)  # right-aligned for decode
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    if bias is not None:
        logits = logits + bias
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", probs, v.astype(probs.dtype)).astype(q.dtype)
