"""rwkv6-1.6b ("Finch") — attention-free, data-dependent decay linear
recurrence. [arXiv:2404.05892; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,  # heads = d/rwkv_head_dim
    d_ff=7168, vocab=65536,
    rwkv_head_dim=64, pos_embed="none",
    mlp="swiglu", norm="rms",
    source="arXiv:2404.05892",
)

SMOKE = ArchConfig(
    name="rwkv6-smoke", family="ssm",
    n_layers=2, d_model=128, n_heads=2, n_kv_heads=2,
    d_ff=256, vocab=512, rwkv_head_dim=64, pos_embed="none",
    mlp="swiglu", norm="rms",
)
