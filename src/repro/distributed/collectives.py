"""Distributed-optimization building blocks:

* int8 gradient compression with error feedback (for cross-pod gradient
  all-reduce: 4x wire-bytes reduction on the 'pod' axis, where links are
  slowest) — pure JAX, shard_map-compatible.
* hierarchical all-reduce helper (reduce-scatter in-pod, all-reduce
  cross-pod on shards, all-gather in-pod) expressed with jax.lax
  collectives for use under shard_map.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_grad_int8(g: jax.Array, error: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization with error feedback.

    Returns (q, scale, new_error).  The residual (g + error - dequant(q))
    is carried to the next step, so compression bias does not accumulate
    (Seide et al. / 1-bit SGD lineage, as used by modern grad-compression
    stacks)."""
    g32 = g.astype(jnp.float32) + error
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_error = g32 - q.astype(jnp.float32) * scale
    return q, scale, new_error


def dequantize_grad(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, error: jax.Array, axis_name: str):
    """int8-compressed gradient all-reduce over ``axis_name``.

    For use inside shard_map: quantize locally, sum int8 payloads (widened
    to int32 to avoid overflow across the axis), combine scales by max.
    Returns (reduced_f32, new_error)."""
    q, scale, new_error = quantize_grad_int8(g, error)
    scale_max = jax.lax.pmax(scale, axis_name)
    # Re-quantize against the shared scale so the sum is well-defined.
    requant = jnp.clip(jnp.round(
        dequantize_grad(q, scale) / scale_max), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(requant, axis_name)
    return total.astype(jnp.float32) * scale_max, new_error


def hierarchical_all_reduce(x: jax.Array, *, pod_axis: str = "pod",
                            data_axis: str = "data"):
    """reduce-scatter within the pod, all-reduce across pods on the shard,
    all-gather within the pod — the bandwidth-optimal schedule when
    cross-pod links are the bottleneck (for use inside shard_map over a
    ('pod','data',...) mesh)."""
    shard = jax.lax.psum_scatter(x, data_axis, scatter_dimension=0,
                                 tiled=True)
    shard = jax.lax.psum(shard, pod_axis)
    return jax.lax.all_gather(shard, data_axis, axis=0, tiled=True)
