"""Paper Fig. 13: irregular-shaped GEMM (M, N in 80..200 step 30, K=25600).

Reports the planner's edge handling: padding waste (padded FLOPs / true
FLOPs), the chosen edge blocks, and interpret-mode correctness of the
predicated kernel on one representative irregular cell (the paper's
predicate-register story)."""
import numpy as np

import jax.numpy as jnp

from benchmarks.common import emit, modeled_time_s, record, record_plan
from repro.core.blocking import plan_gemm
from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref


def run(check_kernel: bool = True):
    k = 25600
    rng = np.random.default_rng(1)
    for m in range(80, 201, 30):
        for n in range(80, 201, 30):
            plan = plan_gemm(m, n, k, "float32")
            padded = plan.grid[0] * plan.bm * plan.grid[1] * plan.bn \
                * plan.grid[2] * plan.bk * 2
            waste = padded / plan.flops
            t = modeled_time_s(plan.flops * waste, plan.hbm_bytes, "float32")
            emit(f"irregular_{m}x{n}", 0.0,
                 f"pad_overhead={waste:.3f};blocks=({plan.bm},{plan.bn},{plan.bk});"
                 f"modeled_ms={t*1e3:.2f};notes={plan.notes or 'aligned'}")
            record_plan(f"irregular_{m}x{n}", "gemm", plan,
                        metrics={"pad_overhead": waste,
                                 "modeled_padded_ms": t * 1e3})
    if check_kernel:
        m, n, kk = 110, 170, 384   # reduced-K predicated correctness probe
        a = jnp.asarray(rng.standard_normal((m, kk)), "float32")
        b = jnp.asarray(rng.standard_normal((kk, n)), "float32")
        err = float(np.max(np.abs(
            np.asarray(mpgemm_pallas(a, b, interpret=True))
            - np.asarray(mpgemm_ref(a, b)))))
        emit("irregular_kernel_check", 0.0, f"maxerr={err:.2e}")
        record("irregular_kernel_check", "gemm", kind="trace",
               workload={"m": m, "n": n, "k": kk},
               metrics={"interpret_check_failures": float(err >= 1e-3)})


if __name__ == "__main__":
    run()
