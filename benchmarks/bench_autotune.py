"""Beyond-paper: closed-loop autotuning over the paper's GEMM workloads.

Runs ``tune_gemm`` on a subset of the Table III DeepSeek/LLaMA shapes,
persists the winners into a JSON plan cache, and emits the analytic-vs-tuned
characterization report (markdown) — the TPU analogue of the paper's
"characterize, then design" Section III methodology.

Modes (env ``REPRO_TUNE_MODE``, default ``auto``):
  * on TPU, ``auto`` == ``compiled``: real measured sweeps.
  * on CPU, ``auto`` == ``modeled``: deterministic roofline scoring — the
    sweep machinery runs end-to-end, and the analytic plan wins every row by
    construction (the report is the model/measurement *agreement* check).
  * ``interpret`` exercises the full measurement path on CPU; the small
    shapes below keep that tractable.

Outputs: ``autotune_plans.json`` (the cache) + ``autotune_report.md`` next
to it (env ``REPRO_TUNE_OUT`` overrides the directory), and the usual
``name,us_per_call,derived`` CSV lines on stdout.
"""
import os
import tempfile

from benchmarks.common import PAPER_WORKLOADS, emit, record
from repro.tuning import PlanCache, tune_gemm, write_report

# Table III IDs spanning the three regimes: decode-skinny (1), prefill-wide
# (8), square-ish training (17), plus a LLaMA low-rank shape (20).
_TUNE_IDS = (1, 8, 17, 20)

# Small shapes for interpret-mode sweeps (CPU CI): same skinny/wide/square
# structure, scaled down so the Python grid interpreter stays fast.
_INTERPRET_WORKLOADS = [
    (64, 256, 512), (128, 768, 256), (512, 512, 512),
]


def run(mode: str = None, out_dir: str = None, dtype: str = "bfloat16"):
    mode = mode or os.environ.get("REPRO_TUNE_MODE", "auto")
    # Artifacts default OUTSIDE the tree: the other benches only print CSV,
    # and `benchmarks/run.py` must not litter the invoker's cwd.
    out_dir = out_dir or os.environ.get("REPRO_TUNE_OUT") or os.path.join(
        tempfile.gettempdir(), "repro_autotune")
    os.makedirs(out_dir, exist_ok=True)
    cache = PlanCache(os.path.join(out_dir, "autotune_plans.json"))

    if mode == "interpret":
        workloads = _INTERPRET_WORKLOADS
        kwargs = dict(max_candidates=6, iters=1, warmup=1)
    else:
        workloads = [(m, n, k) for (i, m, n, k) in PAPER_WORKLOADS
                     if i in _TUNE_IDS]
        kwargs = dict(max_candidates=24, iters=3)

    results = []
    for (m, n, k) in workloads:
        r = tune_gemm(m, n, k, dtype, mode=mode, cache=cache, save=False,
                      **kwargs)
        results.append(r)
        emit(f"autotune_{m}x{n}x{k}_{dtype}", r.best.wall_us,
             f"analytic_us={r.analytic.wall_us:.1f};"
             f"speedup={r.speedup:.3f};"
             f"blocks={'x'.join(map(str, r.best.blocks))};"
             f"moved={int(r.tuned_differs)};mode={r.best.mode}")
        # Modeled mode is deterministic (speedup == 1 by construction);
        # measured modes put the sweep numbers in `noisy` only.
        deterministic = r.best.mode == "modeled"
        record(f"autotune_{m}x{n}x{k}_{dtype}", "gemm",
               kind="model" if deterministic else "wall",
               workload={"m": m, "n": n, "k": k, "dtype": dtype,
                         "mode": r.best.mode},
               metrics={"candidates": float(len(r.measurements)),
                        **({"modeled_speedup": r.speedup}
                           if deterministic else {})},
               noisy={} if deterministic else
               {"best_wall_us": r.best.wall_us,
                "analytic_wall_us": r.analytic.wall_us,
                "speedup": r.speedup})
    cache.save()
    report_path = os.path.join(out_dir, "autotune_report.md")
    write_report(results, report_path)
    emit("autotune_cache", 0.0,
         f"entries={len(cache)};cache={cache.path};report={report_path}")
    record("autotune_cache", "gemm", kind="trace",
           workload={"mode": mode},
           metrics={"cache_entries": float(len(cache))})
    return results


if __name__ == "__main__":
    run()
