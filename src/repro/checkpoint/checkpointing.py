"""Sharded checkpointing: one .npy per pytree leaf + a JSON manifest.

Design for 1000+ nodes (documented; exercised here on one host):
  * every host writes only its addressable shards (`leaf_slices`), so
    checkpoint bandwidth scales with the fleet;
  * the manifest records (tree structure, leaf shapes/dtypes, step, data
    pipeline state, mesh shape), so restore can RE-SHARD onto a different
    mesh — the elastic-scaling path: on node failure, restart with a smaller
    mesh and `restore(..., target_shardings=new_shardings)`;
  * writes go to a temp dir + atomic rename, so a crash mid-save never
    corrupts the latest checkpoint;
  * saves run on a background thread (training continues) — the async
    distributed-checkpoint pattern.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, jax.tree_util.tree_structure(tree)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------ save -------------------------------------

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = True):
        """Snapshot `tree` (params/opt/whatever pytree) at `step`."""
        flat, _ = _flatten(tree)
        # Materialize to host memory first (cheap view for numpy arrays).
        host = {k: np.asarray(v) for k, v in flat.items()}
        if self._thread is not None:
            self._thread.join()

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "extra": extra or {}, "leaves": {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".npy"
                logical = str(arr.dtype)
                save_arr = arr
                if arr.dtype.kind == "V" or logical not in np.sctypeDict:
                    # ml_dtypes (bfloat16, fp8...) are not numpy-native:
                    # store the raw bits and record the logical dtype.
                    save_arr = arr.view(
                        {1: np.uint8, 2: np.uint16, 4: np.uint32}[
                            arr.dtype.itemsize])
                np.save(os.path.join(tmp, fname), save_arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": logical,
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------ restore ----------------------------------

    def list_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                target_shardings: Any = None):
        """Restore into the structure of `tree_like`.  If `target_shardings`
        (matching pytree of NamedShardings) is given, leaves are placed
        sharded — this is the elastic re-mesh path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, _ = _flatten(tree_like)
        flat_shard = _flatten(target_shardings)[0] if target_shardings else {}
        restored = {}
        for key in flat_like:
            info = manifest["leaves"][key]
            arr = np.load(os.path.join(d, info["file"]))
            if str(arr.dtype) != info["dtype"]:
                # raw-bit storage of non-numpy-native dtypes (bfloat16 &c)
                import ml_dtypes
                arr = arr.view(getattr(ml_dtypes, info["dtype"], None)
                               or np.dtype(info["dtype"]))
            if key in flat_shard and flat_shard[key] is not None:
                restored[key] = jax.device_put(arr, flat_shard[key])
            else:
                restored[key] = jax.numpy.asarray(arr)
        # Rebuild the tree in tree_like's structure.
        leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        ordered = []
        for path, _ in leaves_paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            ordered.append(restored[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest
