"""Plan cache: key stability, disk round-trip, miss fallback, and the
end-to-end guarantee that ``mp_dot`` consumes cached (tuned) plans."""
import json

import numpy as np
import pytest

import jax.numpy as jnp

import repro.kernels.mpgemm as mpgemm_mod
from repro.core import config as cfg
from repro.core.blocking import plan_gemm, plan_with_blocks
from repro.core.gemm import mp_dot
from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref
from repro.tuning import (
    PlanCache, lookup_plan, make_key, set_plan_cache,
)


@pytest.fixture
def isolated_cache(tmp_path):
    """A fresh on-disk cache installed as the process-global one."""
    cache = PlanCache(tmp_path / "plans.json")
    prev = set_plan_cache(cache)
    yield cache
    set_plan_cache(prev)


def test_key_stability_and_sensitivity():
    key = make_key(64, 256, 128, "float32")
    # The exact string IS the on-disk schema — changing it invalidates every
    # persisted cache, so pin it.
    assert key == ("m64n256k128|a=float32|b=float32|out=float32"
                   "|ta=0|tb=0|beta=0|hw=tpu_v5e")
    assert make_key(64, 256, 128, "float32") == key
    # Every field the kernel's behavior depends on must move the key.
    assert make_key(64, 256, 129, "float32") != key
    assert make_key(64, 256, 128, "bfloat16") != key
    assert make_key(64, 256, 128, "float32", trans_b=True) != key
    assert make_key(64, 256, 128, "float32", beta=1.0) != key
    # Dtype defaulting matches the planner's policy defaults.
    assert make_key(64, 256, 128, "float32", "float32", "float32") == key


def test_roundtrip_save_load(tmp_path):
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    plan = plan_gemm(256, 256, 512, "bfloat16")
    key = make_key(256, 256, 512, "bfloat16")
    cache.put(key, plan, meta={"wall_us": 3.5, "mode": "modeled"})
    cache.save()

    reloaded = PlanCache(path)
    assert len(reloaded) == 1
    assert reloaded.get(key) == plan          # full dataclass equality
    assert reloaded.get_meta(key)["wall_us"] == 3.5
    assert reloaded.get("missing") is None


def test_corrupt_or_foreign_cache_reads_as_empty(tmp_path):
    path = tmp_path / "plans.json"
    for junk in ("{not json", json.dumps([1, 2]), json.dumps("x"),
                 json.dumps({"version": 999, "entries": {"k": {}}}),
                 json.dumps({"version": 1, "entries": "oops"})):
        path.write_text(junk)
        assert PlanCache(path).get("k") is None
        assert len(PlanCache(path)) == 0


def test_clear_then_save_purges_disk(tmp_path):
    """clear() must invalidate the file, not get merge-resurrected."""
    path = tmp_path / "plans.json"
    cache = PlanCache(path)
    cache.put(make_key(64, 128, 128, "float32"),
              plan_gemm(64, 128, 128, "float32"))
    cache.save()
    cache.clear()
    cache.save()
    assert len(PlanCache(path)) == 0


def test_cache_miss_falls_back_to_analytic(isolated_cache, rng):
    """Empty cache == seed behavior: the analytic planner runs the GEMM."""
    a = jnp.asarray(rng.standard_normal((64, 128)), "float32")
    b = jnp.asarray(rng.standard_normal((128, 256)), "float32")
    assert lookup_plan(64, 256, 128, "float32") is None
    out = mpgemm_pallas(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mpgemm_ref(a, b)),
                               atol=1e-5, rtol=1e-5)


def test_mp_dot_consumes_cached_plan(isolated_cache, rng, monkeypatch):
    """A cache hit must bypass the analytic planner entirely."""
    m, k, n = 64, 128, 256
    x = jnp.asarray(rng.standard_normal((m, k)), "float32")
    w = jnp.asarray(rng.standard_normal((k, n)), "float32")
    with cfg.gemm_backend("interpret"):
        expected = mp_dot(x, w, policy="fp32")

    tuned = plan_with_blocks(m, n, k, 32, 128, 128, "float32", notes="tuned")
    analytic = plan_gemm(m, n, k, "float32")
    assert (tuned.bm, tuned.bn, tuned.bk) != (analytic.bm, analytic.bn,
                                              analytic.bk)
    isolated_cache.put(make_key(m, n, k, "float32"), tuned)

    def _fail(*a, **kw):
        raise AssertionError("analytic planner called despite cache hit")

    monkeypatch.setattr(mpgemm_mod, "plan_gemm", _fail)
    with cfg.gemm_backend("interpret"):
        got = mp_dot(x, w, policy="fp32")
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               atol=1e-5, rtol=1e-5)


def test_direct_kernel_call_consumes_cached_plan(isolated_cache, rng,
                                                 monkeypatch):
    m, k, n = 32, 128, 128
    a = jnp.asarray(rng.standard_normal((m, k)), "float32")
    b = jnp.asarray(rng.standard_normal((k, n)), "float32")
    isolated_cache.put(
        make_key(m, n, k, "float32"),
        plan_with_blocks(m, n, k, 8, 128, 128, "float32", notes="tuned"),
    )
    monkeypatch.setattr(
        mpgemm_mod, "plan_gemm",
        lambda *a, **kw: (_ for _ in ()).throw(AssertionError("fallback ran")),
    )
    out = mpgemm_pallas(a, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(mpgemm_ref(a, b)),
                               atol=1e-5, rtol=1e-5)


def test_concurrent_savers_merge_instead_of_clobbering(tmp_path):
    """Two writers sharing one path must not lose each other's entries."""
    path = tmp_path / "plans.json"
    a, b = PlanCache(path), PlanCache(path)
    key_a = make_key(64, 128, 128, "float32")
    key_b = make_key(128, 128, 128, "float32")
    a.put(key_a, plan_gemm(64, 128, 128, "float32"))
    b.put(key_b, plan_gemm(128, 128, 128, "float32"))
    a.save()
    b.save()   # b loaded before a saved; must merge, not overwrite
    reloaded = PlanCache(path)
    assert key_a in reloaded and key_b in reloaded


def test_disabled_cache_means_analytic(isolated_cache):
    prev = set_plan_cache(None)
    try:
        assert lookup_plan(64, 64, 64, "float32") is None
    finally:
        set_plan_cache(prev)


def test_persisted_cache_survives_process_reload(tmp_path, rng):
    """Write with one PlanCache object, consume via a fresh one — the
    cross-process story (same file, new process == new object)."""
    path = tmp_path / "plans.json"
    writer = PlanCache(path)
    tuned = plan_with_blocks(64, 128, 128, 32, 128, 128, "float32",
                             notes="tuned")
    writer.put(make_key(64, 128, 128, "float32"), tuned)
    writer.save()

    prev = set_plan_cache(PlanCache(path))
    try:
        hit = lookup_plan(64, 128, 128, "float32")
        assert hit is not None and hit.bm == 32 and "tuned" in hit.notes
    finally:
        set_plan_cache(prev)
