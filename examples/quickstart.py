"""Quickstart: the paper's technique in five lines, then a tiny end-to-end
train + serve round-trip on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- 1. MPGEMM
from repro.core.blocking import plan_gemm
from repro.kernels.mpgemm import mpgemm_pallas
from repro.kernels.ref import mpgemm_ref

m, n, k = 512, 24576 // 16, 1536   # a DeepSeek workload shard (paper Table III)
plan = plan_gemm(m, n, k, "bfloat16")
print("analytic plan:", plan.describe())

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((m, k)), jnp.bfloat16)
b = jnp.asarray(rng.standard_normal((k, n)), jnp.bfloat16)
out = mpgemm_pallas(a, b, interpret=True)          # Pallas kernel (interpret on CPU)
ref = mpgemm_ref(a, b)                             # pure-jnp oracle
print("kernel vs oracle max err:",
      float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))))

# ------------------------------------------------- 2. a model on top of it
from repro.configs import base as cb
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig
from repro.configs.base import ShapeConfig

cfg = cb.get("h2o-danube3-4b", smoke=True)         # reduced same-family config
model = build_model(cfg, policy="bf16")
trainer = Trainer(model, ShapeConfig("tiny", 64, 4, "train"),
                  TrainerConfig(steps=20, log_every=5, opt=AdamWConfig(lr=1e-3)))
params, _ = trainer.run()
print("loss:", trainer.metrics_log[0]["loss"], "->",
      trainer.metrics_log[-1]["loss"])

# ------------------------------------------------------------- 3. serve it
from repro.serve.engine import Request, ServeEngine

eng = ServeEngine(model, params, max_batch=2, max_len=96)
reqs = [Request(uid=i, prompt=rng.integers(2, cfg.vocab, (12,)).astype(np.int32),
                max_new_tokens=8) for i in range(3)]
print("generated:", {k: v[:8] for k, v in eng.generate(reqs).items()})
print("OK")
