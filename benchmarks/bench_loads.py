"""Paper Fig. 3 analogue: load granularity vs effective bandwidth.

SME: 64B single-Z loads reach 230 GB/s; 256B four-Z groups reach 900 GB/s.
TPU: DMA row efficiency rises with the contiguous bytes per row.  We sweep
the block minor-dim span and report the efficiency model used by the
planner (eff = row/(row + min_dma_row)) and the resulting modeled GEMM
time on a reference workload — showing why the planner's >=512B constraint
(the four-Z-register rule) is binding."""
import jax.numpy as jnp

from benchmarks.common import emit, record
from repro.core.blocking import plan_gemm
from repro.core.constants import DEFAULT_HW


def run():
    hw = DEFAULT_HW
    m, n, k = 4096, 4096, 7168
    plan = plan_gemm(m, n, k, "float32")
    for row_bytes in (64, 128, 256, 512, 1024, 2048):
        eff = row_bytes / (row_bytes + hw.min_dma_row_bytes)
        bw = hw.hbm_bw * eff
        t = plan.hbm_bytes / bw
        emit(f"load_granularity_{row_bytes}B", 0.0,
             f"eff_bw_GBps={bw/1e9:.0f};modeled_mem_time_ms={t*1e3:.2f};"
             f"rel_to_1024B={(row_bytes/(row_bytes+512))/(1024/1536):.2f}")
        record(f"load_granularity_{row_bytes}B", "gemm",
               workload={"m": m, "n": n, "k": k, "row_bytes": row_bytes},
               metrics={"eff_bw_GBps": bw / 1e9,
                        "modeled_mem_time_ms": t * 1e3})
    # the planner's chosen minor spans honor the constraint
    emit("load_granularity_plan_check", 0.0,
         f"bk_bytes={plan.bk*4};bn_bytes={plan.bn*4};min_required=512")
    record("load_granularity_plan_check", "gemm",
           workload={"m": m, "n": n, "k": k},
           metrics={"bk_row_bytes": plan.bk * 4,
                    "bn_row_bytes": plan.bn * 4})


if __name__ == "__main__":
    run()
