"""Precision policies — the paper's multi-precision GEMM surface (Section V).

SME pairs lower-precision inputs with higher-precision accumulation
(FP16->FP32, INT8->INT32).  The MXU's native pairs are bf16->f32 and
int8->int32; fp32 runs at 1/4 MXU rate (the paper's FP64 story, one level
up the precision ladder).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    name: str
    compute_dtype: str   # what GEMM operands are cast to
    acc_dtype: str       # accumulator precision
    param_dtype: str     # how params are stored
    out_dtype: str       # activation dtype flowing between layers
    quantized: bool = False  # dynamic per-tensor int8 quantization

    def flops_per_chip(self, hw) -> float:
        if self.quantized:
            return hw.peak_ops_int8
        if self.compute_dtype in ("bfloat16", "float16"):
            return hw.peak_flops_bf16
        return hw.peak_flops_fp32


FP32 = PrecisionPolicy("fp32", "float32", "float32", "float32", "float32")
BF16 = PrecisionPolicy("bf16", "bfloat16", "float32", "float32", "bfloat16")
# Pure-bf16 storage for serving (halves weight HBM traffic).
BF16_SERVE = PrecisionPolicy("bf16_serve", "bfloat16", "float32", "bfloat16", "bfloat16")
INT8 = PrecisionPolicy("int8", "int8", "int32", "bfloat16", "bfloat16", quantized=True)

POLICIES = {p.name: p for p in (FP32, BF16, BF16_SERVE, INT8)}


def get_policy(name_or_policy) -> PrecisionPolicy:
    if isinstance(name_or_policy, PrecisionPolicy):
        return name_or_policy
    return POLICIES[name_or_policy]


def quantize_per_tensor(x, dtype=jnp.int8):
    """Dynamic symmetric per-tensor quantization (used by the INT8 policy)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(dtype), scale
