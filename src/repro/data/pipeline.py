"""Deterministic synthetic token pipeline: shard-aware, exactly resumable.

Every batch is a pure function of (seed, step), so restoring a checkpoint
at step N reproduces the identical remaining stream — the data-side half of
fault-tolerant training.  On a real cluster, each host materializes only its
addressable shard (``host_slice``); here we expose the same interface with a
single host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticLM:
    """Zipf-ish synthetic token stream with next-token structure (a noisy
    affine map over token ids) so loss actually decreases during training."""

    def __init__(self, vocab: int, global_batch: int, seq_len: int,
                 seed: int = 0, extra_specs: Optional[Dict] = None):
        self.vocab = vocab
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.state = PipelineState(seed=seed, step=0)
        self.extra_specs = extra_specs or {}

    # -- resumability ---------------------------------------------------------

    def snapshot(self) -> Dict:
        return dataclasses.asdict(self.state)

    def restore(self, snap: Dict):
        self.state = PipelineState(**snap)

    # -- batch synthesis ------------------------------------------------------

    def _tokens(self, rng: np.random.Generator) -> np.ndarray:
        b, s, v = self.global_batch, self.seq_len, self.vocab
        # zipf-flavored marginal + deterministic affine next-token structure
        base = rng.zipf(1.3, size=(b, 1)).clip(1, v - 1)
        steps = rng.integers(1, 7, size=(b, 1))
        noise = rng.integers(0, 3, size=(b, s + 1))
        pos = np.arange(s + 1)[None, :]
        return ((base + steps * pos + noise) % v).astype(np.int32)

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, self.state.step]))
        batch = {"tokens": self._tokens(rng)}
        for name, (shape, dtype) in self.extra_specs.items():
            batch[name] = rng.standard_normal(
                (self.global_batch,) + tuple(shape)).astype(dtype)
        self.state.step += 1
        return batch

    def host_slice(self, batch: Dict[str, np.ndarray],
                   host_id: int = 0, n_hosts: int = 1):
        """The per-host shard of the global batch (multi-host deployment)."""
        per = self.global_batch // n_hosts
        return {k: v[host_id * per:(host_id + 1) * per] for k, v in batch.items()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def device_batch(batch: Dict[str, np.ndarray], shardings: Optional[Dict] = None):
    """Place a host batch onto devices with the given NamedShardings."""
    out = {}
    for k, v in batch.items():
        if shardings and k in shardings:
            out[k] = jax.device_put(v, shardings[k])
        else:
            out[k] = jnp.asarray(v)
    return out
