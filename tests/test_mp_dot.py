"""mp_dot: policy semantics, custom-VJP fused-transpose grads, backends."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.gemm import mp_dot, mp_einsum
from repro.core.policy import POLICIES, quantize_per_tensor


@pytest.mark.parametrize("policy", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_mp_dot_forward_and_grad(rng, policy, backend):
    x = jnp.asarray(rng.standard_normal((4, 32, 64)), "float32")
    w = jnp.asarray(rng.standard_normal((64, 48)), "float32")
    b = jnp.asarray(rng.standard_normal((48,)), "float32")

    def loss(x, w, b):
        return jnp.sum(mp_dot(x, w, b, policy=policy, backend=backend) ** 2)

    val, grads = jax.value_and_grad(loss, argnums=(0, 1, 2))(x, w, b)
    assert jnp.isfinite(val)
    for g in grads:
        assert bool(jnp.all(jnp.isfinite(g)))
    # xla and interpret backends agree exactly in structure
    val2 = loss(x, w, b)
    np.testing.assert_allclose(float(val), float(val2), rtol=1e-6)


def test_backends_agree(rng):
    x = jnp.asarray(rng.standard_normal((8, 64)), "float32")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    for policy in ["fp32", "bf16", "int8"]:
        a = mp_dot(x, w, policy=policy, backend="xla")
        b = mp_dot(x, w, policy=policy, backend="interpret")
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-5, rtol=1e-4)


def test_trans_w_matches_einsum(rng):
    x = jnp.asarray(rng.standard_normal((4, 16, 64)), "float32")
    wt = jnp.asarray(rng.standard_normal((48, 64)), "float32")
    y = mp_dot(x, wt, policy="fp32", trans_w=True)
    ref = jnp.einsum("bsk,nk->bsn", x, wt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
    g = jax.grad(lambda w: jnp.sum(
        mp_dot(x, w, policy="fp32", trans_w=True) ** 2))(wt)
    gr = jax.grad(lambda w: jnp.sum(jnp.einsum("bsk,nk->bsn", x, w) ** 2))(wt)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-3)


def test_fp32_grads_match_reference(rng):
    x = jnp.asarray(rng.standard_normal((16, 32)), "float32")
    w = jnp.asarray(rng.standard_normal((32, 24)), "float32")
    g1 = jax.grad(lambda w: jnp.sum(mp_dot(x, w, policy="fp32") ** 2))(w)
    g2 = jax.grad(lambda w: jnp.sum((x @ w) ** 2))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


def test_int8_policy_quantizes(rng):
    x = jnp.asarray(rng.standard_normal((32, 64)), "float32")
    q, scale = quantize_per_tensor(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(scale),
                               np.asarray(x), atol=float(scale) * 0.51)


def test_int8_forward_close_to_fp32(rng):
    x = jnp.asarray(rng.standard_normal((16, 128)), "float32")
    w = jnp.asarray(rng.standard_normal((128, 32)), "float32")
    y8 = mp_dot(x, w, policy="int8")
    y32 = mp_dot(x, w, policy="fp32")
    err = float(jnp.max(jnp.abs(y8.astype(jnp.float32) - y32)))
    scale = float(jnp.max(jnp.abs(y32)))
    assert err < 0.05 * scale


def test_mp_einsum_policy_dtypes(rng):
    a = jnp.asarray(rng.standard_normal((2, 8, 16)), "float32")
    b = jnp.asarray(rng.standard_normal((2, 16, 4)), "float32")
    out = mp_einsum("bij,bjk->bik", a, b, policy="bf16")
    assert out.dtype == jnp.bfloat16
    out32 = mp_einsum("bij,bjk->bik", a, b, policy="fp32")
    assert out32.dtype == jnp.float32


# --- polymorphic operand + deprecation shims ---------------------------------

def test_polymorphic_b_dispatches_by_type(rng):
    from repro.core.blocking import plan_gemm
    from repro.core.gemm import mp_dot_grouped
    from repro.packing.pack import pack_operand
    from repro.sparse.sparsify import sparsify_magnitude

    x = jnp.asarray(rng.standard_normal((8, 64)), "float32")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    dense = mp_dot(x, w, policy="fp32")

    pk = pack_operand(w, plan_gemm(8, 32, 64, "float32", "float32"))
    sp = sparsify_magnitude(w, (32, 32), density=1.0)
    y_pk = mp_dot(x, pk, policy="fp32", backend="interpret")
    y_sp = mp_dot(x, sp, policy="fp32", backend="interpret")
    np.testing.assert_allclose(np.asarray(y_pk), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_sp), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)

    xg = jnp.asarray(rng.standard_normal((2, 8, 64)), "float32")
    wg = jnp.asarray(rng.standard_normal((2, 64, 32)), "float32")
    ref = jnp.einsum("gmk,gkn->gmn", xg, wg)
    got = mp_dot_grouped(xg, wg, policy="fp32")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_legacy_keywords_warn_and_match(rng):
    from repro.core.gemm import mp_dot_grouped
    from repro.sparse.sparsify import sparsify_magnitude

    x = jnp.asarray(rng.standard_normal((8, 64)), "float32")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    sp = sparsify_magnitude(w, (32, 32), density=0.5)

    with pytest.warns(DeprecationWarning, match=r"mp_dot\(w=\.\.\.\)"):
        y_w = mp_dot(x, w=w, policy="fp32")
    np.testing.assert_allclose(np.asarray(y_w),
                               np.asarray(mp_dot(x, w, policy="fp32")))

    with pytest.warns(DeprecationWarning, match=r"mp_dot\(b_sparse=\.\.\.\)"):
        y_s = mp_dot(x, b_sparse=sp, policy="fp32", backend="interpret")
    np.testing.assert_allclose(
        np.asarray(y_s),
        np.asarray(mp_dot(x, sp, policy="fp32", backend="interpret")))

    xg = jnp.asarray(rng.standard_normal((2, 8, 64)), "float32")
    wg = jnp.asarray(rng.standard_normal((2, 64, 32)), "float32")
    with pytest.warns(DeprecationWarning, match="mp_dot_grouped"):
        g_w = mp_dot_grouped(xg, w=wg, policy="fp32")
    np.testing.assert_allclose(np.asarray(g_w),
                               np.asarray(mp_dot_grouped(xg, wg, policy="fp32")))


def test_mpgemm_wrapper_legacy_keywords_warn(rng):
    from repro.core.blocking import plan_gemm
    from repro.kernels.mpgemm import mpgemm_pallas
    from repro.packing.pack import pack_operand
    from repro.sparse.sparsify import sparsify_magnitude

    x = jnp.asarray(rng.standard_normal((8, 64)), "float32")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    pk = pack_operand(w, plan_gemm(8, 32, 64, "float32", "float32"))
    sp = sparsify_magnitude(w, (32, 32), density=0.5)

    new_pk = mpgemm_pallas(x, pk, interpret=True)
    with pytest.warns(DeprecationWarning, match=r"b_packed=\.\.\."):
        old_pk = mpgemm_pallas(x, b_packed=pk, interpret=True)
    np.testing.assert_allclose(np.asarray(new_pk), np.asarray(old_pk))

    new_sp = mpgemm_pallas(x, sp, interpret=True)
    with pytest.warns(DeprecationWarning, match=r"b_sparse=\.\.\."):
        old_sp = mpgemm_pallas(x, b_sparse=sp, interpret=True)
    np.testing.assert_allclose(np.asarray(new_sp), np.asarray(old_sp))

    with pytest.raises(ValueError, match="exactly one"):
        mpgemm_pallas(x, w, b_packed=pk, interpret=True)
