"""Static int8 weight quantization (core/quantization.py) + mp_dot
integration."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import base as cb
from repro.core.gemm import mp_dot
from repro.core.quantization import (
    dequantize_tensor, is_quantized, quantize_params, quantize_tensor,
)
from repro.models.transformer import build_model


def test_quantize_roundtrip(rng):
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    wd = quantize_tensor(w)
    assert wd["q"].dtype == jnp.int8
    back = dequantize_tensor(wd, jnp.float32)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w),
                               atol=float(wd["scale"]) * 0.51)


def test_mp_dot_consumes_quantized(rng):
    x = jnp.asarray(rng.standard_normal((8, 64)), "bfloat16")
    w = jnp.asarray(rng.standard_normal((64, 32)), "float32")
    y_ref = mp_dot(x, w, policy="bf16")
    y_q = mp_dot(x, quantize_tensor(w), policy="bf16")
    err = float(jnp.max(jnp.abs(y_q.astype(jnp.float32)
                                - y_ref.astype(jnp.float32))))
    assert err < 0.1 * float(jnp.max(jnp.abs(y_ref.astype(jnp.float32)))) + 0.1


def test_quantize_params_selective():
    cfg = cb.get("starcoder2-3b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    pq = quantize_params(params)
    # attn weights quantized; norms and embeddings untouched
    sample = jax.tree_util.tree_map(lambda x: x, pq["stack"][0])
    assert is_quantized(sample["attn"]["wq"])
    assert not is_quantized(sample["ln1"]["scale"]) \
        and sample["ln1"]["scale"].dtype != jnp.int8
    assert pq["embed"].dtype == params["embed"].dtype


def test_quantized_model_generates(rng):
    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    pq = quantize_params(jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16) if a.dtype == jnp.float32 else a,
        params))
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 17)), "int32")
    l_ref, c_ref = model.prefill(params, {"tokens": toks[:, :16]}, max_len=24)
    l_q, c_q = model.prefill(pq, {"tokens": toks[:, :16]}, max_len=24)
    a, b = [np.asarray(x[:, :cfg.vocab], np.float32) for x in (l_ref, l_q)]
    # weight-only int8 keeps top-1 on the vast majority of rows
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
    d_q, _ = model.decode_step(pq, toks[:, 16:17], c_q, jnp.int32(16))
    assert bool(jnp.all(jnp.isfinite(d_q[:, :cfg.vocab])))
