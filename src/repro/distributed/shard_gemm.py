"""Sharded MPGEMM: tensor/expert-parallel ``mp_dot`` with overlap.

The paper's core move is hierarchical cache-aware partitioning; a device
mesh is the next level of that hierarchy.  This module teaches the whole
spec-driven GEMM stack (``core/gemm.py`` → ``kernels/mpgemm.py``) to run
under ``shard_map`` over the 1-D tensor-parallel meshes of
``launch/mesh.py::make_tp_mesh``:

``mp_dot_sharded`` — one logical ``y = x @ b`` with B (and optionally X)
partitioned over a mesh axis.  Three partitions:

  * ``"column"`` — B split along N, X replicated.  No collective; the
    output comes back N-sharded.  The only partition that supports the
    polymorphic packed/tile-sparse B operands (see below).
  * ``"row"``    — B and X split along K.  Each device holds one K-slice
    partial of the FULL (M, N) product, so a reduction over the axis is
    required.  ``overlap="ring"`` (default) runs a **ring reduce-scatter
    matmul**: the local K-contribution is computed one N-chunk at a time,
    and between chunk GEMMs the partial accumulator takes one ``ppermute``
    hop around the ring — P-1 collective steps interleaved with P tile-
    compute steps (the traced jaxpr literally alternates ``dot``/
    ``ppermute``; ``benchmarks/bench_distributed.py`` gates on it), instead
    of ``overlap="blocking"``'s single monolithic ``psum`` after all
    compute.
  * ``"gather"`` — X split along M (sequence parallel), B split along N.
    ``overlap="ring"`` runs a **ring all-gather matmul**: each step
    multiplies the M-shard currently held against the local N-shard and
    writes its output rows, then passes the shard one hop on;
    ``overlap="blocking"`` all-gathers X first, then runs one local GEMM.

``mp_dot_grouped_sharded`` — grouped (MoE expert) GEMMs, expert-parallel:
experts are split over the mesh axis and tokens travel.  Inside the
``shard_map`` an ``all_to_all`` re-shards X from token-sharded
``(G, M/P, K)`` to expert-sharded ``(G/P, M, K)``, the local grouped
MPGEMM runs over the device's experts only, and a second ``all_to_all``
restores token sharding — the classic MoE dispatch/combine pair with the
weights never moving.

**Per-shard planning.**  Inside ``shard_map`` every shape IS the local
shard, so the block planner / plan-cache lookups the kernel launch makes
at trace time (``mpgemm_pallas_spec``) automatically compute CMR on the
per-device (M, N, K) — the mesh is one more level of the paper's
partitioning hierarchy.  Each sharded trace additionally runs under
``tuning.plan_cache.mesh_namespace(mesh_plan_tag(...))``, so tuned sharded
plans live in a ``|mesh=tp4[model]``-suffixed key namespace and never
alias single-device tunings of the same local shape.

**Polymorphic B operands.**  ``shard_operand`` splits a dense array,
:class:`~repro.packing.PackedOperand`, or
:class:`~repro.sparse.TileSparseOperand` along its N-tile axis (grouped
operands: along G) into per-shard operands whose payloads carry only that
shard's tiles.  Packed/sparse shards cannot ride a single ``shard_map``
program — their static layout aux (tile counts, sparse nnz/schedule)
differs per shard, and SPMD requires one program — so
``mp_dot_sharded`` runs them as per-shard programs concatenated under an
output sharding constraint: under ``jit`` over the mesh, GSPMD places each
shard's compute (and therefore its payload) on its own device group.  The
dense paths carry the overlap machinery and the jaxpr gate.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.packing.layout import PackedOperand, is_packed
from repro.sparse.layout import TileSparseOperand, is_sparse
from repro.tuning.plan_cache import mesh_namespace

PARTITIONS = ("column", "row", "gather")
OVERLAPS = ("ring", "blocking")

Operand = Union[jax.Array, PackedOperand, TileSparseOperand]


# --------------------------------------------------------------------- mesh

def mesh_axis_size(mesh, axis: str) -> int:
    return int(dict(mesh.shape)[axis])


def mesh_plan_tag(mesh, axis: str) -> str:
    """Plan-cache namespace tag for a sharded GEMM over ``mesh[axis]``.

    Keyed by axis SIZE (not device identity): a tuned per-shard plan is
    valid for any 4-way slice of any mesh, exactly like single-device plans
    are valid for any device of the same hardware generation.
    """
    return f"tp{mesh_axis_size(mesh, axis)}[{axis}]"


def _check_div(what: str, value: int, shards: int) -> int:
    if value % shards != 0:
        raise ValueError(
            f"{what} = {value} is not divisible by the mesh axis size "
            f"{shards}; pad the operand or pick a different partition")
    return value // shards


# --------------------------------------------------- operand sharding (N/G)

def _shard_dense(b: jax.Array, shards: int, *, axis: str,
                 trans_w: bool) -> Tuple[jax.Array, ...]:
    if axis == "g":
        if b.ndim != 3:
            raise ValueError(f"group sharding needs a (G, K, N) operand, "
                             f"got shape {b.shape}")
        _check_div("G", b.shape[0], shards)
        return tuple(jnp.split(b, shards, axis=0))
    n_axis = (b.ndim - 2) if trans_w else (b.ndim - 1)
    _check_div("N", b.shape[n_axis], shards)
    return tuple(jnp.split(b, shards, axis=n_axis))


def _shard_packed(p: PackedOperand, shards: int, *,
                  axis: str) -> Tuple[PackedOperand, ...]:
    lay = p.layout
    grouped = lay.g != 1
    if axis == "g":
        if not grouped:
            raise ValueError("group sharding needs a grouped PackedOperand")
        gl = _check_div("G", lay.g, shards)
        parts = []
        for s in range(shards):
            payload = p.payload[s * gl:(s + 1) * gl]
            scales = (p.scales[s * gl:(s + 1) * gl]
                      if p.scales is not None else None)
            if gl == 1:  # PackedLayout g=1 means "not grouped": drop the axis
                payload = payload[0]
                scales = scales[0] if scales is not None else None
            parts.append(PackedOperand(
                payload, scales, dataclasses.replace(lay, g=gl)))
        return tuple(parts)
    # N sharding: the shard boundary must fall on the bn tile lattice, so
    # each shard owns whole (bk, bn) tiles and no padding column splits.
    nl = _check_div("N", lay.n, shards)
    if nl % lay.bn != 0:
        raise ValueError(
            f"per-shard N = {nl} is not a multiple of the packed tile width "
            f"bn = {lay.bn}; shard boundaries must fall on tile boundaries")
    nnb_l = nl // lay.bn
    j_axis = 2 if grouped else 1
    parts = []
    for s in range(shards):
        sl = [slice(None)] * p.payload.ndim
        sl[j_axis] = slice(s * nnb_l, (s + 1) * nnb_l)
        payload = p.payload[tuple(sl)]
        scales = None
        if p.scales is not None:
            ssl = [slice(None)] * p.scales.ndim
            ssl[j_axis] = slice(s * nnb_l, (s + 1) * nnb_l)
            scales = p.scales[tuple(ssl)]
        parts.append(PackedOperand(
            payload, scales, dataclasses.replace(lay, n=nl)))
    return tuple(parts)


def _sparse_column_slice(p: TileSparseOperand, cols: Sequence[int],
                         *, n: int, g: int) -> TileSparseOperand:
    """Rebuild a TileSparseOperand keeping only BSR columns ``cols``
    (which must be contiguous in the column-major (g, j) order)."""
    lay = p.layout
    lo, hi = lay.indptr[cols[0]], lay.indptr[cols[-1] + 1]
    indptr = tuple(lay.indptr[c] - lo for c in cols)
    indptr = indptr + (hi - lo,)
    indices = lay.indices[lo:hi]
    # Stored tiles of contiguous columns are a contiguous payload slice;
    # re-append the shared trailing zero tile (slot nnz) for anchor visits.
    payload = jnp.concatenate([p.payload[lo:hi], p.payload[lay.nnz:]], axis=0)
    scales = None
    if p.scales is not None:
        scales = jnp.concatenate([p.scales[lo:hi], p.scales[lay.nnz:]],
                                 axis=0)
    new_lay = dataclasses.replace(lay, n=n, g=g, indptr=indptr,
                                  indices=indices)
    return TileSparseOperand(payload, scales, new_lay)


def _shard_sparse(p: TileSparseOperand, shards: int, *,
                  axis: str) -> Tuple[TileSparseOperand, ...]:
    lay = p.layout
    if axis == "g":
        if lay.g == 1:
            raise ValueError("group sharding needs a grouped "
                             "TileSparseOperand")
        gl = _check_div("G", lay.g, shards)
        return tuple(
            _sparse_column_slice(
                p, range(s * gl * lay.nnb, (s + 1) * gl * lay.nnb),
                n=lay.n, g=gl)
            for s in range(shards))
    nl = _check_div("N", lay.n, shards)
    if nl % lay.bn != 0:
        raise ValueError(
            f"per-shard N = {nl} is not a multiple of the sparse tile width "
            f"bn = {lay.bn}; shard boundaries must fall on tile boundaries")
    nnb_l = nl // lay.bn
    parts = []
    for s in range(shards):
        cols = [gi * lay.nnb + j
                for gi in range(lay.g)
                for j in range(s * nnb_l, (s + 1) * nnb_l)]
        if lay.g > 1:
            # Column-major (g, j) order: an N slice of a grouped operand is
            # NOT contiguous across groups, so rebuild per group and re-fold.
            raise ValueError(
                "N-sharding a grouped sparse operand is unsupported; shard "
                "grouped operands along G (expert parallelism)")
        parts.append(_sparse_column_slice(p, cols, n=nl, g=lay.g))
    return tuple(parts)


def shard_operand(b: Operand, shards: int, *, axis: str = "n",
                  trans_w: bool = False) -> Tuple[Operand, ...]:
    """Split a GEMM B operand into ``shards`` per-device operands.

    ``axis="n"`` splits output columns on the tile lattice (tensor
    parallelism); ``axis="g"`` splits expert groups (expert parallelism).
    Packed and tile-sparse operands keep only their shard's payload tiles —
    the per-device memory story: a 4-way shard holds 1/4 of the payload
    bytes (plus the sparse zero-anchor tile).
    """
    if shards < 1:
        raise ValueError(f"shards must be >= 1, got {shards}")
    if axis not in ("n", "g"):
        raise ValueError(f"axis must be 'n' or 'g', got {axis!r}")
    if shards == 1:
        return (b,)
    if is_packed(b):
        return _shard_packed(b, shards, axis=axis)
    if is_sparse(b):
        return _shard_sparse(b, shards, axis=axis)
    return _shard_dense(b, shards, axis=axis, trans_w=trans_w)


# ------------------------------------------------------- dense shard bodies

def _ring_row_body(axis: str, size: int, dot):
    """Ring reduce-scatter matmul body: P chunk GEMMs, P-1 ppermute hops.

    Device ``me`` computes its local-K contribution to N-chunk
    ``(me - t - 1) mod P`` at step t and adds the accumulator received from
    its ring predecessor; after P-1 hops the accumulator arriving at device
    ``me`` has visited every device exactly when it carries chunk ``me`` —
    the fully reduced shard the out_spec reassembles.  The python loop
    unrolls, so the traced program literally interleaves one ``ppermute``
    between consecutive chunk GEMMs — that is the overlap the XLA/TPU
    scheduler exploits (and the jaxpr gate asserts).
    """
    perm = [(i, (i + 1) % size) for i in range(size)]

    def body(xl, bl):
        nc = bl.shape[-1] // size
        me = jax.lax.axis_index(axis)

        def chunk(i):
            start = jnp.mod(i, size) * nc
            return jax.lax.dynamic_slice_in_dim(bl, start, nc, axis=1)

        acc = dot(xl, chunk(me - 1))
        for t in range(1, size):
            recv = jax.lax.ppermute(acc, axis, perm)
            acc = recv + dot(xl, chunk(me - t - 1))
        return acc

    return body


def _ring_gather_body(axis: str, size: int, dot):
    """Ring all-gather matmul body: each step multiplies the currently held
    M-shard of X against the local N-shard of B and writes its output rows,
    then forwards the shard one ring hop — compute on shard t overlaps the
    transfer of shard t+1 (double buffering in XLA's async scheduler)."""
    perm = [(i, (i + 1) % size) for i in range(size)]

    def body(xl, bl):
        ml = xl.shape[0]
        me = jax.lax.axis_index(axis)
        buf = xl
        out = None
        for t in range(size):
            part = dot(buf, bl)
            y = jnp.zeros((ml * size, part.shape[1]), part.dtype) \
                if out is None else out
            src = jnp.mod(me - t, size)
            out = jax.lax.dynamic_update_slice_in_dim(y, part, src * ml,
                                                      axis=0)
            if t < size - 1:
                buf = jax.lax.ppermute(buf, axis, perm)
        return out

    return body


# ---------------------------------------------------------------- mp_dot

def mp_dot_sharded(
    x: jax.Array,
    b: Operand,
    bias: Optional[jax.Array] = None,
    *,
    mesh,
    axis: str = "model",
    partition: str = "column",
    overlap: str = "ring",
    policy="bf16",
    backend: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """``mp_dot`` partitioned over ``mesh[axis]``; returns the global (M, N).

    See the module docstring for the partition/overlap matrix.  Packed and
    tile-sparse ``b`` support ``partition="column"`` only (their static
    layouts differ per shard, which rules out a single SPMD program);
    dense ``b`` supports all three, with ``overlap`` selecting the chunked
    ring schedule or the blocking-collective baseline.
    """
    if partition not in PARTITIONS:
        raise ValueError(f"partition must be one of {PARTITIONS}, "
                         f"got {partition!r}")
    if overlap not in OVERLAPS:
        raise ValueError(f"overlap must be one of {OVERLAPS}, "
                         f"got {overlap!r}")
    size = mesh_axis_size(mesh, axis)
    tag = mesh_plan_tag(mesh, axis)
    kw = dict(policy=policy, backend=backend, out_dtype=out_dtype)

    if is_packed(b) or is_sparse(b):
        if partition != "column":
            raise NotImplementedError(
                f"packed/sparse operands shard along N only "
                f"(partition='column'), got partition={partition!r}")
        return _column_parts(x, b, bias, mesh=mesh, axis=axis, size=size,
                             tag=tag, **kw)

    if b.ndim != 2:
        raise ValueError(f"mp_dot_sharded expects a 2-D dense b, got "
                         f"shape {b.shape}")
    m, k = x.shape

    if partition == "column":
        _check_div("N", b.shape[1], size)

        def body(xl, bl, biasl):
            return mp_dot(xl, bl, biasl, **kw)

        f = shard_map(body, mesh,
                      in_specs=(P(None, None), P(None, axis), P(axis)),
                      out_specs=P(None, axis), check_rep=False)
        with mesh_namespace(tag):
            return f(x, b, _bias_or_empty(bias, b.shape[1]))

    if partition == "row":
        _check_div("K", k, size)
        if overlap == "ring":
            # The ring emits the reduced result one N-chunk per device.
            _check_div("N (ring chunking)", b.shape[1], size)

        # Partial K-contributions must accumulate across devices in f32 —
        # ring hops (or the psum) would otherwise round at the policy's
        # output precision once per step.
        def dot(xl, bl):
            return mp_dot(xl, bl, policy=policy, backend=backend,
                          out_dtype=jnp.float32)

        if overlap == "ring":
            body = _ring_row_body(axis, size, dot)
            out_spec = P(None, axis)
        else:
            def body(xl, bl):
                return jax.lax.psum(dot(xl, bl), axis)
            out_spec = P(None, None)
        f = shard_map(body, mesh,
                      in_specs=(P(None, axis), P(axis, None)),
                      out_specs=out_spec, check_rep=False)
        with mesh_namespace(tag):
            y = f(x, b)
        return _finish(y, bias, out_dtype, policy)

    # partition == "gather": x M-sharded, b N-sharded, out (M, N) N-sharded.
    _check_div("M", m, size)
    _check_div("N", b.shape[1], size)

    def dot(xl, bl):
        return mp_dot(xl, bl, policy=policy, backend=backend,
                      out_dtype=jnp.float32)

    if overlap == "ring":
        body = _ring_gather_body(axis, size, dot)
    else:
        def body(xl, bl):
            full = jax.lax.all_gather(xl, axis, axis=0, tiled=True)
            return dot(full, bl)
    f = shard_map(body, mesh,
                  in_specs=(P(axis, None), P(None, axis)),
                  out_specs=P(None, axis), check_rep=False)
    with mesh_namespace(tag):
        y = f(x, b)
    return _finish(y, bias, out_dtype, policy)


def _bias_or_empty(bias: Optional[jax.Array], n: int) -> jax.Array:
    # shard_map wants a concrete operand per in_spec; a (N,) zero bias is
    # free after fusion and keeps one program for both cases.
    return bias if bias is not None else jnp.zeros((n,), jnp.float32)


def _finish(y: jax.Array, bias: Optional[jax.Array], out_dtype,
            policy) -> jax.Array:
    """Bias + output cast for the reduction partitions (row/gather), where
    bias can only be applied to the fully reduced result."""
    if bias is not None:
        y = y + bias[None, :].astype(y.dtype)
    from repro.core.policy import get_policy
    tgt = out_dtype if out_dtype is not None else get_policy(policy).out_dtype
    return y.astype(tgt)


def _column_parts(x, b, bias, *, mesh, axis, size, tag, **kw):
    """Packed/tile-sparse column partition: per-shard programs.

    Each shard's GEMM traces with its LOCAL (m, n_local, k) — so the block
    planner and plan cache see per-shard shapes — inside the mesh plan
    namespace.  The concatenated output carries a sharding constraint;
    under jit over the mesh, GSPMD back-propagates it so each part's
    payload and compute stay on that shard's devices.
    """
    parts = shard_operand(b, size, axis="n")
    nl = parts[0].layout.n
    outs = []
    with mesh_namespace(tag):
        for s, bs in enumerate(parts):
            bias_s = bias[s * nl:(s + 1) * nl] if bias is not None else None
            outs.append(mp_dot(x, bs, bias_s, **kw))
    y = jnp.concatenate(outs, axis=-1)
    return jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(None, axis)))


# ------------------------------------------------------------ grouped MoE

def mp_dot_grouped_sharded(
    x: jax.Array,
    b: Operand,
    bias: Optional[jax.Array] = None,
    *,
    mesh,
    axis: str = "model",
    group_sizes: Optional[jax.Array] = None,
    policy="bf16",
    backend: Optional[str] = None,
    out_dtype=None,
) -> jax.Array:
    """Expert-parallel ``mp_dot_grouped``: experts sharded, tokens routed.

    Dense ``b`` (G, K, N) runs the all-to-all dispatch/combine pair inside
    one ``shard_map`` (weights never move; each device runs the grouped
    MPGEMM over its G/P experts with the full token set for those experts).
    Packed/sparse grouped operands shard along G as per-shard programs
    (static layouts differ per shard — same constraint as the 2-D column
    partition).  The ragged ``group_sizes`` mask is applied on the global
    output, mirroring ``mp_dot_grouped``'s outside-the-VJP masking.
    """
    size = mesh_axis_size(mesh, axis)
    tag = mesh_plan_tag(mesh, axis)
    kw = dict(policy=policy, backend=backend, out_dtype=out_dtype)
    if x.ndim != 3:
        raise ValueError(f"expects x of rank 3 (G, M, K), got {x.shape}")
    g, m, _ = x.shape

    if is_packed(b) or is_sparse(b):
        y = _ep_parts(x, b, bias, mesh=mesh, axis=axis, size=size, tag=tag,
                      **kw)
    else:
        if b.ndim != 3:
            raise ValueError(f"expects dense b of rank 3 (G, K, N), got "
                             f"shape {b.shape}")
        _check_div("G", g, size)
        _check_div("M (token sharding)", m, size)

        def body(xl, bl, biasl):
            # dispatch: token-sharded (G, M/P, K) -> expert-sharded
            # (G/P, M, K); every token reaches the device owning its expert.
            xr = jax.lax.all_to_all(xl, axis, split_axis=0, concat_axis=1,
                                    tiled=True)
            yl = mp_dot_grouped(xr, bl, biasl, **kw)
            # combine: back to token sharding for the caller's next op.
            return jax.lax.all_to_all(yl, axis, split_axis=1, concat_axis=0,
                                      tiled=True)

        bias_full = (bias if bias is not None
                     else jnp.zeros((g, b.shape[-1]), jnp.float32))
        if bias_full.ndim == 1:
            bias_full = jnp.broadcast_to(bias_full[None, :],
                                         (g, bias_full.shape[0]))
        f = shard_map(
            body, mesh,
            in_specs=(P(None, axis, None), P(axis, None, None),
                      P(axis, None)),
            out_specs=P(None, axis, None), check_rep=False)
        with mesh_namespace(tag):
            y = f(x, b, bias_full)

    if group_sizes is not None:
        sizes = jnp.asarray(group_sizes, jnp.int32).reshape(-1, 1, 1)
        rows = jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
        y = jnp.where(rows < sizes, y, jnp.zeros_like(y))
    return y


def _ep_parts(x, b, bias, *, mesh, axis, size, tag, **kw):
    """Expert-parallel packed/sparse path: per-shard grouped programs over
    G/P experts each, concatenated under an expert-sharded constraint."""
    g = x.shape[0]
    _check_div("G", g, size)
    gl = g // size
    parts = shard_operand(b, size, axis="g")
    outs = []
    with mesh_namespace(tag):
        for s, bs in enumerate(parts):
            xs = x[s * gl:(s + 1) * gl]
            bias_s = bias[s * gl:(s + 1) * gl] if (
                bias is not None and bias.ndim == 2) else bias
            if gl == 1:
                # shard_operand squeezed the group axis (layout g=1);
                # run the single expert as a 2-D GEMM and restore the axis.
                b2 = bias_s[0] if (bias_s is not None
                                  and bias_s.ndim == 2) else bias_s
                outs.append(mp_dot(xs[0], bs, b2, **kw)[None])
            else:
                outs.append(mp_dot_grouped(xs, bs, bias_s, **kw))
    y = jnp.concatenate(outs, axis=0)
    return jax.lax.with_sharding_constraint(
        y, NamedSharding(mesh, P(axis, None, None)))
