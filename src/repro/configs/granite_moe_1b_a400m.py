"""granite-moe-1b-a400m — 32-expert top-8 MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=32, experts_per_token=8,
    rope_theta=10000.0, mlp="swiglu", norm="rms",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = ArchConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab=512,
    n_experts=8, experts_per_token=4,
    mlp="swiglu", norm="rms", tie_embeddings=True,
)
