"""mp_dot_grouped / mpgemm_grouped_pallas: einsum equivalence across the
precision policies, ragged groups via masking, fused-transpose VJP, and the
grouped plan/cache plumbing."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.blocking import plan_gemm, plan_grouped_gemm
from repro.core.gemm import mp_dot_grouped, mp_einsum
from repro.kernels.mpgemm import mpgemm_grouped_pallas
from repro.tuning import PlanCache, make_key, set_plan_cache, tune_grouped_gemm

G, M, K, N = 4, 24, 40, 24


@pytest.fixture
def ops(rng):
    x = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    return x, w


def _ref(x, w):
    return jnp.einsum("gmk,gkn->gmn", x.astype(jnp.float32),
                      w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("policy", ["fp32", "bf16", "int8"])
def test_forward_matches_einsum_reference(ops, policy, backend):
    x, w = ops
    y = mp_dot_grouped(x, w, policy=policy, backend=backend)
    ref = np.asarray(_ref(x, w))
    got = np.asarray(y, np.float32)
    if policy == "fp32":
        np.testing.assert_allclose(got, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(got, ref, atol=0.15)  # bf16 mantissa
    else:  # int8 dynamic per-tensor: bounded relative error vs fp32
        assert np.abs(got - ref).max() < 0.05 * np.abs(ref).max()


@pytest.mark.parametrize("policy", ["fp32", "bf16", "int8"])
def test_backends_agree(ops, policy):
    x, w = ops
    a = mp_dot_grouped(x, w, policy=policy, backend="xla")
    b = mp_dot_grouped(x, w, policy=policy, backend="interpret")
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=1e-5, rtol=1e-4)


def test_trans_w_matches_einsum(ops):
    x, w = ops
    wt = jnp.swapaxes(w, 1, 2)  # stored (G, N, K)
    y = mp_dot_grouped(x, wt, policy="fp32", trans_w=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(_ref(x, w)),
                               atol=1e-5)


@pytest.mark.parametrize("policy", ["fp32", "bf16"])
def test_vjp_matches_einsum(ops, policy):
    x, w = ops

    def f1(x, w):
        return jnp.sum(mp_dot_grouped(x, w, policy=policy,
                                      out_dtype=jnp.float32) ** 2)

    def f2(x, w):
        cd = jnp.float32 if policy == "fp32" else jnp.bfloat16
        return jnp.sum(jnp.einsum(
            "gmk,gkn->gmn", x.astype(cd), w.astype(cd),
            preferred_element_type=jnp.float32) ** 2)

    g1 = jax.grad(f1, (0, 1))(x, w)
    g2 = jax.grad(f2, (0, 1))(x, w)
    tol = 1e-4 if policy == "fp32" else 0.35  # bf16 bwd partial sums
    scale = max(float(jnp.abs(g2[0]).max()), 1.0)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol * scale)


def test_int8_vjp_is_ste_and_finite(ops):
    """int8 backward runs in the bf16 sibling (straight-through estimator)."""
    x, w = ops
    g = jax.grad(lambda w: jnp.sum(
        mp_dot_grouped(x, w, policy="int8") ** 2))(w)
    assert bool(jnp.all(jnp.isfinite(g))) and float(jnp.abs(g).sum()) > 0


def test_ragged_groups_mask_output_and_grads(ops):
    x, w = ops
    sizes = jnp.asarray([M, 10, 0, 17], jnp.int32)
    y = mp_dot_grouped(x, w, policy="fp32", group_sizes=sizes)
    ref = np.asarray(_ref(x, w))
    for gi, s in enumerate([M, 10, 0, 17]):
        assert np.all(np.asarray(y[gi, s:]) == 0.0)
        np.testing.assert_allclose(np.asarray(y[gi, :s]), ref[gi, :s],
                                   atol=1e-5)
    # masked rows contribute no gradient; group 2 (size 0) none at all
    dx = jax.grad(lambda x: jnp.sum(mp_dot_grouped(
        x, w, policy="fp32", group_sizes=sizes) ** 2))(x)
    assert np.all(np.asarray(dx[2]) == 0.0)
    assert np.all(np.asarray(dx[1, 10:]) == 0.0)
    assert float(jnp.abs(dx[0]).sum()) > 0


def test_bias_forward_and_grad(ops):
    x, w = ops
    bias = jnp.ones((G, N), jnp.float32)
    y = mp_dot_grouped(x, w, bias, policy="fp32")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_ref(x, w)) + 1.0, atol=1e-5)
    db = jax.grad(lambda b: jnp.sum(
        mp_dot_grouped(x, w, b, policy="fp32")))(bias)
    np.testing.assert_allclose(np.asarray(db), float(M), atol=1e-5)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_shared_1d_bias_all_backends_and_grad(ops, backend):
    """A shared (N,) bias broadcasts to every group on both backends, and
    its gradient sum-reduces back to (N,)."""
    x, w = ops
    bias = jnp.arange(N, dtype=jnp.float32)
    y = mp_dot_grouped(x, w, bias, policy="fp32", backend=backend)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_ref(x, w)) + np.arange(N),
                               atol=1e-5)
    db = jax.grad(lambda b: jnp.sum(mp_dot_grouped(
        x, w, b, policy="fp32", backend=backend)))(bias)
    assert db.shape == (N,)
    np.testing.assert_allclose(np.asarray(db), float(G * M), atol=1e-4)


def test_static_int8_weights_under_int8_policy(ops):
    """Static {"q","scale"} expert weights must dequantize to float (not the
    int8 policy's own compute dtype) before dynamic re-quantization."""
    from repro.core.quantization import quantize_tensor
    x, w = ops
    wq = quantize_tensor(w * 0.01)   # small scale: int8 truncation would zero it
    ref = np.asarray(_ref(x, w * 0.01))
    y = np.asarray(mp_dot_grouped(x, wq, policy="int8"), np.float32)
    assert np.abs(y).max() > 0.1 * np.abs(ref).max()   # not collapsed to ~0
    assert np.abs(y - ref).max() < 0.1 * np.abs(ref).max()


def test_grad_wrt_x_with_static_int8_weights(ops):
    """grad through mp_dot_grouped must work when w is a static {"q","scale"}
    dict (the bwd rule contracts against the dequantized array, not the
    dict) — the serving-weights MoE configuration."""
    from repro.core.quantization import quantize_tensor
    x, w = ops
    wq = quantize_tensor(w)
    dx = jax.grad(lambda x: jnp.sum(
        mp_dot_grouped(x, wq, policy="bf16") ** 2))(x)
    assert dx.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(dx))) and float(jnp.abs(dx).sum()) > 0


def test_non_f32_bias_grad_dtype(ops):
    """dbias cotangent must match a non-f32 bias primal's dtype."""
    x, w = ops
    bias = jnp.ones((G, N), jnp.bfloat16)
    db = jax.grad(lambda b: jnp.sum(mp_dot_grouped(
        x, w, b, policy="bf16", out_dtype=jnp.float32)))(bias)
    assert db.dtype == jnp.bfloat16 and db.shape == (G, N)


def test_kernel_epilogue_fusion(rng):
    a = jnp.asarray(rng.standard_normal((3, 16, 48)), "float32")
    b = jnp.asarray(rng.standard_normal((3, 48, 24)), "float32")
    bias = jnp.asarray(rng.standard_normal((3, 24)), "float32")
    y = mpgemm_grouped_pallas(a, b, alpha=0.5, bias=bias, activation="relu",
                              interpret=True)
    ref = jax.nn.relu(0.5 * _ref(a, b) + bias[:, None, :])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)


def test_grouped_plan_scaling_and_key_namespace():
    p2 = plan_gemm(M, N, K, "float32")
    pg = plan_grouped_gemm(G, M, N, K, "float32")
    assert pg.g == G and (pg.bm, pg.bn, pg.bk) == (p2.bm, p2.bn, p2.bk)
    assert pg.flops == G * p2.flops and pg.hbm_bytes == G * p2.hbm_bytes
    assert pg.vmem_bytes == p2.vmem_bytes          # group adds no working set
    assert abs(pg.cmr - p2.cmr) < 1e-9             # CMR is g-invariant
    k2 = make_key(M, N, K, "float32")
    kg = make_key(M, N, K, "float32", g=G)
    assert kg != k2 and kg.startswith(f"g{G}|")
    assert make_key(M, N, K, "float32", g=1) == k2  # 2-D schema unchanged


def test_tuned_grouped_plan_is_consumed(ops):
    """tune_grouped_gemm persists under the grouped key; mp_dot_grouped
    picks the tuned plan up transparently with identical numerics."""
    x, w = ops
    cache = PlanCache(None)
    res = tune_grouped_gemm(G, M, N, K, "float32", mode="modeled",
                            max_candidates=4, cache=cache)
    assert res.best.plan.g == G
    assert res.key in cache and cache.get(res.key).g == G
    baseline = mp_dot_grouped(x, w, policy="fp32", backend="interpret")
    prev = set_plan_cache(cache)
    try:
        tuned = mp_dot_grouped(x, w, policy="fp32", backend="interpret")
    finally:
        set_plan_cache(prev)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(baseline),
                               atol=1e-6)


def test_mp_einsum_routes_grouped_specs(ops):
    x, w = ops
    ref = np.asarray(_ref(x, w))
    y = mp_einsum("end,edf->enf", x, w, policy="fp32")
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5)
    wt = jnp.swapaxes(w, 1, 2)
    y2 = mp_einsum("bij,bkj->bik", x, wt, policy="fp32")
    np.testing.assert_allclose(np.asarray(y2), ref, atol=1e-5)
    # non-grouped specs still take the einsum path (shape sanity only)
    att = mp_einsum("bhqd,bhkd->bhqk",
                    jnp.ones((2, 2, 4, 8)), jnp.ones((2, 2, 4, 8)),
                    policy="fp32")
    assert att.shape == (2, 2, 4, 4)
