"""GemmSpec / EpilogueSpec registry: kernel-vs-oracle parity and grad
parity for the fused epilogues (gated activation, residual add) across the
spec matrix (2-D / grouped) x policies x backends, plus registry/key
plumbing."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import config as cfg
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.core.gemm_spec import (
    ACTIVATIONS, EpilogueSpec, GemmSpec, apply_epilogue, epilogue_kinds,
    get_epilogue, register_epilogue,
)
from repro.kernels.mpgemm import mpgemm_grouped_pallas, mpgemm_pallas
from repro.kernels.ref import mpgemm_ref
from repro.tuning import make_key

G, M, K, N = 3, 24, 40, 16


@pytest.fixture
def ops(rng):
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    e = jnp.asarray(rng.standard_normal((M, N)), "float32")
    return x, w, e


@pytest.fixture
def gops(rng):
    x = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    e = jnp.asarray(rng.standard_normal((G, M, N)), "float32")
    return x, w, e


def _fused_ref(x, w, ep_kind, act, extra):
    """Explicit jnp formula (independent of apply_epilogue) for the op."""
    acc = jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))
    a = ACTIVATIONS[act](acc)
    if ep_kind == "gated":
        return a * extra.astype(jnp.float32)
    if ep_kind == "residual":
        return a + extra.astype(jnp.float32)
    return a


# --- registry plumbing -------------------------------------------------------

def test_builtin_kinds_registered():
    assert set(epilogue_kinds()) >= {"linear", "gated", "residual"}
    assert get_epilogue("gated").extra_operands == ("gate",)
    assert get_epilogue("residual").extra_operands == ("residual",)


def test_unknown_kind_and_activation_raise():
    with pytest.raises(ValueError, match="unknown epilogue kind"):
        EpilogueSpec(kind="nope")
    with pytest.raises(ValueError, match="unknown activation"):
        EpilogueSpec(activation="tanhh")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_epilogue("linear", bwd=lambda *a: None,
                          needs_pre=lambda ep: False)(lambda *a: None)


def test_gemm_spec_validation():
    with pytest.raises(ValueError, match="tile_scaled"):
        GemmSpec(tile_scaled=True)
    with pytest.raises(ValueError, match="ragged"):
        GemmSpec(ragged=True)
    with pytest.raises(ValueError, match="pack time"):
        GemmSpec(packed=True, trans_b=True)
    assert GemmSpec(out_dtype=jnp.float32).out_dtype == "float32"


def test_epilogue_tag_namespaces_cache_keys():
    """Fused and unfused tunings must never collide; linear keys stay
    byte-identical to the pre-registry schema."""
    assert EpilogueSpec().tag == ""
    assert EpilogueSpec(kind="linear", activation="relu").tag == ""
    assert EpilogueSpec(kind="gated", activation="silu").tag == "gated-silu"
    assert EpilogueSpec(kind="residual").tag == "residual"
    base = make_key(M, N, K, "float32")
    assert make_key(M, N, K, "float32", epilogue="") == base
    fused = make_key(M, N, K, "float32", epilogue="gated-silu")
    assert fused != base and fused.endswith("|ep=gated-silu")
    assert fused != make_key(M, N, K, "float32", epilogue="residual")


def test_op_level_operand_validation(ops):
    x, w, e = ops
    with pytest.raises(ValueError, match="requires operand"):
        mp_dot(x, w, epilogue=EpilogueSpec(kind="gated", activation="silu"))
    with pytest.raises(ValueError, match="not consumed"):
        mp_dot(x, w, gate=e, residual=e)


# --- kernel vs oracle parity (spec x epilogue matrix) ------------------------

@pytest.mark.parametrize("kind,act", [
    ("linear", "relu"), ("gated", "silu"), ("gated", None),
    ("residual", None), ("residual", "gelu"),
])
@pytest.mark.parametrize("m,n,k", [(M, N, K), (100, 70, 50)])
def test_kernel_matches_oracle_2d(rng, kind, act, m, n, k):
    a = jnp.asarray(rng.standard_normal((m, k)), "float32")
    b = jnp.asarray(rng.standard_normal((k, n)), "float32")
    e = jnp.asarray(rng.standard_normal((m, n)), "float32")
    kw = {"gate": e} if kind == "gated" else (
        {"residual": e} if kind == "residual" else {})
    out = mpgemm_pallas(a, b, activation=act, interpret=True, **kw)
    ref = mpgemm_ref(a, b, activation=act, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("kind,act", [
    ("linear", "relu"), ("gated", "silu"), ("residual", None),
])
def test_kernel_matches_oracle_grouped(rng, kind, act):
    a = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    b = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    e = jnp.asarray(rng.standard_normal((G, M, N)), "float32")
    kw = {"gate": e} if kind == "gated" else (
        {"residual": e} if kind == "residual" else {})
    out = mpgemm_grouped_pallas(a, b, activation=act, interpret=True, **kw)
    ref = mpgemm_ref(a, b, activation=act, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_grouped_beta_c_epilogue(rng):
    """beta·C on the grouped path — new capability of the unified factory
    (the hand-cloned grouped kernel had no C term)."""
    a = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    b = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    c = jnp.asarray(rng.standard_normal((G, M, N)), "float32")
    out = mpgemm_grouped_pallas(a, b, c, beta=0.5, alpha=2.0,
                                activation="relu", interpret=True)
    ref = mpgemm_ref(a, b, c, beta=0.5, alpha=2.0, activation="relu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_apply_epilogue_is_shared_semantics(rng):
    """The oracle and the kernel both consume apply_epilogue — spot-check
    the composed order of operations directly."""
    acc = jnp.asarray(rng.standard_normal((4, 8)), "float32")
    bias = jnp.asarray(rng.standard_normal((1, 8)), "float32")
    g = jnp.asarray(rng.standard_normal((4, 8)), "float32")
    ep = EpilogueSpec(kind="gated", activation="silu", alpha=0.5)
    got = apply_epilogue(ep, acc, bias=bias, extras=(g,))
    want = jax.nn.silu(0.5 * acc + bias) * g
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# --- op-level forward parity (spec x epilogue x policy x backend) ------------

@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("policy", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("kind,act", [("gated", "silu"), ("residual", None),
                                      ("linear", "gelu")])
def test_mp_dot_fused_forward(ops, policy, backend, kind, act):
    x, w, e = ops
    kw = {"gate": e} if kind == "gated" else (
        {"residual": e} if kind == "residual" else {})
    y = mp_dot(x, w, policy=policy, backend=backend, activation=act, **kw)
    ref = np.asarray(_fused_ref(x, w, kind, act, e))
    got = np.asarray(y, np.float32)
    if policy == "fp32":
        np.testing.assert_allclose(got, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(got, ref, atol=0.25)
    else:  # int8 dynamic per-tensor: bounded relative error
        assert np.abs(got - ref).max() < 0.08 * max(np.abs(ref).max(), 1.0)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("policy", ["fp32", "bf16", "int8"])
@pytest.mark.parametrize("kind,act", [("gated", "silu"), ("residual", None)])
def test_mp_dot_grouped_fused_forward(gops, policy, backend, kind, act):
    x, w, e = gops
    kw = {"gate": e} if kind == "gated" else {"residual": e}
    y = mp_dot_grouped(x, w, policy=policy, backend=backend,
                       activation=act, out_dtype=jnp.float32, **kw)
    ref = np.asarray(_fused_ref(x, w, kind, act, e))
    got = np.asarray(y, np.float32)
    if policy == "fp32":
        np.testing.assert_allclose(got, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(got, ref, atol=0.25)
    else:
        assert np.abs(got - ref).max() < 0.08 * max(np.abs(ref).max(), 1.0)


@pytest.mark.parametrize("kind", ["gated", "residual"])
def test_fused_backends_agree(ops, kind):
    x, w, e = ops
    kw = {"gate": e} if kind == "gated" else {"residual": e}
    a = mp_dot(x, w, policy="bf16", backend="xla", activation="silu", **kw)
    b = mp_dot(x, w, policy="bf16", backend="interpret", activation="silu",
               **kw)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               atol=1e-5, rtol=1e-4)


# --- grad parity for the new fusions -----------------------------------------

@pytest.mark.parametrize("policy,tol", [("fp32", 1e-4), ("bf16", 0.35)])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_gated_grad_parity_2d(ops, policy, backend, tol):
    x, w, e = ops

    def fused(x, w, e):
        return jnp.sum(mp_dot(x, w, policy=policy, backend=backend,
                              activation="silu", gate=e,
                              out_dtype=jnp.float32) ** 2)

    def unfused(x, w, e):
        cd = jnp.float32 if policy == "fp32" else jnp.bfloat16
        h = jnp.matmul(x.astype(cd), w.astype(cd),
                       preferred_element_type=jnp.float32)
        return jnp.sum((jax.nn.silu(h) * e) ** 2)

    g1 = jax.grad(fused, (0, 1, 2))(x, w, e)
    g2 = jax.grad(unfused, (0, 1, 2))(x, w, e)
    scale = max(float(jnp.abs(g2[0]).max()), 1.0)
    for a, b in zip(g1, g2):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=tol * scale)


@pytest.mark.parametrize("act", [None, "gelu"])
def test_residual_grad_parity_2d(ops, act):
    x, w, e = ops

    def fused(x, w, e):
        return jnp.sum(mp_dot(x, w, policy="fp32", activation=act,
                              residual=e) ** 2)

    def unfused(x, w, e):
        return jnp.sum((_fused_ref(x, w, "residual", act, e)) ** 2)

    g1 = jax.grad(fused, (0, 1, 2))(x, w, e)
    g2 = jax.grad(unfused, (0, 1, 2))(x, w, e)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-4)


def test_gated_grad_parity_grouped(gops):
    x, w, e = gops

    def fused(x, w, e):
        return jnp.sum(mp_dot_grouped(x, w, policy="fp32",
                                      activation="silu", gate=e,
                                      out_dtype=jnp.float32) ** 2)

    def unfused(x, w, e):
        h = jnp.einsum("gmk,gkn->gmn", x, w,
                       preferred_element_type=jnp.float32)
        return jnp.sum((jax.nn.silu(h) * e) ** 2)

    g1 = jax.grad(fused, (0, 1, 2))(x, w, e)
    g2 = jax.grad(unfused, (0, 1, 2))(x, w, e)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-4)


def test_gated_with_bias_grad(ops):
    """dbias must flow through the activation derivative (Σ dz, not Σ dy)."""
    x, w, e = ops
    bias = jnp.asarray(np.linspace(-1, 1, N), "float32")

    def fused(b):
        return jnp.sum(mp_dot(x, w, b, policy="fp32", activation="silu",
                              gate=e) ** 2)

    def unfused(b):
        h = jnp.matmul(x, w) + b[None, :]
        return jnp.sum((jax.nn.silu(h) * e) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(fused)(bias)),
                               np.asarray(jax.grad(unfused)(bias)),
                               atol=1e-3, rtol=1e-4)


def test_ragged_gated_masks_grads(gops):
    """Fused epilogue composes with ragged group_sizes masking."""
    x, w, e = gops
    sizes = jnp.asarray([M, 7, 0], jnp.int32)
    dx = jax.grad(lambda x: jnp.sum(mp_dot_grouped(
        x, w, policy="fp32", activation="silu", gate=e,
        group_sizes=sizes) ** 2))(x)
    assert np.all(np.asarray(dx[2]) == 0.0)
    assert np.all(np.asarray(dx[1, 7:]) == 0.0)
    assert float(jnp.abs(dx[0]).sum()) > 0


def test_alpha_epilogue_grad_chains(ops):
    """y = alpha·(x@w): grads must carry the alpha factor (regression —
    the backward GEMMs once dropped it), while dbias (added after alpha)
    must not."""
    x, w, _ = ops
    bias = jnp.zeros((N,), jnp.float32)
    ep = EpilogueSpec(alpha=2.0)

    def fused(x, w, b):
        return jnp.sum(mp_dot(x, w, b, policy="fp32", epilogue=ep) ** 2)

    def reff(x, w, b):
        return jnp.sum((2.0 * (x @ w) + b[None, :]) ** 2)

    g1 = jax.grad(fused, (0, 1, 2))(x, w, bias)
    g2 = jax.grad(reff, (0, 1, 2))(x, w, bias)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-5)


# --- spec-aware tuning -------------------------------------------------------

def test_tune_gemm_epilogue_namespace_and_consumption(rng):
    """tune_gemm(epilogue=…) sweeps the fused spec (interpret launch carries
    the gate operand) and persists under the epilogue-tagged key, which the
    fused mp_dot launch then consumes — and the unfused key stays absent."""
    from repro.tuning import PlanCache, set_plan_cache, tune_gemm
    ep = EpilogueSpec(kind="gated", activation="silu")
    cache = PlanCache(None)
    res = tune_gemm(M, N, K, "float32", mode="interpret", max_candidates=3,
                    iters=1, epilogue=ep, cache=cache)
    assert res.key.endswith("|ep=gated-silu")
    assert res.key in cache
    assert make_key(M, N, K, "float32") not in cache
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    e = jnp.asarray(rng.standard_normal((M, N)), "float32")
    baseline = mp_dot(x, w, policy="fp32", backend="interpret",
                      activation="silu", gate=e)
    prev = set_plan_cache(cache)
    try:
        tuned = mp_dot(x, w, policy="fp32", backend="interpret",
                       activation="silu", gate=e)
    finally:
        set_plan_cache(prev)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(baseline),
                               atol=1e-6)


def test_tune_grouped_gemm_epilogue_beta_in_key():
    """A grouped tuning measured WITH a beta·C stream must persist under
    the beta+epilogue-tagged key the launch reads back (regression — the
    grouped tuner once keyed beta-carrying sweeps as beta=0)."""
    from repro.tuning import PlanCache, tune_grouped_gemm
    ep = EpilogueSpec(kind="residual", beta=1.0)
    cache = PlanCache(None)
    res = tune_grouped_gemm(G, M, N, K, "float32", mode="interpret",
                            max_candidates=2, iters=1, epilogue=ep,
                            cache=cache)
    assert "|beta=1|" in res.key and res.key.endswith("|ep=residual")
    assert res.key.startswith(f"g{G}|")
    assert res.key in cache
    assert make_key(M, N, K, "float32", g=G) not in cache


def test_extra_mn_inputs_priced_in_plan():
    """Fused operands enlarge the modeled working set and traffic (paper
    eqs (1)/(3) extended), so the planner can see the fused launch."""
    from repro.core.blocking import plan_with_blocks
    p0 = plan_with_blocks(256, 256, 256, 128, 128, 128, "float32")
    p1 = plan_with_blocks(256, 256, 256, 128, 128, 128, "float32",
                          extra_mn_inputs=1)
    assert p1.vmem_bytes > p0.vmem_bytes
    assert p1.hbm_bytes == p0.hbm_bytes + 256 * 256 * 4


# --- model-layer integration -------------------------------------------------

def test_swiglu_fused_matches_unfused(rng):
    """The fused SwiGLU MLP (layers.py) must match the unfused composition
    within compute-dtype rounding, forward and backward."""
    from repro.models.layers import init_swiglu, swiglu_mlp
    params = init_swiglu(jax.random.PRNGKey(0), 32, 64)
    x = jnp.asarray(rng.standard_normal((4, 8, 32)), "float32")
    r = jnp.asarray(rng.standard_normal((4, 8, 32)), "float32")

    def run(fused, params, x):
        with cfg.fused_epilogue(fused):
            return swiglu_mlp(params, x, "fp32", residual=r)

    yf = run(True, params, x)
    yu = run(False, params, x)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yu),
                               atol=1e-4, rtol=1e-4)
    gf = jax.grad(lambda p: jnp.sum(run(True, p, x) ** 2))(params)
    gu = jax.grad(lambda p: jnp.sum(run(False, p, x) ** 2))(params)
    for name in params:
        np.testing.assert_allclose(np.asarray(gf[name]),
                                   np.asarray(gu[name]),
                                   atol=1e-2, rtol=1e-3)


def test_spec_launch_normalizes_tile_scaled(rng):
    """mpgemm_pallas_spec must derive packed/tile_scaled from the ACTUAL
    operand: a default-constructed spec over a per-tile-scaled int8 payload
    still streams the scales (regression — a bare GemmSpec(packed=True)
    once skipped the dequant silently)."""
    from repro.core.blocking import plan_gemm
    from repro.kernels.mpgemm import mpgemm_pallas_spec
    from repro.packing import pack_operand
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    wp = pack_operand(w, plan_gemm(M, N, K, "float32", "int8"),
                      dtype="int8", backend="xla")
    assert wp.layout.per_tile_scales
    y = mpgemm_pallas_spec(x, b_packed=wp, spec=GemmSpec(packed=True),
                           out_dtype="float32", interpret=True)
    ref = jnp.matmul(x, w)
    # per-tile int8 quantization: close to the dense product, not garbage
    err = float(jnp.abs(y - ref).max())
    assert err < 0.05 * float(jnp.abs(ref).max()), err


def test_packed_weight_with_fused_epilogue(rng):
    """Registry epilogues compose with the packed-B path (spec matrix
    corner: packed x gated)."""
    from repro.core.blocking import plan_gemm
    from repro.packing import pack_operand
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    e = jnp.asarray(rng.standard_normal((M, N)), "float32")
    packed = pack_operand(w, plan_gemm(M, N, K, "float32"),
                          backend="interpret")
    with cfg.gemm_backend("interpret"):
        y = mp_dot(x, packed, policy="fp32", activation="silu", gate=e)
    ref = _fused_ref(x, w, "gated", "silu", e)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
