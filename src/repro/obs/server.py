"""Stdlib HTTP surfacing for the registry and tracer.

``start_metrics_server(port)`` spins up a ``ThreadingHTTPServer`` on a
daemon thread serving:

* ``/metrics``       — Prometheus text exposition of the ambient registry
* ``/metrics.json``  — the same snapshot as sorted JSON
* ``/trace``         — the ambient tracer's Chrome ``trace.json`` so far
                       (404 when tracing is off)

Port 0 binds an ephemeral port; the bound port is on the returned
handle.  The server reads shared state only through the registry/tracer
locks, so it is safe to scrape mid-run.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.obs import registry as _registry
from repro.obs import trace as _trace

__all__ = ["MetricsServer", "start_metrics_server"]


class _Handler(BaseHTTPRequestHandler):
    def _send(self, code: int, content_type: str, body: str) -> None:
        data = body.encode("utf-8")
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0]
        if path in ("/metrics", "/"):
            reg = _registry.get_registry()
            if reg is None:
                self._send(503, "text/plain; charset=utf-8",
                           "metrics disabled (REPRO_OBS=off)\n")
            else:
                self._send(200, "text/plain; version=0.0.4; charset=utf-8",
                           reg.prometheus_text())
        elif path == "/metrics.json":
            reg = _registry.get_registry()
            if reg is None:
                self._send(503, "application/json", "{}\n")
            else:
                self._send(200, "application/json",
                           reg.to_json(indent=1) + "\n")
        elif path == "/trace":
            tracer = _trace.get_tracer()
            if tracer is None:
                self._send(404, "text/plain; charset=utf-8",
                           "tracing off (use --trace-out / set_tracer)\n")
            else:
                self._send(200, "application/json",
                           json.dumps(tracer.chrome_trace()) + "\n")
        else:
            self._send(404, "text/plain; charset=utf-8",
                       "endpoints: /metrics /metrics.json /trace\n")

    def log_message(self, format: str, *args) -> None:
        pass  # scrapes must not spam the serve log


class MetricsServer:
    """A running observability endpoint; ``close()`` to stop."""

    def __init__(self, host: str, port: int):
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-metrics",
            daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def start_metrics_server(port: int = 0,
                         host: str = "127.0.0.1") -> MetricsServer:
    """Serve ``/metrics`` + ``/trace`` on a daemon thread; returns the
    handle (``.port`` resolves port 0 to the bound port)."""
    return MetricsServer(host, port)
