"""File-backed packed-weight cache — pack once, serve forever.

Packing a large serving checkpoint is a one-time cost, but it is paid at
every process start unless the packed payloads persist.  This module
mirrors ``tuning/plan_cache.py``'s design one level up the data ladder:

* **Keying** follows the plan cache's canonical-string discipline: a key
  names one packed artifact exactly —

      ``<weight name>|<layout.tag>|k{K}n{N}[g{G}]|src=<dtype>|sha=<digest>``

  The layout tag carries (bk, bn, payload dtype) — and, for tile-SPARSE
  layouts (``repro.sparse.TileSparseLayout``), the nnz count and the
  sparsity-pattern digest — so a *plan change* (retuning, hardware change)
  OR a *sparsity change* (different density, different pattern, sparse vs
  dense pack of the same weight) changes the key and transparently
  invalidates the cached payload: the cache can never serve tiles packed
  for a different block decision, and sparse-packed and dense-packed
  payloads of the same weight can never alias.  The content digest does
  the same for a weight update (new checkpoint -> new digest -> repack).

* **Persistence** is a directory: ``index.json`` (versioned, atomically
  replaced under the plan cache's advisory file lock) maps keys to
  ``.npz`` payload files written tmp-then-rename, so concurrent packers
  sharing a cache dir lose nothing and never read torn files.

* **Process-global behavior** is controlled by ``REPRO_PACK_CACHE``:
  unset — in-memory cache (packs are reused within the process);
  ``<dir>`` — persistent cache at that directory; ``off``/``0`` — disabled
  (every ``get_or_pack`` repacks).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
from pathlib import Path
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs.registry import counter_inc
from repro.packing.layout import PackedLayout, PackedOperand

_SCHEMA_VERSION = 1

_OFF_VALUES = ("off", "0", "none", "disabled")


def _count_weight_lookup(kind: str, result: str) -> None:
    counter_inc("packed_weight_cache_lookups_total",
                help="packed-weight cache reads by layout kind and outcome",
                kind=kind, result=result)


def _file_lock(path: Path):
    """The plan cache's advisory cross-process lock, shared lazily —
    importing repro.tuning at module level would close an import cycle
    (tuning -> kernels -> packing.layout -> this module)."""
    from repro.tuning.plan_cache import _file_lock as impl
    return impl(path)


def weight_digest(w) -> str:
    """Content fingerprint of a weight: sha256 over bytes + shape + dtype."""
    arr = np.asarray(w)
    h = hashlib.sha256()
    h.update(str(arr.shape).encode())
    h.update(str(arr.dtype).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def make_weight_key(name: str, w, layout) -> str:
    """Canonical cache key for one packed/sparse weight (module docstring).

    ``layout`` is any layout exposing ``tag``/``k``/``n``/``g``/
    ``orig_dtype`` — :class:`PackedLayout` or
    ``repro.sparse.TileSparseLayout``.  The tag is what keeps the two
    namespaces (and every sparsity pattern within the sparse one) from
    ever aliasing.
    """
    group = f"g{layout.g}|" if layout.g != 1 else ""
    return (f"{name}|{layout.tag}|{group}k{layout.k}n{layout.n}"
            f"|src={layout.orig_dtype}|sha={weight_digest(w)[:16]}")


def _operand_classes():
    """(layout kind -> (layout cls, operand cls)) — lazy so this module
    never hard-imports repro.sparse (packing is the lower layer)."""
    from repro.sparse.layout import TileSparseLayout, TileSparseOperand
    return {
        "packed": (PackedLayout, PackedOperand),
        "tile_sparse": (TileSparseLayout, TileSparseOperand),
    }


def _layout_kind(layout) -> str:
    return "packed" if isinstance(layout, PackedLayout) else "tile_sparse"


def _layout_to_dict(layout) -> dict:
    d = dataclasses.asdict(layout)
    # JSON round-trip turns the sparse index tuples into lists; the
    # constructor normalizes them back (TileSparseLayout.__post_init__).
    d["kind"] = _layout_kind(layout)
    return d


def _layout_from_dict(d: dict):
    d = dict(d)
    kind = d.pop("kind", "packed")
    layout_cls, _ = _operand_classes()[kind]
    return layout_cls(**d)


def _operand_for(layout, payload, scales):
    _, operand_cls = _operand_classes()[_layout_kind(layout)]
    return operand_cls(payload, scales, layout)


def _restore_payload_dtype(raw: np.ndarray, dtype_str: str):
    """Undo npz's erasure of extension dtypes.

    numpy has no native bfloat16 (etc.): ``np.savez`` writes such payloads
    as raw void records (``V2``) and ``np.load`` hands them back that way,
    which made every DISK hit of a bf16 payload silently miss (the
    ``jnp.asarray`` failed and ``get`` treated it as a corrupt entry).
    The layout records the true payload dtype, so a same-itemsize view
    restores it losslessly.
    """
    want = jnp.dtype(dtype_str)
    if raw.dtype != want and raw.dtype.kind == "V" \
            and raw.dtype.itemsize == want.itemsize:
        raw = raw.view(want)
    return jnp.asarray(raw)


class PackedWeightCache:
    """Directory-backed (or in-memory) map key -> :class:`PackedOperand`.

    Thread-safe.  ``path=None`` keeps packed payloads purely in memory —
    the process-global default, and what tests use.

    Example (runnable on CPU)::

        >>> import jax.numpy as jnp
        >>> from repro.packing import PackedWeightCache, pack_operand
        >>> cache = PackedWeightCache("/tmp/packed")
        >>> w = jnp.ones((64, 32))
        >>> p = cache.get_or_pack("mlp/w_up", w, (16, 16))
        >>> cache.get_or_pack("mlp/w_up", w, (16, 16)) is not None  # hit
        True
    """

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = Path(path) if path is not None else None
        self._lock = threading.RLock()
        self._mem: Dict[str, PackedOperand] = {}
        self.hits = 0
        self.misses = 0

    # -- index persistence ---------------------------------------------------

    def _index_path(self) -> Path:
        return self.path / "index.json"

    def _read_index(self) -> Dict[str, dict]:
        if self.path is None or not self._index_path().exists():
            return {}
        try:
            raw = json.loads(self._index_path().read_text())
        except (OSError, json.JSONDecodeError):
            return {}
        if not isinstance(raw, dict) or raw.get("version") != _SCHEMA_VERSION:
            return {}
        entries = raw.get("entries")
        return dict(entries) if isinstance(entries, dict) else {}

    def _write_index(self, entries: Dict[str, dict]) -> None:
        payload = json.dumps({"version": _SCHEMA_VERSION, "entries": entries},
                             indent=1, sort_keys=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(payload)
            os.replace(tmp, self._index_path())
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    # -- map interface -------------------------------------------------------

    def get(self, key: str) -> Optional[PackedOperand]:
        with self._lock:
            if key in self._mem:
                return self._mem[key]
            if self.path is None:
                return None
            entry = self._read_index().get(key)
            if entry is None:
                return None
            try:
                data = np.load(self.path / entry["file"])
                layout = _layout_from_dict(entry["layout"])
                payload = _restore_payload_dtype(data["payload"],
                                                 layout.dtype)
                scales = (jnp.asarray(data["scales"])
                          if "scales" in data.files else None)
            except (OSError, KeyError, TypeError, ValueError):
                return None  # corrupt entry == miss, never a crash
            packed = _operand_for(layout, payload, scales)
            self._mem[key] = packed
            return packed

    def put(self, key: str, packed: PackedOperand) -> None:
        with self._lock:
            self._mem[key] = packed
            if self.path is None:
                return
            self.path.mkdir(parents=True, exist_ok=True)
            fname = hashlib.sha256(key.encode()).hexdigest()[:24] + ".npz"
            arrays = {"payload": np.asarray(packed.payload)}
            if packed.scales is not None:
                arrays["scales"] = np.asarray(packed.scales)
            fd, tmp = tempfile.mkstemp(dir=str(self.path), suffix=".npz.tmp")
            try:
                with os.fdopen(fd, "wb") as f:
                    np.savez(f, **arrays)
                os.replace(tmp, self.path / fname)
            except BaseException:
                if os.path.exists(tmp):
                    os.unlink(tmp)
                raise
            with _file_lock(self._index_path()):
                entries = self._read_index()  # merge concurrent writers
                entries[key] = {"file": fname,
                                "layout": _layout_to_dict(packed.layout)}
                self._write_index(entries)

    def keys(self):
        with self._lock:
            disk = set(self._read_index()) if self.path is not None else set()
            return sorted(disk | set(self._mem))

    def clear(self) -> None:
        """Drop every entry (memory and, for a dir cache, the index — npz
        payload files are unlinked too: packed payloads can be GBs)."""
        with self._lock:
            self._mem = {}
            if self.path is None or not self.path.exists():
                return
            with _file_lock(self._index_path()):
                for entry in self._read_index().values():
                    f = self.path / entry.get("file", "")
                    if f.suffix == ".npz" and f.exists():
                        f.unlink()
                self._write_index({})

    def __contains__(self, key: str) -> bool:
        with self._lock:
            if key in self._mem:
                return True
            return self.path is not None and key in self._read_index()

    def __len__(self) -> int:
        return len(self.keys())

    # -- the convenience every caller wants ----------------------------------

    def get_or_pack(self, name: str, w, plan_or_blocks, *,
                    trans_w: bool = False, dtype=None,
                    backend: Optional[str] = None,
                    pack_fn: Optional[Callable] = None,
                    lead_axes: int = 0) -> PackedOperand:
        """Return the cached packed form of ``w`` under ``name``, packing
        (and caching) on miss.  Key = name + layout + content digest, so a
        plan change or weight update is an automatic miss (invalidation).
        ``pack_fn`` overrides the packer and ``lead_axes`` marks leading
        stack axes the packer vmaps over (scanned layer stacks), excluded
        from the per-slice layout but included in the digest."""
        from repro.packing.pack import _blocks_of, _layout_for, pack_operand
        bk, bn = _blocks_of(plan_or_blocks)
        core = w
        for _ in range(lead_axes):
            core = core[0]
        layout = _layout_for(core, bk, bn, trans_w=trans_w, dtype=dtype,
                             grouped=(core.ndim == 3))
        key = make_weight_key(name, w, layout)
        hit = self.get(key)
        if hit is not None:
            self.hits += 1
            _count_weight_lookup(_layout_kind(layout), "hit")
            return hit
        self.misses += 1
        _count_weight_lookup(_layout_kind(layout), "miss")
        packer = pack_fn or pack_operand
        packed = packer(w, (bk, bn), trans_w=trans_w, dtype=dtype,
                        backend=backend)
        self.put(key, packed)
        return packed

    def get_or_build(self, name: str, w, layout, build_fn: Callable):
        """Layout-first sibling of :meth:`get_or_pack` for operands whose
        layout is computed by the caller (the tile-sparse subsystem: the
        sparsity pattern IS part of the layout, and its tag/digest must be
        in the key).  ``build_fn()`` produces the operand on a miss."""
        key = make_weight_key(name, w, layout)
        hit = self.get(key)
        if hit is not None:
            self.hits += 1
            _count_weight_lookup(_layout_kind(layout), "hit")
            return hit
        self.misses += 1
        _count_weight_lookup(_layout_kind(layout), "miss")
        built = build_fn()
        self.put(key, built)
        return built


# -- process-global cache -----------------------------------------------------

_global_lock = threading.Lock()
_global_cache: Optional[PackedWeightCache] = None
_global_configured = False


def _env_cache() -> Optional[PackedWeightCache]:
    env = os.environ.get("REPRO_PACK_CACHE", "").strip()
    if env.lower() in _OFF_VALUES:
        return None
    if env:
        return PackedWeightCache(env)
    return PackedWeightCache(None)  # in-memory process-global default


def get_pack_cache() -> Optional[PackedWeightCache]:
    """The process-global packed-weight cache (None == disabled)."""
    global _global_cache, _global_configured
    with _global_lock:
        if not _global_configured:
            _global_cache = _env_cache()
            _global_configured = True
        return _global_cache


def set_pack_cache(cache: Optional[PackedWeightCache]):
    """Install ``cache`` as the process-global cache; returns the previous.

    ``None`` disables caching (every pack_params call repacks).
    """
    global _global_cache, _global_configured
    with _global_lock:
        prev = _global_cache if _global_configured else None
        _global_cache = cache
        _global_configured = True
        return prev
