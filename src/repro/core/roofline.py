"""Three-term roofline from a compiled AOT artifact.

    compute_s    = HLO_FLOPs(per-device) / peak_FLOP/s
    memory_s     = HLO_bytes(per-device) / HBM_bw
    collective_s = collective_bytes(per-device) / link_bw

(The per-device HLO module is the post-SPMD program, so dividing per-device
terms by per-chip rates is identical to the global/(chips x rate) form.)

FLOPs/bytes come from core.hlo_analysis (NOT cost_analysis: XLA counts while
bodies once; our stacks are scanned).  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) gives the useful-compute ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.constants import DEFAULT_HW, HardwareSpec
from repro.core.hlo_analysis import HloCost, analyze_hlo_text


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # per-device HLO terms
    flops: float
    dot_flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    # seconds
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    # useful-compute accounting
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs * chips)
    step_s: float                # max of the three terms (no-overlap bound)
    hw_peak_used: float
    notes: str = ""

    def row(self) -> str:
        return (
            f"{self.arch:>22s} {self.shape:>12s} {self.mesh:>6s} "
            f"comp={self.compute_s:9.4f}s mem={self.memory_s:9.4f}s "
            f"coll={self.collective_s:9.4f}s -> {self.bottleneck:10s} "
            f"useful={self.useful_ratio:6.3f}"
        )


def model_flops(cfg, shape, kind: str) -> float:
    """6*N*D for training; 2*N*D for inference forward (per generated token
    for decode).  N = active params."""
    n_active = cfg.active_params()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_report(
    *, arch: str, shape_cfg, mesh_name: str, n_chips: int, hlo: HloCost,
    cfg, kind: str, policy: str = "bf16", hw: HardwareSpec = DEFAULT_HW,
    notes: str = "",
) -> RooflineReport:
    peak = hw.peak_flops_bf16 if policy != "fp32" else hw.peak_flops_fp32
    if policy == "int8":
        peak = hw.peak_ops_int8
    compute_s = hlo.flops / peak
    memory_s = hlo.hbm_bytes / hw.hbm_bw
    wire = getattr(hlo, "wire_bytes", 0.0) or hlo.collective_bytes
    collective_s = wire / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape_cfg, kind)
    useful = mf / max(1.0, hlo.flops * n_chips)
    return RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, n_chips=n_chips,
        flops=hlo.flops, dot_flops=hlo.dot_flops, hbm_bytes=hlo.hbm_bytes,
        collective_bytes=hlo.collective_bytes,
        collective_by_kind=dict(hlo.collective_by_kind),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops_global=mf, useful_ratio=useful,
        step_s=max(terms.values()), hw_peak_used=peak, notes=notes,
    )


def report_to_dict(r: RooflineReport) -> Dict:
    return dataclasses.asdict(r)
