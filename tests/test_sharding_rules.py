"""Sharding rules validated on a real (small) mesh in a subprocess — the
main pytest process must keep a single device, so the 8-device check runs
via a child interpreter."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import base as cb
from repro.distributed.sharding import param_pspec


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


@pytest.mark.parametrize("path,shape,expect", [
    ("embed", (49280, 1024), ("model", "data")),
    ("head", (1024, 49280), ("data", "model")),
    ("stack/0/attn/wq", (24, 1024, 1024), (None, "data", "model")),
    ("stack/0/attn/wo", (24, 1024, 1024), (None, "model", "data")),
    ("stack/0/mlp/w_gate", (24, 1024, 512), (None, "data", "model")),
    # granite experts: E=32 divisible by model=16 -> expert parallelism
    ("stack/0/w_gate", (24, 32, 1024, 512), (None, "model", "data", None)),
    # mixtral experts: E=8 not divisible -> TP inside experts
    ("stack/0/w_up", (56, 8, 6144, 16384), (None, None, "data", "model")),
    ("stack/0/ln1/scale", (24, 1024), (None, None)),
    # vocab NOT divisible: guard drops the axis
    ("embed_odd", (49155, 1024), (None, "data")),
])
def test_param_rules(path, shape, expect):
    cfg = cb.get("granite-moe-1b-a400m")
    name = "embed" if path == "embed_odd" else path
    spec = param_pspec(name, shape, cfg, _FakeMesh())
    assert tuple(spec) == expect, (path, tuple(spec))


_CHILD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import base as cb
    from repro.distributed import sharding as sh, act
    from repro.launch.mesh import make_test_mesh
    from repro.models.transformer import build_model

    mesh = make_test_mesh(2, 2, multi_pod=True)   # (2,2,2) pod/data/model
    cfg = cb.get("granite-moe-1b-a400m", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    shard = sh.params_shardings(params, cfg, mesh)
    params = jax.tree_util.tree_map(jax.device_put, params, shard)
    batch = {"tokens": jnp.zeros((4, 17), jnp.int32)}
    bshard = sh.batch_shardings(batch, mesh)
    batch = jax.tree_util.tree_map(jax.device_put, batch, bshard)
    with mesh, act.use_mesh(mesh):
        loss = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), loss
    # decode path on the mesh
    caches = model.init_caches(4, 32)
    cshard = sh.caches_shardings(jax.eval_shape(lambda: caches), cfg, mesh)
    caches = jax.tree_util.tree_map(jax.device_put, caches, cshard)
    with mesh, act.use_mesh(mesh):
        logits, caches = jax.jit(model.decode_step)(
            params, jnp.zeros((4, 1), jnp.int32), caches, jnp.int32(0))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    print("SHARDED_OK", float(loss))
""")


def test_sharded_execution_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-2000:]
