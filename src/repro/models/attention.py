"""Attention cores: chunked (memory-efficient, differentiable), banded
sliding-window, single-step decode, and the Pallas flash kernel dispatch.

Backend policy mirrors core/gemm.py: on TPU the Pallas flash kernel runs; on
CPU (tests / dry-run) the pure-XLA chunked implementation lowers — identical
math, identical asymptotic memory behaviour (online softmax over KV blocks).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import config as cfg
from repro.distributed import act
from repro.kernels.flash_attention import flash_attention

NEG_INF = -1e30


def _repeat_kv(k, h: int):
    """(B, Hkv, T, D) -> (B, H, T, D) by group broadcast (no copy under XLA)."""
    b, hkv, t, d = k.shape
    if hkv == h:
        return k
    g = h // hkv
    return jnp.broadcast_to(k[:, :, None], (b, hkv, g, t, d)).reshape(b, h, t, d)


def _pad_heads_for_tp(q, k, v):
    """Pad the head dim to a multiple of the mesh's 'model' axis.

    When H does not divide the TP axis (phi3-medium: 40 heads on a 16-wide
    axis), the divisibility guard would REPLICATE attention across the axis
    — 16x the flops and logits traffic per device (measured: phi3-medium
    prefill_32k memory term 20.3s vs 1.7s compute).  Padding to the next
    multiple (40->48) costs 20% padded compute but shards 16 ways: ~13x net
    reduction.  K/V are expanded to full MHA first so padded q heads pair
    with zero K/V (softmax over zero logits -> zero output, sliced off).
    Returns (q, k, v, original_h)."""
    h = q.shape[1]
    mesh = act.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return q, k, v, h
    m = mesh.shape["model"]
    if h % m == 0:
        return q, k, v, h
    hp = -(-h // m) * m
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    pad = [(0, 0), (0, hp - h), (0, 0), (0, 0)]
    return jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad), h


def dense_attention(q, k, v, *, causal=True, window=None, scale=None, lengths=None):
    """Reference/dense path; fine for short T (smoke tests, decode)."""
    q, k, v, h_orig = _pad_heads_for_tp(q, k, v)
    b, h, tq, d = q.shape
    tk = k.shape[2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    q = act.constrain(q, "batch", "model", None, None)
    k = act.constrain(k, "batch", "model", None, None)
    v = act.constrain(v, "batch", "model", None, None)
    scale = scale if scale is not None else 1.0 / d ** 0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    qi = jnp.arange(tq)[:, None] + (tk - tq)
    ki = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    mask = mask[None, None]
    if lengths is not None:  # per-example valid KV length (decode)
        mask = mask & (ki[None, None] < lengths[:, None, None, None])
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32).astype(q.dtype)
    return out[:, :h_orig]


def chunked_attention(
    q, k, v, *, causal=True, scale=None,
    q_chunk: int = 1024, kv_chunk: int = 1024,
):
    """Online-softmax attention scanning q-chunks x kv-chunks (XLA path).

    Memory is O(q_chunk * kv_chunk) per step instead of O(Tq*Tk); the scan
    body is checkpointed so backward recomputes chunk logits (flash-style).
    """
    q, k, v, h_orig = _pad_heads_for_tp(q, k, v)
    b, h, tq, d = q.shape
    tk = k.shape[2]
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    q = act.constrain(q, "batch", "model", None, None)
    k = act.constrain(k, "batch", "model", None, None)
    v = act.constrain(v, "batch", "model", None, None)
    scale = scale if scale is not None else 1.0 / d ** 0.5
    q_chunk = min(q_chunk, tq)
    kv_chunk = min(kv_chunk, tk)
    # Pad to chunk multiples.
    pq = (-tq) % q_chunk
    pk = (-tk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = qp.shape[2] // q_chunk
    nk = kp.shape[2] // kv_chunk
    offset = tk - tq  # right-aligned causal (prefill continuation)

    def _block(i, j, qblk, kblk, vblk, m, l, acc, need_mask=True):
        """One (q-chunk i, kv-chunk j) online-softmax update.

        ``need_mask=False`` skips the causal/tail select pass entirely —
        valid for strictly-below-diagonal blocks when q_chunk == kv_chunk
        and tq == tk (every key predates every query and no tail padding
        is touched).  Elides a full read+write over the logits block."""
        s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk,
                       preferred_element_type=jnp.float32) * scale
        if need_mask:
            qpos = i * q_chunk + jnp.arange(q_chunk)[:, None] + offset
            kpos = j * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = kpos < tk
            if causal:
                mask &= kpos <= qpos
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def _init(nq_):
        return (
            act.constrain(jnp.full((b, h, nq_ * q_chunk), NEG_INF,
                                   jnp.float32), "batch", "model", None),
            act.constrain(jnp.zeros((b, h, nq_ * q_chunk), jnp.float32),
                          "batch", "model", None),
            act.constrain(jnp.zeros((b, h, nq_ * q_chunk, d), jnp.float32),
                          "batch", "model", None, None),
        )

    if causal and nq > 1 and tq == tk:
        # TRIANGULAR block schedule: only (i, j<=i) pairs are visited, so
        # the ~half of blocks that the causal mask fully kills never load,
        # compute, or spill logits (48% of attention HBM traffic at nq=32;
        # EXPERIMENTS.md §Perf, phi3-medium hillclimb iteration 2).
        # Strictly-below-diagonal pairs additionally skip the mask select
        # pass (iteration 3) when chunk sizes allow.
        maskless_ok = (q_chunk == kv_chunk)

        def make_step(need_mask):
            def pair_step(carry, ij):
                m, l, acc = carry
                i, j = ij
                qblk = jax.lax.dynamic_slice(
                    qp, (0, 0, i * q_chunk, 0), (b, h, q_chunk, d))
                kblk = jax.lax.dynamic_slice(
                    kp, (0, 0, j * kv_chunk, 0), (b, h, kv_chunk, d))
                vblk = jax.lax.dynamic_slice(
                    vp, (0, 0, j * kv_chunk, 0), (b, h, kv_chunk, d))
                mi = jax.lax.dynamic_slice(
                    m, (0, 0, i * q_chunk), (b, h, q_chunk))
                li = jax.lax.dynamic_slice(
                    l, (0, 0, i * q_chunk), (b, h, q_chunk))
                ai = jax.lax.dynamic_slice(
                    acc, (0, 0, i * q_chunk, 0), (b, h, q_chunk, d))
                mi, li, ai = _block(i, j, qblk, kblk, vblk, mi, li, ai,
                                    need_mask=need_mask)
                m = jax.lax.dynamic_update_slice(m, mi, (0, 0, i * q_chunk))
                l = jax.lax.dynamic_update_slice(l, li, (0, 0, i * q_chunk))
                acc = jax.lax.dynamic_update_slice(
                    acc, ai, (0, 0, i * q_chunk, 0))
                return (m, l, acc), None
            return pair_step

        carry = _init(nq)
        offdiag = [(i, j) for i in range(nq) for j in range(i)]
        if offdiag and maskless_ok:
            pi = jnp.asarray([p_[0] for p_ in offdiag], jnp.int32)
            pj = jnp.asarray([p_[1] for p_ in offdiag], jnp.int32)
            carry, _ = jax.lax.scan(
                jax.checkpoint(make_step(False)), carry, (pi, pj))
            diag = [(i, i) for i in range(nq)]
        else:
            diag = [(i, j) for i in range(nq) for j in range(i + 1)]
        pi = jnp.asarray([p_[0] for p_ in diag], jnp.int32)
        pj = jnp.asarray([p_[1] for p_ in diag], jnp.int32)
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(make_step(True)), carry, (pi, pj))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        return out[:, :h_orig, :tq]

    # Rectangular schedule (cross-attention / uneven tq,tk).
    qs = qp.reshape(b, h, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    ks = kp.reshape(b, h, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, h, nk, kv_chunk, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_blk):
        qi_idx, qblk = qi_blk

        def kv_step(carry, kv_blk):
            m, l, acc = carry
            kj_idx, kblk, vblk = kv_blk
            m, l, acc = _block(qi_idx, kj_idx, qblk, kblk, vblk, m, l, acc)
            return (m, l, acc), None

        init = (
            act.constrain(jnp.full((b, h, q_chunk), NEG_INF, jnp.float32),
                          "batch", "model", None),
            act.constrain(jnp.zeros((b, h, q_chunk), jnp.float32),
                          "batch", "model", None),
            act.constrain(jnp.zeros((b, h, q_chunk, d), jnp.float32),
                          "batch", "model", None, None),
        )
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(kv_step), init, (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, nq * q_chunk, d)
    return out[:, :h_orig, :tq]


def banded_window_attention(q, k, v, *, window: int, scale=None):
    """Sliding-window self-attention with truly sub-quadratic FLOPs.

    Queries are grouped into blocks of size ``window``; each block attends to
    itself and its predecessor (2*window keys) under the exact causal+window
    mask.  HLO FLOPs are O(T * 2*window * d) — this is what makes the
    long_500k shape lowerable for SWA architectures.
    """
    q, k, v, h_orig = _pad_heads_for_tp(q, k, v)
    b, h, t, d = q.shape
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    q = act.constrain(q, "batch", "model", None, None)
    k = act.constrain(k, "batch", "model", None, None)
    v = act.constrain(v, "batch", "model", None, None)
    scale = scale if scale is not None else 1.0 / d ** 0.5
    w = window
    pad = (-t) % w
    tp = t + pad
    nb = tp // w
    qb = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(b, h, nb, w, d)
    kb = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(b, h, nb, w, d)
    vb = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))).reshape(b, h, nb, w, d)
    # Previous block of K/V (zeros for block 0).
    kprev = jnp.concatenate([jnp.zeros_like(kb[:, :, :1]), kb[:, :, :-1]], axis=2)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :, :1]), vb[:, :, :-1]], axis=2)
    k2 = jnp.concatenate([kprev, kb], axis=3)   # (b,h,nb,2w,d)
    v2 = jnp.concatenate([vprev, vb], axis=3)
    s = jnp.einsum("bhnqd,bhnkd->bhnqk", qb, k2,
                   preferred_element_type=jnp.float32) * scale
    qi = jnp.arange(w)[:, None] + w             # position within [prev|self]
    ki = jnp.arange(2 * w)[None, :]
    mask = (ki <= qi) & (ki > qi - w)
    blk0_mask = mask & (ki >= w)                # block 0 has no predecessor
    bidx = jnp.arange(nb)[:, None, None]
    full_mask = jnp.where(bidx == 0, blk0_mask[None], mask[None])
    s = jnp.where(full_mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhnqk,bhnkd->bhnqd", p.astype(v2.dtype), v2,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, h, tp, d)[:, :h_orig, :t]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, window=None, scale=None):
    """One-token attention against a (possibly ring-buffered) KV cache.

    q: (B, H, 1, D); caches: (B, Hkv, S, D); lengths: (B,) valid entries.
    GQA is computed in GROUPED form — q reshaped to (B, Hkv, G, D) and
    contracted against the (B, Hkv, S, D) cache directly — so the KV heads
    are never repeated/materialized.  This keeps the cache's
    sequence-parallel sharding (S over 'model') intact: the softmax
    reductions over the sharded S axis lower to small all-reduces
    (flash-decode style) instead of cache replication.

    For ring caches (SWA), entries are stored mod S and all S slots are
    valid once the ring has wrapped — the mask is on slot validity, not
    recency (the ring overwrite already evicts out-of-window keys).
    """
    b, h, _, d = q.shape
    hkv, s_max = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    scale = scale if scale is not None else 1.0 / d ** 0.5
    # Barrier anchors the (layer-sliced) cache values inside the layer loop:
    # without it, XLA:CPU hoists the bf16->f32 dot-operand upcast out of the
    # loop and maintains a full f32 shadow copy of the stacked cache in the
    # while carry (2x cache memory + full-cache converts every iteration).
    k_cache, v_cache = jax.lax.optimization_barrier((k_cache, v_cache))
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    mask = jnp.arange(s_max)[None, None, None] < lengths[:, None, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(b, h, 1, d).astype(q.dtype)


try:
    from jax import shard_map as _shard_map  # jax >= 0.7 name
except ImportError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map
from jax.sharding import PartitionSpec as P


def flash_decode_sharded(q, k_cache, v_cache, k_new, v_new, pos, mesh,
                         *, scale=None):
    """Sequence-parallel flash decode under shard_map.

    The KV cache stays sharded (batch over the data axes, sequence over
    'model').  Each model shard:
      * writes the new K/V row ONLY if the ring slot falls in its range
        (lax.cond — no full-cache select rewrite, unlike partitioned DUS),
      * computes attention over its local sequence chunk in f32 (cast of a
        bounded per-layer slice — no whole-stack f32 shadow copies),
      * combines with a log-sum-exp psum over 'model' (flash-decode).
    This is the distributed analogue of the paper's K-dim blocking with a
    resident accumulator: the reduction is streamed in shards and combined
    once.  Returns (o, k_cache, v_cache)."""
    b, h, _, d = q.shape
    hkv = k_cache.shape[1]
    sm = scale if scale is not None else 1.0 / d ** 0.5
    m_size = mesh.shape["model"]

    def body(q, kc, vc, kn, vn, pos):
        sl = kc.shape[2]
        midx = jax.lax.axis_index("model")
        slot = pos % (sl * m_size)
        local_start = midx * sl
        in_range = (slot >= local_start) & (slot < local_start + sl)

        def write(c, new):
            return jax.lax.dynamic_update_slice(
                c, new.astype(c.dtype), (0, 0, slot - local_start, 0))

        kc = jax.lax.cond(in_range, lambda: write(kc, kn), lambda: kc)
        vc = jax.lax.cond(in_range, lambda: write(vc, vn), lambda: vc)

        bl = q.shape[0]
        g = h // hkv
        # Keep cache operands in their stored bf16 and accumulate f32 via
        # preferred_element_type: casting the cache slice to f32 here makes
        # XLA maintain a full f32 shadow of the stacked cache in the layer
        # scan carry (measured +30 GB/step; EXPERIMENTS.md §Perf).
        qg = q.reshape(bl, hkv, g, d).astype(kc.dtype)
        logits = jnp.einsum("bhgd,bhkd->bhgk", qg, kc,
                            preferred_element_type=jnp.float32) * sm
        length = jnp.minimum(pos + 1, sl * m_size)
        valid = (local_start + jnp.arange(sl))[None, None, None] < length
        logits = jnp.where(valid, logits, NEG_INF)
        m_loc = logits.max(-1)
        m_glob = jax.lax.pmax(m_loc, "model")
        p = jnp.exp(logits - m_glob[..., None])
        l_glob = jax.lax.psum(p.sum(-1), "model")
        o_glob = jax.lax.psum(
            jnp.einsum("bhgk,bhkd->bhgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32), "model")
        o = o_glob / jnp.maximum(l_glob[..., None], 1e-30)
        return o.reshape(bl, h, 1, d).astype(q.dtype), kc, vc

    da = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = da if len(da) > 1 else da[0]
    qs = P(bspec, None, None, None)
    cs = P(bspec, None, "model", None)
    return _shard_map(
        body, mesh=mesh,
        in_specs=(qs, cs, cs, qs, qs, P()),
        out_specs=(qs, cs, cs),
    )(q, k_cache, v_cache, k_new, v_new, pos)


def can_flash_decode(q, k_cache, mesh) -> bool:
    import os
    if os.environ.get("REPRO_NO_FLASH_DECODE"):
        return False
    if mesh is None or "model" not in mesh.axis_names:
        return False
    b, h = q.shape[0], q.shape[1]
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    ddp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            ddp *= mesh.shape[a]
    return (b % ddp == 0 and s % mesh.shape["model"] == 0
            and h % hkv == 0 and "data" in mesh.axis_names)


def attention_core(
    q, k, v, *, causal=True, window: Optional[int] = None, scale=None,
    backend: Optional[str] = None,
):
    """Prefill/train dispatch: Pallas flash on TPU, chunked/banded on XLA."""
    backend = backend or cfg.get_gemm_backend()
    t = q.shape[2]
    if backend in ("pallas", "interpret"):
        return flash_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            interpret=(backend == "interpret"),
        )
    if window is not None and causal and t > 2 * window:
        return banded_window_attention(q, k, v, window=window, scale=scale)
    if t <= 2048:
        return dense_attention(q, k, v, causal=causal, window=window, scale=scale)
    return chunked_attention(q, k, v, causal=causal, scale=scale)


# --------------------------------------------------------------------------
# Paged attention (continuous-batching serving; docs/serving.md)
#
# KV lives in a pooled page array (P, Hkv, page_size, D); each request's
# logical KV stream is the concatenation of the pages its block-table row
# names.  Positions in the math below are LOGICAL (page j, offset o ->
# j*page_size + o); which physical page backs them is irrelevant to masking.
# --------------------------------------------------------------------------


def paged_kv_write(k_pages, v_pages, k_new, v_new, block_tables, q_start,
                   n_valid):
    """Scatter a (B, C) chunk of fresh K/V rows into the page pool.

    ``k_new``/``v_new``: (B, Hkv, C, D); token i of request b lands at
    logical position ``q_start[b] + i`` -> physical page
    ``block_tables[b, pos // ps]``, offset ``pos % ps``.  Rows with
    ``i >= n_valid[b]`` are dead: they are routed to the reserved scratch
    page 0 (slot ``(b*C + i) % ps`` — scratch content is never read as
    valid, the attention mask kills it).
    """
    b, hkv, c, d = k_new.shape
    ps = k_pages.shape[2]
    w = block_tables.shape[1]
    pos = q_start[:, None] + jnp.arange(c)[None, :]            # (B, C)
    page = jnp.take_along_axis(
        block_tables, jnp.clip(pos // ps, 0, w - 1), axis=1)   # (B, C)
    offset = pos % ps
    valid = jnp.arange(c)[None, :] < n_valid[:, None]
    scratch_off = (jnp.arange(c)[None, :] + jnp.arange(b)[:, None] * c) % ps
    page = jnp.where(valid, page, 0)
    offset = jnp.where(valid, offset, scratch_off)
    pg = page.reshape(-1)
    off = offset.reshape(-1)
    k_rows = k_new.transpose(0, 2, 1, 3).reshape(b * c, hkv, d)
    v_rows = v_new.transpose(0, 2, 1, 3).reshape(b * c, hkv, d)
    k_pages = k_pages.at[pg, :, off].set(k_rows.astype(k_pages.dtype))
    v_pages = v_pages.at[pg, :, off].set(v_rows.astype(v_pages.dtype))
    return k_pages, v_pages


def paged_attention_ref(q, k_pages, v_pages, block_tables, q_start, lengths,
                        *, causal=True, window=None, scale=None):
    """Pure-XLA paged attention: gather the block-table pages into a dense
    per-request KV stream, then masked grouped-GQA softmax.  Numerically
    the oracle for the Pallas kernel and the CPU serving path."""
    b, h, tq, d = q.shape
    p_pages, hkv, ps, _ = k_pages.shape
    w = block_tables.shape[1]
    g = h // hkv
    s_max = w * ps
    scale = scale if scale is not None else 1.0 / d ** 0.5
    tok = (block_tables[:, :, None] * ps
           + jnp.arange(ps)[None, None, :]).reshape(b, s_max)   # (B, S)
    kf = k_pages.transpose(0, 2, 1, 3).reshape(p_pages * ps, hkv, d)
    vf = v_pages.transpose(0, 2, 1, 3).reshape(p_pages * ps, hkv, d)
    k = kf[tok].transpose(0, 2, 1, 3)                           # (B, Hkv, S, D)
    v = vf[tok].transpose(0, 2, 1, 3)
    qg = q.reshape(b, hkv, g, tq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    qi = q_start[:, None] + jnp.arange(tq)[None, :]             # (B, Tq)
    ki = jnp.arange(s_max)
    mask = ki[None, None, :] < lengths[:, None, None]           # (B, 1, S)
    if causal:
        mask = mask & (ki[None, None, :] <= qi[:, :, None])     # (B, Tq, S)
    if window is not None:
        mask = mask & (ki[None, None, :] > qi[:, :, None] - window)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)
    return o.reshape(b, h, tq, d).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, q_start, lengths, *,
                    causal=True, window: Optional[int] = None, scale=None,
                    backend: Optional[str] = None):
    """Serving dispatch for paged KV: Pallas block-table kernel on TPU,
    XLA gather reference elsewhere (same math, same logical masking)."""
    backend = backend or cfg.get_gemm_backend()
    if backend in ("pallas", "interpret"):
        from repro.kernels.flash_attention import paged_flash_attention
        return paged_flash_attention(
            q, k_pages, v_pages, block_tables, q_start, lengths,
            causal=causal, window=window, scale=scale,
            interpret=(backend == "interpret"))
    return paged_attention_ref(
        q, k_pages, v_pages, block_tables, q_start, lengths,
        causal=causal, window=window, scale=scale)
