"""Recurrent blocks: RWKV-6 ("Finch") time/channel mix and RecurrentGemma's
RG-LRU + causal-conv Griffin block.

Both recurrences are processed in CHUNKS with an exact inner scan; the outer
chunk scan is checkpointed, so backward memory is O(T / chunk) boundary
states — the paper's blocking discipline (bound the resident working set,
stream the reduction) applied to linear recurrences instead of GEMM K-loops.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.models.layers import dense_init, rmsnorm

CHUNK = 128


def _chunk_scan(step_fn, state, xs, chunk: int):
    """scan(step_fn) over leading time axis, chunked + checkpointed.

    xs leaves: (T, ...).  The T % chunk tail runs as a separate unpadded
    scan — zero-padding the tail would run extra recurrence steps and
    corrupt the carried state (caught by tests/test_recurrent.py).
    """
    t = jax.tree_util.tree_leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    n_full = t // chunk
    rem = t - n_full * chunk
    ys_parts = []
    if n_full:
        xs_main = jax.tree_util.tree_map(
            lambda a: a[: n_full * chunk].reshape(
                (n_full, chunk) + a.shape[1:]), xs)

        def chunk_fn(carry, xc):
            return jax.lax.scan(step_fn, carry, xc)

        state, ys = jax.lax.scan(jax.checkpoint(chunk_fn), state, xs_main)
        ys_parts.append(jax.tree_util.tree_map(
            lambda a: a.reshape((n_full * chunk,) + a.shape[2:]), ys))
    if rem:
        xs_rem = jax.tree_util.tree_map(lambda a: a[n_full * chunk:], xs)
        state, ys_rem = jax.lax.scan(step_fn, state, xs_rem)
        ys_parts.append(ys_rem)
    if len(ys_parts) == 1:
        return state, ys_parts[0]
    ys = jax.tree_util.tree_map(
        lambda *parts: jnp.concatenate(parts, axis=0), *ys_parts)
    return state, ys


# =========================== RWKV-6 (Finch) ===================================

def init_rwkv(key, cfg):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    r = 32  # low-rank for data-dependent lerp / decay
    ks = jax.random.split(key, 12)
    return {
        "ln1": {"scale": jnp.zeros((d,), jnp.float32)},
        "ln2": {"scale": jnp.zeros((d,), jnp.float32)},
        # time-mix
        "mu": jnp.full((5, d), 0.5, jnp.float32),        # r,k,v,w,g lerp bases
        "lora_a": dense_init(ks[0], d, r * 5),
        "lora_b": (jax.random.normal(ks[1], (5, r, d)) * 0.01).astype(jnp.float32),
        "wr": dense_init(ks[2], d, d),
        "wk": dense_init(ks[3], d, d),
        "wv": dense_init(ks[4], d, d),
        "wg": dense_init(ks[5], d, d),
        "wo": dense_init(ks[6], d, d),
        "w_base": jnp.full((d,), -6.0, jnp.float32),     # decay base (pre -exp)
        "w_lora_a": dense_init(ks[7], d, 64),
        "w_lora_b": (jax.random.normal(ks[8], (64, d)) * 0.01).astype(jnp.float32),
        "u": (jax.random.normal(ks[9], (h, dh)) * 0.1).astype(jnp.float32),
        "gn_scale": jnp.ones((d,), jnp.float32),
        # channel-mix
        "mu_c": jnp.full((2, d), 0.5, jnp.float32),
        "ck": dense_init(ks[10], d, cfg.d_ff),
        "cv": dense_init(ks[11], cfg.d_ff, d),
        "cr": dense_init(jax.random.fold_in(key, 99), d, d),
    }


def _token_shift(x, prev):
    """prev: (B, d) last token of the previous segment; returns shifted x."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _wkv_step(state, inp):
    """state: (B, H, dk, dv);  inp r/k/v/w: (B, H, dh), u: (H, dh)."""
    r, k, v, w, u = inp
    kv = k[..., :, None] * v[..., None, :]               # (B,H,dk,dv)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[..., :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, out


def rwkv_time_mix(params, x, prev_shift, state, cfg, policy):
    """x: (B,T,d).  Returns (out, new_shift, new_state)."""
    b, t, d = x.shape
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    xs = _token_shift(x, prev_shift)
    # data-dependent lerp: mix_i = mu_i + tanh(x A) B_i   (low-rank, per stream)
    lora = jnp.tanh(mp_dot(x, params["lora_a"], policy=policy))
    lora = lora.reshape(b, t, 5, -1).astype(jnp.float32)
    # Grouped GEMM over the 5 mix streams: (5, b*t, r) x (5, r, d) in one
    # MPGEMM launch (group = stream) instead of a 4-D einsum.
    lora5 = lora.reshape(b * t, 5, -1).transpose(1, 0, 2)
    dd = mp_dot_grouped(lora5, params["lora_b"], policy="fp32",
                        out_dtype=jnp.float32)
    dd = dd.transpose(1, 0, 2).reshape(b, t, 5, d)
    mix = jnp.clip(params["mu"][None, None] + dd, 0.0, 1.0)     # (B,T,5,d)
    xi = (x[:, :, None].astype(jnp.float32) * mix
          + xs[:, :, None].astype(jnp.float32) * (1 - mix)).astype(x.dtype)
    xr, xk, xv, xw, xg = [xi[:, :, i] for i in range(5)]
    r = mp_dot(xr, params["wr"], policy=policy)
    k = mp_dot(xk, params["wk"], policy=policy)
    v = mp_dot(xv, params["wv"], policy=policy)
    g = mp_dot(xg, params["wg"], policy=policy)
    wlog = -jnp.exp(
        params["w_base"][None, None]
        + jnp.tanh(mp_dot(xw, params["w_lora_a"], policy=policy)).astype(jnp.float32)
        @ params["w_lora_b"]
    )                                                            # (B,T,d) <= 0
    w = jnp.exp(wlog)                                            # decay in (0,1)

    def heads(a):
        return a.reshape(b, t, h, dh).transpose(1, 0, 2, 3).astype(jnp.float32)

    u = params["u"]  # constant across time; fed via closure

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        return _wkv_step(s, (r_t, k_t, v_t, w_t, u))

    state, outs = _chunk_scan(step, state,
                              (heads(r), heads(k), heads(v), heads(w)), CHUNK)
    out = outs.transpose(1, 0, 2, 3).reshape(b, t, d)            # (B,T,d)
    out = rmsnorm(out, params["gn_scale"] - 1.0)                 # group-ish norm
    out = out.astype(x.dtype) * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = mp_dot(out, params["wo"], policy=policy)
    return out, x[:, -1], state


def rwkv_channel_mix(params, x, prev_shift, policy):
    xs = _token_shift(x, prev_shift)
    mix = params["mu_c"][None, None]
    x32, xs32 = x.astype(jnp.float32), xs.astype(jnp.float32)
    xk = (x32 * mix[:, :, 0] + xs32 * (1 - mix[:, :, 0])).astype(x.dtype)
    xr = (x32 * mix[:, :, 1] + xs32 * (1 - mix[:, :, 1])).astype(x.dtype)
    k = mp_dot(xk, params["ck"], policy=policy)
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    v = mp_dot(k, params["cv"], policy=policy)
    r = jax.nn.sigmoid(mp_dot(xr, params["cr"], policy=policy).astype(jnp.float32))
    return (r.astype(x.dtype) * v), x[:, -1]


def rwkv_fwd(params, x, ctx):
    """Full RWKV-6 layer (train/prefill, fresh state).
    Returns (x, aux=0, cache|None) per the uniform block interface."""
    cfg, policy = ctx["cfg"], ctx["policy"]
    b, t, d = x.shape
    h = d // cfg.rwkv_head_dim
    dh = cfg.rwkv_head_dim
    state0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    shift0 = jnp.zeros((b, d), x.dtype)
    hmix = rmsnorm(x, params["ln1"]["scale"])
    o, shift_t, state = rwkv_time_mix(params, hmix, shift0, state0, cfg, policy)
    x = x + o
    hmix = rmsnorm(x, params["ln2"]["scale"])
    o, shift_c = rwkv_channel_mix(params, hmix, shift0, policy)
    x = x + o
    cache = None
    if ctx.get("collect_cache"):
        dt = ctx.get("cache_dtype", jnp.bfloat16)
        cache = {"state": state, "shift_t": shift_t.astype(dt),
                 "shift_c": shift_c.astype(dt)}
    return x, jnp.float32(0.0), cache


def rwkv_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    d = cfg.d_model
    h = d // cfg.rwkv_head_dim
    return {
        "state": jnp.zeros((batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                           jnp.float32),
        "shift_t": jnp.zeros((batch, d), dtype),
        "shift_c": jnp.zeros((batch, d), dtype),
    }


def rwkv_decode(params, x, cache, ctx):
    """x: (B,1,d) — one recurrence step; constant-memory decode."""
    cfg, policy = ctx["cfg"], ctx["policy"]
    hmix = rmsnorm(x, params["ln1"]["scale"])
    o, shift_t, state = rwkv_time_mix(
        params, hmix, cache["shift_t"], cache["state"], cfg, policy)
    x = x + o
    hmix = rmsnorm(x, params["ln2"]["scale"])
    o, shift_c = rwkv_channel_mix(params, hmix, cache["shift_c"], policy)
    x = x + o
    return x, {"state": state, "shift_t": shift_t.astype(cache["shift_t"].dtype),
               "shift_c": shift_c.astype(cache["shift_c"].dtype)}


# =========================== RG-LRU (Griffin) =================================

def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "ln1": {"scale": jnp.zeros((d,), jnp.float32)},
        "ln2": {"scale": jnp.zeros((d,), jnp.float32)},
        "w_x": dense_init(ks[0], d, w),        # recurrent branch in-proj
        "w_y": dense_init(ks[1], d, w),        # gate branch in-proj
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1
                   ).astype(jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_gate_r": dense_init(ks[3], w, w),   # recurrence gate
        "w_gate_i": dense_init(ks[4], w, w),   # input gate
        "lambda_p": jnp.full((w,), 2.0, jnp.float32),  # softplus param of a
        "w_out": dense_init(ks[5], w, d),
        "mlp": {
            "w_gate": dense_init(ks[6], d, cfg.d_ff),
            "w_up": dense_init(jax.random.fold_in(key, 7), d, cfg.d_ff),
            "w_down": dense_init(jax.random.fold_in(key, 8), cfg.d_ff, d),
        },
    }


_C_RGLRU = 8.0


def _causal_conv(x, w, b, conv_state=None):
    """Depthwise causal conv1d.  x: (B,T,W); w: (K,W).  conv_state: (B,K-1,W)."""
    kw = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None].astype(x.dtype)
        for i in range(kw)
    )
    new_state = xp[:, -(kw - 1):] if kw > 1 else conv_state
    return out + b.astype(x.dtype), new_state


def rglru_scan(params, u, h0):
    """RG-LRU recurrence.  u: (B,T,W) conv output; h0: (B,W) f32."""
    r = jax.nn.sigmoid(mp_dot(u, params["w_gate_r"], policy="fp32"))
    i = jax.nn.sigmoid(mp_dot(u, params["w_gate_i"], policy="fp32"))
    log_a = -_C_RGLRU * jax.nn.softplus(params["lambda_p"])[None, None] * \
        r.astype(jnp.float32)                                    # (B,T,W) <= 0
    a = jnp.exp(log_a)
    gated = (i.astype(jnp.float32) * u.astype(jnp.float32))
    scale = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    def step(h, inp):
        a_t, gx_t = inp
        h = a_t * h + gx_t
        return h, h

    xs = (a.transpose(1, 0, 2), (scale * gated).transpose(1, 0, 2))
    h_last, hs = _chunk_scan(step, h0, xs, CHUNK)
    return hs.transpose(1, 0, 2).astype(u.dtype), h_last


def rglru_fwd(params, x, ctx):
    cfg, policy = ctx["cfg"], ctx["policy"]
    b, t, d = x.shape
    w = cfg.lru_width or d
    h = rmsnorm(x, params["ln1"]["scale"])
    # gate branch
    y = jax.nn.gelu(mp_dot(h, params["w_y"], policy=policy).astype(jnp.float32))
    # recurrent branch
    u = mp_dot(h, params["w_x"], policy=policy)
    u, conv_state = _causal_conv(u, params["conv_w"], params["conv_b"])
    hs, h_last = rglru_scan(params, u, jnp.zeros((b, w), jnp.float32))
    o = mp_dot(hs * y.astype(hs.dtype), params["w_out"], policy=policy)
    x = x + o
    from repro.models.layers import swiglu_mlp  # local import to avoid cycle
    x = x + swiglu_mlp(params["mlp"], rmsnorm(x, params["ln2"]["scale"]), policy)
    cache = None
    if ctx.get("collect_cache"):
        dt = ctx.get("cache_dtype", jnp.bfloat16)
        cache = {"h": h_last, "conv": conv_state.astype(dt)}
    return x, jnp.float32(0.0), cache


def rglru_init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def rglru_decode(params, x, cache, ctx):
    cfg, policy = ctx["cfg"], ctx["policy"]
    h = rmsnorm(x, params["ln1"]["scale"])
    y = jax.nn.gelu(mp_dot(h, params["w_y"], policy=policy).astype(jnp.float32))
    u = mp_dot(h, params["w_x"], policy=policy)
    u, conv = _causal_conv(u, params["conv_w"], params["conv_b"],
                           cache["conv"].astype(u.dtype))
    hs, h_last = rglru_scan(params, u, cache["h"])
    o = mp_dot(hs * y.astype(hs.dtype), params["w_out"], policy=policy)
    x = x + o
    from repro.models.layers import swiglu_mlp
    x = x + swiglu_mlp(params["mlp"], rmsnorm(x, params["ln2"]["scale"]), policy)
    return x, {"h": h_last, "conv": conv.astype(cache["conv"].dtype)}
