"""Harness-level tests: every bench module imports clean, the run.py
--smoke/--emit/--only/--diff paths work end to end, and every emitted
record validates against the BENCH schema."""
import importlib
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

BENCH_MODULES = [
    "benchmarks.common",
    "benchmarks.bench_autotune",
    "benchmarks.bench_breakdown",
    "benchmarks.bench_distributed",
    "benchmarks.bench_epilogue",
    "benchmarks.bench_gemm_workloads",
    "benchmarks.bench_irregular",
    "benchmarks.bench_loads",
    "benchmarks.bench_mixed_precision",
    "benchmarks.bench_obs",
    "benchmarks.bench_packing",
    "benchmarks.bench_quant",
    "benchmarks.bench_serve",
    "benchmarks.bench_sparse",
    "benchmarks.bench_tiles",
    "benchmarks.roofline_report",
    "benchmarks.run",
]


@pytest.mark.parametrize("mod", BENCH_MODULES)
def test_smoke_import(mod):
    importlib.import_module(mod)


def test_run_sys_path_idempotent():
    """Re-importing the harness must not grow sys.path (satellite fix:
    the old insert-always version stacked duplicates)."""
    import benchmarks.run as run
    before = list(sys.path)
    importlib.reload(run)
    importlib.reload(run)
    added = [p for p in sys.path if p not in before]
    assert added == [], f"sys.path grew on re-import: {added}"


def test_run_areas_cover_registry():
    import benchmarks.run as run
    assert set(run.AREA_RUNNERS) == set(run.AREAS) == \
        {"gemm", "packing", "quant", "sparse", "serve", "distributed",
         "obs"}


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    """One full --smoke --emit across all areas (shared by the tests)."""
    import benchmarks.run as run
    out = tmp_path_factory.mktemp("bench_out")
    rc = run.main(["--smoke", "--emit", "--out", str(out)])
    assert rc == 0
    return out


class TestEmit(object):
    def test_writes_every_area(self, emitted):
        for area in ("gemm", "packing", "quant", "sparse", "serve",
                     "distributed", "obs"):
            assert (emitted / f"BENCH_{area}.json").exists()

    def test_emitted_files_schema_valid(self, emitted):
        from repro.perf.trajectory import read_bench, validate_bench_dict
        for area in ("gemm", "packing", "quant", "sparse", "serve",
                     "distributed", "obs"):
            path = emitted / f"BENCH_{area}.json"
            raw = json.loads(path.read_text())
            assert validate_bench_dict(raw) == []
            bf = read_bench(path)          # raises on schema violations
            assert bf.area == area
            assert len(bf.records) > 0
            for rec in bf.records:
                assert rec.area == area
                for key, val in rec.metrics.items():
                    assert isinstance(val, (int, float)), (rec.name, key)

    def test_known_anchors_present(self, emitted):
        """Representative records from each bench family made it through."""
        from repro.perf.trajectory import read_bench
        gemm = read_bench(emitted / "BENCH_gemm.json").by_name()
        assert "gemm_workload_01_float32" in gemm
        assert "epilogue_trace_swiglu" in gemm
        assert "breakdown_geomean_partition" in gemm
        packing = read_bench(emitted / "BENCH_packing.json").by_name()
        assert any(n.startswith("packing_01_bf16") for n in packing)
        sparse = read_bench(emitted / "BENCH_sparse.json").by_name()
        assert "sparse_trace_llama-w19_d0.5" in sparse
        serve = read_bench(emitted / "BENCH_serve.json").by_name()
        assert "serve_trace_w4" in serve
        assert "serve_e2e_smoke" in serve
        dist = read_bench(emitted / "BENCH_distributed.json").by_name()
        assert "dist_model_row_w6_p8" in dist
        assert "dist_trace_ring_row" in dist
        oarea = read_bench(emitted / "BENCH_obs.json").by_name()
        assert "obs_gate_transparency" in oarea
        assert oarea["obs_gate_transparency"].metrics[
            "payload_identical"] == 1.0

    def test_paper_workload_metrics_match_accounting(self, emitted):
        """The emitted Table III records carry the metrics core's numbers."""
        from repro.core.blocking import plan_gemm
        from repro.perf.metrics import gemm_flops
        from repro.perf.trajectory import read_bench
        gemm = read_bench(emitted / "BENCH_gemm.json").by_name()
        rec = gemm["gemm_workload_01_float32"]
        plan = plan_gemm(64, 2112, 7168, "float32")
        assert rec.metrics["flops"] == float(gemm_flops(64, 2112, 7168))
        assert rec.metrics["hbm_bytes"] == float(plan.hbm_bytes)
        assert rec.plan["blocks"] == [plan.bm, plan.bn, plan.bk]

    def test_packed_prep_bytes_zero_in_records(self, emitted):
        """The packing area's headline fact survives into the artifact."""
        from repro.perf.trajectory import read_bench
        packing = read_bench(emitted / "BENCH_packing.json")
        prep = [r.metrics["prep_bytes_packed"] for r in packing.records
                if "prep_bytes_packed" in r.metrics]
        assert prep and all(v == 0.0 for v in prep)

    def test_diff_self_is_clean_and_perturbed_fails(self, emitted,
                                                    tmp_path):
        import benchmarks.run as run
        # self-diff: exit 0 (byte-identical emission)
        rc = run.main(["--smoke", "--emit", "--only", "sparse",
                       "--out", str(tmp_path / "cur"),
                       "--diff", str(emitted)])
        assert rc == 0
        # perturb one deterministic metric beyond tolerance: exit 1
        bad_dir = tmp_path / "bad_base"
        bad_dir.mkdir()
        raw = json.loads((emitted / "BENCH_sparse.json").read_text())
        for rec in raw["records"]:
            if rec["metrics"]:
                key = sorted(rec["metrics"])[0]
                rec["metrics"][key] = rec["metrics"][key] * 2 + 1
                break
        (bad_dir / "BENCH_sparse.json").write_text(json.dumps(raw))
        rc = run.main(["--smoke", "--emit", "--only", "sparse",
                       "--out", str(tmp_path / "cur2"),
                       "--diff", str(bad_dir)])
        assert rc == 1

    def test_recorder_uninstalled_after_run(self, emitted):
        from benchmarks import common
        assert common.get_recorder() is None


def test_committed_baselines_valid():
    """The baselines shipped in-tree parse and cover every area."""
    from repro.perf.trajectory import read_bench
    base = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "baselines")
    for area in ("gemm", "packing", "quant", "sparse", "serve",
                 "distributed", "obs"):
        bf = read_bench(os.path.join(base, f"BENCH_{area}.json"))
        assert bf.area == area and len(bf.records) > 0
