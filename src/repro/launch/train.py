"""Training entrypoint.

    PYTHONPATH=src python -m repro.launch.train --arch h2o-danube3-4b \
        --smoke --steps 50 [--mesh-test]     # CPU-sized run
    # On a real fleet: run under the production mesh with --mesh-test
    # replaced by the cluster's jax.distributed initialization.
"""
import argparse

import jax

from repro.configs import base as cb
from repro.configs.base import ShapeConfig
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube3-4b",
                    choices=cb.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default="bf16",
                    choices=["fp32", "bf16", "int8"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = cb.get(args.arch, smoke=args.smoke)
    model = build_model(cfg, policy=args.policy)
    print(f"[train] {cfg.name}: {cfg.total_params()/1e6:.1f}M params, "
          f"policy={args.policy}, devices={jax.device_count()}")
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    tcfg = TrainerConfig(steps=args.steps, microbatches=args.microbatches,
                         checkpoint_dir=args.ckpt,
                         opt=AdamWConfig(lr=args.lr))
    trainer = Trainer(model, shape, tcfg)
    params = opt = None
    start = 0
    if args.resume and args.ckpt:
        p_like, o_like = trainer.init_state()
        params, opt, start = trainer.restore(p_like, o_like)
        print(f"[train] resumed from step {start}")
    trainer.run(params, opt, start_step=start)
    print("[train] done; final loss",
          trainer.metrics_log[-1]["loss"] if trainer.metrics_log else "n/a")


if __name__ == "__main__":
    main()
