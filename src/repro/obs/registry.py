"""Process-wide metrics registry: counters, gauges, histograms with labels.

The registry is the one sink for runtime counters across the stack —
plan-cache hits, packed-weight / paged-KV cache traffic, per-spec kernel
launch counts, deprecation-shim invocations, flash-attention fallbacks,
and serve-engine telemetry.  Design constraints, in order:

* **Near-zero overhead.**  The hot-path entry points are the module-level
  helpers (``counter_inc`` / ``gauge_set`` / ``observe``); when metrics
  are disabled (``REPRO_OBS=off`` or ``set_registry(None)``) they return
  after one attribute check and allocate nothing.  When enabled, one
  increment is a dict lookup + ``+=`` under a lock.
* **Thread-safe.**  The serve HTTP server snapshots from a daemon thread
  while the engine increments; a single registry lock covers both.
* **Deterministic exposition.**  ``snapshot()`` / ``to_json()`` /
  ``prometheus_text()`` sort families and label series, so two identical
  runs produce byte-identical dumps (the property ``bench_obs`` gates).

Label values are stringified and the label *set* is canonicalised by
sorting keys, so ``c.inc(a="1", b="2")`` and ``c.inc(b="2", a="1")`` hit
the same series.  Keep label cardinality bounded (kinds and namespaces,
never raw shapes or keys).

The ambient registry follows the same process-global pattern as
``tuning.plan_cache.get_plan_cache`` / ``set_plan_cache``.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "counter_inc",
    "gauge_set",
    "get_registry",
    "metrics_enabled",
    "observe",
    "set_registry",
]

LabelSet = Tuple[Tuple[str, str], ...]

#: Histogram bucket upper bounds (seconds-flavoured; +Inf is implicit).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    1e-4, 5e-4, 1e-3, 5e-3, 1e-2, 5e-2, 0.1, 0.5, 1.0, 5.0, 10.0)

_OFF_VALUES = {"off", "0", "false", "none", "disabled"}


def _labelset(labels: Mapping[str, object]) -> LabelSet:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _series_key(name: str, labelset: LabelSet) -> str:
    if not labelset:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labelset)
    return f"{name}{{{inner}}}"


class _Family:
    """Base for one named metric family holding label-keyed series."""

    kind = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str):
        self._registry = registry
        self.name = name
        self.help = help
        self._series: Dict[LabelSet, object] = {}

    def labelsets(self) -> List[LabelSet]:
        with self._registry._lock:
            return sorted(self._series)


class Counter(_Family):
    """Monotonically increasing float per label set."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name!r}: negative increment "
                             f"{amount!r}")
        ls = _labelset(labels)
        with self._registry._lock:
            self._series[ls] = self._series.get(ls, 0.0) + amount

    def value(self, **labels: object) -> float:
        with self._registry._lock:
            return float(self._series.get(_labelset(labels), 0.0))

    def total(self) -> float:
        """Sum over every label series."""
        with self._registry._lock:
            return float(sum(self._series.values()))


class Gauge(_Family):
    """Last-write-wins float per label set."""

    kind = "gauge"

    def set(self, value: float, **labels: object) -> None:
        with self._registry._lock:
            self._series[_labelset(labels)] = float(value)

    def add(self, amount: float, **labels: object) -> None:
        ls = _labelset(labels)
        with self._registry._lock:
            self._series[ls] = self._series.get(ls, 0.0) + amount

    def value(self, **labels: object) -> float:
        with self._registry._lock:
            return float(self._series.get(_labelset(labels), 0.0))


class _HistData:
    __slots__ = ("count", "sum", "bucket_counts")

    def __init__(self, n_buckets: int):
        self.count = 0
        self.sum = 0.0
        self.bucket_counts = [0] * (n_buckets + 1)  # +1 for +Inf


class Histogram(_Family):
    """Cumulative-bucket histogram per label set."""

    kind = "histogram"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(registry, name, help)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError(f"histogram {self.name!r}: empty buckets")

    def observe(self, value: float, **labels: object) -> None:
        ls = _labelset(labels)
        with self._registry._lock:
            data = self._series.get(ls)
            if data is None:
                data = self._series[ls] = _HistData(len(self.buckets))
            data.count += 1
            data.sum += value
            for i, ub in enumerate(self.buckets):
                if value <= ub:
                    data.bucket_counts[i] += 1
                    break
            else:
                data.bucket_counts[-1] += 1

    def snapshot_one(self, **labels: object) -> Dict[str, object]:
        with self._registry._lock:
            data = self._series.get(_labelset(labels))
            if data is None:
                return {"count": 0, "sum": 0.0, "buckets": {}}
            return self._hist_dict(data)

    def _hist_dict(self, data: _HistData) -> Dict[str, object]:
        cumulative, out = 0, {}
        for ub, n in zip(self.buckets, data.bucket_counts):
            cumulative += n
            out[repr(ub)] = cumulative
        out["+Inf"] = data.count
        return {"count": data.count, "sum": data.sum, "buckets": out}


class MetricsRegistry:
    """A family-name → Counter/Gauge/Histogram map with one lock."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._families: Dict[str, _Family] = {}

    # -- family accessors (get-or-create, type-checked) ----------------------

    def _family(self, cls, name: str, help: str, **kwargs) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(self, name, help, **kwargs)
                self._families[name] = fam
            elif not isinstance(fam, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {fam.kind}, "
                    f"not {cls.kind}")
            return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._family(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._family(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._family(Histogram, name, help, buckets=buckets)

    # -- exposition ----------------------------------------------------------

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """Deterministic nested dict: kind → series-key → value."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                for ls in sorted(fam._series):
                    key = _series_key(name, ls)
                    if isinstance(fam, Histogram):
                        out["histograms"][key] = fam._hist_dict(
                            fam._series[ls])
                    elif isinstance(fam, Gauge):
                        out["gauges"][key] = float(fam._series[ls])
                    else:
                        out["counters"][key] = float(fam._series[ls])
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (0.0.4)."""
        lines: List[str] = []
        with self._lock:
            for name in sorted(self._families):
                fam = self._families[name]
                if fam.help:
                    lines.append(f"# HELP {name} {fam.help}")
                lines.append(f"# TYPE {name} {fam.kind}")
                for ls in sorted(fam._series):
                    if isinstance(fam, Histogram):
                        data = fam._series[ls]
                        cumulative = 0
                        for ub, n in zip(fam.buckets, data.bucket_counts):
                            cumulative += n
                            lines.append(_series_key(
                                f"{name}_bucket",
                                ls + (("le", repr(ub)),)) +
                                f" {cumulative}")
                        lines.append(_series_key(
                            f"{name}_bucket", ls + (("le", "+Inf"),)) +
                            f" {data.count}")
                        lines.append(
                            f"{_series_key(name + '_sum', ls)} {data.sum}")
                        lines.append(
                            f"{_series_key(name + '_count', ls)} "
                            f"{data.count}")
                    else:
                        lines.append(
                            f"{_series_key(name, ls)} {fam._series[ls]}")
        return "\n".join(lines) + "\n"

    def reset(self) -> None:
        with self._lock:
            self._families.clear()


# --- the ambient process-wide registry ---------------------------------------

_ambient_lock = threading.Lock()
_ambient: Optional[MetricsRegistry] = None
_ambient_initialised = False


def _default_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "on").strip().lower() \
        not in _OFF_VALUES


def get_registry() -> Optional[MetricsRegistry]:
    """The ambient registry (created on first use; None when disabled)."""
    global _ambient, _ambient_initialised
    if _ambient_initialised:
        return _ambient
    with _ambient_lock:
        if not _ambient_initialised:
            _ambient = MetricsRegistry() if _default_enabled() else None
            _ambient_initialised = True
    return _ambient


def set_registry(registry: Optional[MetricsRegistry]
                 ) -> Optional[MetricsRegistry]:
    """Install ``registry`` as ambient (None disables); returns previous."""
    global _ambient, _ambient_initialised
    with _ambient_lock:
        prev = _ambient if _ambient_initialised else None
        _ambient = registry
        _ambient_initialised = True
    return prev


def metrics_enabled() -> bool:
    reg = get_registry()
    return reg is not None and reg.enabled


# --- hot-path helpers (no-ops when disabled) ---------------------------------

def counter_inc(name: str, amount: float = 1.0, *, help: str = "",
                **labels: object) -> None:
    reg = get_registry()
    if reg is None or not reg.enabled:
        return
    reg.counter(name, help).inc(amount, **labels)


def gauge_set(name: str, value: float, *, help: str = "",
              **labels: object) -> None:
    reg = get_registry()
    if reg is None or not reg.enabled:
        return
    reg.gauge(name, help).set(value, **labels)


def observe(name: str, value: float, *, help: str = "",
            **labels: object) -> None:
    reg = get_registry()
    if reg is None or not reg.enabled:
        return
    reg.histogram(name, help).observe(value, **labels)
