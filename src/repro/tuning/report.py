"""Characterization report — analytic model vs. measured sweep, side by side.

Produces the markdown table `benchmarks/bench_autotune.py` emits: one row
per workload shape comparing the open-loop analytic plan with the sweep
winner on predicted traffic, CMR (the paper's eq (3) objective), and wall
clock.  This is the TPU analogue of the paper's Table III / Fig. 10-11
"model vs. hardware" comparison.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.tuning.microbench import TuneResult

_HEADER = (
    "| workload (M×N×K, dtype) | analytic blocks | tuned blocks | "
    "traffic MiB (analytic→tuned) | CMR (analytic→tuned) | "
    "wall µs (analytic→tuned) | speedup | mode |"
)
_RULE = "|---|---|---|---|---|---|---|---|"


def _fmt_blocks(blocks) -> str:
    return "×".join(str(b) for b in blocks)


def _row(r: TuneResult) -> str:
    ap, bp = r.analytic.plan, r.best.plan
    workload = f"{ap.m}×{ap.n}×{ap.k}, {ap.a_dtype}"
    tuned = _fmt_blocks(r.best.blocks) + ("" if r.tuned_differs else " (=analytic)")
    return (
        f"| {workload} | {_fmt_blocks(r.analytic.blocks)} | {tuned} "
        f"| {ap.hbm_bytes / 2**20:.1f} → {bp.hbm_bytes / 2**20:.1f} "
        f"| {ap.cmr:.1f} → {bp.cmr:.1f} "
        f"| {r.analytic.wall_us:.1f} → {r.best.wall_us:.1f} "
        f"| {r.speedup:.2f}× | {r.best.mode} |"
    )


def characterization_report(results: Iterable[TuneResult]) -> str:
    """Markdown report for a batch of :func:`~repro.tuning.tune_gemm` runs.

    Example (runnable on CPU)::

        >>> from repro.tuning import PlanCache, tune_gemm
        >>> from repro.tuning.report import characterization_report
        >>> r = tune_gemm(128, 128, 256, mode="modeled", cache=PlanCache(None))
        >>> print(characterization_report([r]))  # doctest: +ELLIPSIS
        # MPGEMM autotuning characterization...
    """
    results = list(results)
    lines: List[str] = [
        "# MPGEMM autotuning characterization",
        "",
        "Analytic plan = open-loop optimum of the eq (1)-(3) model "
        "(core/blocking.py).  Tuned plan = measured winner of the bounded "
        "lattice sweep around it (tuning/microbench.py).",
        "",
        _HEADER,
        _RULE,
    ]
    lines += [_row(r) for r in results]
    tuned = sum(1 for r in results if r.tuned_differs)
    if results:
        geo = 1.0
        for r in results:
            geo *= r.speedup
        geo **= 1.0 / len(results)
        lines += [
            "",
            f"Tuning moved the plan on {tuned}/{len(results)} workloads; "
            f"geomean measured speedup {geo:.3f}× "
            "(≥ 1.0 by construction: the analytic plan is always in the "
            "sweep).",
        ]
    return "\n".join(lines)


def write_report(results: Iterable[TuneResult], path) -> str:
    """Render and write the report; returns the markdown string."""
    md = characterization_report(results)
    with open(path, "w") as f:
        f.write(md + "\n")
    return md
