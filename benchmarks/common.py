"""Shared benchmark utilities.

CPU wall-clock here is a *sanity signal only* (this container has no TPU);
the graded numbers are the modeled roofline terms derived from the analytic
planner and the compiled dry-run artifacts (EXPERIMENTS.md §Methodology).

Besides the CSV ``emit`` lines, every bench function reports its numbers
through :func:`record`, which forwards to the active
:class:`repro.perf.trajectory.Recorder` when the harness installed one
(``benchmarks/run.py --emit``) and is a no-op otherwise — standalone
``python benchmarks/bench_*.py`` runs stay print-only.
"""
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.constants import DEFAULT_HW

# Paper Table III: GEMM workloads from DeepSeek (1-18) and LLaMA (19-24).
PAPER_WORKLOADS = [
    (1, 64, 2112, 7168), (2, 64, 24576, 1536), (3, 64, 32768, 512),
    (4, 64, 7168, 16384), (5, 64, 4096, 7168), (6, 64, 7168, 2048),
    (7, 128, 2112, 7168), (8, 128, 24576, 1536), (9, 128, 32768, 512),
    (10, 128, 7168, 16384), (11, 128, 4096, 7168), (12, 128, 7168, 2048),
    (13, 4096, 2112, 7168), (14, 4096, 24576, 1536), (15, 4096, 32768, 512),
    (16, 4096, 7168, 16384), (17, 4096, 4096, 7168), (18, 4096, 7168, 2048),
    (19, 4096, 256, 4096), (20, 11008, 256, 4096), (21, 4096, 256, 11008),
    (22, 5120, 256, 5120), (23, 13824, 256, 5120), (24, 5120, 256, 13824),
]

# MoE expert grouped-GEMM workloads: (name, G experts, M tokens/expert, N, K).
# Shapes from the framework's own MoE configs (configs/mixtral_8x22b.py,
# configs/granite_moe_1b_a400m.py) at a 4k-token training step with top-k
# routing and capacity factor 1.25: M ≈ 1.25 * k * T / E.  These are the
# paper's DeepSeek/LLaMA serving shapes in their grouped (expert-batched)
# form — the workloads mp_dot_grouped exists for.
MOE_GROUPED_WORKLOADS = [
    ("mixtral-8x22b-up", 8, 1280, 16384, 6144),
    ("mixtral-8x22b-down", 8, 1280, 6144, 16384),
    ("granite-moe-up", 32, 1280, 512, 1024),
    ("granite-moe-down", 32, 1280, 1024, 512),
    ("deepseek-v2-lite-up", 64, 480, 1408, 2048),
    ("deepseek-v2-lite-down", 64, 480, 2048, 1408),
]


def wall_time_us(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def modeled_time_s(flops: float, bytes_: float, dtype: str = "bfloat16",
                   hw=DEFAULT_HW) -> float:
    peak = {"float32": hw.peak_flops_fp32, "bfloat16": hw.peak_flops_bf16,
            "int8": hw.peak_ops_int8}[dtype]
    return max(flops / peak, bytes_ / hw.hbm_bw)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.2f},{derived}")


# --- structured-record plumbing ----------------------------------------------
# The harness (benchmarks/run.py --emit) installs a Recorder; bench modules
# call record(...) unconditionally and the call no-ops when none is active.

_RECORDER = None


def set_recorder(recorder) -> Optional[object]:
    """Install (or clear, with None) the active Recorder; returns the old."""
    global _RECORDER
    old, _RECORDER = _RECORDER, recorder
    return old


def get_recorder():
    return _RECORDER


def record(name: str, area: str, *, kind: str = "model", workload=None,
           metrics=None, noisy=None, plan=None, phases=None) -> None:
    """Report one structured benchmark result to the active Recorder.

    ``metrics`` are deterministic (modeled/traced — the diff gates them);
    ``noisy`` holds wall-clock numbers carried for trajectory plots but
    never compared.  No-op when no Recorder is installed, so bench modules
    can call this unconditionally.
    """
    if _RECORDER is None:
        return
    from repro.perf.metrics import WorkloadRecord
    _RECORDER.add(WorkloadRecord(
        name=name, area=area, kind=kind, workload=dict(workload or {}),
        metrics=dict(metrics or {}), noisy=dict(noisy or {}),
        plan=plan, phases=phases))


def record_plan(name: str, area: str, plan, *, source: str = "analytic",
                workload=None, metrics=None, noisy=None) -> None:
    """:func:`record` for a GemmPlan-backed number: the record auto-carries
    the plan's flops / hbm_bytes / cmr / tile_visits / modeled_us plus its
    blocking provenance.  No-op without an active Recorder."""
    if _RECORDER is None:
        return
    from repro.perf.metrics import record_from_plan
    _RECORDER.add(record_from_plan(
        name, area, plan, source=source, workload=workload,
        metrics=metrics, noisy=noisy))
