"""Serving entrypoint: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --smoke --requests 6 --policy int8
"""
import argparse
import os
import time

import numpy as np

import jax

from repro.configs import base as cb
from repro.models.transformer import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m",
                    choices=cb.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="bf16",
                    choices=["bf16", "bf16_serve", "int8"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--pack", action="store_true",
                    help="pack static weights into kernel-native tile "
                         "layouts at load time (repro.packing; cache via "
                         "REPRO_PACK_CACHE)")
    ap.add_argument("--no-fuse", action="store_true",
                    help="disable the fused gated-activation/residual "
                         "epilogues (core/gemm_spec.py) — the unfused A/B "
                         "baseline benchmarks/bench_epilogue.py measures")
    args = ap.parse_args()

    if args.no_fuse:
        # Read lazily at trace time by models/layers.py via
        # core.config.fused_epilogues(), so setting it before build works.
        os.environ["REPRO_FUSED_EPILOGUE"] = "0"

    cfg = cb.get(args.arch, smoke=args.smoke)
    model = build_model(cfg, policy=args.policy, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    if args.pack:
        from repro.packing import pack_params, packed_param_bytes
        params = pack_params(params, policy=args.policy,
                             m_hint=args.batch * 32)
        print(f"[serve] packed static weights: "
              f"{packed_param_bytes(params)/2**20:.1f} MiB payload")
    eng = ServeEngine(model, params, batch_size=args.batch,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(2, cfg.vocab,
                                        (int(rng.integers(4, 32)),))
                    .astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.time()
    out = eng.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"[serve] {args.requests} requests, {n_tok} tokens, {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s CPU, policy={args.policy})")
    for uid in sorted(out):
        print(f"  req{uid}: {out[uid][:10]}")


if __name__ == "__main__":
    main()
