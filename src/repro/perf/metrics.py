"""Metrics-accounting core: the numbers every benchmark record carries.

One definition of the modeled FLOPs / HBM-traffic / tile-visit accounting,
shared by the benchmark harness (``benchmarks/common.py``), the trajectory
writer, and the tests that pin the math down.  The GEMM terms mirror
``core/blocking.py`` exactly (``gemm_bytes`` delegates to
``modeled_traffic_bytes``; the tests cross-check both on hand-computed
paper workloads), so a record's modeled terms can never drift from what
the planner actually optimizes.

The per-phase model accounting (:func:`phase_flops`) follows the
llm-profiler shape: each phase names one GEMM family of the forward pass
with its fwd FLOPs and the bwd FLOPs the two backward GEMMs cost
(``bwd = 2 * fwd`` for every matmul — dL/dx and dL/dW are each another
GEMM of the same volume).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.blocking import GemmPlan, modeled_traffic_bytes
from repro.core.codecs import dtype_bytes as _codec_bytes, plan_dtype
from repro.core.constants import DEFAULT_HW, HardwareSpec

# Record kinds: how the metrics were obtained.
#   model — deterministic analytic/planner terms (diffed tightly)
#   trace — jaxpr-derived structural facts (exact, diffed tightly)
#   wall  — wall-clock measurements (noisy; diff ignores them)
RECORD_KINDS = ("model", "trace", "wall", "report")


def _dtype_bytes(dtype):
    """Bytes per element by BITS-per-element, not ``dtype.itemsize`` —
    sub-byte payload codecs (int4) price fractionally (core/codecs.py)."""
    return _codec_bytes(dtype)


# --- GEMM accounting ---------------------------------------------------------

def gemm_flops(m: int, n: int, k: int, *, g: int = 1,
               density: float = 1.0) -> int:
    """MACs×2 for a (possibly grouped, possibly tile-sparse) GEMM.

    Matches ``GemmPlan.flops``: grouped instances scale by G, a
    tile-sparse B prunes MACs linearly with stored-tile density.
    """
    if g < 1:
        raise ValueError(f"group count must be >= 1, got {g}")
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    return int(2 * g * m * n * k * density)


def gemm_bytes(
    m: int, n: int, k: int, bm: int, bn: int,
    *,
    a_dtype="float32", b_dtype=None, out_dtype=None,
    g: int = 1, beta: float = 0.0, extra_mn_inputs: int = 0,
    density: float = 1.0,
) -> int:
    """Modeled HBM traffic of the K-innermost revisiting grid.

    Delegates the 2-D term to ``core/blocking.py::modeled_traffic_bytes``
    (the single source of truth the planner optimizes) and lifts it per
    group — matching ``grouped_plan_from_2d``'s "no cross-group reuse"
    model.  ``extra_mn_inputs`` counts fused-epilogue (M, N) operands;
    ``density`` prices a tile-sparse B.
    """
    a_dtype = str(jnp.dtype(a_dtype))
    b_dtype = plan_dtype(b_dtype if b_dtype is not None else a_dtype)
    out_dtype = str(jnp.dtype(out_dtype or a_dtype))
    per_group = modeled_traffic_bytes(
        m, n, k, bm, bn,
        _dtype_bytes(a_dtype), _dtype_bytes(b_dtype),
        _dtype_bytes(out_dtype),
        beta=beta, extra_mn_inputs=extra_mn_inputs, density=density,
    )
    return int(per_group * g)


def tile_visits(
    m: int, n: int, k: int, bm: int, bn: int, bk: int,
    *,
    g: int = 1, schedule_len: Optional[int] = None,
) -> int:
    """Grid steps of the launched kernel — the trace-time fact the sparse
    benchmarks gate on.

    Dense: ``g * ceil(m/bm) * ceil(n/bn) * ceil(k/bk)`` (the 3-D revisiting
    grid, group as leading axis).  Tile-sparse (``schedule_len`` given):
    the grid is ``(m/bm, schedule_len)`` — the stored-tile schedule already
    contains every (group, kk, j) visit including anchor visits, so the
    sparse count is ``ceil(m/bm) * schedule_len``.
    """
    m_blocks = math.ceil(m / bm)
    if schedule_len is not None:
        return m_blocks * schedule_len
    return g * m_blocks * math.ceil(n / bn) * math.ceil(k / bk)


def modeled_gemm_us(flops: float, bytes_: float, dtype: str = "bfloat16",
                    hw: HardwareSpec = DEFAULT_HW) -> float:
    """Two-term roofline time in microseconds (same peaks table the
    benchmarks and the tuner's modeled mode use)."""
    if dtype == "fp8e4m3":
        peak = hw.peak_ops_int8      # 8-bit MXU rate (no separate fp8 peak)
    elif jnp.dtype(dtype).kind == "i":
        peak = hw.peak_ops_int8
    elif str(jnp.dtype(dtype)) in ("bfloat16", "float16"):
        peak = hw.peak_flops_bf16
    else:
        peak = hw.peak_flops_fp32
    return max(flops / peak, bytes_ / hw.hbm_bw) * 1e6


# --- sharded-GEMM comm/overlap accounting ------------------------------------

COLLECTIVES = ("reduce_scatter", "all_gather", "all_reduce", "all_to_all")


def collective_bytes(kind: str, payload_bytes: int, axis_size: int) -> int:
    """Wire bytes ONE device sends for a ring collective over ``axis_size``.

    ``payload_bytes`` is the per-device operand the collective is applied
    to: the full partial for reduce_scatter/all_reduce, the local shard for
    all_gather, the local (to-be-redistributed) buffer for all_to_all.
    Standard ring costs: reduce_scatter moves P-1 chunks of 1/P each,
    all_gather forwards the shard P-1 times, all_reduce is a
    reduce_scatter + all_gather, all_to_all keeps 1/P at home.
    """
    if kind not in COLLECTIVES:
        raise ValueError(f"kind must be one of {COLLECTIVES}, got {kind!r}")
    p = int(axis_size)
    if p <= 1:
        return 0
    if kind == "reduce_scatter":
        return int(payload_bytes * (p - 1) / p)
    if kind == "all_gather":
        return int(payload_bytes * (p - 1))
    if kind == "all_reduce":
        return int(2 * payload_bytes * (p - 1) / p)
    return int(payload_bytes * (p - 1) / p)          # all_to_all


def sharded_gemm_comm_bytes(
    m: int, n: int, k: int, *, partition: str, axis_size: int,
    g: int = 1, acc_itemsize: int = 4, x_itemsize: int = 2,
) -> int:
    """Per-device wire bytes of one sharded GEMM
    (``distributed/shard_gemm.py``), by partition:

    * ``column`` — no collective (B sharded along N, X replicated): 0.
    * ``row``    — ring reduce-scatter of the full (M, N) f32 partial.
    * ``gather`` — ring all-gather of the (M/P, K) X shard.
    * ``expert`` — all-to-all dispatch of the token-sharded (G, M/P, K)
      activations plus the combine of the expert-sharded (G/P, M, N)
      outputs.
    """
    p = int(axis_size)
    if partition == "column":
        return 0
    if partition == "row":
        return collective_bytes("reduce_scatter", m * n * acc_itemsize, p)
    if partition == "gather":
        return collective_bytes("all_gather", (m // p) * k * x_itemsize, p)
    if partition == "expert":
        dispatch = collective_bytes(
            "all_to_all", g * (m // p) * k * x_itemsize, p)
        combine = collective_bytes(
            "all_to_all", (g // p) * m * n * acc_itemsize, p)
        return dispatch + combine
    raise ValueError(f"unknown partition {partition!r}")


def modeled_collective_us(bytes_: float,
                          hw: HardwareSpec = DEFAULT_HW) -> float:
    """Ring-collective wire time over the interconnect, microseconds."""
    return bytes_ / hw.ici_bw * 1e6


def modeled_overlap(compute_us: float, comm_us: float,
                    steps: int) -> Dict[str, float]:
    """Pipeline model of the chunked ring schedule.

    ``steps`` is the chunk count — the mesh axis size for the ring matmuls,
    1 for the blocking-collective baseline.  Each step's permute runs
    concurrently with the next step's chunk GEMM, so with per-step compute
    ``gc = compute/steps`` and per-step comm ``cc = comm/steps``::

        pipelined_us   = max(gc, cc) * (steps - 1) + gc + cc
        exposed_comm   = pipelined_us - compute_us
        overlap_frac   = 1 - exposed_comm / comm_us

    ``steps = 1`` degenerates to fully exposed comm (``overlap_frac = 0``);
    compute-bound chunking approaches ``1 - 1/steps``.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    if comm_us <= 0.0:
        return {"pipelined_us": float(compute_us),
                "exposed_comm_us": 0.0, "overlap_frac": 0.0}
    gc, cc = compute_us / steps, comm_us / steps
    pipelined = max(gc, cc) * (steps - 1) + gc + cc
    exposed = pipelined - compute_us
    return {"pipelined_us": float(pipelined),
            "exposed_comm_us": float(exposed),
            "overlap_frac": float(1.0 - exposed / comm_us)}


# --- llm-profiler-style per-phase model accounting ---------------------------

@dataclasses.dataclass(frozen=True)
class PhaseFlops:
    """One forward-pass phase's GEMM FLOPs, with its backward cost.

    ``bwd = 2 * fwd`` for pure-GEMM phases (each forward matmul costs two
    backward matmuls of the same volume); phases with no trainable matmul
    (embedding lookup) carry fwd = bwd = 0.
    """

    name: str
    fwd: int
    bwd: int

    @property
    def total(self) -> int:
        return self.fwd + self.bwd

    def to_dict(self) -> dict:
        return {"name": self.name, "fwd": self.fwd, "bwd": self.bwd}

    @staticmethod
    def from_dict(d: dict) -> "PhaseFlops":
        return PhaseFlops(name=d["name"], fwd=int(d["fwd"]),
                          bwd=int(d["bwd"]))


def _gemm_phase(name: str, flops: int) -> PhaseFlops:
    return PhaseFlops(name=name, fwd=int(flops), bwd=int(2 * flops))


def phase_flops(cfg, tokens: int, seq_len: int) -> List[PhaseFlops]:
    """Per-phase fwd/bwd GEMM FLOPs for one step of ``tokens`` tokens.

    The llm-profiler decomposition, instantiated on our ArchConfig: every
    phase is a named GEMM family, fwd = 2 * tokens * (weight volume), and
    attention's quadratic terms use ``seq_len`` (scores and output each
    cost 2*T*s*heads*head_dim).  MoE phases count the per-token ACTIVE
    experts (router + experts_per_token expert MLPs); the dense/moe/
    recurrent split follows ``cfg.pattern``.
    """
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    mlp_mats = 2 if cfg.mlp == "gelu" else 3
    pattern = cfg.pattern
    n_attn = sum(1 for kind in pattern
                 if kind in ("dense", "cross", "attn_local", "moe"))
    n_dense_mlp = sum(1 for kind in pattern
                      if kind in ("dense", "cross", "attn_local"))
    n_moe = sum(1 for kind in pattern if kind == "moe")
    n_rec = len(pattern) - n_attn  # rwkv / rglru layers

    qkv_w = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
    out_w = cfg.n_heads * hd * d
    phases = [
        PhaseFlops("embed", 0, 0),
        _gemm_phase("attn_qkv", 2 * tokens * qkv_w * n_attn),
        _gemm_phase("attn_scores",
                    2 * tokens * seq_len * cfg.n_heads * hd * n_attn),
        _gemm_phase("attn_values",
                    2 * tokens * seq_len * cfg.n_heads * hd * n_attn),
        _gemm_phase("attn_out", 2 * tokens * out_w * n_attn),
        _gemm_phase("mlp", 2 * tokens * mlp_mats * d * f * n_dense_mlp),
    ]
    if n_moe:
        phases.append(_gemm_phase(
            "moe_router", 2 * tokens * d * cfg.n_experts * n_moe))
        phases.append(_gemm_phase(
            "moe_experts",
            2 * tokens * mlp_mats * d * f
            * max(1, cfg.experts_per_token) * n_moe))
    if n_rec:
        # Recurrent blocks: the 6 d×d mixing mats + 2 d×f channel-mix mats
        # + the d×d output mat (ArchConfig.active_params' rwkv model).
        phases.append(_gemm_phase(
            "recurrent", 2 * tokens * (7 * d * d + 2 * d * f) * n_rec))
    phases.append(_gemm_phase("logits", 2 * tokens * d * cfg.vocab))
    return phases


def total_flops(phases: List[PhaseFlops]) -> Dict[str, int]:
    """{"fwd": Σ, "bwd": Σ, "total": Σ} over a phase list."""
    fwd = sum(p.fwd for p in phases)
    bwd = sum(p.bwd for p in phases)
    return {"fwd": fwd, "bwd": bwd, "total": fwd + bwd}


# --- the record every benchmark emits ----------------------------------------

@dataclasses.dataclass
class WorkloadRecord:
    """One workload's metrics in one benchmark run.

    ``metrics`` holds deterministic numbers the CI diff compares (modeled
    roofline terms, traced launch counts, tile visits, FLOPs accounting);
    ``noisy`` holds wall-clock style measurements that are recorded for
    the trajectory but never gated on.  ``plan`` is the blocking-decision
    provenance (which blocks, whose choice, what it modeled); ``phases``
    the optional per-phase FLOPs breakdown.
    """

    name: str
    area: str
    kind: str = "model"
    workload: Dict = dataclasses.field(default_factory=dict)
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    noisy: Dict[str, float] = dataclasses.field(default_factory=dict)
    plan: Optional[Dict] = None
    phases: Optional[List[PhaseFlops]] = None

    def __post_init__(self):
        if self.kind not in RECORD_KINDS:
            raise ValueError(
                f"record kind {self.kind!r} not in {RECORD_KINDS}")
        if not self.name:
            raise ValueError("record name must be non-empty")

    def to_dict(self) -> dict:
        d = {
            "name": self.name,
            "area": self.area,
            "kind": self.kind,
            "workload": dict(self.workload),
            "metrics": {k: self.metrics[k] for k in sorted(self.metrics)},
            "noisy": {k: self.noisy[k] for k in sorted(self.noisy)},
        }
        if self.plan is not None:
            d["plan"] = dict(self.plan)
        if self.phases is not None:
            d["phases"] = [p.to_dict() for p in self.phases]
        return d

    @staticmethod
    def from_dict(d: dict) -> "WorkloadRecord":
        return WorkloadRecord(
            name=d["name"], area=d["area"], kind=d.get("kind", "model"),
            workload=dict(d.get("workload", {})),
            metrics=dict(d.get("metrics", {})),
            noisy=dict(d.get("noisy", {})),
            plan=dict(d["plan"]) if d.get("plan") is not None else None,
            phases=[PhaseFlops.from_dict(p) for p in d["phases"]]
            if d.get("phases") is not None else None,
        )


def plan_provenance(plan: GemmPlan, source: str = "analytic") -> dict:
    """JSON-safe provenance of a blocking decision: enough to answer "which
    blocks served this number, and who chose them" when a later diff moves."""
    return {
        "blocks": [plan.bm, plan.bn, plan.bk],
        "grid": list(plan.grid),
        "g": plan.g,
        "source": source,
        "vmem_bytes": plan.vmem_bytes,
        "notes": plan.notes,
    }


def record_from_plan(
    name: str, area: str, plan: GemmPlan,
    *,
    kind: str = "model",
    source: str = "analytic",
    workload: Optional[Dict] = None,
    metrics: Optional[Dict[str, float]] = None,
    noisy: Optional[Dict[str, float]] = None,
    hw: HardwareSpec = DEFAULT_HW,
) -> WorkloadRecord:
    """Record carrying a plan's modeled roofline terms + provenance.

    The plan's own flops/hbm_bytes/cmr become the base metrics (so every
    GEMM record automatically carries the terms the diff gates on);
    ``metrics`` adds/overrides benchmark-specific ones.
    """
    base = {
        "flops": float(plan.flops),
        "hbm_bytes": float(plan.hbm_bytes),
        "cmr": float(plan.cmr),
        "tile_visits": float(tile_visits(
            plan.m, plan.n, plan.k, plan.bm, plan.bn, plan.bk, g=plan.g)),
        "modeled_us": modeled_gemm_us(plan.flops, plan.hbm_bytes,
                                      plan.a_dtype, hw),
    }
    base.update(metrics or {})
    wl = {"m": plan.m, "n": plan.n, "k": plan.k, "g": plan.g,
          "a_dtype": plan.a_dtype, "b_dtype": plan.b_dtype,
          "out_dtype": plan.out_dtype}
    wl.update(workload or {})
    return WorkloadRecord(
        name=name, area=area, kind=kind, workload=wl, metrics=base,
        noisy=dict(noisy or {}), plan=plan_provenance(plan, source),
    )
