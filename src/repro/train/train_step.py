"""Training step: grad accumulation over microbatches (scan), AdamW update,
remat-friendly.  Designed so the AOT-lowered HLO stays compact (microbatch
loop is a while; layer stack is a while inside it)."""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, AdamWState, adamw_init, adamw_update, cosine_schedule


def pick_microbatches(cfg, shape, ddp: int, budget_bytes: float = 4e9) -> int:
    """Smallest power-of-two microbatch count keeping per-device live
    activations (layer-boundary residuals under unit-remat) under budget."""
    b_dev = max(1, shape.global_batch // ddp)
    resid = b_dev * shape.seq_len * cfg.d_model * 2 * max(1, cfg.n_layers)
    m = 1
    while m < b_dev and resid / m > budget_bytes:
        m *= 2
    # microbatch count must divide the global batch AND keep >= ddp per mb
    while m > 1 and (shape.global_batch % m or shape.global_batch // m < ddp):
        m //= 2
    return max(1, m)


def make_train_step(model, opt_cfg: AdamWConfig, microbatches: int = 1,
                    total_steps: int = 100000, warmup: int = 500,
                    grad_shardings=None):
    """-> train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_shardings`` (a params-like tree of NamedShardings) pins each
    microbatch's gradients to the parameter sharding, so GSPMD emits
    reduce-scatters into the shards instead of full-tensor all-reduces
    (EXPERIMENTS.md §Perf, mixtral hillclimb)."""

    def loss_fn(params, mb):
        return model.loss_fn(params, mb)

    def _pin(g):
        if grad_shardings is None:
            return g
        return jax.tree_util.tree_map(
            jax.lax.with_sharding_constraint, g, grad_shardings)

    def train_step(params, opt_state: AdamWState, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _pin(grads)
        else:
            def split(a):
                return a.reshape((microbatches, a.shape[0] // microbatches)
                                 + a.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)

            def body(carry, mb):
                gacc, lacc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g = _pin(g)
                gacc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + loss), None

            init = (jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
                jnp.float32(0.0))
            (grads, loss), _ = jax.lax.scan(body, init, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            loss = loss / microbatches

        lr_scale = cosine_schedule(opt_state.step, warmup=warmup,
                                   total=total_steps)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, opt_cfg, lr_scale=lr_scale)
        metrics["loss"] = loss
        metrics["lr_scale"] = lr_scale
        return params, opt_state, metrics

    return train_step


def make_eval_step(model):
    def eval_step(params, batch):
        return model.loss_fn(params, batch)
    return eval_step
