"""Versioned ``BENCH_<area>.json`` writer/reader + the harness Recorder.

The trajectory file is the unit of perf history: one file per benchmark
area (``gemm`` / ``packing`` / ``sparse``), a versioned schema, an
environment stamp (metadata only — the diff never compares it), and a
name-sorted record list so committed baselines produce minimal git diffs.

File schema (version 1)::

    {
      "schema_version": 1,
      "area": "gemm",
      "environment": {"python": ..., "jax": ..., "platform": ...},
      "records": [WorkloadRecord.to_dict(), ...]   # sorted by name
    }

Writers are atomic (tmp + rename, same discipline as the PlanCache);
readers validate and raise on unknown schema versions rather than
silently mis-diffing.
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import sys
import tempfile
from pathlib import Path
from typing import Dict, List, Optional

from repro.perf.metrics import RECORD_KINDS, WorkloadRecord

SCHEMA_VERSION = 1

AREAS = ("gemm", "packing", "quant", "sparse", "serve", "distributed",
         "obs")


def bench_path(directory, area: str) -> Path:
    """The canonical ``BENCH_<area>.json`` path under ``directory``."""
    return Path(directory) / f"BENCH_{area}.json"


def environment_stamp() -> Dict[str, str]:
    """Where these numbers came from — metadata, never compared by diff."""
    try:
        import jax
        jax_version = jax.__version__
        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax always present in-tree
        jax_version, backend = "unavailable", "unavailable"
    return {
        "python": platform.python_version(),
        "jax": jax_version,
        "backend": backend,
        "platform": sys.platform,
        "machine": platform.machine(),
    }


# --- validation --------------------------------------------------------------

def validate_record_dict(d: dict) -> List[str]:
    """Schema problems of one record dict ([] == valid)."""
    problems = []
    if not isinstance(d, dict):
        return [f"record is not a dict: {type(d).__name__}"]
    for field in ("name", "area"):
        if not isinstance(d.get(field), str) or not d.get(field):
            problems.append(f"record field {field!r} missing or empty")
    if d.get("kind", "model") not in RECORD_KINDS:
        problems.append(f"record kind {d.get('kind')!r} not in "
                        f"{RECORD_KINDS}")
    for field in ("metrics", "noisy", "workload"):
        val = d.get(field, {})
        if not isinstance(val, dict):
            problems.append(f"record field {field!r} is not a dict")
    metrics = d.get("metrics", {})
    if isinstance(metrics, dict):
        for key, val in metrics.items():
            if not isinstance(val, (int, float)) or isinstance(val, bool):
                problems.append(
                    f"metric {key!r} is not numeric: {val!r}")
    phases = d.get("phases")
    if phases is not None:
        if not isinstance(phases, list):
            problems.append("record field 'phases' is not a list")
        else:
            for p in phases:
                if not isinstance(p, dict) or not {"name", "fwd",
                                                   "bwd"} <= set(p):
                    problems.append(f"malformed phase entry: {p!r}")
    return problems


def validate_bench_dict(d: dict) -> List[str]:
    """Schema problems of a whole BENCH file dict ([] == valid)."""
    problems = []
    if not isinstance(d, dict):
        return [f"bench file is not a dict: {type(d).__name__}"]
    if d.get("schema_version") != SCHEMA_VERSION:
        problems.append(
            f"schema_version {d.get('schema_version')!r} != "
            f"{SCHEMA_VERSION}")
    if not isinstance(d.get("area"), str) or not d.get("area"):
        problems.append("area missing or empty")
    records = d.get("records")
    if not isinstance(records, list):
        return problems + ["records is not a list"]
    seen = set()
    for i, rec in enumerate(records):
        for p in validate_record_dict(rec):
            problems.append(f"records[{i}]: {p}")
        name = rec.get("name") if isinstance(rec, dict) else None
        if name in seen:
            problems.append(f"records[{i}]: duplicate record name {name!r}")
        seen.add(name)
        if isinstance(rec, dict) and rec.get("area") not in (None,
                                                             d.get("area")):
            problems.append(
                f"records[{i}]: area {rec.get('area')!r} != file area "
                f"{d.get('area')!r}")
    return problems


# --- file I/O ----------------------------------------------------------------

@dataclasses.dataclass
class BenchFile:
    """One parsed BENCH_<area>.json."""

    area: str
    schema_version: int
    environment: Dict[str, str]
    records: List[WorkloadRecord]

    def by_name(self) -> Dict[str, WorkloadRecord]:
        return {r.name: r for r in self.records}


def write_bench(directory, area: str, records: List[WorkloadRecord],
                *, environment: Optional[Dict[str, str]] = None) -> Path:
    """Atomically write ``BENCH_<area>.json``; returns the path.

    Records are sorted by name and serialized with sorted keys + trailing
    newline, so re-emitting identical numbers produces a byte-identical
    file (the property the committed-baseline workflow depends on).
    """
    path = bench_path(directory, area)
    path.parent.mkdir(parents=True, exist_ok=True)
    dup = [r.name for r in records
           if sum(1 for o in records if o.name == r.name) > 1]
    if dup:
        raise ValueError(f"duplicate record names in area {area!r}: "
                         f"{sorted(set(dup))}")
    payload = {
        "schema_version": SCHEMA_VERSION,
        "area": area,
        "environment": environment if environment is not None
        else environment_stamp(),
        "records": [r.to_dict() for r in
                    sorted(records, key=lambda r: r.name)],
    }
    problems = validate_bench_dict(payload)
    if problems:
        raise ValueError(f"refusing to write invalid bench file: "
                         f"{problems}")
    text = json.dumps(payload, indent=1, sort_keys=True) + "\n"
    fd, tmp = tempfile.mkstemp(dir=str(path.parent), suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def read_bench(path) -> BenchFile:
    """Parse + validate one BENCH file; raises ValueError on bad schema."""
    raw = json.loads(Path(path).read_text())
    problems = validate_bench_dict(raw)
    if problems:
        raise ValueError(f"{path}: invalid bench file: {problems[:5]}")
    return BenchFile(
        area=raw["area"],
        schema_version=raw["schema_version"],
        environment=dict(raw.get("environment", {})),
        records=[WorkloadRecord.from_dict(r) for r in raw["records"]],
    )


# --- the harness recorder ----------------------------------------------------

class Recorder:
    """Collects WorkloadRecords across benchmark modules, grouped by area.

    The benchmark harness installs one via ``benchmarks.common
    .set_recorder``; every ``common.record(...)`` call lands here.  Later
    records with a name already recorded in the same area REPLACE the
    earlier one (a re-run of a bench function is an update, not a
    duplicate).
    """

    def __init__(self):
        self._by_area: Dict[str, Dict[str, WorkloadRecord]] = {}

    def add(self, record: WorkloadRecord) -> None:
        problems = validate_record_dict(record.to_dict())
        if problems:
            raise ValueError(f"invalid record {record.name!r}: {problems}")
        self._by_area.setdefault(record.area, {})[record.name] = record

    def areas(self) -> List[str]:
        return sorted(self._by_area)

    def records(self, area: str) -> List[WorkloadRecord]:
        return sorted(self._by_area.get(area, {}).values(),
                      key=lambda r: r.name)

    def __len__(self) -> int:
        return sum(len(v) for v in self._by_area.values())

    def write_all(self, directory,
                  *, environment: Optional[Dict[str, str]] = None
                  ) -> Dict[str, Path]:
        """One BENCH_<area>.json per recorded area; {area: path}."""
        return {area: write_bench(directory, area, self.records(area),
                                  environment=environment)
                for area in self.areas()}
