"""``mp_dot`` — the paper's technique as a first-class, differentiable op.

Every matmul in every model in this framework flows through here.  The op:

* applies a :class:`PrecisionPolicy` (fp32 / bf16->f32 / dynamic int8->i32 —
  the paper's Section V multi-precision surface),
* consults the tuned-plan cache (repro.tuning) so empirically characterized
  block shapes transparently replace the analytic planner's on a hit,
* dispatches to the Pallas MPGEMM kernel (TPU / interpret) or to an XLA
  ``dot_general`` with identical precision semantics (CPU dry-run; XLA
  picks its own tiling, so plans only affect the kernel backends),
* implements its own VJP whose backward GEMMs use the **fused-transpose**
  kernel variants (dx = dy · Wᵀ, dW = Xᵀ · dy) — the training-time payoff of
  the paper's on-the-fly transposition: no transposed weight copies are ever
  materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import config as cfg
from repro.core.policy import PrecisionPolicy, get_policy, quantize_per_tensor
from repro.kernels.mpgemm import mpgemm_pallas


def _dims(trans_a: bool, trans_b: bool):
    ca = 0 if trans_a else 1
    cb = 1 if trans_b else 0
    return (((ca,), (cb,)), ((), ()))


def _cached_plan(x, w, trans_a: bool, trans_b: bool, out_dtype):
    """Tuned plan for this GEMM instance from the global plan cache, or None.

    Resolved at trace time (shapes are static under jit), so a cache hit
    changes only the BlockSpecs baked into the lowered kernel — numerics are
    plan-independent.  Miss -> None -> mpgemm_pallas falls back to the
    analytic planner.  Lazy import: core must not hard-depend on tuning.
    """
    from repro.tuning.plan_cache import lookup_plan
    m = x.shape[1] if trans_a else x.shape[0]
    k = x.shape[0] if trans_a else x.shape[1]
    n = w.shape[0] if trans_b else w.shape[1]
    return lookup_plan(
        m, n, k, x.dtype, w.dtype, out_dtype,
        trans_a=trans_a, trans_b=trans_b,
    )


def _matmul_2d(
    x, w, bias, policy: PrecisionPolicy, trans_a: bool, trans_b: bool, backend: str,
    out_dtype=None, acc_dtype=None,
):
    """One 2-D GEMM under a policy, on the selected backend.

    ``acc_dtype`` overrides the accumulator/partial-sum dtype: backward
    GEMMs pass bf16 so that TP partial-sum all-reduces move bf16 instead of
    f32 (halves gradient/activation-grad wire bytes; standard practice).

    ``w`` may be a static-int8 {"q","scale"} dict (core/quantization.py):
    the dequant rides the GEMM — int8 HBM reads, upcast at the compute unit."""
    from repro.core.quantization import dequantize_tensor, is_quantized
    if is_quantized(w):
        w = dequantize_tensor(w, jnp.dtype(policy.compute_dtype))
    out_dtype = out_dtype or policy.out_dtype
    if policy.quantized:
        xq, sx = quantize_per_tensor(x)
        wq, sw = quantize_per_tensor(w)
        scale = sx * sw
        if backend in ("pallas", "interpret"):
            return mpgemm_pallas(
                xq, wq, trans_a=trans_a, trans_b=trans_b, scale=scale,
                bias=bias, out_dtype=out_dtype,
                plan=_cached_plan(xq, wq, trans_a, trans_b, out_dtype),
                interpret=(backend == "interpret"),
            )
        acc = jax.lax.dot_general(
            xq, wq, _dims(trans_a, trans_b), preferred_element_type=jnp.int32
        )
        y = acc.astype(jnp.float32) * scale
        if bias is not None:
            y = y + bias.reshape(1, -1).astype(y.dtype)
        return y.astype(out_dtype)

    cd = jnp.dtype(policy.compute_dtype)
    xc = x.astype(cd)
    wc = w.astype(cd)
    if wc.dtype != w.dtype:
        # Pin the down-cast to happen shard-local BEFORE any FSDP
        # all-gather: without the barrier GSPMD gathers the f32 master
        # weights and converts after, doubling gather wire bytes
        # (measured on mixtral train_4k — EXPERIMENTS.md §Perf).
        wc = jax.lax.optimization_barrier(wc)
    if backend in ("pallas", "interpret"):
        return mpgemm_pallas(
            xc, wc, trans_a=trans_a, trans_b=trans_b, bias=bias,
            out_dtype=out_dtype,
            plan=_cached_plan(xc, wc, trans_a, trans_b, out_dtype),
            interpret=(backend == "interpret"),
        )
    acc = jax.lax.dot_general(
        xc, wc, _dims(trans_a, trans_b),
        preferred_element_type=jnp.dtype(acc_dtype or policy.acc_dtype),
    )
    if bias is not None:
        acc = acc + bias.reshape(1, -1).astype(acc.dtype)
    return acc.astype(out_dtype)


# --- differentiable core -----------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mp_dot_core(x2d, w, bias, policy_name: str, trans_w: bool, backend: str):
    policy = get_policy(policy_name)
    return _matmul_2d(x2d, w, bias, policy, False, trans_w, backend)


def _mp_dot_fwd(x2d, w, bias, policy_name, trans_w, backend):
    y = _mp_dot_core(x2d, w, bias, policy_name, trans_w, backend)
    return y, (x2d, w, bias is not None)


def _mp_dot_bwd(policy_name, trans_w, backend, res, dy):
    x2d, w, has_bias = res
    policy = get_policy(policy_name)
    # Backward runs in the non-quantized sibling precision (STE for int8).
    bwd_policy = get_policy("fp32" if policy.name == "fp32" else "bf16")
    # bf16 partial sums so TP/FSDP gradient reductions move bf16 on the wire
    # (no-op for the fp32 policy).
    bwd_acc = "float32" if policy.name == "fp32" else "bfloat16"
    # dx = dy @ op(w)^T : if w stored (k,n) -> dy(m,n) x w(k,n)^T == trans_b=True
    #                     if w stored (n,k) (trans_w) -> plain dy @ w.
    dx = _matmul_2d(
        dy, w, None, bwd_policy, False, not trans_w, backend,
        out_dtype=x2d.dtype, acc_dtype=bwd_acc,
    )
    # dw: (k,n) = x^T @ dy ; transposed storage: (n,k) = dy^T @ x.
    if trans_w:
        dw = _matmul_2d(
            dy, x2d, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    else:
        dw = _matmul_2d(
            x2d, dy, None, bwd_policy, True, False, backend,
            out_dtype=w.dtype, acc_dtype=bwd_acc,
        )
    dbias = jnp.sum(dy, axis=0, dtype=jnp.float32) if has_bias else None
    return dx, dw, dbias


_mp_dot_core.defvjp(_mp_dot_fwd, _mp_dot_bwd)


def mp_dot(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    policy="bf16",
    trans_w: bool = False,
    backend: Optional[str] = None,
) -> jax.Array:
    """y[..., n] = x[..., k] @ (w[n, k]ᵀ if trans_w else w[k, n]) + bias.

    ``trans_w=True`` is the on-the-fly-transposition path — used e.g. for
    tied-embedding logits (w stored (vocab, d_model)).
    """
    policy = get_policy(policy)
    backend = backend or cfg.get_gemm_backend()
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if bias is not None:
        bias = bias.reshape(-1)
    y2d = _mp_dot_core(x2d, w, bias, policy.name, trans_w, backend)
    wshape = w["q"].shape if isinstance(w, dict) else w.shape
    n = wshape[0] if trans_w else wshape[-1]
    return y2d.reshape(*lead, n)


def mp_einsum(spec: str, *operands, policy="bf16") -> jax.Array:
    """Policy-aware einsum for non-2D contractions (MoE experts, attention).

    Runs on XLA with the policy's compute/accumulate dtypes; quantized
    policies fall back to their bf16 sibling here (documented in DESIGN.md —
    per-expert dynamic quantization would need per-slice scales).
    """
    policy = get_policy(policy)
    if policy.quantized:
        policy = get_policy("bf16")
    cd = jnp.dtype(policy.compute_dtype)
    ops = [o.astype(cd) if jnp.dtype(o.dtype).kind == "f" else o for o in operands]
    out = jnp.einsum(
        spec, *ops, preferred_element_type=jnp.dtype(policy.acc_dtype)
    )
    return out.astype(policy.out_dtype)
