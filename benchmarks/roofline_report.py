"""Aggregate experiments/dryrun JSONs into the EXPERIMENTS.md §Roofline
table (markdown) and a CSV."""
import json
import os

from benchmarks.common import record

DRYRUN_DIR = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "experiments", "dryrun"))


def load(mesh="single"):
    rows = []
    if not os.path.isdir(DRYRUN_DIR):
        return rows
    for fname in sorted(os.listdir(DRYRUN_DIR)):
        if not fname.endswith(".json"):
            continue
        with open(os.path.join(DRYRUN_DIR, fname)) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            rows.append(r)
    return rows


def markdown_table(mesh="single"):
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| useful | mem/dev GiB | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(load(mesh), key=lambda r: (r["arch"],
                                               order.get(r["shape"], 9))):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | SKIP: {r['reason'][:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | ERROR |")
            continue
        ro = r["roofline"]
        mem = r["memory"].get("peak_bytes_est", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4g} "
            f"| {ro['memory_s']:.4g} | {ro['collective_s']:.4g} "
            f"| **{ro['bottleneck']}** | {ro['useful_ratio']:.3f} "
            f"| {mem:.2f} | mb={r.get('microbatches','-')} |")
    return "\n".join(lines)


def run():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        ok = sum(1 for r in rows if r["status"] == "ok")
        skip = sum(1 for r in rows if r["status"] == "skip")
        err = len(rows) - ok - skip
        print(f"roofline_report_{mesh},0.00,cells={len(rows)};ok={ok};"
              f"skip={skip};error={err}")
        record(f"roofline_report_{mesh}", "gemm", kind="report",
               workload={"mesh": mesh},
               metrics={"cells": float(len(rows)), "cells_ok": float(ok),
                        "cells_skip": float(skip),
                        "cells_error": float(err)})


if __name__ == "__main__":
    print(markdown_table("single"))
    print()
    print(markdown_table("multi"))
