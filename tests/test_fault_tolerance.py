"""distributed/fault_tolerance.py: straggler detection state machine,
elastic-mesh planning, and the crash/straggle simulation harness."""
import pytest

from repro.distributed.fault_tolerance import (
    FailureEvent, StragglerDetector, plan_elastic_mesh, simulate_failures,
)


def test_straggler_ok_suspect_remesh_progression():
    det = StragglerDetector(factor=2.0, patience=3)
    assert det.observe(1.0) == "ok"          # first sample seeds the EWMA
    assert det.observe(1.1) == "ok"
    # Three consecutive slow steps: suspect, suspect, then remesh.
    assert det.observe(5.0) == "suspect"
    assert det.observe(5.0) == "suspect"
    assert det.observe(5.0) == "remesh"
    assert det.suspect_streak == 0           # streak resets after remesh
    # Slow steps never poison the EWMA baseline.
    assert det.ewma < 2.0


def test_straggler_streak_resets_on_recovery():
    det = StragglerDetector(factor=2.0, patience=2)
    det.observe(1.0)
    assert det.observe(5.0) == "suspect"
    assert det.observe(1.0) == "ok"          # recovery clears the streak
    assert det.observe(5.0) == "suspect"     # needs a fresh streak
    assert det.observe(5.0) == "remesh"


def test_plan_elastic_mesh_shrinks_data_axis():
    assert plan_elastic_mesh(1024, model_parallel=16) == (64, 16)
    # Losing chips shrinks data parallelism; the model axis never moves
    # (weight shardings stay valid across the re-mesh).
    assert plan_elastic_mesh(1000, model_parallel=16) == (62, 16)
    assert plan_elastic_mesh(16, model_parallel=16) == (1, 16)
    assert plan_elastic_mesh(15, model_parallel=16) is None
    assert plan_elastic_mesh(40, model_parallel=16, min_data=3) is None


def test_simulate_crash_restores_from_checkpoint():
    saved = []
    log = simulate_failures(
        run_step=lambda step: 1.0,
        total_steps=12,
        events=[FailureEvent(step=7, kind="crash")],
        checkpoint_every=5,
        save=saved.append,
        restore=lambda: saved[-1] if saved else 0,
    )
    assert (7, "crash->restore") in log
    # Steps 5..6 re-ran after restoring the step-5 checkpoint (the crash
    # hit before boundary 10, so each boundary still saves exactly once).
    assert saved == [5, 10]
    assert [s for s, what in log if what == "checkpoint"] == [5, 10]


def test_simulate_straggle_trips_detector():
    events = [FailureEvent(step=s, kind="straggle", magnitude=10.0)
              for s in (4, 5, 6)]
    log = simulate_failures(
        run_step=lambda step: 1.0, total_steps=10, events=events,
        checkpoint_every=100,
    )
    verdicts = [what for _, what in log]
    assert verdicts == ["suspect", "suspect", "remesh"]
    assert [s for s, _ in log] == [4, 5, 6]


def test_simulate_no_events_is_clean():
    log = simulate_failures(run_step=lambda step: 1.0, total_steps=7,
                            events=[], checkpoint_every=3)
    assert log == [(3, "checkpoint"), (6, "checkpoint")]
