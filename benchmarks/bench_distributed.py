"""Sharded MPGEMM: modeled comm-vs-compute overlap, the collective-schedule
trace gate, and the multi-device parity smoke.

Three measurement families (area ``distributed``, -> ``BENCH_distributed
.json``):

  * ``dist_model_*`` — pure-arithmetic scale-out accounting per paper
                       workload and mesh size: per-device wire bytes from
                       the ring-collective cost model, chunked-pipeline
                       exposed-comm time vs the blocking-collective
                       baseline, and the LOCAL-shard CMR the mesh-aware
                       planner keys plans on (vs the single-device CMR the
                       same shape would get — the reason ``make_key`` grew
                       a ``|mesh=`` namespace).  Deterministic, device-
                       count independent.
  * ``dist_trace_*`` — the **collective-schedule gate**: the traced jaxpr
                       of the ring ``mp_dot_sharded`` must contain exactly
                       P-1 ``ppermute`` equations interleaved with >= P
                       chunk GEMMs and NO ``psum`` (the all-at-the-end
                       blocking collective it replaces); the blocking
                       variant must show the converse; the expert-parallel
                       grouped path must dispatch and combine through two
                       ``all_to_all``s.  Trace-time facts — needs >= 4
                       devices, so on smaller hosts the counts come from a
                       subprocess re-exec under
                       ``--xla_force_host_platform_device_count=8`` (the
                       records are identical either way).
  * parity smoke     — sharded outputs vs the single-device ``mp_dot`` /
                       ``mp_dot_grouped`` oracle across mesh sizes and
                       operand encodings (dense / packed / tile-sparse /
                       ragged expert-parallel).  Device-count dependent ->
                       asserted under ``--smoke`` only, never recorded.

``--smoke`` runs the hard gates and exits nonzero on any failure.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, record

# Paper Table III rows with M, N and K all divisible by every modeled mesh
# size: a decode row, its batched variant, and a large training row.
DIST_WORKLOADS = [(6, 64, 7168, 2048), (12, 128, 7168, 2048),
                  (17, 4096, 4096, 7168)]

# One grouped MoE shape with G divisible by 8 (benchmarks/common.py).
DIST_MOE = ("granite-moe-up", 32, 1280, 512, 1024)

MESH_SIZES = (2, 4, 8)

# Trace-gate problem: tiny, but P | M, N, K and P | G.
_TRACE_P = 4
_TRACE_MNK = (16, 64, 128)
_TRACE_GMNK = (4, 8, 32, 16)


def _overlap_metrics(compute_us: float, comm_us: float, steps: int) -> dict:
    from repro.perf.metrics import modeled_overlap
    ring = modeled_overlap(compute_us, comm_us, steps)
    blocking = modeled_overlap(compute_us, comm_us, 1)
    out = {f"ring_{k}": v for k, v in ring.items()}
    out["blocking_exposed_comm_us"] = blocking["exposed_comm_us"]
    out["speedup_vs_blocking"] = (blocking["pipelined_us"]
                                  / max(ring["pipelined_us"], 1e-30))
    return out


def run(rows=None):
    """Modeled scale-out accounting: wire bytes, pipelined vs blocking
    exposed comm, and the local-shard CMR the mesh planner keys on."""
    from repro.core.blocking import plan_gemm
    from repro.perf.metrics import (
        gemm_flops, modeled_collective_us, modeled_gemm_us,
        sharded_gemm_comm_bytes,
    )

    rows = rows if rows is not None else []
    for wid, m, n, k in DIST_WORKLOADS:
        cmr_global = plan_gemm(m, n, k, "bfloat16").cmr
        for p in MESH_SIZES:
            # row partition: B K-sharded, ring reduce-scatter of the f32
            # partial; local compute is P chunk GEMMs of (m, n/P, k/P).
            chunk = plan_gemm(m, n // p, k // p, "bfloat16")
            compute_us = p * modeled_gemm_us(chunk.flops, chunk.hbm_bytes)
            comm_bytes = sharded_gemm_comm_bytes(
                m, n, k, partition="row", axis_size=p)
            comm_us = modeled_collective_us(comm_bytes)
            cmr_local = plan_gemm(m, n, k // p, "bfloat16").cmr
            mets = {"comm_bytes": float(comm_bytes),
                    "comm_us": comm_us, "compute_us": compute_us,
                    "cmr_local": cmr_local, "cmr_global": cmr_global}
            mets.update(_overlap_metrics(compute_us, comm_us, p))
            emit(f"dist_model_row_w{wid}_p{p}", 0.0,
                 f"comm_bytes={comm_bytes};"
                 f"exposed_ring={mets['ring_exposed_comm_us']:.2f}us;"
                 f"exposed_blocking={mets['blocking_exposed_comm_us']:.2f}us;"
                 f"cmr_local={cmr_local:.1f};cmr_global={cmr_global:.1f}")
            record(f"dist_model_row_w{wid}_p{p}", "distributed",
                   workload={"paper_row": wid, "m": m, "n": n, "k": k,
                             "partition": "row", "axis_size": p},
                   metrics=mets)
            rows.append(dict(name=f"dist_model_row_w{wid}_p{p}", **mets))

            # gather partition: X M-sharded, ring all-gather; local compute
            # is P step GEMMs of (m/P, n/P, k).
            step = plan_gemm(m // p, n // p, k, "bfloat16")
            compute_us = p * modeled_gemm_us(step.flops, step.hbm_bytes)
            comm_bytes = sharded_gemm_comm_bytes(
                m, n, k, partition="gather", axis_size=p)
            comm_us = modeled_collective_us(comm_bytes)
            cmr_local = plan_gemm(m, n // p, k, "bfloat16").cmr
            mets = {"comm_bytes": float(comm_bytes),
                    "comm_us": comm_us, "compute_us": compute_us,
                    "cmr_local": cmr_local, "cmr_global": cmr_global}
            mets.update(_overlap_metrics(compute_us, comm_us, p))
            emit(f"dist_model_gather_w{wid}_p{p}", 0.0,
                 f"comm_bytes={comm_bytes};"
                 f"exposed_ring={mets['ring_exposed_comm_us']:.2f}us;"
                 f"cmr_local={cmr_local:.1f};cmr_global={cmr_global:.1f}")
            record(f"dist_model_gather_w{wid}_p{p}", "distributed",
                   workload={"paper_row": wid, "m": m, "n": n, "k": k,
                             "partition": "gather", "axis_size": p},
                   metrics=mets)
            rows.append(dict(name=f"dist_model_gather_w{wid}_p{p}",
                             **mets))

    # expert partition: tokens all-to-all'd to their expert shard; local
    # compute is the (G/P)-expert grouped GEMM.
    name, g, m, n, k = DIST_MOE
    cmr_global = plan_gemm(m, n, k, "bfloat16").cmr
    for p in MESH_SIZES:
        local = plan_gemm(m, n, k, "bfloat16")
        flops = gemm_flops(m, n, k, g=g // p)
        compute_us = (g // p) * modeled_gemm_us(local.flops,
                                                local.hbm_bytes)
        comm_bytes = sharded_gemm_comm_bytes(
            m, n, k, partition="expert", axis_size=p, g=g)
        comm_us = modeled_collective_us(comm_bytes)
        mets = {"comm_bytes": float(comm_bytes), "comm_us": comm_us,
                "compute_us": compute_us, "local_flops": float(flops),
                "cmr_local": local.cmr, "cmr_global": cmr_global}
        # Dispatch overlaps per-expert GEMMs the same way ring steps do.
        mets.update(_overlap_metrics(compute_us, comm_us, g // p))
        emit(f"dist_model_expert_{name}_p{p}", 0.0,
             f"comm_bytes={comm_bytes};"
             f"exposed_ring={mets['ring_exposed_comm_us']:.2f}us;"
             f"exposed_blocking={mets['blocking_exposed_comm_us']:.2f}us")
        record(f"dist_model_expert_{name}_p{p}", "distributed",
               workload={"moe": name, "g": g, "m": m, "n": n, "k": k,
                         "partition": "expert", "axis_size": p},
               metrics=mets)
        rows.append(dict(name=f"dist_model_expert_{name}_p{p}", **mets))
    return rows


def _trace_counts() -> dict:
    """Op counts of each sharded-GEMM schedule (requires >= 4 devices).

    ``obs.audit.schedule_counts`` owns the walk: ordered GEMM/collective
    occurrences plus the ring-interleave summary (every ppermute separated
    from the next by a chunk GEMM)."""
    import jax
    import jax.numpy as jnp

    from repro.distributed import mp_dot_grouped_sharded, mp_dot_sharded
    from repro.launch.mesh import make_tp_mesh
    from repro.obs import audit

    p = _TRACE_P
    mesh = make_tp_mesh(p)
    m, n, k = _TRACE_MNK
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    out = {}
    for variant, partition, overlap in (
            ("ring_row", "row", "ring"),
            ("blocking_row", "row", "blocking"),
            ("ring_gather", "gather", "ring")):
        out[variant] = audit.schedule_counts(audit.trace(
            lambda xx, bb, _p=partition, _o=overlap: mp_dot_sharded(
                xx, bb, mesh=mesh, partition=_p, overlap=_o,
                policy="fp32", backend="xla"), x, b))

    g, gm, gk, gn = _TRACE_GMNK
    xg = jax.ShapeDtypeStruct((g, gm, gk), jnp.float32)
    bg = jax.ShapeDtypeStruct((g, gk, gn), jnp.float32)
    out["expert_grouped"] = audit.schedule_counts(audit.trace(
        lambda xx, bb: mp_dot_grouped_sharded(
            xx, bb, mesh=mesh, policy="fp32", backend="xla"), xg, bg))
    return out


def _trace_counts_subprocess() -> dict:
    """Re-exec under forced host devices; counts are trace-time facts so
    the records match the in-process path byte for byte."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "trace.json")
        subprocess.run(
            [sys.executable, os.path.abspath(__file__),
             "--trace-json", path],
            check=True, env=env, cwd=root)
        with open(path) as f:
            return json.load(f)


def run_trace_gate(assert_gate: bool = False):
    """The jaxpr proof that the ring schedule is CHUNKED and INTERLEAVED:
    P-1 ppermutes threaded between >= P chunk GEMMs with no psum, where
    the blocking baseline is one psum after one GEMM."""
    import jax

    p = _TRACE_P
    if jax.device_count() >= p:
        counts = _trace_counts()
    else:
        counts = _trace_counts_subprocess()

    for variant, c in counts.items():
        m, n, k = _TRACE_MNK
        emit(f"dist_trace_{variant}", 0.0,
             f"dots={c['dots']};ppermutes={c['ppermutes']};"
             f"psums={c['psums']};all_to_alls={c['all_to_alls']};"
             f"interleaved={c['interleaved']}")
        record(f"dist_trace_{variant}", "distributed", kind="trace",
               workload={"m": m, "n": n, "k": k, "axis_size": p,
                         "variant": variant},
               metrics={key: float(val) for key, val in c.items()})

    if assert_gate:
        ring = counts["ring_row"]
        assert ring["ppermutes"] == p - 1 and ring["psums"] == 0, (
            f"ring row schedule is not a chunked ring: {ring}")
        assert ring["dots"] >= p and ring["interleaved"], (
            f"ring row chunk GEMMs not interleaved with permutes: {ring}")
        gather = counts["ring_gather"]
        assert gather["ppermutes"] == p - 1 and gather["psums"] == 0, (
            f"ring gather schedule is not a chunked ring: {gather}")
        assert gather["dots"] >= p and gather["interleaved"], (
            f"ring gather GEMMs not interleaved with permutes: {gather}")
        blocking = counts["blocking_row"]
        assert blocking["psums"] >= 1 and blocking["ppermutes"] == 0, (
            f"blocking baseline grew a ring: {blocking}")
        ep = counts["expert_grouped"]
        assert ep["all_to_alls"] == 2 and ep["dots"] >= 1, (
            f"expert path is not dispatch/combine all-to-all: {ep}")
    return counts


def run_parity(assert_gate: bool = True):
    """Sharded vs single-device oracle across operand encodings; needs a
    multi-device host (the CI multidevice job), never recorded."""
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core.gemm import mp_dot, mp_dot_grouped
    from repro.distributed import mp_dot_grouped_sharded, mp_dot_sharded
    from repro.launch.mesh import make_tp_mesh
    from repro.packing.pack import pack_operand
    from repro.sparse.sparsify import sparsify_magnitude

    sizes = [p for p in (1, 2, 4, 8) if p <= jax.device_count()]
    assert len(sizes) >= 2, (
        f"parity smoke needs >= 2 devices, got {jax.device_count()} — "
        "set XLA_FLAGS=--xla_force_host_platform_device_count=8")
    rng = np.random.default_rng(0)
    m, n, k = 64, 128, 256
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((n,)), jnp.float32)
    want = mp_dot(x, b, bias, policy="fp32", backend="xla")
    worst = 0.0
    for p in sizes:
        mesh = make_tp_mesh(p)
        for partition in ("column", "row", "gather"):
            for overlap in ("ring", "blocking"):
                got = mp_dot_sharded(
                    x, b, bias, mesh=mesh, partition=partition,
                    overlap=overlap, policy="fp32", backend="xla")
                err = float(jnp.max(jnp.abs(got - want)))
                worst = max(worst, err)
                if assert_gate:
                    assert err < 1e-3, (
                        f"p={p} {partition}/{overlap} diverged: {err}")
        # packed + tile-sparse ride the per-shard-parts column path
        pk = pack_operand(b, (32, 16))
        got = mp_dot_sharded(x, pk, bias, mesh=mesh, policy="fp32")
        errp = float(jnp.max(jnp.abs(
            got - mp_dot(x, pk, bias, policy="fp32"))))
        sp = sparsify_magnitude(b, (32, 16), density=0.5)
        got = mp_dot_sharded(x, sp, bias, mesh=mesh, policy="fp32")
        errs = float(jnp.max(jnp.abs(
            got - mp_dot(x, sp, bias, policy="fp32"))))
        worst = max(worst, errp, errs)
        if assert_gate:
            assert errp < 1e-3, f"p={p} packed diverged: {errp}"
            assert errs < 1e-3, f"p={p} sparse diverged: {errs}"

    # ragged expert-parallel grouped
    g, gm, gk, gn = 8, 32, 64, 48
    xg = jnp.asarray(rng.standard_normal((g, gm, gk)), jnp.float32)
    bg = jnp.asarray(rng.standard_normal((g, gk, gn)), jnp.float32)
    sizes_g = [p for p in sizes if g % p == 0]
    gs = jnp.asarray(rng.integers(0, gm + 1, (g,)), jnp.int32)
    want_g = mp_dot_grouped(xg, bg, group_sizes=gs, policy="fp32",
                            backend="xla")
    for p in sizes_g:
        mesh = make_tp_mesh(p)
        got = mp_dot_grouped_sharded(xg, bg, mesh=mesh, group_sizes=gs,
                                     policy="fp32", backend="xla")
        err = float(jnp.max(jnp.abs(got - want_g)))
        worst = max(worst, err)
        if assert_gate:
            assert err < 1e-3, f"p={p} expert-parallel diverged: {err}"
    emit("dist_parity_smoke", 0.0,
         f"mesh_sizes={sizes};max_abs_err={worst:.2e}")
    return worst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="hard gates: chunked-ring trace schedule + "
                         "multi-device parity vs the mp_dot oracle "
                         "(CI multidevice job)")
    ap.add_argument("--trace-json", default=None, metavar="PATH",
                    help=argparse.SUPPRESS)  # internal re-exec mode
    args = ap.parse_args()

    if args.trace_json:
        with open(args.trace_json, "w") as f:
            json.dump(_trace_counts(), f)
        return

    run()
    run_trace_gate(assert_gate=args.smoke)
    if args.smoke:
        run_parity(assert_gate=True)


if __name__ == "__main__":
    main()
