"""Fused vs unfused epilogues on the gated-MLP (SwiGLU) workloads.

The registry epilogues (core/gemm_spec.py) move post-GEMM elementwise work
into the kernel's accumulator store.  For a SwiGLU MLP the gating step
``silu(x@w_gate) * (x@w_up)`` is the win:

  unfused: gate GEMM writes h_gate; a separate elementwise pass re-reads
           h_gate and up, applies silu, multiplies, writes h
           -> 4 extra (M, d_ff)-sized HBM transfers + one more launch;
  fused:   the gate GEMM streams ``up`` as an epilogue operand and writes
           act(acc)·up directly -> 2 transfers, zero extra launches.

The residual-add fusion removes the block's ``x + mlp(x)`` elementwise pass
the same way (2 extra transfers -> riding the down projection's store).

Workloads are the framework's own MoE configs (configs/mixtral_8x22b.py,
configs/granite_moe_1b_a400m.py): the dense per-token SwiGLU shape and the
grouped (expert-batched) form the MoE layer launches.

Reported per workload:

  * ``epilogue_bytes``  — modeled HBM bytes of the gating step, fused vs
                          unfused (the elementwise pass packing can't help
                          with — only epilogue fusion removes it);
  * ``launches``        — Pallas launches per MLP forward, counted from the
                          traced jaxpr of the jitted fused/unfused MLP
                          (exact, timing-noise-free);
  * wall-clock sanity on one small shape (interpret kernel, CPU).

``--smoke`` asserts the jaxpr facts CI gates on: the fused SwiGLU MLP
traces to exactly 3 Pallas launches with ZERO stand-alone gating ops — the
gated-activation step (gate GEMM + silu + product) is a single launch —
while the unfused trace carries the separate elementwise pass.  Set
``REPRO_EPILOGUE_OUT`` to also write ``epilogue_report.md``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import emit, modeled_time_s, record, wall_time_us
from repro.core import config as cfg
from repro.models.layers import init_swiglu, swiglu_mlp
from repro.obs import audit

# (name, G groups or None, M tokens, d_model, d_ff) — dense SwiGLU shapes
# plus the grouped expert-batched form (M ≈ capacity tokens per expert at a
# 4k-token step, matching benchmarks/common.MOE_GROUPED_WORKLOADS).
GATED_MLP_WORKLOADS = [
    ("mixtral-8x22b-mlp", None, 4096, 6144, 16384),
    ("granite-moe-mlp", None, 4096, 1024, 512),
    ("mixtral-8x22b-experts", 8, 1280, 6144, 16384),
    ("granite-moe-experts", 32, 1280, 1024, 512),
]


def _gating_bytes(g, m, f, itemsize: int = 2):
    """Modeled HBM bytes of the gating step beyond the two GEMMs.

    Unfused: write h_gate, then the elementwise pass reads h_gate + up and
    writes h.  Fused: the gate GEMM's epilogue streams up once and writes h
    once.  (The up write and the down-projection read are common to both.)
    """
    elems = (g or 1) * m * f
    return 4 * elems * itemsize, 2 * elems * itemsize


def trace_counts(fused: bool, m: int = 32, d: int = 64, f: int = 128):
    """(pallas launches, stand-alone gating ops) of a jitted SwiGLU MLP.

    Primitive counts come from ``obs.audit.primitive_counts``, which skips
    pallas_call bodies (their internal ops are fused in-kernel — that is
    the point)."""
    params = init_swiglu(jax.random.PRNGKey(0), d, f)
    x = jax.ShapeDtypeStruct((m, d), jnp.bfloat16)

    def mlp(params, x):
        with cfg.gemm_backend("interpret"), cfg.fused_epilogue(fused):
            return swiglu_mlp(params, x, "bf16")

    counts = audit.primitive_counts(audit.trace(mlp, params, x))
    launches = counts.get("pallas_call", 0)
    # The gating pass at the XLA level: silu's sigmoid + the h_gate·up
    # product.  Fused, both live inside the gate GEMM's kernel body.
    gating_ops = counts.get("logistic", 0)
    return launches, gating_ops, counts


def run(smoke: bool = False, rows=None):
    rows = rows if rows is not None else []
    work = GATED_MLP_WORKLOADS[:2] if smoke else GATED_MLP_WORKLOADS
    for name, g, m, d, f in work:
        un_b, fu_b = _gating_bytes(g, m, f)
        un_us = modeled_time_s(0, un_b) * 1e6   # pure-memory elementwise pass
        fu_us = modeled_time_s(0, fu_b) * 1e6
        rows.append(dict(name=name, g=g or 1, m=m, d=d, f=f,
                         unfused_bytes=un_b, fused_bytes=fu_b,
                         unfused_us=un_us, fused_us=fu_us))
        emit(f"epilogue_{name}", fu_us,
             f"g={g or 1};gating_bytes={un_b}->{fu_b};"
             f"modeled_us={un_us:.1f}->{fu_us:.1f};"
             f"saved_frac={1 - fu_b / un_b:.2f}")
        record(f"epilogue_{name}", "gemm",
               workload={"g": g or 1, "m": m, "d_model": d, "d_ff": f},
               metrics={"unfused_gating_bytes": float(un_b),
                        "fused_gating_bytes": float(fu_b),
                        "fused_modeled_us": fu_us,
                        "saved_frac": 1 - fu_b / un_b})
    return rows


def run_trace_gate(assert_fused: bool = False):
    """The jaxpr facts: fused SwiGLU == 3 launches, gating in-kernel."""
    fused_launches, fused_gate, _ = trace_counts(True)
    unfused_launches, unfused_gate, _ = trace_counts(False)
    emit("epilogue_trace_swiglu", 0.0,
         f"fused_pallas_calls={fused_launches};"
         f"fused_standalone_gating_ops={fused_gate};"
         f"unfused_pallas_calls={unfused_launches};"
         f"unfused_standalone_gating_ops={unfused_gate}")
    record("epilogue_trace_swiglu", "gemm", kind="trace",
           metrics={"fused_launches": float(fused_launches),
                    "fused_gating_ops": float(fused_gate),
                    "unfused_launches": float(unfused_launches),
                    "unfused_gating_ops": float(unfused_gate)})
    if assert_fused:
        assert fused_launches == 3, (
            f"fused SwiGLU MLP must be exactly 3 Pallas launches "
            f"(up, gate+gating, down), got {fused_launches}")
        assert fused_gate == 0, (
            f"fused trace still has {fused_gate} stand-alone gating ops — "
            f"the gated epilogue is not riding the GEMM")
        assert unfused_gate > 0, (
            "unfused baseline lost its elementwise gating pass — the A/B "
            "no longer measures fusion")
    return fused_launches, fused_gate, unfused_gate


def run_wall_sanity():
    """CPU wall clock, small shape, interpret kernel: the fused gating step
    must not be slower than GEMM + separate elementwise (it does strictly
    less memory work)."""
    rng = np.random.default_rng(0)
    m, d, f = 64, 128, 256
    params = init_swiglu(jax.random.PRNGKey(0), d, f)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.bfloat16)

    def make(fused):
        def mlp(params, x):
            with cfg.gemm_backend("interpret"), cfg.fused_epilogue(fused):
                return swiglu_mlp(params, x, "bf16")
        return jax.jit(mlp)

    us_fused = wall_time_us(make(True), params, x, iters=3)
    us_unfused = wall_time_us(make(False), params, x, iters=3)
    emit("epilogue_wall_sanity_64x128x256_bf16", us_fused,
         f"unfused_us={us_unfused:.1f};fused_us={us_fused:.1f}")
    record("epilogue_wall_sanity_64x128x256_bf16", "gemm", kind="wall",
           workload={"m": 64, "d_model": 128, "d_ff": 256},
           noisy={"fused_wall_us": us_fused,
                  "unfused_wall_us": us_unfused})
    return us_unfused, us_fused


def write_report(rows, trace, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "epilogue_report.md")
    fused_launches, fused_gate, unfused_gate = trace
    lines = [
        "# Fused vs unfused epilogues (gated SwiGLU MLP)",
        "",
        "Gating-step HBM bytes are modeled: unfused pays write(h_gate) + "
        "read(h_gate) + read(up) + write(h); the gated epilogue "
        "(core/gemm_spec.py) pays read(up) + write(h) inside the gate "
        "GEMM's store.",
        "",
        "| workload | G | M | d_model | d_ff | gating B unfused | fused | "
        "saved | modeled us unfused -> fused |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']} | {r['g']} | {r['m']} | {r['d']} | {r['f']} "
            f"| {r['unfused_bytes']:,} | {r['fused_bytes']:,} "
            f"| {1 - r['fused_bytes'] / r['unfused_bytes']:.0%} "
            f"| {r['unfused_us']:.1f} -> {r['fused_us']:.1f} |")
    lines += [
        "",
        f"**Jaxpr proof:** the fused SwiGLU MLP traces to "
        f"{fused_launches} Pallas launches with {fused_gate} stand-alone "
        f"gating ops (gate GEMM + silu + product = ONE launch); the "
        f"unfused trace carries {unfused_gate} separate gating ops.",
        "",
    ]
    with open(path, "w") as fobj:
        fobj.write("\n".join(lines))
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="2 workloads + hard jaxpr assertions (CI gate)")
    args = ap.parse_args()

    rows = run(smoke=args.smoke)
    trace = run_trace_gate(assert_fused=True)
    if not args.smoke:
        run_wall_sanity()

    out_dir = os.environ.get("REPRO_EPILOGUE_OUT")
    if out_dir:
        print(f"report: {write_report(rows, trace, out_dir)}")


if __name__ == "__main__":
    main()
