"""Continuous-batching serve stack: paged KV allocator invariants, paged
flash-attention parity against a dense oracle, prefix-sharing reuse, and
end-to-end engine behavior (no head-of-line stall, paged < dense KV bytes,
preemption, deprecation shims)."""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serve.kv_cache import SCRATCH_PAGE, PagedKVCache, cdiv


# =============================== allocator ===================================

def test_alloc_free_roundtrip():
    kv = PagedKVCache(num_pages=9, page_size=4)
    assert kv.free_pages == 8 and kv.pages_in_use == 0
    kv.allocate("a")
    assert kv.ensure("a", 10)            # 3 pages
    assert kv.pages_in_use == 3 and kv.length("a") == 10
    assert all(p != SCRATCH_PAGE for p in kv.table("a"))
    kv.advance("a", 1)
    assert kv.length("a") == 11
    kv.check_invariants()
    kv.free_seq("a")
    assert kv.pages_in_use == 0 and kv.free_pages == 8
    kv.check_invariants()


def test_ensure_all_or_nothing_rollback():
    kv = PagedKVCache(num_pages=5, page_size=4)   # 4 allocatable
    kv.allocate("a")
    assert kv.ensure("a", 8)             # 2 pages
    kv.allocate("b")
    assert kv.ensure("b", 4)             # 1 page -> 1 left
    before = kv.table("b")
    assert not kv.ensure("b", 12)        # needs 2 more, only 1 free
    assert kv.table("b") == before       # rolled back, nothing leaked
    assert kv.free_pages == 1
    kv.check_invariants()
    # the remaining page is still allocatable after the failed grow
    assert kv.ensure("b", 8)
    assert kv.free_pages == 0
    kv.check_invariants()


def test_double_free_raises():
    kv = PagedKVCache(num_pages=4, page_size=2)
    kv.allocate("a")
    assert kv.ensure("a", 2)
    kv.free_seq("a")
    with pytest.raises(KeyError):
        kv.free_seq("a")                 # table already gone
    kv.check_invariants()


def test_block_table_row_scratch_padded():
    kv = PagedKVCache(num_pages=6, page_size=2)
    kv.allocate("a")
    assert kv.ensure("a", 3)             # 2 pages
    row = kv.block_table_row("a", width=5)
    assert row.dtype == np.int32 and row.shape == (5,)
    assert list(row[:2]) == kv.table("a")
    assert all(p == SCRATCH_PAGE for p in row[2:])
    with pytest.raises(ValueError, match="width"):
        kv.block_table_row("a", width=1)


def test_prefix_sharing_reuse_counts():
    ps = 4
    kv = PagedKVCache(num_pages=12, page_size=ps)
    prompt = list(range(100, 111))       # 11 tokens = 2 full pages + 3

    kv.allocate("donor")
    assert kv.ensure("donor", len(prompt))
    added = kv.register_prefix("donor", prompt)
    assert added == 2 and kv.prefix_entries == 2
    donor_pages = kv.table("donor")

    # A sharer with the same prompt reuses BOTH full pages...
    pages, shared = kv.match_prefix(prompt)
    assert pages == donor_pages[:2] and shared == 2 * ps
    # ...but never the partial tail, and never ALL pages of an exact
    # page-multiple prompt (>= 1 token must remain to prefill).
    exact = list(range(100, 108))        # 8 tokens = 2 exact pages
    pages_e, shared_e = kv.match_prefix(exact)
    assert shared_e == ps and len(pages_e) == 1

    kv.allocate("sharer", shared_pages=pages, shared_tokens=shared)
    assert kv.stats.prefix_hit_tokens == shared
    assert kv.ensure("sharer", len(prompt))
    assert kv.table("sharer")[:2] == donor_pages[:2]      # physically shared
    assert kv.table("sharer")[2] != donor_pages[2]
    kv.check_invariants()

    # Shared pages survive the donor's exit (index + sharer hold refs)...
    kv.free_seq("donor")
    kv.check_invariants()
    again, shared2 = kv.match_prefix(prompt)
    assert again == donor_pages[:2] and shared2 == 2 * ps
    # ...and return to the pool only after every holder drops them.
    kv.free_seq("sharer")
    kv.check_invariants()
    assert kv.pages_in_use == 2          # prefix index still pins them

    kv.allocate("other", shared_pages=again, shared_tokens=shared2)
    with pytest.raises(ValueError, match="full pages"):
        kv.allocate("bad", shared_pages=again, shared_tokens=3)


def test_prefix_eviction_under_pressure():
    ps = 2
    kv = PagedKVCache(num_pages=4, page_size=ps)      # 3 allocatable
    prompt = [1, 2, 3]
    kv.allocate("donor")
    assert kv.ensure("donor", 3)                      # 2 pages
    kv.register_prefix("donor", prompt)
    kv.free_seq("donor")
    assert kv.pages_in_use == 1 and kv.prefix_entries == 1

    # Demand exceeding the free list reclaims the unreferenced prefix page.
    kv.allocate("big")
    assert kv.ensure("big", 6)                        # needs all 3 pages
    assert kv.stats.evictions == 1 and kv.prefix_entries == 0
    assert kv.pages_in_use == 3
    kv.check_invariants()
    # Pool exhausted and nothing evictable -> ensure refuses.
    kv.allocate("late")
    assert not kv.ensure("late", 1)


# ======================= paged attention vs dense oracle =====================

def _dense_oracle(q, kd, vd, q_start, lengths, causal, window):
    """Masked grouped-GQA softmax over DENSE per-request K/V (numpy f32)."""
    b, h, tq, d = q.shape
    _, hkv, t, _ = kd.shape
    g = h // hkv
    scale = 1.0 / d ** 0.5
    out = np.zeros_like(q, dtype=np.float32)
    for bi in range(b):
        for hi in range(h):
            kv_h = hi // g
            s = (q[bi, hi].astype(np.float32)
                 @ kd[bi, kv_h].astype(np.float32).T) * scale    # (tq, t)
            qi = q_start[bi] + np.arange(tq)[:, None]
            ki = np.arange(t)[None, :]
            mask = np.broadcast_to(ki < lengths[bi], (tq, t)).copy()
            if causal:
                mask &= ki <= qi
            if window is not None:
                mask &= ki > qi - window
            s = np.where(mask, s, -1e30)
            p = np.exp(s - s.max(axis=1, keepdims=True))
            p = p / np.maximum(p.sum(axis=1, keepdims=True), 1e-30)
            out[bi, hi] = p @ vd[bi, kv_h].astype(np.float32)
    return out


def _paged_setup(rng, b, hkv, g, tq, t_max, d, ps):
    """Random pool + block tables + the dense K/V each table represents."""
    w = cdiv(t_max, ps)
    n_pages = 1 + b * w
    k_pages = rng.standard_normal((n_pages, hkv, ps, d)).astype(np.float32)
    v_pages = rng.standard_normal((n_pages, hkv, ps, d)).astype(np.float32)
    perm = rng.permutation(np.arange(1, n_pages))     # scrambled physical ids
    bt = perm[: b * w].reshape(b, w).astype(np.int32)
    kd = k_pages[bt].transpose(0, 2, 1, 3, 4).reshape(b, hkv, w * ps, d)
    vd = v_pages[bt].transpose(0, 2, 1, 3, 4).reshape(b, hkv, w * ps, d)
    q = rng.standard_normal((b, hkv * g, tq, d)).astype(np.float32)
    return q, k_pages, v_pages, bt, kd, vd


@pytest.mark.parametrize("tq,window", [(1, None), (6, None), (4, 7)])
def test_paged_flash_matches_dense_oracle(rng, tq, window):
    from repro.kernels.flash_attention import paged_flash_attention
    from repro.models.attention import paged_attention_ref

    b, hkv, g, d, ps, t_max = 2, 2, 2, 64, 8, 32
    q, kp, vp, bt, kd, vd = _paged_setup(rng, b, hkv, g, tq, t_max, d, ps)
    q_start = np.array([5, 17], np.int32)
    lengths = q_start + tq                            # ragged: rows differ

    want = _dense_oracle(q, kd, vd, q_start, lengths, True, window)
    got_k = paged_flash_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
        jnp.asarray(q_start), jnp.asarray(lengths), causal=True,
        window=window, interpret=True)
    got_r = paged_attention_ref(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
        jnp.asarray(q_start), jnp.asarray(lengths), causal=True,
        window=window)
    np.testing.assert_allclose(np.asarray(got_k), want, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(got_r), want, atol=2e-5, rtol=2e-5)


def test_paged_kv_write_scatter(rng):
    from repro.models.attention import paged_kv_write

    b, hkv, c, d, ps, w = 2, 2, 4, 8, 4, 3
    n_pages = 1 + b * w
    kp = jnp.zeros((n_pages, hkv, ps, d), jnp.float32)
    vp = jnp.zeros((n_pages, hkv, ps, d), jnp.float32)
    bt = jnp.asarray(1 + np.arange(b * w).reshape(b, w), jnp.int32)
    q_start = jnp.asarray([2, 5], jnp.int32)
    n_valid = jnp.asarray([4, 2], jnp.int32)          # row 1: 2 dead slots
    k_new = jnp.asarray(rng.standard_normal((b, hkv, c, d)), jnp.float32)
    v_new = jnp.asarray(rng.standard_normal((b, hkv, c, d)), jnp.float32)

    kp2, vp2 = paged_kv_write(kp, vp, k_new, v_new, bt, q_start, n_valid)
    kp2, vp2 = np.asarray(kp2), np.asarray(vp2)
    for bi in range(b):
        for i in range(int(n_valid[bi])):
            pos = int(q_start[bi]) + i
            pg, off = int(bt[bi, pos // ps]), pos % ps
            np.testing.assert_array_equal(kp2[pg, :, off],
                                          np.asarray(k_new)[bi, :, i])
            np.testing.assert_array_equal(vp2[pg, :, off],
                                          np.asarray(v_new)[bi, :, i])
    # Dead rows landed ONLY in the scratch page; real pages untouched
    # beyond the valid writes (count the nonzero rows).
    real = kp2[1:]
    assert (np.abs(real) > 0).any(axis=-1).sum() == int(n_valid.sum()) * hkv


# ============================== engine e2e ===================================

@pytest.fixture(scope="module")
def engine_setup():
    from repro.configs import base as cb
    from repro.models.transformer import build_model

    cfg = cb.get("phi3-mini-3.8b", smoke=True)
    model = build_model(cfg, policy="bf16", remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _prompt(cfg, n, seed=0):
    r = np.random.default_rng(seed)
    return r.integers(2, cfg.vocab, (n,)).astype(np.int32)


def test_no_head_of_line_stall_and_kv_bytes(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)
    long_uid = eng.add_request(_prompt(cfg, 12, seed=1), max_new_tokens=24)
    s1 = eng.add_request(_prompt(cfg, 6, seed=2), max_new_tokens=3)
    s2 = eng.add_request(_prompt(cfg, 6, seed=3), max_new_tokens=3)

    finish_step = {}
    step = 0
    while eng.pending:
        for req in eng.step():
            finish_step[req.uid] = step
        step += 1
        assert step < 200
    # Short requests retire strictly before the long one: continuous
    # batching backfills their slots instead of waiting for the wave.
    assert finish_step[s1] < finish_step[long_uid]
    assert finish_step[s2] < finish_step[long_uid]

    steps = eng.step_telemetry
    assert [s.step for s in steps] == list(range(len(steps)))
    assert {s.phase for s in steps} <= {"prefill", "mixed", "decode"}
    assert sum(s.tokens for s in steps) == 24 + 3 + 3
    # Paged footprint strictly below the dense wave allocation throughout.
    assert all(s.kv_bytes < s.kv_bytes_dense for s in steps)
    assert all(s.kv_bytes == s.pages_in_use * 8 * eng._token_bytes
               for s in steps)
    eng.kv.check_invariants()
    assert eng.kv.live_sequences == 0            # everything retired


def test_prefix_sharing_and_output_parity(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    prompt = _prompt(cfg, 20, seed=7)

    # Donor prefills the full prompt; a later sharer with the same prompt
    # reuses the donor's full KV pages and must emit the same greedy tokens.
    eng = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)
    donor = eng.add_request(prompt, max_new_tokens=4)
    done = {}
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
    assert eng.kv.prefix_entries == 2            # 16 of 20 tokens indexed
    sharer = eng.add_request(prompt, max_new_tokens=4)
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
    assert eng.kv.stats.prefix_hit_tokens == 16
    # Sharing is transparent: identical prompt => identical greedy tokens.
    assert done[sharer] == done[donor]
    eng.kv.check_invariants()


def test_preemption_requeues_and_completes(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    # A pool too small for both requests' full lengths forces preemption.
    eng = ServeEngine(model, params, max_len=64, max_batch=2, page_size=8,
                      max_pages=8)
    a = eng.add_request(_prompt(cfg, 10, seed=4), max_new_tokens=16)
    b = eng.add_request(_prompt(cfg, 10, seed=5), max_new_tokens=16)
    done = {}
    steps = 0
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
        steps += 1
        assert steps < 300
    assert len(done[a]) == 16 and len(done[b]) == 16
    assert sum(s.preemptions for s in eng.step_telemetry) > 0
    eng.kv.check_invariants()


def test_preemption_mid_reserve_skips_evicted_slots(engine_setup):
    """Regression: with 3 live slots and a dry pool, the oldest slot's
    reservation evicts the newest; the reserve loop must then SKIP the
    freed slot instead of calling ensure() on it (KeyError on its gone
    block table).  One page per 8-token prompt fills the pool exactly, so
    the first decode step needs a page for every slot at once."""
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8,
                      max_pages=4)                 # 3 allocatable pages
    uids = [eng.add_request(_prompt(cfg, 8, seed=10 + i), max_new_tokens=4)
            for i in range(3)]
    done = {}
    steps = 0
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
        steps += 1
        assert steps < 300
    assert sorted(done) == sorted(uids)
    # The first decode step preempts BOTH newer slots (the oldest evicts
    # the newest for its page; the middle one then self-preempts).
    assert sum(s.preemptions for s in eng.step_telemetry) >= 2
    eng.kv.check_invariants()
    assert eng.kv.live_sequences == 0


def test_failed_admission_rolls_back_prefix_stats(engine_setup):
    """Regression: a sharer stuck at the queue head (its prompt doesn't
    fit) must not re-inflate prefix_hit_tokens on every step's admission
    attempt — only the one successful admission counts."""
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    prompt = _prompt(cfg, 20, seed=8)              # 2 full pages + 4 tokens
    eng = ServeEngine(model, params, max_len=64, max_batch=2, page_size=8,
                      max_pages=4)                 # 3 allocatable pages
    donor = eng.add_request(prompt, max_new_tokens=2)
    done = {}
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
    assert eng.kv.prefix_entries == 2              # 2 pinned + 1 free page
    # The blocker takes the last free page; the sharer's admission then
    # fails (its 2 shared pages are unreclaimable) until the blocker ends.
    blocker = eng.add_request(_prompt(cfg, 6, seed=9), max_new_tokens=2)
    sharer = eng.add_request(prompt, max_new_tokens=2)
    steps = 0
    while eng.pending:
        for r in eng.step():
            done[r.uid] = r.out_tokens
        steps += 1
        assert steps < 300
    assert {donor, blocker, sharer} <= set(done)
    assert eng.kv.stats.prefix_hit_tokens == 16    # counted exactly once
    assert done[sharer] == done[donor]
    eng.kv.check_invariants()


def test_engine_admission_errors(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_len=32, max_batch=2, page_size=8)
    with pytest.raises(ValueError, match="max_len"):
        eng.add_request(_prompt(cfg, 40), max_new_tokens=2)
    tiny = ServeEngine(model, params, max_len=64, max_batch=2, page_size=8,
                       max_pages=3)
    with pytest.raises(ValueError, match="pages"):
        tiny.add_request(_prompt(cfg, 30), max_new_tokens=30)


def test_wave_shim_deprecation_and_guards(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    with pytest.warns(DeprecationWarning, match="batch_size"):
        eng = ServeEngine(model, params, batch_size=2, max_len=32)
    with pytest.raises(RuntimeError, match="continuous"):
        eng.add_request(_prompt(cfg, 4))
    with pytest.raises(RuntimeError, match="continuous"):
        eng.step()

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        ServeEngine(model, params, max_len=32, max_batch=2)   # no warning


def test_warm_prefixes_populates_index(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    eng = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)
    sys_prompt = _prompt(cfg, 20, seed=11)
    assert eng.warm_prefixes([sys_prompt]) == 2  # 16 of 20 tokens -> 2 pages
    assert eng.kv.prefix_entries == 2
    # Warm-up leaves no live work and no telemetry behind.
    assert eng.step_telemetry == [] and eng._step_counter == 0
    assert eng.kv.live_sequences == 0
    eng.kv.check_invariants()

    # The first real request sharing the warmed system prompt skips its
    # full warmed pages.
    eng.add_request(np.concatenate([sys_prompt, _prompt(cfg, 6, seed=12)]),
                    max_new_tokens=2)
    while eng.pending:
        eng.step()
    assert eng.kv.stats.prefix_hit_tokens == 16
    eng.kv.check_invariants()


def test_warm_prefixes_parity_skips_and_guards(engine_setup):
    from repro.serve.engine import ServeEngine

    cfg, model, params = engine_setup
    prompt = _prompt(cfg, 20, seed=13)

    def drain(eng):
        done = {}
        while eng.pending:
            for r in eng.step():
                done[r.uid] = r.out_tokens
        return done

    cold = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)
    cold_uid = cold.add_request(prompt, max_new_tokens=4)
    cold_out = drain(cold)[cold_uid]

    warm = ServeEngine(model, params, max_len=64, max_batch=3, page_size=8)
    # Sub-page prompts can never be indexed: skipped, not an error.
    assert warm.warm_prefixes([prompt[:4]]) == 0
    assert warm.warm_prefixes([prompt]) == 2
    warm_uid = warm.add_request(prompt, max_new_tokens=4)
    # Sharing warmed pages is transparent: identical greedy tokens.
    assert drain(warm)[warm_uid] == cold_out
    assert warm.kv.stats.prefix_hit_tokens == 16

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        wave = ServeEngine(model, params, batch_size=2, max_len=32)
    with pytest.raises(RuntimeError, match="continuous"):
        wave.warm_prefixes([prompt])
