"""Paged KV-cache bookkeeping for the continuous-batching ServeEngine.

The device-side KV pool (one ``(num_pages, n_kv_heads, page_size, head_dim)``
array pair per layer, built by ``LM.init_paged_caches``) is dumb storage;
this module owns every allocation decision on the host:

  * **free-list allocation** — pages are handed out LIFO from a free list;
    page 0 is permanently reserved as the *scratch* page, so a block-table
    entry of 0 always points at in-bounds (but dead) storage.  Writes for
    padded/invalid token slots and reads past a request's length land there,
    which keeps the Pallas page gather fully in-bounds without any host
    round-trip.
  * **per-request block tables** — ``tables[uid]`` is the ordered list of
    page ids whose concatenation is the request's logical KV stream.  The
    engine materializes them into a dense ``(B, width)`` int32 array (scratch-
    padded) for the kernel.
  * **refcounted prefix sharing** — every *full* page of a prompt is indexed
    under the hash of the prompt prefix it completes.  A later request whose
    prompt starts with the same tokens maps those pages into its own table
    (refcount++) and skips prefilling them.  Only full pages are shared and
    at least one prompt token is always left to prefill, so the sharer never
    writes into a shared page (its first write position is page-aligned into
    its own freshly allocated page) — no copy-on-write is needed.
  * **eviction** — when the free list runs dry, prefix-index entries whose
    pages no live request references are evicted oldest-first to reclaim
    pages.  If that still isn't enough the caller sees the failure and
    preempts a request (engine policy, not ours).

Pure host-side numpy/python — nothing here is traced.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.registry import counter_inc

# Page id 0 is never allocated: it is the scratch page every dead block-table
# slot points at.
SCRATCH_PAGE = 0


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _prefix_key(tokens: Sequence[int]) -> str:
    """Content hash of a prompt prefix (order-sensitive, deterministic)."""
    arr = np.asarray(list(tokens), dtype=np.int32)
    return hashlib.sha1(arr.tobytes()).hexdigest()


@dataclasses.dataclass
class PagedStats:
    """Counters the engine folds into StepTelemetry / BENCH_serve.json."""
    allocated_pages: int = 0      # allocation events (lifetime)
    prefix_queries: int = 0
    prefix_hit_pages: int = 0     # pages mapped in via sharing (lifetime)
    prefix_hit_tokens: int = 0    # prompt tokens skipped via sharing
    evictions: int = 0            # prefix entries evicted under pressure


class PagedKVCache:
    """Host-side page allocator + block tables + prefix index.

    ``num_pages`` counts the whole pool *including* the reserved scratch
    page, matching the leading axis of the device pool arrays.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is reserved "
                             f"scratch), got {num_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # LIFO free list; page 0 (SCRATCH_PAGE) is never in it.
        self._free: List[int] = list(range(self.num_pages - 1, 0, -1))
        self._ref = np.zeros(self.num_pages, dtype=np.int64)
        self._tables: Dict[object, List[int]] = {}
        self._lengths: Dict[object, int] = {}
        # prefix key -> page id, oldest-first (eviction order); the index
        # itself holds one reference on every page it names.
        self._prefix: "OrderedDict[str, int]" = OrderedDict()
        self.stats = PagedStats()

    # ---------------------------------------------------------------- pool

    @property
    def pages_in_use(self) -> int:
        """Allocatable pages currently NOT on the free list."""
        return (self.num_pages - 1) - len(self._free)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> float:
        cap = self.num_pages - 1
        return self.pages_in_use / cap if cap else 0.0

    def _reclaim_one(self) -> bool:
        """Evict prefix entries (oldest first) until one page is freed."""
        for key in list(self._prefix):
            page = self._prefix[key]
            if self._ref[page] == 1:        # only the index holds it
                del self._prefix[key]
                self._ref[page] = 0
                self._free.append(page)
                self.stats.evictions += 1
                counter_inc("paged_kv_prefix_evictions_total",
                            help="prefix-index pages reclaimed under "
                                 "memory pressure")
                return True
        return False

    def _take_page(self) -> Optional[int]:
        if not self._free and not self._reclaim_one():
            return None
        page = self._free.pop()
        assert page != SCRATCH_PAGE and self._ref[page] == 0
        self._ref[page] = 1
        self.stats.allocated_pages += 1
        counter_inc("paged_kv_pages_allocated_total",
                    help="page-allocation events (lifetime)")
        return page

    def _release_page(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] < 0:
            raise RuntimeError(f"page {page} refcount went negative "
                               f"(double free)")
        if self._ref[page] == 0:
            self._free.append(page)

    # ------------------------------------------------------------- prefix

    def match_prefix(self, prompt: Sequence[int]) -> Tuple[List[int], int]:
        """Longest indexed full-page prefix of ``prompt``.

        Returns ``(pages, shared_tokens)``.  At least one prompt token is
        always left unshared so prefill still produces the logits that seed
        the first generated token (and so the sharer's first cache write is
        page-aligned into its own page).
        """
        ps = self.page_size
        self.stats.prefix_queries += 1
        max_shareable = (len(prompt) - 1) // ps if len(prompt) else 0
        pages: List[int] = []
        for p in range(max_shareable):
            key = _prefix_key(prompt[:(p + 1) * ps])
            page = self._prefix.get(key)
            if page is None:
                break
            pages.append(page)
        counter_inc("paged_kv_prefix_queries_total",
                    help="prefix-index probes by outcome",
                    result="hit" if pages else "miss")
        return pages, len(pages) * ps

    def register_prefix(self, uid, prompt: Sequence[int]) -> int:
        """Index every full prompt page of a (fully prefilled) request.

        Returns the number of newly indexed pages.  Pages whose prefix key
        is already indexed (e.g. the ones this request itself shared) are
        skipped — the existing entry keeps its age.
        """
        table = self._tables[uid]
        ps = self.page_size
        added = 0
        for p in range(len(prompt) // ps):
            key = _prefix_key(prompt[:(p + 1) * ps])
            if key in self._prefix:
                continue
            page = table[p]
            self._prefix[key] = page
            self._ref[page] += 1
            added += 1
        return added

    @property
    def prefix_entries(self) -> int:
        return len(self._prefix)

    # ---------------------------------------------------------- sequences

    def allocate(self, uid, shared_pages: Sequence[int] = (),
                 shared_tokens: int = 0) -> None:
        """Create a sequence whose table starts with ``shared_pages``."""
        if uid in self._tables:
            raise ValueError(f"uid {uid!r} already allocated")
        if shared_tokens != len(shared_pages) * self.page_size:
            raise ValueError("prefix sharing covers full pages only: "
                             f"{shared_tokens} tokens vs "
                             f"{len(shared_pages)} pages")
        for page in shared_pages:
            self._ref[page] += 1
        self.stats.prefix_hit_pages += len(shared_pages)
        self.stats.prefix_hit_tokens += shared_tokens
        if shared_pages:
            counter_inc("paged_kv_prefix_hit_pages_total",
                        amount=len(shared_pages),
                        help="pages mapped in via prefix sharing")
            counter_inc("paged_kv_prefix_hit_tokens_total",
                        amount=shared_tokens,
                        help="prompt tokens skipped via prefix sharing")
        self._tables[uid] = list(shared_pages)
        self._lengths[uid] = shared_tokens

    def rollback_prefix_hits(self, pages: int, tokens: int) -> None:
        """Undo :meth:`allocate`'s prefix-hit accounting for a sequence
        whose admission was rolled back before it did any work — otherwise
        a request stuck at the queue head re-inflates the sharing counters
        on every admission attempt."""
        self.stats.prefix_hit_pages -= int(pages)
        self.stats.prefix_hit_tokens -= int(tokens)
        # Registry counters are monotone: the rollback gets its own series
        # instead of decrementing the hit counters.
        counter_inc("paged_kv_prefix_rollback_tokens_total",
                    amount=int(tokens),
                    help="prefix-hit tokens rolled back on failed admission")

    def ensure(self, uid, new_length: int) -> bool:
        """Grow ``uid``'s table to cover ``new_length`` tokens.

        Returns False (sequence untouched) if the pool cannot supply the
        pages even after prefix eviction — the engine then preempts.
        """
        table = self._tables[uid]
        need = cdiv(new_length, self.page_size) - len(table)
        if need <= 0:
            self._lengths[uid] = max(self._lengths[uid], new_length)
            return True
        fresh: List[int] = []
        for _ in range(need):
            page = self._take_page()
            if page is None:
                for p in fresh:              # roll back, all-or-nothing
                    self._release_page(p)
                return False
            fresh.append(page)
        table.extend(fresh)
        self._lengths[uid] = max(self._lengths[uid], new_length)
        return True

    def advance(self, uid, n_tokens: int) -> None:
        self._lengths[uid] += int(n_tokens)

    def free_seq(self, uid) -> None:
        """Drop a sequence; pages return to the free list when unreferenced
        (prefix-indexed pages survive for future sharing)."""
        table = self._tables.pop(uid)
        del self._lengths[uid]
        for page in table:
            self._release_page(page)

    def length(self, uid) -> int:
        return self._lengths[uid]

    def table(self, uid) -> List[int]:
        return list(self._tables[uid])

    @property
    def live_sequences(self) -> int:
        return len(self._tables)

    def block_table_row(self, uid, width: int) -> np.ndarray:
        """Dense int32 row for the kernel, scratch-padded to ``width``."""
        table = self._tables[uid]
        if len(table) > width:
            raise ValueError(f"uid {uid!r} holds {len(table)} pages, "
                             f"block-table width is {width}")
        row = np.full(width, SCRATCH_PAGE, dtype=np.int32)
        row[:len(table)] = table
        return row

    def check_invariants(self) -> None:
        """Internal-consistency audit used by tests."""
        counted = np.zeros(self.num_pages, dtype=np.int64)
        for table in self._tables.values():
            for page in table:
                counted[page] += 1
        for page in self._prefix.values():
            counted[page] += 1
        if not np.array_equal(counted, self._ref):
            raise AssertionError(
                f"refcount drift: counted {counted.tolist()} vs "
                f"stored {self._ref.tolist()}")
        free = set(self._free)
        if SCRATCH_PAGE in free:
            raise AssertionError("scratch page leaked into the free list")
        if len(free) != len(self._free):
            raise AssertionError("duplicate pages on the free list")
        for page in free:
            if self._ref[page] != 0:
                raise AssertionError(f"free page {page} has refcount "
                                     f"{self._ref[page]}")
        in_use = {p for p in range(1, self.num_pages) if self._ref[p] > 0}
        if in_use & free:
            raise AssertionError("page both free and referenced")
        if len(in_use) + len(free) != self.num_pages - 1:
            raise AssertionError("pages leaked (neither free nor referenced)")
