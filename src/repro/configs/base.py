"""Architecture + shape configuration registry.

Each assigned architecture gets one file in this package defining ``CONFIG``
(exact published dims) and ``SMOKE`` (reduced same-family config for CPU
smoke tests).  ``repro.configs.get(name)`` resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | ssm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention
    window: Optional[int] = None          # sliding-window size (SWA archs)
    rope_theta: float = 10000.0
    pos_embed: str = "rope"               # rope | learned
    causal: bool = True
    # block structure
    mlp: str = "swiglu"                   # swiglu | gelu
    mlp_bias: bool = False
    norm: str = "rms"                     # rms | layer
    tie_embeddings: bool = False
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds; () -> uniform
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    # vlm
    cross_attn_every: int = 0             # insert 1 cross block every N layers
    n_image_tokens: int = 0
    # enc-dec (audio)
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend output length
    # recurrent
    rwkv_head_dim: int = 64
    conv_width: int = 4
    lru_width: Optional[int] = None
    local_attn_window: int = 2048         # hybrid local-attention window
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        if self.family == "moe":
            return ("moe",) * self.n_layers
        if self.family == "vlm" and self.cross_attn_every:
            unit = ("dense",) * (self.cross_attn_every - 1) + ("cross",)
            reps = self.n_layers // self.cross_attn_every
            rem = self.n_layers - reps * self.cross_attn_every
            return unit * reps + ("dense",) * rem
        if self.family == "ssm":
            return ("rwkv",) * self.n_layers
        return ("dense",) * self.n_layers

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run long_500k? (SWA / recurrent / hybrid-local)."""
        if self.window is not None:
            return True
        return all(k in ("rwkv", "rglru", "attn_local") for k in self.pattern) or \
            any(k in ("rwkv", "rglru") for k in self.pattern)

    def active_params(self, seq_len: int = 0) -> int:
        """Approximate active parameter count (per-token for MoE)."""
        d, f, hd = self.d_model, self.d_ff, self.head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        mlp_mats = 2 if self.mlp == "gelu" else 3
        per = {}
        per["dense"] = attn + mlp_mats * d * f
        per["attn_local"] = per["dense"]
        per["cross"] = per["dense"]
        per["moe"] = attn + mlp_mats * d * f * max(1, self.experts_per_token) + \
            d * self.n_experts
        per["rwkv"] = 6 * d * d + 2 * d * f + d * d
        w = self.lru_width or d
        per["rglru"] = 2 * d * w + 2 * w * w + w * d + 3 * d * f
        total = sum(per[k] for k in self.pattern)
        total += self.encoder_layers * per.get("dense", 0)
        total += self.vocab * d * (1 if self.tie_embeddings else 2)
        return total

    def total_params(self) -> int:
        d, f = self.d_model, self.d_ff
        extra = 0
        if self.family == "moe":
            mlp_mats = 2 if self.mlp == "gelu" else 3
            per_layer_experts = mlp_mats * d * f * self.n_experts
            per_layer_active = mlp_mats * d * f * max(1, self.experts_per_token)
            extra = len(self.pattern) * (per_layer_experts - per_layer_active)
        return self.active_params() + extra


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "h2o-danube3-4b",
    "starcoder2-3b",
    "phi3-mini-3.8b",
    "phi3-medium-14b",
    "mixtral-8x22b",
    "granite-moe-1b-a400m",
    "llama-3.2-vision-11b",
    "rwkv6-1.6b",
    "whisper-medium",
    "recurrentgemma-2b",
]

_MODULES = {
    "h2o-danube3-4b": "h2o_danube3_4b",
    "starcoder2-3b": "starcoder2_3b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "mixtral-8x22b": "mixtral_8x22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "whisper-medium": "whisper_medium",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def get(name: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


def supports_shape(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """(runnable, reason-if-not) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: O(L^2) at 512k (DESIGN.md §4)"
    return True, ""
