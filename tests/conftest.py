import os
import sys

# Tests see exactly ONE device by default (the dry-run's 512-device trick
# is strictly scoped to launch/dryrun.py).  The CI multidevice job opts in
# to N virtual host devices by exporting REPRO_FORCE_HOST_DEVICES=N, which
# must land in XLA_FLAGS before jax initializes — this file runs before any
# test module imports jax, so this is the one place the flag may be set.
os.environ.setdefault("JAX_PLATFORMS", "cpu")
_force = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _force:
    _flag = f"--xla_force_host_platform_device_count={int(_force)}"
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = f"{_flags} {_flag}".strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
