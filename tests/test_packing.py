"""Packed-operand subsystem: pack/unpack round-trips, packed-vs-unpacked
numerical equivalence through mp_dot/mp_dot_grouped (fwd + bwd, all
policies), the grouped packed path, the packed-weight cache, the plan-key
layout namespace, and the pack_params tree walker."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.blocking import plan_gemm
from repro.core.gemm import mp_dot, mp_dot_grouped
from repro.kernels.mpgemm import mpgemm_grouped_pallas, mpgemm_pallas
from repro.packing import (
    PackedOperand, PackedWeightCache, is_packed, make_weight_key,
    pack_operand, pack_params, unpack_operand,
)
from repro.tuning import make_key

G, M, K, N = 4, 24, 40, 24
BLOCKS = (16, 8)


@pytest.fixture
def ops(rng):
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    return x, w


@pytest.fixture
def gops(rng):
    x = jnp.asarray(rng.standard_normal((G, M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((G, K, N)), "float32")
    return x, w


# --- pack -> unpack round trips ----------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("trans_w", [False, True])
@pytest.mark.parametrize("kn", [(K, N), (33, 17), (8, 8), (129, 7)])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_roundtrip(rng, kn, trans_w, dtype, backend):
    k, n = kn
    src = jnp.asarray(rng.standard_normal((n, k) if trans_w else (k, n)),
                      "float32")
    p = pack_operand(src, BLOCKS, trans_w=trans_w, dtype=dtype,
                     backend=backend)
    u = unpack_operand(p, backend=backend)
    ref = np.asarray(src).T if trans_w else np.asarray(src)
    assert u.shape == (k, n)
    err = np.abs(np.asarray(u, np.float32) - ref).max()
    scale = max(1.0, np.abs(ref).max())
    tol = {"float32": 1e-7, "bfloat16": 0.01, "int8": 0.02}[dtype]
    assert err <= tol * scale
    # payload edge pads are exactly zero (the no-B-predication contract)
    tiles = np.asarray(p.payload, np.float32)
    if k % p.layout.bk:
        assert np.all(tiles[-1, :, k % p.layout.bk:, :] == 0)
    if n % p.layout.bn:
        assert np.all(tiles[:, -1, :, n % p.layout.bn:] == 0)


def test_pallas_and_reference_pack_agree(rng):
    w = jnp.asarray(rng.standard_normal((33, 17)), "float32")
    for dtype in ("float32", "int8"):
        a = pack_operand(w, BLOCKS, dtype=dtype, backend="xla")
        b = pack_operand(w, BLOCKS, dtype=dtype, backend="interpret")
        assert np.array_equal(np.asarray(a.payload), np.asarray(b.payload))
        if dtype == "int8":
            np.testing.assert_allclose(np.asarray(a.scales),
                                       np.asarray(b.scales), rtol=1e-6)


def test_grouped_roundtrip(rng):
    w = jnp.asarray(rng.standard_normal((G, 33, 17)), "float32")
    for backend in ("xla", "interpret"):
        p = pack_operand(w, BLOCKS, dtype="int8", backend=backend)
        assert p.layout.g == G and p.payload.shape[0] == G
        u = unpack_operand(p, backend=backend)
        assert u.shape == w.shape
        err = np.abs(np.asarray(u) - np.asarray(w)).max()
        assert err < 0.02 * np.abs(np.asarray(w)).max()


# --- packed vs unpacked through mp_dot (fwd + bwd) ---------------------------

@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("policy,pdt", [("fp32", "float32"),
                                        ("bf16", "bfloat16"),
                                        ("int8", "int8")])
def test_mp_dot_packed_matches_unpacked(ops, policy, pdt, backend):
    x, w = ops
    p = pack_operand(w, plan_gemm(M, N, K, "float32"), dtype=pdt,
                     backend="interpret")
    y0 = np.asarray(mp_dot(x, w, policy=policy, backend=backend), np.float32)
    y1 = np.asarray(mp_dot(x, p, policy=policy, backend=backend), np.float32)
    ref = np.asarray(x) @ np.asarray(w)
    # Same policy tolerances as test_grouped_gemm vs the fp32 reference...
    if policy == "fp32":
        np.testing.assert_allclose(y1, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(y1, ref, atol=0.15)
    else:
        assert np.abs(y1 - ref).max() < 0.05 * np.abs(ref).max()
    # ...and packed tracks unpacked at least as tightly (per-tile scales
    # can only refine the per-tensor ones).
    assert np.abs(y1 - y0).max() <= max(1e-5, 0.05 * np.abs(ref).max())


def test_mp_dot_packed_trans_w(ops):
    x, w = ops
    wt = jnp.asarray(np.asarray(w).T)          # stored (N, K)
    p = pack_operand(wt, BLOCKS, trans_w=True, backend="interpret")
    y = mp_dot(x, p, policy="fp32", trans_w=True, backend="interpret")
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x) @ np.asarray(w), atol=1e-5)
    with pytest.raises(ValueError, match="trans_w"):
        mp_dot(x, p, policy="fp32", trans_w=False, backend="interpret")


@pytest.mark.parametrize("trans_w", [False, True])
@pytest.mark.parametrize("policy", ["fp32", "bf16"])
def test_mp_dot_packed_vjp_matches_unpacked(ops, policy, trans_w):
    x, w = ops
    pdt = "float32" if policy == "fp32" else "bfloat16"
    src = jnp.asarray(np.asarray(w).T) if trans_w else w  # storage form
    p = pack_operand(src, BLOCKS, trans_w=trans_w, dtype=pdt,
                     backend="interpret")
    wc = w.astype(pdt)                          # dense twin of the payload

    def f_packed(x, p):
        return jnp.sum(mp_dot(x, p, policy=policy, trans_w=trans_w,
                              backend="interpret")
                       .astype(jnp.float32) ** 2)

    def f_dense(x, w):
        return jnp.sum(mp_dot(x, w, policy=policy, backend="interpret")
                       .astype(jnp.float32) ** 2)

    dx1, dp = jax.grad(f_packed, (0, 1))(x, p)
    dx0, dw0 = jax.grad(f_dense, (0, 1))(x, wc)
    tol = 1e-5 if policy == "fp32" else 0.35
    scale = max(1.0, float(jnp.abs(dx0).max()))
    np.testing.assert_allclose(np.asarray(dx1), np.asarray(dx0),
                               atol=tol * scale)
    # The packed-weight cotangent unpacks to the dense (k, n) weight
    # gradient — in the LOGICAL orientation even for trans_w payloads
    # (the cotangent pack must not re-apply the resolved transpose).
    dw1 = unpack_operand(dp, backend="interpret")
    scale = max(1.0, float(jnp.abs(dw0).max()))
    np.testing.assert_allclose(np.asarray(dw1, np.float32),
                               np.asarray(dw0, np.float32),
                               atol=tol * scale)


def test_mp_dot_packed_int8_vjp_is_ste_and_frozen(ops):
    """int8 payloads: dx flows (STE through the bf16 sibling), the weight
    cotangent is symbolically zero (frozen serving weights)."""
    x, w = ops
    p = pack_operand(w, BLOCKS, dtype="int8", backend="interpret")
    dx = jax.grad(lambda x: jnp.sum(
        mp_dot(x, p, policy="int8", backend="interpret") ** 2))(x)
    assert bool(jnp.all(jnp.isfinite(dx))) and float(jnp.abs(dx).sum()) > 0
    _, dp = jax.grad(lambda x, p: jnp.sum(
        mp_dot(x, p, policy="int8", backend="interpret") ** 2),
        (0, 1), allow_int=True)(x, p)
    assert dp.payload.dtype == jax.dtypes.float0
    assert float(jnp.abs(dp.scales).sum()) == 0.0


def test_mp_dot_packed_with_bias(ops):
    x, w = ops
    bias = jnp.arange(N, dtype=jnp.float32)
    p = pack_operand(w, BLOCKS, backend="interpret")
    y = mp_dot(x, p, bias, policy="fp32", backend="interpret")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x) @ np.asarray(w) + np.arange(N),
        atol=1e-5)
    db = jax.grad(lambda b: jnp.sum(
        mp_dot(x, p, b, policy="fp32", backend="interpret")))(bias)
    np.testing.assert_allclose(np.asarray(db), float(M), atol=1e-5)


# --- grouped packed path -----------------------------------------------------

@pytest.mark.parametrize("backend", ["xla", "interpret"])
@pytest.mark.parametrize("policy,pdt", [("fp32", "float32"),
                                        ("bf16", "bfloat16"),
                                        ("int8", "int8")])
def test_grouped_packed_matches_unpacked(gops, policy, pdt, backend):
    x, w = gops
    p = pack_operand(w, BLOCKS, dtype=pdt, backend="interpret")
    y0 = np.asarray(mp_dot_grouped(x, w, policy=policy, backend=backend),
                    np.float32)
    y1 = np.asarray(mp_dot_grouped(x, p, policy=policy, backend=backend),
                    np.float32)
    ref = np.einsum("gmk,gkn->gmn", np.asarray(x), np.asarray(w))
    if policy == "fp32":
        np.testing.assert_allclose(y1, ref, atol=1e-5)
    elif policy == "bf16":
        np.testing.assert_allclose(y1, ref, atol=0.15)
    else:
        assert np.abs(y1 - ref).max() < 0.05 * np.abs(ref).max()
    assert np.abs(y1 - y0).max() <= max(1e-5, 0.05 * np.abs(ref).max())


def test_grouped_packed_vjp_and_group_sizes(gops):
    x, w = gops
    p = pack_operand(w, BLOCKS, backend="interpret")
    sizes = jnp.asarray([M, 10, 0, 17], jnp.int32)
    y = mp_dot_grouped(x, p, policy="fp32", backend="interpret",
                       group_sizes=sizes)
    ref = np.einsum("gmk,gkn->gmn", np.asarray(x), np.asarray(w))
    for gi, s in enumerate([M, 10, 0, 17]):
        assert np.all(np.asarray(y[gi, s:]) == 0.0)
        np.testing.assert_allclose(np.asarray(y[gi, :s]), ref[gi, :s],
                                   atol=1e-5)
    dx = jax.grad(lambda x: jnp.sum(mp_dot_grouped(
        x, p, policy="fp32", backend="interpret",
        group_sizes=sizes) ** 2))(x)
    assert np.all(np.asarray(dx[2]) == 0.0)
    assert float(jnp.abs(dx[0]).sum()) > 0


def test_kernel_rejects_mismatched_plan_and_group(gops):
    x, w = gops
    p2 = pack_operand(w[0], BLOCKS, backend="interpret")
    pg = pack_operand(w, BLOCKS, backend="interpret")
    with pytest.raises(ValueError, match="grouped"):
        mpgemm_pallas(x[0], b_packed=pg, interpret=True)
    with pytest.raises(ValueError, match="2-D"):
        mpgemm_grouped_pallas(x, b_packed=p2, interpret=True)
    bad_plan = plan_gemm(M, N, K, "float32")
    if (bad_plan.bn, bad_plan.bk) != (p2.layout.bn, p2.layout.bk):
        with pytest.raises(ValueError, match="incompatible"):
            mpgemm_pallas(x[0], b_packed=p2, plan=bad_plan, interpret=True)
    with pytest.raises(ValueError, match="exactly one"):
        mpgemm_pallas(x[0], w[0], b_packed=p2, interpret=True)


def test_explicit_plan_with_tile_scaled_payload_coerces_acc(rng):
    """An explicitly supplied plan carrying an int32 accumulator must not
    reach the kernel with a tile-scaled payload (scaled partials are f32):
    the kernel coerces, matching _packed_plan's derivation."""
    from repro.core.blocking import plan_with_blocks
    from repro.core.policy import quantize_per_tensor
    x = jnp.asarray(rng.standard_normal((M, K)), "float32")
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    p = pack_operand(w, BLOCKS, dtype="int8", backend="interpret")
    xq, sx = quantize_per_tensor(x)
    plan = plan_with_blocks(M, N, K, 16, p.layout.bn, p.layout.bk,
                            "int8", "int8", "float32", "int32")
    y = mpgemm_pallas(xq, b_packed=p, scale=sx, out_dtype="float32",
                      plan=plan, interpret=True)
    ref = np.asarray(x) @ np.asarray(w)
    assert np.abs(np.asarray(y) - ref).max() < 0.05 * np.abs(ref).max()


# --- plan-cache layout namespace (make_key satellite) ------------------------

def test_make_key_layout_tag_is_namespaced_and_byte_stable():
    base = make_key(M, N, K, "float32")
    assert base == make_key(M, N, K, "float32", layout="")  # byte-stable
    p = pack_operand(jnp.ones((K, N)), BLOCKS, backend="xla")
    tagged = make_key(M, N, K, "float32", layout=p.layout.tag)
    assert tagged != base and tagged.startswith(base)
    other = dataclasses.replace(p.layout, bn=2 * p.layout.bn)
    assert make_key(M, N, K, "float32", layout=other.tag) != tagged


# --- packed-weight cache -----------------------------------------------------

def test_cache_hit_and_invalidation_on_plan_change(rng, tmp_path):
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    cache = PackedWeightCache(tmp_path / "packed")
    p1 = cache.get_or_pack("layer0/w_up", w, BLOCKS, backend="xla")
    assert (cache.hits, cache.misses) == (0, 1)
    p2 = cache.get_or_pack("layer0/w_up", w, BLOCKS, backend="xla")
    assert (cache.hits, cache.misses) == (1, 1)
    assert np.array_equal(np.asarray(p1.payload), np.asarray(p2.payload))
    # plan change -> different layout tag -> miss (repack, not stale tiles)
    cache.get_or_pack("layer0/w_up", w, (8, 8), backend="xla")
    assert cache.misses == 2
    # weight update -> digest change -> miss
    cache.get_or_pack("layer0/w_up", w * 2.0, BLOCKS, backend="xla")
    assert cache.misses == 3
    assert len(cache) == 3


def test_cache_persists_across_instances(rng, tmp_path):
    w = jnp.asarray(rng.standard_normal((33, 17)), "float32")
    path = tmp_path / "packed"
    PackedWeightCache(path).get_or_pack("head", w, BLOCKS, dtype="int8",
                                        backend="xla")
    fresh = PackedWeightCache(path)           # new process stand-in
    p = fresh.get_or_pack("head", w, BLOCKS, dtype="int8", backend="xla")
    assert (fresh.hits, fresh.misses) == (1, 0)
    u = unpack_operand(p, backend="xla")
    assert np.abs(np.asarray(u) - np.asarray(w)).max() < 0.02 * float(
        jnp.abs(w).max())
    key = make_weight_key("head", w, p.layout)
    assert key in fresh
    fresh.clear()
    assert len(fresh) == 0 and key not in PackedWeightCache(path)


def test_cache_disk_roundtrip_preserves_bfloat16(rng, tmp_path):
    """Regression (PR 5): numpy's npz writes extension dtypes (bfloat16)
    as raw void records, which made every DISK hit of a bf16 payload fail
    to reconstruct and silently repack.  The layout's recorded dtype must
    restore the payload losslessly across processes."""
    w = jnp.asarray(rng.standard_normal((K, N)), "float32")
    path = tmp_path / "packed"
    p0 = PackedWeightCache(path).get_or_pack("w", w, BLOCKS,
                                             dtype="bfloat16", backend="xla")
    fresh = PackedWeightCache(path)           # new process stand-in
    p1 = fresh.get_or_pack("w", w, BLOCKS, dtype="bfloat16", backend="xla")
    assert (fresh.hits, fresh.misses) == (1, 0)
    assert p1.payload.dtype == jnp.bfloat16
    assert np.array_equal(
        np.asarray(p0.payload, np.float32), np.asarray(p1.payload,
                                                       np.float32))


# --- pack_params tree walker -------------------------------------------------

def test_pack_params_walks_dense_moe_and_stacked(rng):
    params = {
        "embed": jnp.asarray(rng.standard_normal((64, 16)), "float32"),
        "tail": [{
            "mlp": {"w_up": jnp.asarray(rng.standard_normal((16, 32)),
                                        "float32"),
                    "router": jnp.asarray(rng.standard_normal((16, 4)),
                                          "float32")},
            "moe": {"w_gate": jnp.asarray(rng.standard_normal((4, 16, 32)),
                                          "float32")},
        }],
        "stack": [{
            "attn": {"wq": jnp.asarray(rng.standard_normal((3, 16, 16)),
                                       "float32")},
            "moe": {"w_down": jnp.asarray(
                rng.standard_normal((3, 4, 32, 16)), "float32")},
        }],
    }
    packed = pack_params(params, policy="bf16", m_hint=16, cache=None)
    assert not is_packed(packed["embed"])                 # gather source
    assert not is_packed(packed["tail"][0]["mlp"]["router"])
    p_up = packed["tail"][0]["mlp"]["w_up"]
    assert is_packed(p_up) and p_up.layout.g == 1
    p_moe = packed["tail"][0]["moe"]["w_gate"]
    assert is_packed(p_moe) and p_moe.layout.g == 4       # grouped experts
    p_stack = packed["stack"][0]["attn"]["wq"]
    assert is_packed(p_stack) and p_stack.layout.g == 1
    assert p_stack.payload.shape[0] == 3                  # leading layer axis
    p_stack_moe = packed["stack"][0]["moe"]["w_down"]
    assert is_packed(p_stack_moe) and p_stack_moe.layout.g == 4
    assert p_stack_moe.payload.shape[:2] == (3, 4)

    # scan slicing the stacked payload yields per-layer packed operands
    # whose mp_dot output matches the dense per-layer GEMM
    x = jnp.asarray(rng.standard_normal((5, 16)), "float32")

    def body(carry, wq_l):
        return carry + mp_dot(x, wq_l, policy="bf16",
                              backend="interpret"), None
    y_packed, _ = jax.lax.scan(body, jnp.zeros((5, 16)), p_stack)
    y_dense, _ = jax.lax.scan(body, jnp.zeros((5, 16)),
                              params["stack"][0]["attn"]["wq"])
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_dense),
                               atol=1e-3)


def test_pack_params_int8_policy_quantizes_per_tile(rng):
    w = jnp.asarray(rng.standard_normal((16, 32)), "float32")
    packed = pack_params({"tail": [{"w_up": w}]}, policy="int8",
                         m_hint=16, cache=None)
    p = packed["tail"][0]["w_up"]
    assert is_packed(p) and p.layout.dtype == "int8" and p.scales is not None
