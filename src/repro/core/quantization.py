"""Static (offline) int8 weight quantization for serving.

The paper's Section V shows INT8->INT32 GEMM reaching 94% of SME peak; at
the serving-system level the same lever applies to the weight side: decode
is HBM-bound, so storing weights as int8 (+ per-tensor scale) halves weight
traffic vs bf16.  The dequantize rides the GEMM (on TPU: int8 HBM reads,
dequant in VMEM/registers — no extra HBM passes), mirroring the paper's
fused dequant epilogue.

``quantize_params`` rewrites eligible weight matrices as
``{"q": int8, "scale": f32[]}`` dicts; ``core.gemm.mp_dot`` and the MoE
expert dots consume them transparently.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

# Weight leaves eligible for int8 storage (2-D+ GEMM operands).  Embeddings
# (gather-indexed) and norms/gates/router stay high precision.
QUANT_LEAVES = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "ck", "cv", "cr",
    "wr", "wg", "w_x", "w_y", "w_out", "w_gate_r", "w_gate_i", "head",
}


def is_quantized(w) -> bool:
    return isinstance(w, dict) and "q" in w and "scale" in w


def quantize_tensor(w):
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return {"q": q.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def dequantize_tensor(wd, dtype=jnp.bfloat16):
    return (wd["q"].astype(jnp.float32) * wd["scale"]).astype(dtype)


def quantize_params(params: Any) -> Any:
    """Rewrite eligible weight leaves as int8 {"q","scale"} dicts."""

    def walk(path, leaf):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        if (name in QUANT_LEAVES and hasattr(leaf, "ndim") and leaf.ndim >= 2
                and jnp.dtype(leaf.dtype).kind == "f"):
            return quantize_tensor(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(walk, params)
