"""Observability subsystem: registry, tracer, auditor, deprecation shims,
and the plan-cache counter contract.

The gated properties: label-set canonicalisation (kwarg order never forks
a series), thread-safe increments, byte-deterministic exposition, span
nesting + Perfetto-loadable export, auditor parity with the bench gates
it replaced, warn-once-per-site dedup with every call counted, and the
miss -> analytic-fallback -> memo-hit lookup sequence.
"""
import json
import threading
import urllib.error
import urllib.request
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import audit
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer, set_tracer


@pytest.fixture
def registry():
    """A fresh ambient registry, restored on exit."""
    reg = MetricsRegistry()
    prev = obs.set_registry(reg)
    try:
        yield reg
    finally:
        obs.set_registry(prev)


@pytest.fixture
def tracer():
    """A fresh ambient tracer, restored on exit."""
    tr = Tracer()
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)


# --- registry ----------------------------------------------------------------

class TestRegistry:
    def test_labelset_identity(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        c.inc(a="1", b="2")
        c.inc(b="2", a="1")
        assert c.value(a="1", b="2") == 2.0
        assert len(reg.snapshot()["counters"]) == 1

    def test_label_values_stringified(self):
        reg = MetricsRegistry()
        c = reg.counter("x_total")
        c.inc(g=8)
        assert c.value(g="8") == 1.0

    def test_thread_safety(self):
        reg = MetricsRegistry()
        c = reg.counter("races_total")
        n_threads, n_incs = 8, 2000

        def worker(i):
            for _ in range(n_incs):
                c.inc(thread=i % 2)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert c.total() == float(n_threads * n_incs)

    def test_snapshot_deterministic_across_insertion_order(self):
        a, b = MetricsRegistry(), MetricsRegistry()
        a.counter("one_total").inc(k="x")
        a.counter("two_total").inc(k="y")
        a.gauge("g").set(3.0)
        b.gauge("g").set(3.0)
        b.counter("two_total").inc(k="y")
        b.counter("one_total").inc(k="x")
        assert a.to_json() == b.to_json()
        assert a.prometheus_text() == b.prometheus_text()

    def test_prometheus_text_format(self):
        reg = MetricsRegistry()
        reg.counter("hits_total", help="cache hits").inc(2.0, ns="gemm")
        reg.histogram("lat_seconds", buckets=(0.1, 1.0)).observe(0.05)
        text = reg.prometheus_text()
        assert "# HELP hits_total cache hits" in text
        assert "# TYPE hits_total counter" in text
        assert 'hits_total{ns="gemm"} 2.0' in text
        assert 'lat_seconds_bucket{le="0.1"} 1' in text
        assert "lat_seconds_count 1" in text

    def test_histogram_cumulative_buckets(self):
        reg = MetricsRegistry()
        h = reg.histogram("h", buckets=(1.0, 10.0))
        for v in (0.5, 5.0, 50.0):
            h.observe(v)
        snap = h.snapshot_one()
        assert snap["count"] == 3 and snap["sum"] == 55.5
        assert snap["buckets"] == {"1.0": 1, "10.0": 2, "+Inf": 3}

    def test_counter_rejects_negative(self):
        with pytest.raises(ValueError):
            MetricsRegistry().counter("c_total").inc(-1.0)

    def test_kind_conflict_raises(self):
        reg = MetricsRegistry()
        reg.counter("name")
        with pytest.raises(TypeError):
            reg.gauge("name")

    def test_disabled_helpers_are_noops(self):
        prev = obs.set_registry(None)
        try:
            assert not obs.metrics_enabled()
            obs.counter_inc("ghost_total")
            obs.gauge_set("ghost", 1.0)
            obs.observe("ghost_seconds", 0.1)
        finally:
            obs.set_registry(prev)

    def test_module_helpers_hit_ambient(self, registry):
        obs.counter_inc("tick_total", kind="a")
        obs.counter_inc("tick_total", kind="a")
        obs.gauge_set("depth", 7, queue="q")
        assert registry.counter("tick_total").value(kind="a") == 2.0
        assert registry.gauge("depth").value(queue="q") == 7.0


# --- tracer ------------------------------------------------------------------

class TestTracer:
    def test_span_nesting_and_export(self, tracer, tmp_path):
        with obs.span("outer", layer=0):
            with obs.span("inner"):
                obs.annotate(bytes=123)
            obs.instant("tick", step=1)
        path = tmp_path / "trace.json"
        tracer.export(path)
        doc = json.loads(path.read_text())
        events = doc["traceEvents"]
        by_name = {e["name"]: e for e in events}
        assert set(by_name) == {"outer", "inner", "tick"}
        # annotate lands on the INNERMOST open span.
        assert by_name["inner"]["args"] == {"bytes": 123}
        assert by_name["outer"]["args"] == {"layer": 0}
        assert by_name["tick"]["ph"] == "i"
        for ev in events:
            assert {"ph", "name", "ts", "pid", "tid"} <= set(ev)
        # inner closes before outer, and starts after it.
        assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]

    def test_disabled_span_is_shared_nullcontext(self):
        prev = set_tracer(None)
        try:
            assert not obs.tracing_enabled()
            cm1, cm2 = obs.span("a"), obs.span("b", x=1)
            assert cm1 is cm2  # no per-call allocation when off
            with cm1:
                obs.annotate(dropped=True)  # no-op, must not raise
            obs.instant("nothing")
        finally:
            set_tracer(prev)

    def test_len_and_clear(self, tracer):
        with obs.span("s"):
            pass
        assert len(tracer) == 1
        tracer.clear()
        assert len(tracer) == 0


# --- auditor parity with the bench gates -------------------------------------

class TestAudit:
    M, N, K = 32, 256, 256

    def _weight(self):
        rng = np.random.default_rng(0)
        return jnp.asarray(rng.standard_normal((self.K, self.N)),
                           jnp.float32)

    def _x(self):
        return jax.ShapeDtypeStruct((self.M, self.K), jnp.bfloat16)

    def test_dense_gemm_single_launch(self):
        from repro.core.gemm import mp_dot
        jx = audit.trace(
            lambda x, w: mp_dot(x, w, policy="bf16", backend="interpret"),
            self._x(), self._weight())
        assert audit.count_pallas(jx) == 1
        assert len(audit.pallas_grids(jx)) == 1
        assert audit.first_pallas_grid(jx)  # nonempty grid tuple

    def test_packed_int4_one_launch_zero_dequants(self):
        from repro.core.blocking import plan_gemm
        from repro.core.gemm import mp_dot
        from repro.packing import pack_operand
        plan = plan_gemm(self.M, self.N, self.K, "bfloat16", "int4")
        packed = pack_operand(self._weight(), plan, dtype="int4",
                              backend="xla")
        jx = audit.trace(
            lambda x, p: mp_dot(x, p, policy="bf16", backend="interpret"),
            self._x(), packed)
        assert audit.count_pallas(jx) == 1
        count, nbytes = audit.weight_sized_intermediates(
            jx, self.K * self.N, prims=audit.DEQUANT_PRIMS,
            skip_pallas_bodies=True)
        assert count == 0 and nbytes == 0

    def test_sparse_grid_walks_schedule(self):
        from repro.core.gemm import mp_dot
        from repro.sparse import TileSparseOperand, sparsify_magnitude
        sp = sparsify_magnitude(self._weight(), (128, 128), density=0.5,
                                dtype="bfloat16")
        jx = audit.trace(
            lambda x, payload: mp_dot(
                x, TileSparseOperand(payload, sp.scales, sp.layout),
                policy="bf16", backend="interpret"),
            self._x(),
            jax.ShapeDtypeStruct(sp.payload.shape, sp.payload.dtype))
        assert audit.first_pallas_grid(jx)[-1] == sp.layout.schedule_len

    def test_prep_bytes_packed_vs_unpacked(self):
        from repro.core.blocking import plan_gemm
        from repro.core.gemm import mp_dot
        from repro.packing import pack_operand
        w = self._weight()
        plan = plan_gemm(self.M, self.N, self.K, "bfloat16")
        packed = pack_operand(w, plan, dtype="bfloat16", backend="xla")
        packed_bytes = audit.prep_bytes(
            lambda x, p: mp_dot(x, p, policy="bf16", backend="interpret"),
            self._x(), packed, weight_elems=self.K * self.N)
        unpacked_bytes = audit.prep_bytes(
            lambda x, w: mp_dot(x, w, policy="bf16", backend="interpret"),
            self._x(), w, weight_elems=self.K * self.N)
        assert packed_bytes == 0
        assert unpacked_bytes > 0

    def test_first_pallas_grid_raises_without_launch(self):
        jx = audit.trace(lambda a, b: a + b,
                         jnp.ones((2, 2)), jnp.ones((2, 2)))
        assert audit.count_pallas(jx) == 0
        with pytest.raises(ValueError, match="no pallas_call"):
            audit.first_pallas_grid(jx)

    def test_schedule_counts_shape(self):
        jx = audit.trace(
            lambda a, b: jnp.dot(a, b), jnp.ones((4, 4)), jnp.ones((4, 4)))
        counts = audit.schedule_counts(jx)
        assert counts["dots"] == 1
        assert set(counts) == {"dots", "ppermutes", "psums",
                               "all_to_alls", "interleaved"}


# --- deprecation shims -------------------------------------------------------

class TestDeprecation:
    def test_warn_once_per_site_count_every_call(self, registry):
        obs.reset_warned_sites()
        try:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                for _ in range(5):
                    obs.warn_deprecated("test_shim", "use the new thing")
            assert len(caught) == 1  # one site -> one warning
            assert issubclass(caught[0].category, DeprecationWarning)
            assert registry.counter("deprecated_call_total").value(
                shim="test_shim") == 5.0
        finally:
            obs.reset_warned_sites()

    def test_reset_rearms_warning(self, registry):
        obs.reset_warned_sites()
        try:
            def call():
                obs.warn_deprecated("test_shim2", "gone soon")
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                call()
                obs.reset_warned_sites()
                call()
            assert len(caught) == 2
        finally:
            obs.reset_warned_sites()

    def test_engine_batch_size_shim_counted(self, registry):
        from repro.configs import base as cb
        from repro.models.transformer import build_model
        from repro.serve.engine import ServeEngine
        obs.reset_warned_sites()
        try:
            cfg = cb.get("phi3-mini-3.8b", smoke=True)
            model = build_model(cfg, policy="bf16", remat=False)
            params = model.init(jax.random.PRNGKey(0))
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                ServeEngine(model, params, max_len=32, batch_size=2,
                            page_size=8)
            assert registry.counter("deprecated_call_total").value(
                shim="serve_engine.batch_size") == 1.0
        finally:
            obs.reset_warned_sites()


# --- plan-cache counter contract ---------------------------------------------

class TestPlanCacheCounters:
    def test_miss_fallback_memo_hit_sequence(self, registry):
        from repro.core.blocking import plan_gemm
        from repro.tuning.plan_cache import (
            PlanCache, clear_analytic_memo, lookup_plan, make_key,
            note_analytic_fallback, set_plan_cache,
        )
        prev = set_plan_cache(PlanCache(None))
        try:
            args = (48, 128, 256, "bfloat16")
            assert lookup_plan(*args, analytic_memo=True) is None
            note_analytic_fallback(make_key(*args), plan_gemm(*args))
            assert lookup_plan(*args, analytic_memo=True) is not None
            assert lookup_plan(*args, analytic_memo=True) is not None
            c = registry.counter("plan_cache_lookups_total")
            assert c.value(namespace="default", result="miss") == 1.0
            assert c.value(namespace="default",
                           result="hit_analytic") == 2.0
            assert registry.counter(
                "plan_cache_analytic_fallback_total").value(
                namespace="default") == 1.0
            # Installing a new cache clears the memo: back to a miss.
            set_plan_cache(PlanCache(None))
            assert lookup_plan(*args, analytic_memo=True) is None
            assert c.value(namespace="default", result="miss") == 2.0
        finally:
            set_plan_cache(prev)
            clear_analytic_memo()

    def test_launch_counter_labels(self, registry):
        from repro.core.gemm import mp_dot
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        audit.trace(
            lambda x, w: mp_dot(x, w, policy="bf16", backend="interpret"),
            jax.ShapeDtypeStruct((16, 64), jnp.bfloat16), w)
        assert registry.counter("gemm_launches_total").value(
            layout="dense", codec="none", epilogue="linear",
            sparse="false", grouped="false") >= 1.0


# --- metrics server ----------------------------------------------------------

class TestServer:
    def test_endpoints(self, registry, tracer):
        from repro.obs.server import start_metrics_server
        obs.counter_inc("served_total", route="x")
        with obs.span("covered"):
            pass
        with start_metrics_server(port=0) as server:
            text = urllib.request.urlopen(
                server.url + "/metrics", timeout=5).read().decode()
            assert 'served_total{route="x"} 1.0' in text
            snap = json.loads(urllib.request.urlopen(
                server.url + "/metrics.json", timeout=5).read())
            assert 'served_total{route="x"}' in snap["counters"]
            trace_doc = json.loads(urllib.request.urlopen(
                server.url + "/trace", timeout=5).read())
            assert any(e["name"] == "covered"
                       for e in trace_doc["traceEvents"])

    def test_trace_404_when_tracing_off(self, registry):
        from repro.obs.server import start_metrics_server
        prev = set_tracer(None)
        try:
            with start_metrics_server(port=0) as server:
                with pytest.raises(urllib.error.HTTPError) as err:
                    urllib.request.urlopen(server.url + "/trace", timeout=5)
                assert err.value.code == 404
        finally:
            set_tracer(prev)
